//! Serving bench: throughput and p99 fabric latency under skewed
//! 3-tenant traffic — unified time-share vs. static equal split vs.
//! FILCO dynamic re-composition (switch costs included, schedules
//! resolved through the serve-layer cache). Every row — the unified
//! baseline included — runs through the same `FabricEngine`, so the
//! comparison shares one cost model by construction.
//!
//! Run: `cargo bench --bench serve_multitenant`

use filco::arch::FilcoConfig;
use filco::dse::Solver;
use filco::platform::Platform;
use filco::report::{eng, Table};
use filco::serve::{
    equal_split_per_request, poisson_trace, simulate, PolicyConfig, Scenario, ScheduleCache,
    ServeReport, Strategy, TenantSpec,
};
use filco::workload::zoo;

fn main() {
    let platform = Platform::vck190();
    let base = FilcoConfig::default_for(&platform);
    let solver = Solver::Ga { population: 32, generations: 60, seed: 0xF11C0 };
    let cache = ScheduleCache::new(solver);

    let tenants = vec![
        TenantSpec::new("mlp-l", zoo::mlp_l()),
        TenantSpec::new("deit-s", zoo::deit_s()),
        TenantSpec::new("pointnet", zoo::pointnet()),
    ];

    // Rates calibrated to the measured equal-split service times: the
    // heavy tenant is pushed to 2.5x its slice's capacity.
    let per = equal_split_per_request(&platform, &base, &tenants, &cache);
    let rates = [2.5 / per[0], 0.1 / per[1], 0.1 / per[2]];
    let arrivals = poisson_trace(&rates, 100.0 * per[0], 0xBEEF);
    println!(
        "skewed trace: {} arrivals, heavy tenant mlp-l at 2.5x equal-split capacity\n",
        arrivals.len()
    );

    let sc = Scenario { platform, base, tenants, arrivals, switch_cost_s: None };
    let policy = PolicyConfig::calibrated(per[0]);

    let t0 = std::time::Instant::now();
    // Packed variant: the two light tenants may share one partition,
    // time-multiplexed; the amortization gate is opened wide so the
    // row depends only on the fit bound, not absolute model scale.
    let packed = PolicyConfig { pack_swap_margin: 10.0, ..policy.clone().with_packing() };
    let strategies = [
        ("unified", Strategy::Unified),
        ("static-equal", Strategy::StaticEqual),
        ("dynamic-batch", Strategy::Dynamic(policy.clone().without_preemption())),
        ("dynamic-preempt", Strategy::Dynamic(policy)),
        ("dynamic-packed", Strategy::Dynamic(packed)),
    ];
    let reports: Vec<(&str, ServeReport)> =
        strategies.iter().map(|(n, s)| (*n, simulate(&sc, s, &cache))).collect();

    let mut t = Table::new(
        "Serving under skewed 3-tenant traffic (fabric time)",
        &[
            "strategy",
            "completion s",
            "req/s",
            "worst p99 s",
            "heavy p99 s",
            "switches",
            "preempts",
            "packs",
            "swaps",
            "served",
            "rejected",
        ],
    );
    for (name, rep) in &reports {
        t.row(&[
            name.to_string(),
            eng(rep.completion_s),
            eng(rep.throughput_rps()),
            eng(rep.worst_p99_s()),
            eng(rep.histograms[0].p99()),
            rep.switches.to_string(),
            rep.preemptions.to_string(),
            rep.packs.to_string(),
            rep.pack_swaps.to_string(),
            rep.total_served().to_string(),
            rep.total_rejected().to_string(),
        ]);
    }
    t.emit("serve_multitenant");
    println!("schedule cache: {}", cache.stats());
    println!("bench wall time: {:.2} s", t0.elapsed().as_secs_f64());

    let (stat, dynr) = (&reports[1].1, &reports[3].1);
    assert_eq!(dynr.total_served(), stat.total_served());
    assert!(
        dynr.completion_s < stat.completion_s,
        "dynamic ({:.4e} s) must beat static equal split ({:.4e} s)",
        dynr.completion_s,
        stat.completion_s
    );
    assert!(dynr.switches >= 1);
    assert!(cache.hits() > 0, "re-partitions must reuse cached schedules");
    println!(
        "dynamic vs static: completion {:.2}x, heavy-tenant p99 {:.2}x",
        stat.completion_s / dynr.completion_s,
        stat.histograms[0].p99() / dynr.histograms[0].p99().max(1e-12)
    );
    let pk = &reports[4].1;
    assert_eq!(pk.total_served(), stat.total_served());
    println!(
        "packed: {} packs (group sizes {:?}), {} unpacks, {} swaps, \
         worst p99 {:.3e} s (unpacked {:.3e} s)",
        pk.packs,
        pk.pack_group_sizes,
        pk.unpacks,
        pk.pack_swaps,
        pk.worst_p99_s(),
        dynr.worst_p99_s()
    );
    println!("serve_multitenant OK");
}
