//! Serving bench: throughput and p99 fabric latency under skewed
//! 3-tenant traffic — unified time-share vs. static equal split vs.
//! FILCO dynamic re-composition (switch costs included, schedules
//! resolved through the serve-layer cache). Every row — the unified
//! baseline included — runs through the same `FabricEngine`, so the
//! comparison shares one cost model by construction.
//!
//! Besides the table, the bench writes a machine-readable
//! `BENCH_serve.json` snapshot to the repository root (override the
//! location with `FILCO_BENCH_OUT=<path>`): per-strategy throughput /
//! worst-tenant p99 / SLO attainment / engine step ns/op, plus the DSE
//! solve and schedule-cache lookup wall times the serving path depends
//! on, plus a `scenarios` object with static-vs-dynamic worst-p99 and
//! SLO-attainment rows for every built-in zoo scenario. The committed
//! copy tracks serving performance across PRs.
//!
//! Run: `cargo bench --bench serve_multitenant`
//!
//! `FILCO_BENCH_SAMPLE=1` runs a shortened trace with a small solver
//! and skips the strict comparison asserts — CI uses it to validate
//! the snapshot schema without paying the full GA budget.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use filco::arch::FilcoConfig;
use filco::dse::ga::{GaConfig, GaSeed};
use filco::dse::{stage1, Solver};
use filco::platform::Platform;
use filco::report::{eng, Table};
use filco::serve::{
    equal_split_per_request, poisson_trace, scenario, simulate, simulate_cluster,
    simulate_instrumented, ClusterPolicy, DseTuning, PolicyConfig, RunTelemetry, Scenario,
    ScheduleCache, ServeReport, Strategy, TelemetryConfig, TenantSpec,
};
use filco::util::json::Json;
use filco::workload::zoo;

/// Where the snapshot goes: `FILCO_BENCH_OUT`, or `BENCH_serve.json`
/// at the repository root (the crate directory's parent).
fn snapshot_path() -> PathBuf {
    match std::env::var("FILCO_BENCH_OUT") {
        Ok(p) if !p.is_empty() => PathBuf::from(p),
        _ => Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate dir has a parent")
            .join("BENCH_serve.json"),
    }
}

fn num(x: f64) -> Json {
    Json::Num(x)
}

/// One strategy row of the snapshot. Sharded rows carry their
/// wall-clock step-loop speedup against the shards=1 walk of the same
/// scenario (`step_speedup_vs_serial`); fabric-time results are
/// bit-for-bit identical across shard counts by construction.
fn row_json(rep: &ServeReport, tel: &RunTelemetry, speedup_vs_serial: Option<f64>) -> Json {
    let mut m = BTreeMap::new();
    m.insert("completion_s".to_string(), num(rep.completion_s));
    m.insert("throughput_rps".to_string(), num(rep.throughput_rps()));
    m.insert("worst_p99_s".to_string(), num(rep.worst_p99_s()));
    m.insert("heavy_p99_s".to_string(), num(rep.histograms[0].p99()));
    m.insert("served".to_string(), num(rep.total_served() as f64));
    m.insert("switches".to_string(), num(rep.switches as f64));
    m.insert("preemptions".to_string(), num(rep.preemptions as f64));
    m.insert("packs".to_string(), num(rep.packs as f64));
    m.insert("slo_attainment".to_string(), num(rep.worst_slo_attainment()));
    m.insert("engine_steps".to_string(), num(tel.step_profile.steps as f64));
    m.insert("step_ns_per_op".to_string(), num(tel.step_profile.ns_per_step()));
    if let Some(s) = speedup_vs_serial {
        m.insert("step_speedup_vs_serial".to_string(), num(s));
    }
    Json::Obj(m)
}

fn main() {
    let sample = std::env::var("FILCO_BENCH_SAMPLE").is_ok_and(|v| !v.is_empty() && v != "0");
    let platform = Platform::vck190();
    let base = FilcoConfig::default_for(&platform);
    let solver = if sample {
        Solver::Ga { population: 16, generations: 20, seed: 0xF11C0 }
    } else {
        Solver::Ga { population: 32, generations: 60, seed: 0xF11C0 }
    };
    // The accelerated DSE profile the `--dse-workers 4` CLI flag maps
    // to: pooled fitness evaluation, warm starts off neighboring
    // slices, and the convergence cutoff. Worker count never changes a
    // result; warm starts only match or improve makespan.
    let cache = ScheduleCache::new(solver).with_tuning(DseTuning::accelerated(4));

    let tenants = vec![
        TenantSpec::new("mlp-l", zoo::mlp_l()),
        TenantSpec::new("deit-s", zoo::deit_s()),
        TenantSpec::new("pointnet", zoo::pointnet()),
    ];

    // Rates calibrated to the measured equal-split service times: the
    // heavy tenant is pushed to 2.5x its slice's capacity.
    let per = equal_split_per_request(&platform, &base, &tenants, &cache);
    let rates = [2.5 / per[0], 0.1 / per[1], 0.1 / per[2]];
    let duration = if sample { 25.0 } else { 100.0 } * per[0];
    let arrivals = poisson_trace(&rates, duration, 0xBEEF);
    println!(
        "skewed trace: {} arrivals, heavy tenant mlp-l at 2.5x equal-split capacity{}\n",
        arrivals.len(),
        if sample { " (sample mode)" } else { "" }
    );

    let sc = Scenario { platform, base, tenants, arrivals, switch_cost_s: None, shards: 1 };
    let policy = PolicyConfig::calibrated(per[0]);

    let t0 = std::time::Instant::now();
    // Packed variant: the two light tenants may share one partition,
    // time-multiplexed; the amortization gate is opened wide so the
    // row depends only on the fit bound, not absolute model scale.
    let packed = PolicyConfig { pack_swap_margin: 10.0, ..policy.clone().with_packing() };
    let preempt_policy = policy.clone();
    let strategies = [
        ("unified", Strategy::Unified),
        ("static-equal", Strategy::StaticEqual),
        ("dynamic-batch", Strategy::Dynamic(policy.clone().without_preemption())),
        ("dynamic-preempt", Strategy::Dynamic(policy)),
        ("dynamic-packed", Strategy::Dynamic(packed)),
    ];
    // Step profiles ride along for free (two counters); no trace or
    // timeline, so the runs stay pure.
    let tcfg = TelemetryConfig::default();
    let mut reports: Vec<(String, ServeReport, RunTelemetry)> = strategies
        .iter()
        .map(|(n, s)| {
            let (rep, tel) = simulate_instrumented(&sc, s, &cache, &tcfg);
            (n.to_string(), rep, tel)
        })
        .collect();

    // Sharded rows: the dynamic-preempt configuration stepped on a
    // worker pool. Fabric-time results are bit-for-bit identical for
    // every shard count (the differential in
    // rust/tests/serve_engine.rs holds the traces equal); these rows
    // measure the wall-clock step loop, so the snapshot can track the
    // speedup the pool buys on a multi-core host.
    let shard_counts = [1usize, 2, 4];
    for &n in &shard_counts {
        let mut ssc = sc.clone();
        ssc.shards = n;
        let (rep, tel) =
            simulate_instrumented(&ssc, &Strategy::Dynamic(preempt_policy.clone()), &cache, &tcfg);
        reports.push((format!("dynamic-sharded-{n}"), rep, tel));
    }
    let serial_step_ns = reports[5].2.step_profile.ns_per_step();

    let mut t = Table::new(
        "Serving under skewed 3-tenant traffic (fabric time)",
        &[
            "strategy",
            "completion s",
            "req/s",
            "worst p99 s",
            "heavy p99 s",
            "switches",
            "preempts",
            "packs",
            "swaps",
            "served",
            "step ns/op",
        ],
    );
    for (name, rep, tel) in &reports {
        t.row(&[
            name.to_string(),
            eng(rep.completion_s),
            eng(rep.throughput_rps()),
            eng(rep.worst_p99_s()),
            eng(rep.histograms[0].p99()),
            rep.switches.to_string(),
            rep.preemptions.to_string(),
            rep.packs.to_string(),
            rep.pack_swaps.to_string(),
            rep.total_served().to_string(),
            format!("{:.0}", tel.step_profile.ns_per_step()),
        ]);
    }
    t.emit("serve_multitenant");

    // Per-scenario rows: every built-in zoo shape, static equal split
    // vs. dynamic re-composition, worst-tenant p99 and SLO attainment.
    // `rust/tests/serve_scenarios.rs` proves the dominance claims; the
    // snapshot tracks the margins across PRs.
    let mut scen_rows = BTreeMap::new();
    for &name in scenario::builtin_names() {
        let mut spec = scenario::builtin(name).expect("zoo names resolve");
        if sample {
            spec.duration_reqs = 25.0;
        }
        let mat = match spec.materialize(&cache) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("scenario {name} failed to materialize: {e}");
                std::process::exit(1);
            }
        };
        let stat = simulate(&mat.scenario, &Strategy::StaticEqual, &cache);
        let dynr = simulate(&mat.scenario, &Strategy::Dynamic(mat.policy.clone()), &cache);
        let ratio = stat.worst_p99_s() / dynr.worst_p99_s().max(1e-12);
        println!(
            "scenario {name}: {} arrivals | static p99 {} slo {:.3} | \
             dynamic p99 {} slo {:.3} | p99 ratio {:.2}x",
            mat.scenario.arrivals.len(),
            eng(stat.worst_p99_s()),
            stat.worst_slo_attainment(),
            eng(dynr.worst_p99_s()),
            dynr.worst_slo_attainment(),
            ratio
        );
        let mut row = BTreeMap::new();
        row.insert("arrivals".to_string(), num(mat.scenario.arrivals.len() as f64));
        row.insert("static_worst_p99_s".to_string(), num(stat.worst_p99_s()));
        row.insert("dynamic_worst_p99_s".to_string(), num(dynr.worst_p99_s()));
        row.insert("static_slo_attainment".to_string(), num(stat.worst_slo_attainment()));
        row.insert("dynamic_slo_attainment".to_string(), num(dynr.worst_slo_attainment()));
        row.insert("p99_ratio".to_string(), num(ratio));
        scen_rows.insert(name.to_string(), Json::Obj(row));
    }

    // ---- multi-board scaling -----------------------------------------
    // The same skewed shape over four tenants (so four boards still
    // have a resident each), run through the cluster driver at 1, 2
    // and 4 boards with the calibrated placement/migration policy.
    // The snapshot tracks throughput scaling, how many cross-board
    // migrations the imbalance trigger fired, and the worst board's
    // worst-tenant p99 — the cluster-level tail the placement layer is
    // supposed to keep flat.
    let mb_tenants = vec![
        TenantSpec::new("mlp-l", zoo::mlp_l()),
        TenantSpec::new("deit-s", zoo::deit_s()),
        TenantSpec::new("pointnet", zoo::pointnet()),
        TenantSpec::new("mlp-s", zoo::mlp_s()),
    ];
    let mb_per = equal_split_per_request(&sc.platform, &sc.base, &mb_tenants, &cache);
    let mb_rates = [2.5 / mb_per[0], 0.1 / mb_per[1], 0.1 / mb_per[2], 0.1 / mb_per[3]];
    let mb_arrivals =
        poisson_trace(&mb_rates, if sample { 25.0 } else { 100.0 } * mb_per[0], 0xB0A2D);
    let mb_sc = Scenario {
        platform: sc.platform.clone(),
        base: sc.base.clone(),
        tenants: mb_tenants,
        arrivals: mb_arrivals,
        switch_cost_s: None,
        shards: 1,
    };
    let mb_policy = Strategy::Dynamic(PolicyConfig::calibrated(mb_per[0]));
    let mut mb_obj = BTreeMap::new();
    mb_obj.insert("arrivals".to_string(), num(mb_sc.arrivals.len() as f64));
    let mut mb_base_rps = 0.0f64;
    for boards in [1usize, 2, 4] {
        let rep = simulate_cluster(
            &mb_sc,
            &mb_policy,
            boards,
            Some(ClusterPolicy::calibrated(mb_per[0])),
            &cache,
        );
        let rps = rep.report.throughput_rps();
        if boards == 1 {
            mb_base_rps = rps;
        }
        println!(
            "boards={boards}: {:.1} req/s ({:.2}x), {} migrations, worst-board p99 {:.3e} s",
            rps,
            rps / mb_base_rps.max(1e-9),
            rep.migrations,
            rep.worst_board_p99_s()
        );
        let mut row = BTreeMap::new();
        row.insert("throughput_rps".to_string(), num(rps));
        row.insert("throughput_scaling".to_string(), num(rps / mb_base_rps.max(1e-9)));
        row.insert("migrations".to_string(), num(rep.migrations as f64));
        row.insert("worst_board_p99_s".to_string(), num(rep.worst_board_p99_s()));
        row.insert("served".to_string(), num(rep.report.total_served() as f64));
        mb_obj.insert(format!("boards_{boards}"), Json::Obj(row));
    }

    // ---- DSE fast path: cold vs warm, worker scaling -----------------
    // Direct GA timings over the zoo DAGs, separate from the cache
    // wall times above, so the snapshot tracks the solver itself. The
    // warm runs are seeded the way the cache's warm-start probe seeds
    // them and must never lose makespan; the cutoff is what buys the
    // wall-time win at an unchanged generation budget.
    let (dse_pop, dse_gens) = if sample { (16, 20) } else { (32, 60) };
    let budget = GaConfig {
        population: dse_pop,
        generations: dse_gens,
        seed: 0xF11C0,
        ..Default::default()
    };
    let tuned =
        GaConfig { workers: 4, stall_generations: 6, stall_epsilon: 1e-3, ..budget.clone() };
    let dse_dags = [zoo::mlp_s(), zoo::mlp_l(), zoo::deit_s(), zoo::pointnet()];
    let (mut cold_ms, mut warm_ms) = (0.0f64, 0.0f64);
    let (mut stops, mut warm_evals, mut warm_wall_s) = (0usize, 0u64, 0.0f64);
    for d in &dse_dags {
        let tbl = stage1::optimize_pool(&sc.platform, &sc.base, d, 4);
        let t = std::time::Instant::now();
        let serial = budget.solve(d, &tbl, &sc.base);
        let c_ms = t.elapsed().as_secs_f64() * 1e3;
        cold_ms += c_ms;
        let seeds = vec![GaSeed::from_schedule(&serial.schedule, d.len()).expect("valid donor")];
        let t = std::time::Instant::now();
        let warm = tuned.solve_seeded(d, &tbl, &sc.base, &seeds);
        let w_ms = t.elapsed().as_secs_f64() * 1e3;
        warm_ms += w_ms;
        warm_wall_s += warm.elapsed_s.max(1e-9);
        warm_evals += warm.evaluations;
        stops += warm.stopped_early as usize;
        assert!(
            warm.best_makespan <= serial.best_makespan * 1.000_001,
            "{}: warm start lost makespan ({} vs {})",
            d.name,
            warm.best_makespan,
            serial.best_makespan
        );
        println!(
            "dse {}: cold {c_ms:.1} ms -> warm {w_ms:.1} ms, {} gens{}",
            d.name,
            warm.generations_run,
            if warm.stopped_early { " (early stop)" } else { "" }
        );
    }
    // Worker scaling on identical inputs: the outcome must be
    // bit-for-bit invariant, only the wall clock may move.
    let wdag = zoo::mlp_l();
    let wtbl = stage1::optimize_pool(&sc.platform, &sc.base, &wdag, 4);
    let mut workers_ms = BTreeMap::new();
    let (mut w1_ms, mut w1_out) = (0.0f64, None);
    for w in [1usize, 2, 4] {
        let t = std::time::Instant::now();
        let out = GaConfig { workers: w, ..budget.clone() }.solve(&wdag, &wtbl, &sc.base);
        let ms = t.elapsed().as_secs_f64() * 1e3;
        if let Some(ref base) = w1_out {
            assert_eq!(&out, base, "workers={w} changed the GA outcome");
        } else {
            w1_ms = ms;
            w1_out = Some(out);
        }
        workers_ms.insert(w.to_string(), num(w1_ms / ms.max(1e-9)));
        println!("dse workers={w}: {ms:.1} ms ({:.2}x)", w1_ms / ms.max(1e-9));
    }
    let mut dse_obj = BTreeMap::new();
    dse_obj.insert("cold_solve_ms".to_string(), num(cold_ms));
    dse_obj.insert("warm_solve_ms".to_string(), num(warm_ms));
    dse_obj.insert("warm_speedup".to_string(), num(cold_ms / warm_ms.max(1e-9)));
    dse_obj.insert("workers_speedup".to_string(), Json::Obj(workers_ms));
    dse_obj.insert(
        "evals_per_sec".to_string(),
        num(warm_evals as f64 / warm_wall_s.max(1e-9)),
    );
    dse_obj.insert("early_stop_rate".to_string(), num(stops as f64 / dse_dags.len() as f64));
    dse_obj.insert("coalesced_solves".to_string(), num(cache.coalesced_solves() as f64));

    println!("schedule cache: {}", cache.stats());
    println!(
        "DSE: {} solves, {:.1} ms wall total; cache lookups {:.1} us wall total",
        cache.solve_count(),
        cache.solve_ns() as f64 / 1e6,
        cache.lookup_ns() as f64 / 1e3
    );
    println!("bench wall time: {:.2} s", t0.elapsed().as_secs_f64());

    // The machine-readable snapshot. Headline numbers come from the
    // dynamic-preempt row — the configuration the serving claims are
    // about.
    let headline = &reports[3];
    let mut snap = BTreeMap::new();
    snap.insert("bench".to_string(), Json::Str("serve_multitenant".to_string()));
    snap.insert("sample_mode".to_string(), Json::Bool(sample));
    snap.insert("arrivals".to_string(), num(sc.arrivals.len() as f64));
    snap.insert("throughput_rps".to_string(), num(headline.1.throughput_rps()));
    snap.insert("worst_p99_s".to_string(), num(headline.1.worst_p99_s()));
    snap.insert("step_ns_per_op".to_string(), num(headline.2.step_profile.ns_per_step()));
    snap.insert("dse_solve_ms".to_string(), num(cache.solve_ns() as f64 / 1e6));
    snap.insert("dse_solves".to_string(), num(cache.solve_count() as f64));
    snap.insert("cache_lookup_us".to_string(), num(cache.lookup_ns() as f64 / 1e3));
    snap.insert(
        "sharded_step_speedup".to_string(),
        num(serial_step_ns / reports[7].2.step_profile.ns_per_step().max(1e-9)),
    );
    snap.insert("dse".to_string(), Json::Obj(dse_obj));
    snap.insert("multi_board".to_string(), Json::Obj(mb_obj));
    snap.insert("scenarios".to_string(), Json::Obj(scen_rows));
    snap.insert(
        "strategies".to_string(),
        Json::Obj(
            reports
                .iter()
                .map(|(n, rep, tel)| {
                    let speedup = n
                        .starts_with("dynamic-sharded")
                        .then(|| serial_step_ns / tel.step_profile.ns_per_step().max(1e-9));
                    (n.to_string(), row_json(rep, tel, speedup))
                })
                .collect(),
        ),
    );
    let out = snapshot_path();
    let mut text = Json::Obj(snap).to_string_compact();
    text.push('\n');
    match std::fs::write(&out, &text) {
        Ok(()) => println!("snapshot -> {}", out.display()),
        Err(e) => {
            eprintln!("snapshot write to {} failed: {e}", out.display());
            std::process::exit(1);
        }
    }

    let (stat, dynr) = (&reports[1].1, &reports[3].1);
    assert_eq!(dynr.total_served(), stat.total_served());
    assert!(cache.solve_count() > 0, "the bench must exercise real DSE solves");
    // The sharded rows must be the dynamic-preempt run, bit-for-bit —
    // the pool is a throughput knob, never a semantic one.
    for (n, rep, tel) in &reports[5..] {
        assert_eq!(rep.completion_s, dynr.completion_s, "{n}: completion must match serial");
        assert_eq!(rep.served, dynr.served, "{n}: served must match serial");
        println!(
            "{n}: {:.0} ns/step ({:.2}x vs serial)",
            tel.step_profile.ns_per_step(),
            serial_step_ns / tel.step_profile.ns_per_step().max(1e-9)
        );
    }
    if sample {
        // Sample mode exists to validate the snapshot schema cheaply;
        // the short trace makes the strict dominance asserts noisy.
        println!("serve_multitenant OK (sample mode)");
        return;
    }
    assert!(
        dynr.completion_s < stat.completion_s,
        "dynamic ({:.4e} s) must beat static equal split ({:.4e} s)",
        dynr.completion_s,
        stat.completion_s
    );
    assert!(dynr.switches >= 1);
    assert!(cache.hits() > 0, "re-partitions must reuse cached schedules");
    println!(
        "dynamic vs static: completion {:.2}x, heavy-tenant p99 {:.2}x",
        stat.completion_s / dynr.completion_s,
        stat.histograms[0].p99() / dynr.histograms[0].p99().max(1e-12)
    );
    let pk = &reports[4].1;
    assert_eq!(pk.total_served(), stat.total_served());
    println!(
        "packed: {} packs (group sizes {:?}), {} unpacks, {} swaps, \
         worst p99 {:.3e} s (unpacked {:.3e} s)",
        pk.packs,
        pk.pack_group_sizes,
        pk.unpacks,
        pk.pack_swaps,
        pk.worst_p99_s(),
        dynr.worst_p99_s()
    );
    println!("serve_multitenant OK");
}
