//! Fig 9 — throughput on diverse MM workloads (paper §4.2).
//!
//! Synthetic transformer-like workloads over a 3x3 grid of
//! (operation count x inter-layer diversity), comparing CHARM-1, RSN
//! and FILCO. Paper claims reproduced:
//!   * large ops + low diversity: everyone decent, FILCO >= 1.3x is the
//!     paper's aggregate claim — we report the measured factor;
//!   * small ops + high diversity: FILCO > 5x vs CHARM and RSN (their
//!     fixed pages/tiles drown in padding).

use filco::arch::FilcoConfig;
use filco::baseline::charm::{charm1, charm_gflops};
use filco::baseline::rsn::rsn;
use filco::dse::{self, Solver};
use filco::platform::Platform;
use filco::report::Table;
use filco::workload::diverse::{fig9_grid, Diversity, OpBucket};

fn main() {
    let p = Platform::vck190();
    let cfg = FilcoConfig::default_for(&p);

    let mut t = Table::new(
        "Fig 9: throughput (GFLOP/s) on diverse MM workloads",
        &["ops", "diversity", "CHARM", "RSN", "FILCO", "FILCO/best-base"],
    );
    let mut cells = Vec::new();
    for (bucket, div, dag) in fig9_grid(12) {
        let g_charm = charm_gflops(&p, &[charm1(&p)], &dag);
        let g_rsn = rsn(&p).dag_gflops(&p, &dag);
        let sched = dse::two_stage(
            &p,
            &cfg,
            &dag,
            Solver::Ga { population: 48, generations: 100, seed: 0xF19 },
        );
        let g_filco = dag.total_flops() as f64 / sched.makespan / 1e9;
        let edge = g_filco / g_charm.max(g_rsn);
        t.row(&[
            bucket.label().into(),
            div.label().into(),
            format!("{g_charm:.0}"),
            format!("{g_rsn:.0}"),
            format!("{g_filco:.0}"),
            format!("{edge:.2}x"),
        ]);
        cells.push((bucket, div, g_charm, g_rsn, g_filco, edge));
    }
    t.emit("fig9_diverse_mm");

    let cell = |b: OpBucket, d: Diversity| {
        cells.iter().find(|(cb, cd, ..)| *cb == b && *cd == d).unwrap()
    };
    // Shape: FILCO never loses.
    for (b, d, _, _, _, edge) in &cells {
        assert!(*edge >= 0.97, "{}/{}: FILCO edge {edge}", b.label(), d.label());
    }
    // Shape: edge grows toward the small+diverse corner; against the
    // fixed-dataflow design (CHARM) the corner gain reaches the paper's
    // >5x, against the best overlay (RSN) it stays >= 1.2x — together
    // bracketing the paper's aggregate "1.3x~5x vs existing works".
    let edge_large_low = cell(OpBucket::Large, Diversity::Low).5;
    let edge_small_high = cell(OpBucket::Small, Diversity::High).5;
    let c = cell(OpBucket::Small, Diversity::High);
    let vs_charm_small_high = c.4 / c.2;
    println!(
        "corner gains vs best baseline: large/low {edge_large_low:.2}x -> small/high {edge_small_high:.2}x"
    );
    println!(
        "corner gain vs CHARM at small/high: {vs_charm_small_high:.2}x (paper: >5x)"
    );
    assert!(edge_small_high > edge_large_low);
    assert!(edge_small_high >= 1.2, "small/high edge too small: {edge_small_high:.2}");
    assert!(vs_charm_small_high >= 4.0, "vs CHARM: {vs_charm_small_high:.2}");
    // Shape: moving from the large/low corner to the small/high corner,
    // the fixed-dataflow baseline collapses much harder than FILCO
    // (paper: "the performance drops sharply in CHARM").
    let charm_drop =
        cell(OpBucket::Large, Diversity::Low).2 / cell(OpBucket::Small, Diversity::High).2;
    let filco_drop =
        cell(OpBucket::Large, Diversity::Low).4 / cell(OpBucket::Small, Diversity::High).4;
    println!(
        "large/low -> small/high collapse: CHARM {charm_drop:.0}x vs FILCO {filco_drop:.0}x"
    );
    assert!(charm_drop > 2.0 * filco_drop, "CHARM must collapse much harder");
    println!("fig9 OK");
}
