//! Fig 10 — end-to-end performance on realistic BERT models (paper
//! §4.3) with the FILCO feature ablation:
//! CHARM, RSN, FILCO(FP), FILCO(FP,FMF), FILCO(FP,FMF,FMV)
//! across BERT-32 .. BERT-512.
//!
//! Paper claims reproduced:
//!   * small BERTs are communication-bound; only FMV (flexible views)
//!     rescues them — FILCO(FP) and FILCO(FP,FMF) stay near the
//!     baselines, FILCO(FP,FMF,FMV) pulls ahead;
//!   * on large BERTs every feature contributes and FILCO >= baselines.

use filco::arch::{Features, FilcoConfig};
use filco::baseline::charm::{charm1, charm_gflops};
use filco::baseline::rsn::rsn;
use filco::baseline::filco_acc;
use filco::dse::{self, Solver};
use filco::platform::Platform;
use filco::report::Table;
use filco::workload::zoo;

fn main() {
    let p = Platform::vck190();
    let seqs = [32u32, 64, 128, 256, 512];
    let feature_sets = [Features::FP, Features::FP_FMF, Features::ALL];

    let mut t = Table::new(
        "Fig 10: end-to-end BERT throughput (GFLOP/s)",
        &["model", "CHARM", "RSN", "FILCO(FP)", "FILCO(FP,FMF)", "FILCO(FP,FMF,FMV)"],
    );
    let mut rows = Vec::new();
    for &seq in &seqs {
        // 2 encoder layers keep DSE fast; throughput is per-layer
        // invariant for fixed seq.
        let dag = zoo::bert_layers(seq, 2);
        let g_charm = charm_gflops(&p, &[charm1(&p)], &dag);
        let g_rsn = rsn(&p).dag_gflops(&p, &dag);
        let mut filco = Vec::new();
        for f in feature_sets {
            let cfg = FilcoConfig::default_for(&p).with_features(f);
            let sched = dse::two_stage(
                &p,
                &cfg,
                &dag,
                Solver::Ga { population: 40, generations: 80, seed: 0xF10 },
            );
            filco.push(dag.total_flops() as f64 / sched.makespan / 1e9);
        }
        t.row(&[
            format!("BERT-{seq}"),
            format!("{g_charm:.0}"),
            format!("{g_rsn:.0}"),
            format!("{:.0}", filco[0]),
            format!("{:.0}", filco[1]),
            format!("{:.0}", filco[2]),
        ]);
        rows.push((seq, g_charm, g_rsn, filco));
    }
    t.emit("fig10_bert_ablation");

    // Shape checks.
    for (seq, g_charm, g_rsn, filco) in &rows {
        // Features monotone: adding FMF then FMV never hurts.
        assert!(filco[1] >= filco[0] * 0.98, "BERT-{seq}: FMF regressed");
        assert!(filco[2] >= filco[1] * 0.98, "BERT-{seq}: FMV regressed");
        // Full FILCO >= both baselines.
        assert!(
            filco[2] >= g_charm.max(*g_rsn) * 0.97,
            "BERT-{seq}: FILCO {} below baseline {}",
            filco[2],
            g_charm.max(*g_rsn)
        );
    }
    // FMV matters most for the small (communication-bound) BERTs.
    let gain = |r: &(u32, f64, f64, Vec<f64>)| r.3[2] / r.3[1];
    let fmv_gain_small = gain(&rows[0]);
    let fmv_gain_large = gain(&rows[rows.len() - 1]);
    println!(
        "FMV gain: BERT-32 {fmv_gain_small:.2}x vs BERT-512 {fmv_gain_large:.2}x \
         (paper: FMV rescues small models)"
    );
    assert!(fmv_gain_small >= fmv_gain_large * 0.999);
    println!("fig10 OK");
}
