//! Fig 8 — single-AIE efficiency vs #operations (paper §4.1).
//!
//! Sweeps FP32 MM sizes from 8x24x16 to 32x32x32 at the granularity of
//! the atomic 2x8x8 operation and reports the efficiency of FILCO's
//! flexible AIE programming vs static AIE programming (cycle model in
//! `analytical::aie`, standing in for the Versal AIE SystemC simulator).
//!
//! Paper claims reproduced:
//!   * flexible sustains 14x24x16 .. 32x32x32 (6x ops) with <= 5% loss;
//!   * static programming collapses on small MMs (padding).

use filco::analytical::aie::AieKernelModel;
use filco::report::{eng, Table};

fn main() {
    // Sweep: grow each dim in atomic steps, 8x24x16 -> 32x32x32.
    let sizes: Vec<(u32, u32, u32)> = vec![
        (8, 24, 16),
        (10, 24, 16),
        (12, 24, 16),
        (14, 24, 16),
        (16, 24, 16),
        (16, 24, 24),
        (16, 32, 24),
        (20, 32, 24),
        (24, 32, 24),
        (24, 32, 32),
        (28, 32, 32),
        (32, 32, 32),
    ];
    let mut t = Table::new(
        "Fig 8: single-AIE efficiency under #operations variation",
        &["mm", "ops", "flexible", "static", "flex/static"],
    );
    let peak = AieKernelModel::Flexible.efficiency(32, 32, 32);
    let mut flex_at_14 = 0.0;
    for &(m, k, n) in &sizes {
        let ops = m as u64 * k as u64 * n as u64;
        let fe = AieKernelModel::Flexible.efficiency(m, k, n);
        let se = AieKernelModel::Static.efficiency(m, k, n);
        if (m, k, n) == (14, 24, 16) {
            flex_at_14 = fe;
        }
        t.row(&[
            format!("{m}x{k}x{n}"),
            ops.to_string(),
            format!("{:.1}%", fe * 100.0),
            format!("{:.1}%", se * 100.0),
            eng(fe / se),
        ]);
    }
    t.emit("fig8_single_aie");

    // Shape checks (paper §4.1).
    let ops_ratio = (32u64 * 32 * 32) as f64 / (14u64 * 24 * 16) as f64;
    println!("op-count range: {:.1}x  (paper: >6x)", ops_ratio);
    println!(
        "flexible loss at 14x24x16 vs peak: {:.1}% (paper: ~5%)",
        (1.0 - flex_at_14 / peak) * 100.0
    );
    assert!(ops_ratio > 6.0);
    assert!(flex_at_14 / peak > 0.95, "flexible must hold 95% across the range");
    let static_small = AieKernelModel::Static.efficiency(8, 24, 16);
    assert!(static_small < 0.15, "static must collapse on small MMs");
    println!("fig8 OK");
}
