//! Design-choice ablations (DESIGN.md §6 "ablation benches"): not a
//! paper figure — these justify three implementation decisions the
//! paper leaves implicit.
//!
//!  A. Stage-1 tile objective: min-DDR-*time* vs min-DDR-*bytes*.
//!  B. DDR queue depth (AXI outstanding transactions) sensitivity.
//!  C. GA hyper-parameters: population x mutation-rate convergence.

use filco::analytical::TilePolicy;
use filco::arch::{Features, FilcoConfig};
use filco::baseline::filco_acc;
use filco::dse::ga::GaConfig;
use filco::dse::stage1;
use filco::platform::Platform;
use filco::report::Table;
use filco::workload::{zoo, MmShape};

fn main() {
    let p = Platform::vck190();
    let cfg = FilcoConfig::default_for(&p);

    // ---- A: tile objective ---------------------------------------------
    let mut ta = Table::new(
        "Ablation A: Stage-1 tile objective (layer latency, ms)",
        &["shape", "min-time (ours)", "min-bytes", "penalty"],
    );
    let shapes = [
        MmShape::new(1024, 4096, 4096),
        MmShape::new(200, 1024, 4096),
        MmShape::new(64, 768, 3072),
        MmShape::new(512, 512, 512),
    ];
    let mut worst_penalty: f64 = 1.0;
    for s in &shapes {
        let mut time_model = filco_acc(&cfg, Features::ALL);
        time_model.tile_policy = TilePolicy::MinTime;
        let mut bytes_model = filco_acc(&cfg, Features::ALL);
        bytes_model.tile_policy = TilePolicy::MinTraffic;
        let lt = time_model.layer_perf(&p, s).latency_s;
        let lb = bytes_model.layer_perf(&p, s).latency_s;
        worst_penalty = worst_penalty.max(lb / lt);
        ta.row(&[
            format!("{}x{}x{}", s.m, s.k, s.n),
            format!("{:.3}", lt * 1e3),
            format!("{:.3}", lb * 1e3),
            format!("{:.2}x", lb / lt),
        ]);
    }
    ta.emit("ablation_tile_objective");
    assert!(worst_penalty >= 1.0, "min-time can never lose to min-bytes on time");
    println!("worst min-bytes penalty: {worst_penalty:.2}x\n");

    // ---- B: DDR queue depth ----------------------------------------------
    // The platform model amortises per-transaction latency over
    // QUEUE_DEPTH outstanding AXI requests; show the end-to-end
    // sensitivity by scaling txn latency (equivalent to depth 4/8/16).
    let mut tb = Table::new(
        "Ablation B: DDR transaction pipelining (BERT-128 layer latency, ms)",
        &["effective depth", "latency"],
    );
    let shape = MmShape::new(128, 768, 768);
    for (label, lat_scale) in [("4 (2x exposed)", 2.0), ("8 (model)", 1.0), ("16 (0.5x)", 0.5)] {
        let mut plat = Platform::vck190();
        plat.ddr.txn_latency_s *= lat_scale;
        let m = filco_acc(&cfg, Features::ALL);
        let l = m.layer_perf(&plat, &shape).latency_s;
        tb.row(&[label.into(), format!("{:.4}", l * 1e3)]);
    }
    tb.emit("ablation_ddr_depth");
    println!();

    // ---- C: GA hyper-parameters -------------------------------------------
    let dag = zoo::bert_layers(128, 4);
    let table = stage1::optimize(&p, &cfg, &dag);
    let mut tc = Table::new(
        "Ablation C: GA hyper-parameters (BERT-128x4 makespan, ms / time, s)",
        &["population", "mutation", "makespan", "search s"],
    );
    let mut best_overall = f64::INFINITY;
    let mut results = Vec::new();
    for &pop in &[16usize, 64, 128] {
        for &mut_rate in &[0.02f64, 0.1, 0.3] {
            let t = std::time::Instant::now();
            let out = GaConfig {
                population: pop,
                generations: 4096 / pop, // equalised evaluation budget
                mutation_rate: mut_rate,
                seed: 0xAB1A,
                ..Default::default()
            }
            .solve(&dag, &table, &cfg);
            let secs = t.elapsed().as_secs_f64();
            best_overall = best_overall.min(out.best_makespan);
            results.push((pop, mut_rate, out.best_makespan));
            tc.row(&[
                pop.to_string(),
                format!("{mut_rate}"),
                format!("{:.4}", out.best_makespan * 1e3),
                format!("{secs:.2}"),
            ]);
        }
    }
    tc.emit("ablation_ga_hparams");
    // Every configuration lands within 25% of the best (GA robustness);
    // the default (64, 0.1) within 10% under this equalised tiny
    // evaluation budget (low mutation converges fastest on short runs;
    // the default trades that for exploration on Fig-11-sized problems).
    for (pop, mr, mk) in &results {
        assert!(mk / best_overall < 1.25, "GA ({pop},{mr}) off by {:.2}x", mk / best_overall);
    }
    let default_mk = results.iter().find(|(p2, m2, _)| *p2 == 64 && *m2 == 0.1).unwrap().2;
    assert!(
        default_mk / best_overall < 1.10,
        "default hparams off: {:.3}x",
        default_mk / best_overall
    );
    println!("ablations OK");
}
