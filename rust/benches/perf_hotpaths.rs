//! §Perf harness: microbenchmarks for the three L3 hot paths —
//! Stage-1 optimization, GA schedule search (evals/s), and the fabric
//! simulator (instructions/s). Used to drive the EXPERIMENTS.md §Perf
//! iteration log; not a paper figure.

use std::time::Instant;

use filco::arch::FilcoConfig;
use filco::coordinator::instrgen;
use filco::dse::{ga::GaConfig, stage1};
use filco::platform::Platform;
use filco::sim::{self, Fabric};
use filco::workload::zoo;

fn main() {
    let p = Platform::vck190();
    let cfg = FilcoConfig::default_for(&p);

    // --- Stage 1 on a realistic DAG (BERT-128, 12 layers = 96 MMs) ----
    let dag = zoo::bert(128);
    let t = Instant::now();
    let table = stage1::optimize(&p, &cfg, &dag);
    let stage1_s = t.elapsed().as_secs_f64();
    println!(
        "stage1: {} layers in {:.3} s ({:.0} layers/s)",
        dag.len(),
        stage1_s,
        dag.len() as f64 / stage1_s
    );

    // --- GA throughput --------------------------------------------------
    let t = Instant::now();
    let ga = GaConfig { population: 64, generations: 100, seed: 1, ..Default::default() }
        .solve(&dag, &table, &cfg);
    let ga_s = t.elapsed().as_secs_f64();
    println!(
        "ga:     {} evals in {:.3} s ({:.0} evals/s, {} layers each)",
        ga.evaluations,
        ga_s,
        ga.evaluations as f64 / ga_s,
        dag.len()
    );

    // --- simulator throughput -------------------------------------------
    let small = zoo::bert_layers(128, 2);
    let table2 = stage1::optimize(&p, &cfg, &small);
    let sched = GaConfig { population: 16, generations: 10, seed: 2, ..Default::default() }
        .solve(&small, &table2, &cfg)
        .schedule;
    let prog = instrgen::generate(&small, &table2, &sched, 256);
    let t = Instant::now();
    let mut total_instr = 0u64;
    let mut reps = 0;
    while t.elapsed().as_secs_f64() < 1.0 {
        let r = sim::simulate(&p, &Fabric::from_config(&cfg), &prog).unwrap();
        total_instr += r.instructions;
        reps += 1;
    }
    let sim_s = t.elapsed().as_secs_f64();
    println!(
        "sim:    {} instrs x {} reps in {:.3} s ({:.0} instrs/s)",
        prog.total_len(),
        reps,
        sim_s,
        total_instr as f64 / sim_s
    );
}
