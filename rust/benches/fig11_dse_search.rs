//! Fig 11 — DSE search-time comparison, MILP vs GA (paper §4.4).
//!
//! Paper setup: Config-1 = 50 layers x 50 candidates, Config-2 = 50
//! layers x 5000 candidates. Findings to reproduce:
//!   * small task sets: MILP is exact; GA converges faster with ~3%
//!     optimality gap;
//!   * large task sets: GA returns a good point quickly; MILP fails to
//!     produce any valid solution within its budget.
//!
//! We add Config-0 (8 layers x 6 candidates) where our branch-and-bound
//! provably reaches the optimum, so the GA gap is measured against a
//! true optimum — the paper's CPLEX could still solve Config-1 exactly;
//! our dense in-house MILP hits its size guard there, which lands in the
//! same "no valid solution within budget" row as the paper's Config-2.

use std::time::Instant;

use filco::arch::FilcoConfig;
use filco::dse::ga::{GaConfig, GaSeed};
use filco::dse::milp::MilpStatus;
use filco::dse::schedule::{CandidateTable, Mode};
use filco::dse::sched_milp;
use filco::platform::Platform;
use filco::report::Table;
use filco::util::rng::SplitMix64;
use filco::workload::{Dag, MmShape};

/// Synthetic layered DAG + candidate table: `layers` chain-with-skips,
/// `cands` modes per layer with random (f, c, latency) trade-offs.
fn synth(layers: usize, cands: usize, seed: u64) -> (Dag, CandidateTable) {
    let mut rng = SplitMix64::new(seed);
    let mut dag = Dag::new(format!("synth{layers}x{cands}"));
    for i in 0..layers {
        dag.add(format!("l{i}"), MmShape::new(64, 64, 64));
        if i > 0 {
            dag.dep(i - 1, i);
        }
        // Extra skip edges make the DAG non-trivial.
        if i > 3 && rng.below(4) == 0 {
            dag.dep(i - 4, i);
        }
    }
    let mut modes = Vec::with_capacity(layers);
    for _ in 0..layers {
        let mut ms = Vec::with_capacity(cands);
        for _ in 0..cands {
            let f = 1 + rng.below(4) as u32;
            let c = 1 + rng.below(4) as u32;
            // More resources -> lower latency, plus noise.
            let base = 1.0 / (f as f64 * c as f64).sqrt();
            let lat = base * (0.8 + 0.4 * rng.next_f64());
            ms.push(Mode { fmus: f, cus: c, latency_s: lat, tile: (32, 32, 32) });
        }
        modes.push(ms);
    }
    (dag, CandidateTable { modes })
}

fn cfg_fc(f: u32, c: u32) -> FilcoConfig {
    let p = Platform::vck190();
    let mut cfg = FilcoConfig::default_for(&p);
    cfg.n_fmus = f;
    cfg.m_cus = c;
    cfg
}

fn main() {
    let mut t = Table::new(
        "Fig 11: DSE search time, MILP vs GA",
        &["config", "solver", "time (s)", "makespan", "status/gap"],
    );

    // ---- Config-0: exactly solvable ------------------------------------
    let (dag0, tab0) = synth(8, 6, 1);
    let cfg0 = cfg_fc(4, 4);
    let t0 = Instant::now();
    let milp0 = sched_milp::solve(&dag0, &tab0, &cfg0, 120.0);
    let milp0_t = t0.elapsed().as_secs_f64();
    t.row(&[
        "Config-0 (8x6)".into(),
        "MILP".into(),
        format!("{milp0_t:.2}"),
        format!("{:.4}", milp0.schedule.makespan),
        format!("{:?}", milp0.status),
    ]);
    let t0 = Instant::now();
    let ga0 = GaConfig { population: 48, generations: 150, seed: 3, ..Default::default() }
        .solve(&dag0, &tab0, &cfg0);
    let ga0_t = t0.elapsed().as_secs_f64();
    let gap0 = (ga0.best_makespan - milp0.schedule.makespan) / milp0.schedule.makespan;
    t.row(&[
        "Config-0 (8x6)".into(),
        "GA".into(),
        format!("{ga0_t:.2}"),
        format!("{:.4}", ga0.best_makespan),
        format!("gap {:.1}%", gap0 * 100.0),
    ]);

    // ---- Config-1: 50 layers x 50 candidates ---------------------------
    let (dag1, tab1) = synth(50, 50, 2);
    let cfg1 = cfg_fc(16, 8);
    let t1 = Instant::now();
    let milp1 = sched_milp::solve(&dag1, &tab1, &cfg1, 60.0);
    let milp1_t = t1.elapsed().as_secs_f64();
    t.row(&[
        "Config-1 (50x50)".into(),
        "MILP".into(),
        format!("{milp1_t:.2}"),
        "-".into(),
        format!("{:?}", milp1.status),
    ]);
    let t1 = Instant::now();
    let ga1 = GaConfig { population: 64, generations: 200, seed: 4, ..Default::default() }
        .solve(&dag1, &tab1, &cfg1);
    let ga1_t = t1.elapsed().as_secs_f64();
    t.row(&[
        "Config-1 (50x50)".into(),
        "GA".into(),
        format!("{ga1_t:.2}"),
        format!("{:.4}", ga1.best_makespan),
        format!("{} evals", ga1.evaluations),
    ]);

    // ---- Config-2: 50 layers x 5000 candidates -------------------------
    let (dag2, tab2) = synth(50, 5000, 5);
    let cfg2 = cfg_fc(16, 8);
    let t2 = Instant::now();
    let milp2 = sched_milp::solve(&dag2, &tab2, &cfg2, 60.0);
    let milp2_t = t2.elapsed().as_secs_f64();
    t.row(&[
        "Config-2 (50x5000)".into(),
        "MILP".into(),
        format!("{milp2_t:.2}"),
        "-".into(),
        format!("{:?}", milp2.status),
    ]);
    let t2 = Instant::now();
    let ga2 = GaConfig { population: 64, generations: 200, seed: 6, ..Default::default() }
        .solve(&dag2, &tab2, &cfg2);
    let ga2_t = t2.elapsed().as_secs_f64();
    t.row(&[
        "Config-2 (50x5000)".into(),
        "GA".into(),
        format!("{ga2_t:.2}"),
        format!("{:.4}", ga2.best_makespan),
        format!("{} evals", ga2.evaluations),
    ]);

    // ---- Fast-DSE rows: worker pool differential + warm start --------
    // The pool only batches fitness evaluation; children are generated
    // by the serial RNG stream, so every worker count must reproduce
    // the Config-1 GA outcome bit-for-bit while the wall clock drops.
    let ga1_cfg = GaConfig { population: 64, generations: 200, seed: 4, ..Default::default() };
    let mut ga1_w1_t = ga1_t;
    for w in [1usize, 2, 4] {
        let tw = Instant::now();
        let out = GaConfig { workers: w, ..ga1_cfg.clone() }.solve(&dag1, &tab1, &cfg1);
        let wt = tw.elapsed().as_secs_f64();
        assert_eq!(out, ga1, "workers={w} changed the Config-1 GA outcome");
        if w == 1 {
            ga1_w1_t = wt;
        }
        t.row(&[
            "Config-1 (50x50)".into(),
            format!("GA w={w}"),
            format!("{wt:.2}"),
            format!("{:.4}", out.best_makespan),
            format!(
                "{:.2}x, {:.0} evals/s",
                ga1_w1_t / wt.max(1e-9),
                out.evaluations as f64 / wt.max(1e-9)
            ),
        ]);
    }
    // Warm start seeded with the cold run's own schedule plus the
    // convergence cutoff: same budget, equal-or-better makespan,
    // typically far fewer generations.
    let seeds = vec![GaSeed::from_schedule(&ga1.schedule, dag1.len()).expect("valid donor")];
    let tw = Instant::now();
    let warm = GaConfig { workers: 4, stall_generations: 8, stall_epsilon: 1e-3, ..ga1_cfg }
        .solve_seeded(&dag1, &tab1, &cfg1, &seeds);
    let warm_t = tw.elapsed().as_secs_f64();
    assert!(
        warm.best_makespan <= ga1.best_makespan * 1.000_001,
        "warm start lost makespan: {} vs {}",
        warm.best_makespan,
        ga1.best_makespan
    );
    t.row(&[
        "Config-1 (50x50)".into(),
        "GA warm+cutoff".into(),
        format!("{warm_t:.2}"),
        format!("{:.4}", warm.best_makespan),
        format!(
            "{} gens{}, {:.0} evals/s",
            warm.generations_run,
            if warm.stopped_early { " (early stop)" } else { "" },
            warm.evaluations as f64 / warm_t.max(1e-9)
        ),
    ]);
    t.emit("fig11_dse_search");

    // ---- shape checks ----------------------------------------------------
    assert_eq!(milp0.status, MilpStatus::Optimal, "Config-0 must solve exactly");
    assert!(gap0.abs() <= 0.03 + 1e-9, "GA gap on Config-0: {:.2}%", gap0 * 100.0);
    // Large task sets: MILP cannot produce a solution; GA returns a good
    // point fast (paper: within 10 minutes; ours: seconds).
    assert_ne!(milp1.status, MilpStatus::Optimal);
    assert_ne!(milp2.status, MilpStatus::Optimal);
    assert!(ga1_t < 600.0 && ga2_t < 600.0);
    // GA solutions are valid schedules.
    ga1.schedule.validate(&dag1, &tab1, 16, 8).unwrap();
    ga2.schedule.validate(&dag2, &tab2, 16, 8).unwrap();
    println!(
        "GA Config-0 gap {:.1}% (paper ~3%) | GA times: {:.1}s / {:.1}s / {:.1}s",
        gap0 * 100.0, ga0_t, ga1_t, ga2_t
    );
    println!("fig11 OK");
}
