//! Fig 1 — throughput comparison across accelerator designs and models
//! of varying diversity (paper §1).
//!
//! Columns: CHARM-1 (monolithic), CHARM-2, CHARM-3 (multi-diverse),
//! RSN (overlay), FILCO (two-stage DSE on the composable fabric).
//! Rows: MLP-L (low diversity, large), MLP-S (small), DeiT-L, DeiT-S,
//! PointNet (highest diversity).
//!
//! Expected shape (paper): CHARM-1 peaks on MLP-L then collapses with
//! diversity/size; CHARM-2/3 degrade more gracefully but cap the peak;
//! RSN holds until sizes shrink; FILCO >= all across the board.

use filco::arch::FilcoConfig;
use filco::baseline::charm::{charm1, charm2, charm3, charm_gflops};
use filco::baseline::rsn::rsn;
use filco::dse::{self, Solver};
use filco::platform::Platform;
use filco::report::Table;
use filco::workload::zoo;

fn main() {
    let p = Platform::vck190();
    let cfg = FilcoConfig::default_for(&p);
    let models = zoo::fig1_models();

    let mut t = Table::new(
        "Fig 1: throughput (GFLOP/s) for different works",
        &["model", "diversity", "CHARM-1", "CHARM-2", "CHARM-3", "RSN", "FILCO"],
    );
    let mut results: Vec<(String, Vec<f64>)> = Vec::new();
    for dag in &models {
        let g1 = charm_gflops(&p, &[charm1(&p)], dag);
        let g2 = charm_gflops(&p, &charm2(&p), dag);
        let g3 = charm_gflops(&p, &charm3(&p), dag);
        let gr = rsn(&p).dag_gflops(&p, dag);
        let sched = dse::two_stage(
            &p,
            &cfg,
            dag,
            Solver::Ga { population: 48, generations: 100, seed: 0xF16 },
        );
        let gf = dag.total_flops() as f64 / sched.makespan / 1e9;
        t.row(&[
            dag.name.clone(),
            format!("{:.2}", dag.diversity()),
            format!("{g1:.0}"),
            format!("{g2:.0}"),
            format!("{g3:.0}"),
            format!("{gr:.0}"),
            format!("{gf:.0}"),
        ]);
        results.push((dag.name.clone(), vec![g1, g2, g3, gr, gf]));
    }
    t.emit("fig1_throughput");

    // Shape assertions.
    let get = |name: &str| &results.iter().find(|(n, _)| n == name).unwrap().1;
    let mlp_l = get("MLP-L");
    let mlp_s = get("MLP-S");
    // (1) CHARM-1 leads the CHARM family on MLP-L but collapses on MLP-S.
    assert!(mlp_l[0] >= mlp_l[1] * 0.95 && mlp_l[0] >= mlp_l[2] * 0.95);
    let c1_drop = mlp_l[0] / mlp_s[0];
    let c3_drop = mlp_l[2] / mlp_s[2];
    assert!(c1_drop > c3_drop, "CHARM-1 must degrade faster than CHARM-3");
    // (2) FILCO >= every baseline on every model (small tolerance).
    for (name, r) in &results {
        let best_base = r[..4].iter().cloned().fold(0.0f64, f64::max);
        assert!(
            r[4] >= best_base * 0.97,
            "{name}: FILCO {} below best baseline {}",
            r[4],
            best_base
        );
    }
    // (3) FILCO's edge grows with diversity (PointNet vs MLP-L).
    let edge_mlp_l = mlp_l[4] / mlp_l[..4].iter().cloned().fold(0.0f64, f64::max);
    let pnet = get("PointNet");
    let edge_pnet = pnet[4] / pnet[..4].iter().cloned().fold(0.0f64, f64::max);
    println!("FILCO edge: MLP-L {edge_mlp_l:.2}x -> PointNet {edge_pnet:.2}x");
    assert!(edge_pnet > edge_mlp_l);
    println!("fig1 OK");
}
