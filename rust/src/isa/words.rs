//! Typed instruction words for every FILCO function unit (Table 1).

/// A rectangular view into a logically 2-D operand held in an FMU's 1-D
/// buffer (paper §2.3 "flexible on-chip memory views"): rows/cols are
/// *element* indices; the FMU reconstructs addresses as
/// `row * row_stride + col` with the stride carried by `cols_total`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TileView {
    pub start_row: u32,
    pub end_row: u32, // exclusive
    pub start_col: u32,
    pub end_col: u32, // exclusive
}

impl TileView {
    pub fn full(rows: u32, cols: u32) -> Self {
        Self { start_row: 0, end_row: rows, start_col: 0, end_col: cols }
    }

    pub fn rows(&self) -> u32 {
        self.end_row - self.start_row
    }

    pub fn cols(&self) -> u32 {
        self.end_col - self.start_col
    }

    pub fn elements(&self) -> u64 {
        self.rows() as u64 * self.cols() as u64
    }

    pub fn is_valid(&self) -> bool {
        self.end_row > self.start_row && self.end_col > self.start_col
    }
}

/// Instruction Generator header word: tells the dispatcher how many
/// subsequent words go to which unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HeaderInstr {
    pub is_last: bool,
    pub des_unit: super::UnitId,
    pub valid_length: u32,
}

/// IOM Loader word: DDR -> FMU transfer of a `M x N` operand region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IomLoadInstr {
    pub is_last: bool,
    pub ddr_addr: u64,
    pub des_fmu: u16,
    /// Full operand dimensions in DDR (row-major), used to compute burst
    /// strides.
    pub m: u32,
    pub n: u32,
    pub view: TileView,
}

/// IOM Storer word: FMU -> DDR transfer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IomStoreInstr {
    pub is_last: bool,
    pub ddr_addr: u64,
    pub src_fmu: u16,
    pub m: u32,
    pub n: u32,
    pub view: TileView,
}

/// What an FMU does during one buffer phase (paper Fig 4: the same 1-D
/// double buffer is *viewed* and *routed* differently per instruction —
/// this is both FMV (views) and FMF (functionality) in one decoder).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FmuOp {
    Idle,
    /// Receive `count` elements from the IOM into the active buffer.
    RecvFromIom,
    /// Send the addressed tile view to a CU (operand feed).
    SendToCu,
    /// Receive a result tile from a CU (result collect).
    RecvFromCu,
    /// Drain the active buffer to the IOM storer.
    SendToIom,
}

impl FmuOp {
    pub const ALL: [FmuOp; 5] =
        [FmuOp::Idle, FmuOp::RecvFromIom, FmuOp::SendToCu, FmuOp::RecvFromCu, FmuOp::SendToIom];

    pub fn code(self) -> u8 {
        match self {
            FmuOp::Idle => 0,
            FmuOp::RecvFromIom => 1,
            FmuOp::SendToCu => 2,
            FmuOp::RecvFromCu => 3,
            FmuOp::SendToIom => 4,
        }
    }

    pub fn from_code(c: u8) -> Option<Self> {
        Self::ALL.get(c as usize).copied()
    }
}

/// FMU word. `src_cu`/`des_cu` select the pre-routed stream used this
/// phase; `count` bounds the receive; the tile view addresses the 1-D
/// buffer for sends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FmuInstr {
    pub is_last: bool,
    pub ping_op: FmuOp,
    pub pong_op: FmuOp,
    pub src_cu: u16,
    pub des_cu: u16,
    pub count: u32,
    pub view: TileView,
}

/// What a CU does during one phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CuOp {
    Idle,
    /// Run the flexible AIE MM kernel over the loop bounds in the word.
    ComputeMm,
    /// Stream a result tile out to the destination FMU.
    WriteBack,
}

impl CuOp {
    pub const ALL: [CuOp; 3] = [CuOp::Idle, CuOp::ComputeMm, CuOp::WriteBack];

    pub fn code(self) -> u8 {
        match self {
            CuOp::Idle => 0,
            CuOp::ComputeMm => 1,
            CuOp::WriteBack => 2,
        }
    }

    pub fn from_code(c: u8) -> Option<Self> {
        Self::ALL.get(c as usize).copied()
    }
}

/// CU word. `m/k/n` are the *runtime loop bounds* of the flexible AIE
/// kernel (Fig 3 lines 3–7: bounds arrive through input ports); they are
/// in elements and need not be atomic-tile multiples — the kernel rounds
/// up to atomic 2x8x8 operations internally.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CuInstr {
    pub is_last: bool,
    pub ping_op: CuOp,
    pub pong_op: CuOp,
    pub src_fmu: u16,
    pub des_fmu: u16,
    pub count: u32,
    pub m: u32,
    pub k: u32,
    pub n: u32,
}

/// Any instruction word (tagged for stream dispatch + disassembly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    Header(HeaderInstr),
    IomLoad(IomLoadInstr),
    IomStore(IomStoreInstr),
    Fmu(FmuInstr),
    Cu(CuInstr),
}

impl Instr {
    pub fn is_last(&self) -> bool {
        match self {
            Instr::Header(i) => i.is_last,
            Instr::IomLoad(i) => i.is_last,
            Instr::IomStore(i) => i.is_last,
            Instr::Fmu(i) => i.is_last,
            Instr::Cu(i) => i.is_last,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_view_geometry() {
        let v = TileView { start_row: 2, end_row: 10, start_col: 4, end_col: 8 };
        assert_eq!(v.rows(), 8);
        assert_eq!(v.cols(), 4);
        assert_eq!(v.elements(), 32);
        assert!(v.is_valid());
    }

    #[test]
    fn tile_view_full() {
        let v = TileView::full(16, 32);
        assert_eq!(v.elements(), 512);
    }

    #[test]
    fn degenerate_view_invalid() {
        let v = TileView { start_row: 3, end_row: 3, start_col: 0, end_col: 4 };
        assert!(!v.is_valid());
    }

    #[test]
    fn op_codes_roundtrip() {
        for op in FmuOp::ALL {
            assert_eq!(FmuOp::from_code(op.code()), Some(op));
        }
        for op in CuOp::ALL {
            assert_eq!(CuOp::from_code(op.code()), Some(op));
        }
        assert_eq!(FmuOp::from_code(99), None);
        assert_eq!(CuOp::from_code(99), None);
    }
}
