//! Human-readable disassembly of FILCO instruction streams (debugging
//! aid + the `filco disasm` CLI subcommand).

use super::program::{Program, UnitId};
use super::words::*;

fn view_str(v: &TileView) -> String {
    format!("[{}:{}, {}:{}]", v.start_row, v.end_row, v.start_col, v.end_col)
}

/// One-line rendering of a single instruction.
pub fn disasm_instr(i: &Instr) -> String {
    let last = if i.is_last() { " !last" } else { "" };
    match i {
        Instr::Header(h) => {
            format!("HDR  des={} len={}{last}", h.des_unit, h.valid_length)
        }
        Instr::IomLoad(l) => format!(
            "LOAD ddr={:#x} -> FMU{} dims={}x{} view={}{last}",
            l.ddr_addr,
            l.des_fmu,
            l.m,
            l.n,
            view_str(&l.view)
        ),
        Instr::IomStore(s) => format!(
            "STOR FMU{} -> ddr={:#x} dims={}x{} view={}{last}",
            s.src_fmu,
            s.ddr_addr,
            s.m,
            s.n,
            view_str(&s.view)
        ),
        Instr::Fmu(f) => format!(
            "FMU  ping={:?} pong={:?} src=CU{} des=CU{} count={} view={}{last}",
            f.ping_op,
            f.pong_op,
            f.src_cu,
            f.des_cu,
            f.count,
            view_str(&f.view)
        ),
        Instr::Cu(c) => format!(
            "CU   ping={:?} pong={:?} src=FMU{} des=FMU{} count={} mm={}x{}x{}{last}",
            c.ping_op, c.pong_op, c.src_fmu, c.des_fmu, c.count, c.m, c.k, c.n
        ),
    }
}

/// Full program listing, grouped per unit.
pub fn disasm_program(p: &Program) -> String {
    let mut out = String::new();
    let mut units: Vec<UnitId> = p.units().collect();
    units.sort();
    for u in units {
        out.push_str(&format!("== {u} ==\n"));
        for (idx, i) in p.stream(u).iter().enumerate() {
            out.push_str(&format!("  {idx:4}: {}\n", disasm_instr(i)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_kinds() {
        let instrs = [
            Instr::Header(HeaderInstr {
                is_last: false,
                des_unit: UnitId::Fmu(2),
                valid_length: 4,
            }),
            Instr::IomLoad(IomLoadInstr {
                is_last: false,
                ddr_addr: 0x1000,
                des_fmu: 1,
                m: 64,
                n: 64,
                view: TileView::full(64, 64),
            }),
            Instr::Fmu(FmuInstr {
                is_last: true,
                ping_op: FmuOp::RecvFromIom,
                pong_op: FmuOp::SendToCu,
                src_cu: 0,
                des_cu: 3,
                count: 4096,
                view: TileView::full(64, 64),
            }),
        ];
        for i in &instrs {
            let s = disasm_instr(i);
            assert!(!s.is_empty());
        }
        assert!(disasm_instr(&instrs[2]).contains("!last"));
        assert!(disasm_instr(&instrs[1]).contains("0x1000"));
    }

    #[test]
    fn program_listing_groups_by_unit() {
        let mut p = Program::new();
        p.push(
            UnitId::Cu(0),
            Instr::Cu(CuInstr {
                is_last: false,
                ping_op: CuOp::ComputeMm,
                pong_op: CuOp::Idle,
                src_fmu: 0,
                des_fmu: 1,
                count: 1,
                m: 32,
                k: 32,
                n: 32,
            }),
        );
        p.seal();
        let txt = disasm_program(&p);
        assert!(txt.contains("== CU0 =="));
        assert!(txt.contains("mm=32x32x32"));
    }
}
