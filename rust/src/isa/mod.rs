//! FILCO instruction set (paper §2.5, Table 1).
//!
//! FILCO separates *static* parameters (number/capacity of FMUs & CUs,
//! AIE connections inside a CU — fixed at compile time, see
//! [`crate::arch`]) from *runtime* parameters, which are delivered to the
//! function units as small instruction words streamed from off-chip
//! instruction memory by the Instruction Generator.
//!
//! One instruction word per function unit per (ping|pong) phase:
//!
//! | unit       | fields (Table 1)                                                    |
//! |------------|---------------------------------------------------------------------|
//! | InstrGen   | `is_last, des_unit, valid_length`                                   |
//! | IOM Loader | `is_last, ddr_addr, des_fmu, M, N, start_row,end_row,start_col,end_col` |
//! | IOM Storer | `is_last, ddr_addr, src_fmu, M, N, start_row,end_row,start_col,end_col` |
//! | FMU        | `is_last, ping_op, pong_op, src_cu, des_cu, count, start_row,end_row,start_col,end_col` |
//! | CU         | `is_last, ping_op, pong_op, src_fmu, des_fmu, count` (+ the AIE kernel loop bounds `m,k,n` — Fig 3 delivers these through the kernel's input ports; we carry them in the CU word) |
//!
//! Submodules:
//! * [`words`]   — typed instruction structs + operation enums.
//! * [`encode`]  — fixed-width binary encode/decode (the "binary files"
//!   the FILCO framework emits).
//! * [`program`] — per-unit instruction streams for a whole schedule.
//! * [`disasm`]  — human-readable disassembly.

pub mod disasm;
pub mod encode;
pub mod program;
pub mod words;

pub use program::{Program, UnitId};
pub use words::{
    CuInstr, CuOp, FmuInstr, FmuOp, HeaderInstr, IomLoadInstr, IomStoreInstr, Instr, TileView,
};
