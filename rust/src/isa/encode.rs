//! Fixed-width binary encoding for instruction words.
//!
//! Layout: 1 opcode byte, then little-endian fields. The FMU pattern
//! switch the paper highlights ("switched by decoding a few bytes of
//! instructions", §2.5) corresponds to the 2-byte op pair at the head of
//! the FMU word.

use super::program::UnitId;
use super::words::*;

/// Opcode tags.
const OP_HEADER: u8 = 0x01;
const OP_IOM_LOAD: u8 = 0x02;
const OP_IOM_STORE: u8 = 0x03;
const OP_FMU: u8 = 0x04;
const OP_CU: u8 = 0x05;

/// Flags byte: bit0 = is_last.
const FLAG_LAST: u8 = 0x01;

#[derive(Debug, PartialEq)]
pub enum DecodeError {
    Truncated(usize),
    BadOpcode(u8, usize),
    BadField(&'static str),
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated(at) => write!(f, "truncated instruction at byte {at}"),
            DecodeError::BadOpcode(op, at) => write!(f, "unknown opcode {op:#x} at byte {at}"),
            DecodeError::BadField(what) => write!(f, "invalid field: {what}"),
        }
    }
}

impl std::error::Error for DecodeError {}

struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, x: u8) {
        self.buf.push(x);
    }
    fn u16(&mut self, x: u16) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn u32(&mut self, x: u32) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn u64(&mut self, x: u64) {
        self.buf.extend_from_slice(&x.to_le_bytes());
    }
    fn view(&mut self, v: &TileView) {
        self.u32(v.start_row);
        self.u32(v.end_row);
        self.u32(v.start_col);
        self.u32(v.end_col);
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.i + n > self.b.len() {
            return Err(DecodeError::Truncated(self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, DecodeError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, DecodeError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, DecodeError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, DecodeError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn view(&mut self) -> Result<TileView, DecodeError> {
        Ok(TileView {
            start_row: self.u32()?,
            end_row: self.u32()?,
            start_col: self.u32()?,
            end_col: self.u32()?,
        })
    }
}

/// Encode one instruction, appending to `out`.
pub fn encode_into(instr: &Instr, out: &mut Vec<u8>) {
    let mut w = Writer { buf: std::mem::take(out) };
    let flags = |b: bool| if b { FLAG_LAST } else { 0 };
    match instr {
        Instr::Header(h) => {
            w.u8(OP_HEADER);
            w.u8(flags(h.is_last));
            w.u16(h.des_unit.code());
            w.u32(h.valid_length);
        }
        Instr::IomLoad(i) => {
            w.u8(OP_IOM_LOAD);
            w.u8(flags(i.is_last));
            w.u64(i.ddr_addr);
            w.u16(i.des_fmu);
            w.u32(i.m);
            w.u32(i.n);
            w.view(&i.view);
        }
        Instr::IomStore(i) => {
            w.u8(OP_IOM_STORE);
            w.u8(flags(i.is_last));
            w.u64(i.ddr_addr);
            w.u16(i.src_fmu);
            w.u32(i.m);
            w.u32(i.n);
            w.view(&i.view);
        }
        Instr::Fmu(i) => {
            w.u8(OP_FMU);
            w.u8(flags(i.is_last));
            w.u8(i.ping_op.code());
            w.u8(i.pong_op.code());
            w.u16(i.src_cu);
            w.u16(i.des_cu);
            w.u32(i.count);
            w.view(&i.view);
        }
        Instr::Cu(i) => {
            w.u8(OP_CU);
            w.u8(flags(i.is_last));
            w.u8(i.ping_op.code());
            w.u8(i.pong_op.code());
            w.u16(i.src_fmu);
            w.u16(i.des_fmu);
            w.u32(i.count);
            w.u32(i.m);
            w.u32(i.k);
            w.u32(i.n);
        }
    }
    *out = w.buf;
}

/// Encode a whole stream.
pub fn encode_stream(instrs: &[Instr]) -> Vec<u8> {
    let mut out = Vec::with_capacity(instrs.len() * 32);
    for i in instrs {
        encode_into(i, &mut out);
    }
    out
}

/// Decode one instruction starting at `r.i`.
fn decode_one(r: &mut Reader) -> Result<Instr, DecodeError> {
    let at = r.i;
    let op = r.u8()?;
    let flags = r.u8()?;
    let is_last = flags & FLAG_LAST != 0;
    match op {
        OP_HEADER => {
            let code = r.u16()?;
            let des_unit = UnitId::from_code(code).ok_or(DecodeError::BadField("des_unit"))?;
            Ok(Instr::Header(HeaderInstr { is_last, des_unit, valid_length: r.u32()? }))
        }
        OP_IOM_LOAD => Ok(Instr::IomLoad(IomLoadInstr {
            is_last,
            ddr_addr: r.u64()?,
            des_fmu: r.u16()?,
            m: r.u32()?,
            n: r.u32()?,
            view: r.view()?,
        })),
        OP_IOM_STORE => Ok(Instr::IomStore(IomStoreInstr {
            is_last,
            ddr_addr: r.u64()?,
            src_fmu: r.u16()?,
            m: r.u32()?,
            n: r.u32()?,
            view: r.view()?,
        })),
        OP_FMU => {
            let ping_op = FmuOp::from_code(r.u8()?).ok_or(DecodeError::BadField("ping_op"))?;
            let pong_op = FmuOp::from_code(r.u8()?).ok_or(DecodeError::BadField("pong_op"))?;
            Ok(Instr::Fmu(FmuInstr {
                is_last,
                ping_op,
                pong_op,
                src_cu: r.u16()?,
                des_cu: r.u16()?,
                count: r.u32()?,
                view: r.view()?,
            }))
        }
        OP_CU => {
            let ping_op = CuOp::from_code(r.u8()?).ok_or(DecodeError::BadField("ping_op"))?;
            let pong_op = CuOp::from_code(r.u8()?).ok_or(DecodeError::BadField("pong_op"))?;
            Ok(Instr::Cu(CuInstr {
                is_last,
                ping_op,
                pong_op,
                src_fmu: r.u16()?,
                des_fmu: r.u16()?,
                count: r.u32()?,
                m: r.u32()?,
                k: r.u32()?,
                n: r.u32()?,
            }))
        }
        other => Err(DecodeError::BadOpcode(other, at)),
    }
}

/// Decode a whole stream.
pub fn decode_stream(bytes: &[u8]) -> Result<Vec<Instr>, DecodeError> {
    let mut r = Reader { b: bytes, i: 0 };
    let mut out = Vec::new();
    while r.i < r.b.len() {
        out.push(decode_one(&mut r)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;
    use crate::util::rng::SplitMix64;

    fn arbitrary_view(rng: &mut SplitMix64) -> TileView {
        let sr = rng.below(512) as u32;
        let sc = rng.below(512) as u32;
        TileView {
            start_row: sr,
            end_row: sr + 1 + rng.below(512) as u32,
            start_col: sc,
            end_col: sc + 1 + rng.below(512) as u32,
        }
    }

    fn arbitrary_instr(rng: &mut SplitMix64) -> Instr {
        match rng.below(5) {
            0 => Instr::Header(HeaderInstr {
                is_last: rng.below(2) == 1,
                des_unit: UnitId::from_code(rng.below(100) as u16).unwrap(),
                valid_length: rng.next_u64() as u32,
            }),
            1 => Instr::IomLoad(IomLoadInstr {
                is_last: rng.below(2) == 1,
                ddr_addr: rng.next_u64(),
                des_fmu: rng.below(64) as u16,
                m: rng.below(4096) as u32,
                n: rng.below(4096) as u32,
                view: arbitrary_view(rng),
            }),
            2 => Instr::IomStore(IomStoreInstr {
                is_last: rng.below(2) == 1,
                ddr_addr: rng.next_u64(),
                src_fmu: rng.below(64) as u16,
                m: rng.below(4096) as u32,
                n: rng.below(4096) as u32,
                view: arbitrary_view(rng),
            }),
            3 => Instr::Fmu(FmuInstr {
                is_last: rng.below(2) == 1,
                ping_op: FmuOp::from_code(rng.below(5) as u8).unwrap(),
                pong_op: FmuOp::from_code(rng.below(5) as u8).unwrap(),
                src_cu: rng.below(64) as u16,
                des_cu: rng.below(64) as u16,
                count: rng.next_u64() as u32,
                view: arbitrary_view(rng),
            }),
            _ => Instr::Cu(CuInstr {
                is_last: rng.below(2) == 1,
                ping_op: CuOp::from_code(rng.below(3) as u8).unwrap(),
                pong_op: CuOp::from_code(rng.below(3) as u8).unwrap(),
                src_fmu: rng.below(64) as u16,
                des_fmu: rng.below(64) as u16,
                count: rng.next_u64() as u32,
                m: rng.below(1024) as u32,
                k: rng.below(1024) as u32,
                n: rng.below(1024) as u32,
            }),
        }
    }

    #[test]
    fn prop_encode_decode_roundtrip() {
        Cases::new(500).run(|rng| {
            let n = rng.range(1, 20);
            let instrs: Vec<Instr> = (0..n).map(|_| arbitrary_instr(rng)).collect();
            let bytes = encode_stream(&instrs);
            let back = decode_stream(&bytes).expect("decode");
            assert_eq!(instrs, back);
        });
    }

    #[test]
    fn decode_rejects_bad_opcode() {
        let err = decode_stream(&[0xFF, 0x00]).unwrap_err();
        assert_eq!(err, DecodeError::BadOpcode(0xFF, 0));
    }

    #[test]
    fn decode_rejects_truncation() {
        let instrs = vec![Instr::Header(HeaderInstr {
            is_last: true,
            des_unit: UnitId::Cu(3),
            valid_length: 9,
        })];
        let mut bytes = encode_stream(&instrs);
        bytes.pop();
        assert!(matches!(decode_stream(&bytes), Err(DecodeError::Truncated(_))));
    }

    #[test]
    fn decode_rejects_bad_fmu_op() {
        // Craft an FMU word with ping_op code 7.
        let bytes = vec![0x04, 0x00, 0x07, 0x00];
        assert!(matches!(decode_stream(&bytes), Err(DecodeError::BadField("ping_op"))));
    }

    #[test]
    fn instruction_size_budget() {
        // The paper notes only 16 KB of AIE instruction memory; FILCO
        // instruction words must stay tiny ("a few bytes"). Assert every
        // word encodes under 40 bytes.
        let mut rng = SplitMix64::new(3);
        for _ in 0..100 {
            let i = arbitrary_instr(&mut rng);
            let mut out = Vec::new();
            encode_into(&i, &mut out);
            assert!(out.len() <= 40, "{i:?} encoded to {} bytes", out.len());
        }
    }
}
