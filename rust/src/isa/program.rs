//! Per-unit instruction streams ("the ready-to-run binary files" the
//! FILCO framework generates) plus the unit addressing scheme.

use super::words::Instr;

/// Addressable function units in the data plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum UnitId {
    IomLoader,
    IomStorer,
    Fmu(u16),
    Cu(u16),
}

impl UnitId {
    /// Compact numeric code used by the binary encoding: 0, 1, then FMUs
    /// at 2..2+N, CUs at 1024..1024+M.
    pub fn code(self) -> u16 {
        match self {
            UnitId::IomLoader => 0,
            UnitId::IomStorer => 1,
            UnitId::Fmu(i) => 2 + i,
            UnitId::Cu(i) => 1024 + i,
        }
    }

    pub fn from_code(c: u16) -> Option<Self> {
        match c {
            0 => Some(UnitId::IomLoader),
            1 => Some(UnitId::IomStorer),
            c if (2..1024).contains(&c) => Some(UnitId::Fmu(c - 2)),
            c => c.checked_sub(1024).map(UnitId::Cu),
        }
    }
}

impl std::fmt::Display for UnitId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UnitId::IomLoader => write!(f, "IOM.L"),
            UnitId::IomStorer => write!(f, "IOM.S"),
            UnitId::Fmu(i) => write!(f, "FMU{i}"),
            UnitId::Cu(i) => write!(f, "CU{i}"),
        }
    }
}

/// A complete FILCO program: one instruction stream per function unit.
/// Streams are executed in order by each unit's decoder; the control
/// plane interleaves dispatch using header words (encode.rs).
#[derive(Debug, Clone, Default)]
pub struct Program {
    streams: Vec<(UnitId, Vec<Instr>)>,
}

impl Program {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append an instruction to `unit`'s stream (creating it if needed).
    pub fn push(&mut self, unit: UnitId, instr: Instr) {
        if let Some((_, s)) = self.streams.iter_mut().find(|(u, _)| *u == unit) {
            s.push(instr);
        } else {
            self.streams.push((unit, vec![instr]));
        }
    }

    pub fn stream(&self, unit: UnitId) -> &[Instr] {
        self.streams
            .iter()
            .find(|(u, _)| *u == unit)
            .map(|(_, s)| s.as_slice())
            .unwrap_or(&[])
    }

    pub fn units(&self) -> impl Iterator<Item = UnitId> + '_ {
        self.streams.iter().map(|(u, _)| *u)
    }

    pub fn total_len(&self) -> usize {
        self.streams.iter().map(|(_, s)| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Mark the final instruction of every stream `is_last` (the units'
    /// while(1) decoders stop on it).
    pub fn seal(&mut self) {
        for (_, s) in &mut self.streams {
            if let Some(last) = s.last_mut() {
                match last {
                    Instr::Header(i) => i.is_last = true,
                    Instr::IomLoad(i) => i.is_last = true,
                    Instr::IomStore(i) => i.is_last = true,
                    Instr::Fmu(i) => i.is_last = true,
                    Instr::Cu(i) => i.is_last = true,
                }
            }
        }
    }

    /// Every stream must terminate with `is_last` to be executable.
    pub fn validate(&self) -> Result<(), String> {
        for (u, s) in &self.streams {
            match s.last() {
                None => return Err(format!("{u}: empty stream")),
                Some(i) if !i.is_last() => {
                    return Err(format!("{u}: stream not sealed (missing is_last)"))
                }
                _ => {}
            }
            // No is_last in the middle.
            for (idx, i) in s[..s.len() - 1].iter().enumerate() {
                if i.is_last() {
                    return Err(format!("{u}: is_last at {idx} before end of stream"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::words::*;

    fn cu_nop() -> Instr {
        Instr::Cu(CuInstr {
            is_last: false,
            ping_op: CuOp::Idle,
            pong_op: CuOp::Idle,
            src_fmu: 0,
            des_fmu: 0,
            count: 0,
            m: 0,
            k: 0,
            n: 0,
        })
    }

    #[test]
    fn unit_code_roundtrip() {
        for u in [
            UnitId::IomLoader,
            UnitId::IomStorer,
            UnitId::Fmu(0),
            UnitId::Fmu(41),
            UnitId::Cu(0),
            UnitId::Cu(7),
        ] {
            assert_eq!(UnitId::from_code(u.code()), Some(u));
        }
    }

    #[test]
    fn push_and_stream() {
        let mut p = Program::new();
        p.push(UnitId::Cu(0), cu_nop());
        p.push(UnitId::Cu(0), cu_nop());
        p.push(UnitId::Cu(1), cu_nop());
        assert_eq!(p.stream(UnitId::Cu(0)).len(), 2);
        assert_eq!(p.stream(UnitId::Cu(1)).len(), 1);
        assert_eq!(p.stream(UnitId::Cu(2)).len(), 0);
        assert_eq!(p.total_len(), 3);
    }

    #[test]
    fn seal_then_validate() {
        let mut p = Program::new();
        p.push(UnitId::Cu(0), cu_nop());
        p.push(UnitId::Cu(0), cu_nop());
        assert!(p.validate().is_err());
        p.seal();
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validate_rejects_mid_stream_last() {
        let mut p = Program::new();
        let mut first = cu_nop();
        if let Instr::Cu(i) = &mut first {
            i.is_last = true;
        }
        p.push(UnitId::Cu(0), first);
        p.push(UnitId::Cu(0), cu_nop());
        p.seal();
        assert!(p.validate().is_err());
    }
}
