//! # FILCO — Flexible Composing Architecture with Real-Time Reconfigurability
//!
//! Full-system reproduction of the FILCO paper (DAC 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the FILCO coordinator: ISA ([`isa`]), platform
//!   & DDR models ([`platform`]), architecture configuration ([`arch`]),
//!   a cycle-approximate fabric simulator ([`sim`]), analytical
//!   performance models ([`analytical`]) with CHARM/RSN baselines
//!   ([`baseline`]), the two-stage DSE with an in-house MILP
//!   branch-and-bound and a genetic algorithm ([`dse`]), the DNN workload
//!   zoo ([`workload`]), instruction generation + serving
//!   ([`coordinator`], [`codegen`]), the multi-tenant live-serving
//!   subsystem ([`serve`]: bounded tenant queues with admission
//!   control, a worker per fabric partition, a backlog-driven
//!   re-composition policy and a DSE schedule cache) and the PJRT
//!   runtime that executes AOT-compiled JAX/Pallas artifacts
//!   ([`runtime`]; native fallback without the `pjrt` feature).
//! * **L2 (python/compile/model.py)** — JAX compute graphs (BERT, MLP,
//!   bucketed MM) that call the L1 kernel; lowered once to HLO text.
//! * **L1 (python/compile/kernels/flexmm.py)** — the Pallas
//!   flexible-tile MM kernel (the paper's flexible AIE programming).
//!
//! Python never runs on the request path: `make artifacts` AOT-compiles
//! everything; the Rust binary is self-contained afterwards.
//!
//! **Where to start reading:** `ARCHITECTURE.md` at the repository
//! root maps the paper section by section onto these modules (Sec. III
//! composing fabric → [`arch`]/[`coordinator`], Sec. IV analytical
//! model + two-stage DSE → [`analytical`]/[`dse`], the ISA → [`isa`],
//! evaluation figures → `rust/benches/fig*`), walks the serve
//! subsystem's data flow (queue → policy → scheduler/sim → report)
//! including the cursor/interleaver lifecycle, and documents the
//! `filco serve` CLI end to end.

pub mod analytical;
pub mod arch;
pub mod baseline;
pub mod codegen;
pub mod coordinator;
pub mod dse;
pub mod isa;
pub mod platform;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
