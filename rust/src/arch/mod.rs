//! FILCO architecture configuration (paper §2.1, Fig 2).
//!
//! *Static parameters* — fixed before compilation (§2.5): the number and
//! capacity of FMUs/CUs, AIEs per CU, and the stream topology. Everything
//! else (tile sizes, buffer views, FMU functionality, routing choices) is
//! a *runtime parameter* delivered via the ISA.

use crate::platform::Platform;

/// The three flexibility features ablated in Fig 10.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Features {
    /// Flexible parallelism (§2.2): runtime-flexible AIE tile sizes.
    pub fp: bool,
    /// Flexible memory functionality (§2.4): FMUs assigned to operands /
    /// results at runtime.
    pub fmf: bool,
    /// Flexible memory views (§2.3): 1-D buffers viewed as any shape.
    pub fmv: bool,
}

impl Features {
    pub const ALL: Features = Features { fp: true, fmf: true, fmv: true };
    pub const NONE: Features = Features { fp: false, fmf: false, fmv: false };
    pub const FP: Features = Features { fp: true, fmf: false, fmv: false };
    pub const FP_FMF: Features = Features { fp: true, fmf: true, fmv: false };

    pub fn label(&self) -> String {
        if *self == Features::ALL {
            return "FILCO(FP,FMF,FMV)".into();
        }
        let mut parts = Vec::new();
        if self.fp {
            parts.push("FP");
        }
        if self.fmf {
            parts.push("FMF");
        }
        if self.fmv {
            parts.push("FMV");
        }
        if parts.is_empty() {
            "FILCO(none)".into()
        } else {
            format!("FILCO({})", parts.join(","))
        }
    }
}

/// The atomic AIE operation: a 2x8x8 tiled MM packed into one VLIW op
/// (§2.2). Kept in one place; the Pallas kernel mirrors it (flexmm.py).
pub const ATOM_M: u32 = 2;
pub const ATOM_K: u32 = 8;
pub const ATOM_N: u32 = 8;

/// Maximum AIE compute tile (bounded by 32 KB local memory with double
/// buffering): 32x32x32 fp32.
pub const MAX_TILE_M: u32 = 32;
pub const MAX_TILE_K: u32 = 32;
pub const MAX_TILE_N: u32 = 32;

/// Static FILCO configuration: N FMUs, M CUs, K AIEs per CU (§2.1).
///
/// `Eq`/`Hash` (all fields are integers or flags) make a config usable
/// as part of a cache key — see [`crate::serve::cache::ScheduleCache`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct FilcoConfig {
    /// N — number of Flexible Memory Units.
    pub n_fmus: u32,
    /// M — number of Compute Units.
    pub m_cus: u32,
    /// K — AIE tiles per CU.
    pub aies_per_cu: u32,
    /// Capacity of one FMU buffer (bytes, per ping/pong half).
    pub fmu_bytes: u64,
    /// CU buffer bytes (sized to the maximum AIE tile set, block
    /// partitioned — §2.1).
    pub cu_buf_bytes: u64,
    /// Enabled flexibility features.
    pub features: Features,
}

impl FilcoConfig {
    /// Default partition of a platform: use ~96% of the AIE array in 8
    /// CUs and split PL SRAM between 16 FMUs (double-buffered) and the
    /// CU buffers.
    pub fn default_for(p: &Platform) -> Self {
        let m_cus = 8;
        let aies_per_cu = (p.aie_tiles * 24 / 25) / m_cus; // 384/8 = 48 on VCK190
        let n_fmus = 16;
        // CU buffer ("sized to match the maximum AIE tile", §2.1): a
        // block-partitioned staging area holding 8 in-flight tile
        // triples (A, B, C at 32x32x4 B), double buffered — per CU, not
        // per AIE: AIE-local memory holds the working tiles; the CU
        // buffer only decouples FMU streams from the mesh.
        let tile_triple = (32 * 32 * 3) as u64 * 4;
        let cu_buf_bytes = tile_triple * 8 * 2;
        let cu_total = cu_buf_bytes * m_cus as u64;
        let fmu_pool = p.pl_sram_bytes.saturating_sub(cu_total);
        // Each FMU holds a double buffer: capacity below is one half.
        let fmu_bytes = fmu_pool / n_fmus as u64 / 2;
        Self {
            n_fmus,
            m_cus,
            aies_per_cu,
            fmu_bytes,
            cu_buf_bytes,
            features: Features::ALL,
        }
    }

    /// Same fabric with different feature flags (Fig 10 ablation).
    pub fn with_features(mut self, f: Features) -> Self {
        self.features = f;
        self
    }

    /// Total AIE tiles used.
    pub fn aie_tiles_used(&self) -> u32 {
        self.m_cus * self.aies_per_cu
    }

    /// fp32 elements one FMU half-buffer can hold.
    pub fn fmu_elems(&self) -> u64 {
        self.fmu_bytes / 4
    }

    /// Consistency checks against the platform (static parameters must
    /// fit before "compile time").
    pub fn validate(&self, p: &Platform) -> Result<(), String> {
        if self.aie_tiles_used() > p.aie_tiles {
            return Err(format!(
                "{} AIEs used > {} available",
                self.aie_tiles_used(),
                p.aie_tiles
            ));
        }
        let sram = self.cu_buf_bytes * self.m_cus as u64 + self.fmu_bytes * 2 * self.n_fmus as u64;
        if sram > p.pl_sram_bytes {
            return Err(format!("{} B SRAM used > {} available", sram, p.pl_sram_bytes));
        }
        if self.n_fmus == 0 || self.m_cus == 0 || self.aies_per_cu == 0 {
            return Err("degenerate configuration".into());
        }
        // The fully-connected FMU<->CU stream topology (§2.1) needs
        // N*M streams each way; bound by PLIO ports * a generous mux
        // factor — flag absurd configs.
        if self.n_fmus * self.m_cus > p.plio_ports * 16 {
            return Err("stream topology exceeds routable fabric".into());
        }
        Ok(())
    }

    /// Peak fp32 FLOP/s of `cus` compute units on platform `p`.
    pub fn peak_flops(&self, p: &Platform, cus: u32) -> f64 {
        p.aie_peak_flops(cus.min(self.m_cus) * self.aies_per_cu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_fits_vck190() {
        let p = Platform::vck190();
        let c = FilcoConfig::default_for(&p);
        c.validate(&p).expect("default config must validate");
        assert_eq!(c.aie_tiles_used(), 384);
        assert_eq!(c.n_fmus, 16);
    }

    #[test]
    fn fmu_capacity_reasonable() {
        // Each FMU half-buffer should hold at least a 256x256 fp32 matrix
        // (the paper's FMV example stores 256x256 / 128x512 in one FMU).
        let c = FilcoConfig::default_for(&Platform::vck190());
        assert!(c.fmu_elems() >= 256 * 256, "fmu_elems = {}", c.fmu_elems());
    }

    #[test]
    fn validate_rejects_oversubscription() {
        let p = Platform::vck190();
        let mut c = FilcoConfig::default_for(&p);
        c.aies_per_cu = 1000;
        assert!(c.validate(&p).is_err());

        let mut c2 = FilcoConfig::default_for(&p);
        c2.fmu_bytes = p.pl_sram_bytes;
        assert!(c2.validate(&p).is_err());
    }

    #[test]
    fn feature_labels() {
        assert_eq!(Features::ALL.label(), "FILCO(FP,FMF,FMV)");
        assert_eq!(Features::FP.label(), "FILCO(FP)");
        assert_eq!(Features::NONE.label(), "FILCO(none)");
    }

    #[test]
    fn peak_flops_scales_with_cus() {
        let p = Platform::vck190();
        let c = FilcoConfig::default_for(&p);
        let one = c.peak_flops(&p, 1);
        let all = c.peak_flops(&p, c.m_cus);
        assert!((all / one - c.m_cus as f64).abs() < 1e-9);
    }

    #[test]
    fn atom_matches_kernel() {
        // Must agree with python/compile/kernels/flexmm.py ATOM_*.
        assert_eq!((ATOM_M, ATOM_K, ATOM_N), (2, 8, 8));
    }
}
