//! Transaction-level discrete-event engine executing ISA programs.
//!
//! Each function unit advances through its instruction stream; an
//! instruction *fires* when its input packets are available on the
//! inter-unit channels. The engine loops over units until quiescence:
//! either every stream is exhausted (success) or no unit can make
//! progress (deadlock — a generator bug, reported as an error with the
//! stuck unit).

use std::collections::HashMap;

use crate::isa::{CuOp, FmuOp, Instr, Program, UnitId};
use crate::platform::Platform;

use super::trace::{Event, Trace};
use super::{Fabric, SimReport};

/// A data packet on a stream channel.
#[derive(Debug, Clone, Copy)]
struct Packet {
    ready_s: f64,
    #[allow(dead_code)] // carried for trace/debug inspection
    elements: u64,
}

/// Channel key: (producer, consumer). Channels are stored indexed by
/// consumer (§Perf: `reserve` only ever scans one consumer's queues, so
/// keying the map by consumer avoids a full-map walk per attempt).
type ChanKey = (UnitId, UnitId);

pub struct Engine {
    p: Platform,
    fabric: Fabric,
    pub trace_enabled: bool,
}

struct UnitState {
    unit: UnitId,
    pc: usize,
    /// Time this unit becomes free.
    free_at: f64,
    busy: f64,
}

impl Engine {
    pub fn new(p: Platform, fabric: Fabric) -> Self {
        Self { p, fabric, trace_enabled: false }
    }

    /// Stream seconds to move `elements` fp32 over one PLIO port.
    fn stream_time(&self, elements: u64) -> f64 {
        elements as f64 * 4.0 / self.p.plio_bytes_per_sec()
    }

    /// DDR seconds for `elements` fp32 with `row_elems`-wide rows.
    fn ddr_time(&self, elements: u64, row_elems: u64) -> f64 {
        self.p.ddr.transfer_time_s(elements * 4, (row_elems * 4).max(64))
    }

    /// CU compute seconds for an m x k x n kernel launch over K AIEs.
    fn compute_time(&self, m: u32, k: u32, n: u32) -> f64 {
        let cycles = self.fabric.kernel.mm_cycles(m.max(1), k.max(1), n.max(1));
        // Macro tiles parallelise across the CU's AIEs.
        let tiles = (m.max(1).div_ceil(32) as u64)
            * (k.max(1).div_ceil(32) as u64)
            * (n.max(1).div_ceil(32) as u64);
        let aies = self.fabric.aies_per_cu.max(1) as u64;
        let rounds = tiles.div_ceil(aies);
        let per_tile = cycles / tiles as f64;
        rounds as f64 * per_tile / (self.p.aie_ghz * 1e9)
    }

    /// Execute `program`; returns the report or a deadlock diagnosis.
    pub fn run(&self, program: &Program) -> Result<SimReport, String> {
        program.validate()?;
        self.run_traced(program).map(|(r, _)| r)
    }

    /// Execute and also return the event trace.
    pub fn run_traced(&self, program: &Program) -> Result<(SimReport, Trace), String> {
        let mut units: Vec<UnitState> = program
            .units()
            .map(|u| UnitState { unit: u, pc: 0, free_at: 0.0, busy: 0.0 })
            .collect();
        // consumer -> vec of (producer, packet)
        let mut chans: HashMap<UnitId, Vec<(UnitId, Packet)>> = HashMap::new();
        let mut trace = Trace::default();
        let mut ddr_in = 0u64;
        let mut ddr_out = 0u64;
        let mut executed = 0u64;

        // Two-phase packet acquisition: `reserve` finds the earliest
        // `count` packets matching the predicate WITHOUT consuming them;
        // `commit` removes a reservation. An instruction only consumes
        // once ALL of its inputs are reservable — otherwise nothing is
        // touched (consuming eagerly would drop packets on a partially
        // ready instruction and deadlock the fabric).
        type Reservation = Vec<(UnitId, usize)>;
        fn reserve(
            chans: &HashMap<UnitId, Vec<(UnitId, Packet)>>,
            consumer: UnitId,
            pred: impl Fn(UnitId) -> bool,
            count: usize,
            taken: &Reservation,
        ) -> Option<(Reservation, f64)> {
            let queue = chans.get(&consumer)?;
            let mut picks: Reservation = Vec::with_capacity(count);
            let mut ready = 0.0f64;
            for _ in 0..count {
                let mut best: Option<(usize, f64)> = None;
                for (i, (producer, pkt)) in queue.iter().enumerate() {
                    if !pred(*producer) {
                        continue;
                    }
                    if picks.iter().chain(taken.iter()).any(|&(pk, pi)| pk == consumer && pi == i)
                    {
                        continue;
                    }
                    if best.is_none() || pkt.ready_s < best.unwrap().1 {
                        best = Some((i, pkt.ready_s));
                    }
                }
                let (idx, r) = best?;
                ready = ready.max(r);
                picks.push((consumer, idx));
            }
            Some((picks, ready))
        }
        fn commit(chans: &mut HashMap<UnitId, Vec<(UnitId, Packet)>>, mut res: Reservation) {
            // Remove per queue in descending index order so indices stay
            // valid during removal.
            res.sort_by(|a, b| b.1.cmp(&a.1));
            for (key, idx) in res {
                chans.get_mut(&key).unwrap().remove(idx);
            }
        }

        loop {
            let mut progressed = false;
            let mut all_done = true;
            for ui in 0..units.len() {
                let unit = units[ui].unit;
                let stream = program.stream(unit);
                if units[ui].pc >= stream.len() {
                    continue;
                }
                all_done = false;
                let instr = &stream[units[ui].pc];

                // Attempt to fire the instruction.
                let fired: Option<(f64, f64)> = match instr {
                    Instr::Header(_) => {
                        // Control-plane only; zero-time dispatch.
                        Some((units[ui].free_at, units[ui].free_at))
                    }
                    Instr::IomLoad(l) => {
                        let elems = l.view.elements();
                        let dur = self.ddr_time(elems, l.view.cols() as u64);
                        let start = units[ui].free_at;
                        let end = start + dur;
                        chans
                            .entry(UnitId::Fmu(l.des_fmu))
                            .or_default()
                            .push((UnitId::IomLoader, Packet { ready_s: end, elements: elems }));
                        ddr_in += elems * 4;
                        Some((start, end))
                    }
                    Instr::IomStore(s) => {
                        // Wait for the FMU's drain packet.
                        match reserve(
                            &chans,
                            UnitId::IomStorer,
                            |prod| prod == UnitId::Fmu(s.src_fmu),
                            1,
                            &Vec::new(),
                        ) {
                            None => None,
                            Some((res, ready)) => {
                                let elems = s.view.elements();
                                commit(&mut chans, res);
                                let start = units[ui].free_at.max(ready);
                                let dur = self.ddr_time(elems, s.view.cols() as u64);
                                ddr_out += elems * 4;
                                Some((start, start + dur))
                            }
                        }
                    }
                    Instr::Fmu(f) => {
                        // Ping and pong ops run on the two buffer halves;
                        // they may overlap, so the phase duration is the
                        // max of the two op durations. All input packets
                        // are reserved first, then committed atomically.
                        let mut start = units[ui].free_at;
                        let mut durs = [0.0f64; 2];
                        let mut ok = true;
                        let mut reserved: Reservation = Vec::new();
                        let mut outputs: Vec<(ChanKey, Packet)> = Vec::new();
                        for (which, op) in [(0usize, f.ping_op), (1usize, f.pong_op)] {
                            match op {
                                FmuOp::Idle => {}
                                FmuOp::RecvFromIom => {
                                    match reserve(
                                        &chans,
                                        unit,
                                        |prod| prod == UnitId::IomLoader,
                                        1,
                                        &reserved,
                                    ) {
                                        None => {
                                            ok = false;
                                            break;
                                        }
                                        Some((res, ready)) => {
                                            reserved.extend(res);
                                            start = start.max(ready);
                                        }
                                    }
                                }
                                FmuOp::SendToCu => {
                                    let elems = f.view.elements().min(self.fabric.fmu_elems);
                                    durs[which] = durs[which].max(self.stream_time(elems));
                                    outputs.push((
                                        (unit, UnitId::Cu(f.des_cu)),
                                        Packet { ready_s: 0.0, elements: elems },
                                    ));
                                }
                                FmuOp::RecvFromCu => {
                                    match reserve(
                                        &chans,
                                        unit,
                                        |prod| prod == UnitId::Cu(f.src_cu),
                                        1,
                                        &reserved,
                                    ) {
                                        None => {
                                            ok = false;
                                            break;
                                        }
                                        Some((res, ready)) => {
                                            reserved.extend(res);
                                            start = start.max(ready);
                                        }
                                    }
                                }
                                FmuOp::SendToIom => {
                                    let elems = f.view.elements();
                                    durs[which] = durs[which].max(self.stream_time(elems));
                                    outputs.push((
                                        (unit, UnitId::IomStorer),
                                        Packet { ready_s: 0.0, elements: elems },
                                    ));
                                }
                            }
                        }
                        if !ok {
                            None
                        } else {
                            commit(&mut chans, reserved);
                            let end = start + durs[0].max(durs[1]);
                            for ((producer, consumer), mut pkt) in outputs {
                                pkt.ready_s = end;
                                chans.entry(consumer).or_default().push((producer, pkt));
                            }
                            Some((start, end))
                        }
                    }
                    Instr::Cu(c) => {
                        let mut start = units[ui].free_at;
                        let mut dur = 0.0f64;
                        let mut ok = true;
                        let mut reserved: Reservation = Vec::new();
                        let mut outputs: Vec<(ChanKey, Packet)> = Vec::new();
                        for op in [c.ping_op, c.pong_op] {
                            match op {
                                CuOp::Idle => {}
                                CuOp::ComputeMm => {
                                    // Reserve `count` operand packets
                                    // destined to this CU (from any FMU).
                                    match reserve(
                                        &chans,
                                        unit,
                                        |prod| matches!(prod, UnitId::Fmu(_)),
                                        c.count as usize,
                                        &reserved,
                                    ) {
                                        None => {
                                            ok = false;
                                            break;
                                        }
                                        Some((res, ready)) => {
                                            reserved.extend(res);
                                            start = start.max(ready);
                                        }
                                    }
                                    dur += self.compute_time(c.m, c.k, c.n);
                                }
                                CuOp::WriteBack => {
                                    let elems = c.m as u64 * c.n as u64;
                                    dur += self.stream_time(elems);
                                    outputs.push((
                                        (unit, UnitId::Fmu(c.des_fmu)),
                                        Packet { ready_s: 0.0, elements: elems },
                                    ));
                                }
                            }
                        }
                        if !ok {
                            None
                        } else {
                            commit(&mut chans, reserved);
                            let end = start + dur;
                            for ((producer, consumer), mut pkt) in outputs {
                                pkt.ready_s = end;
                                chans.entry(consumer).or_default().push((producer, pkt));
                            }
                            Some((start, end))
                        }
                    }
                };

                if let Some((start, end)) = fired {
                    let st = &mut units[ui];
                    if self.trace_enabled {
                        trace.push(Event { unit, pc: st.pc, start_s: start, end_s: end });
                    }
                    st.busy += end - start;
                    st.free_at = end;
                    st.pc += 1;
                    executed += 1;
                    progressed = true;
                }
            }
            if all_done {
                break;
            }
            if !progressed {
                let stuck: Vec<String> = units
                    .iter()
                    .filter(|u| u.pc < program.stream(u.unit).len())
                    .map(|u| format!("{}@{}", u.unit, u.pc))
                    .collect();
                return Err(format!("simulator deadlock; stuck units: {}", stuck.join(", ")));
            }
        }

        let makespan_s = units.iter().map(|u| u.free_at).fold(0.0, f64::max);
        Ok((
            SimReport {
                makespan_s,
                busy: units.iter().map(|u| (u.unit, u.busy)).collect(),
                ddr_in_bytes: ddr_in,
                ddr_out_bytes: ddr_out,
                instructions: executed,
            },
            trace,
        ))
    }
}

/// Convenience constructor used across tests/benches.
pub fn default_engine() -> (Platform, Fabric) {
    let p = Platform::vck190();
    let cfg = crate::arch::FilcoConfig::default_for(&p);
    (p.clone(), Fabric::from_config(&cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{
        CuInstr, FmuInstr, IomLoadInstr, IomStoreInstr, TileView,
    };

    /// Hand-built single-MM program: load A,B -> FMU0/1 -> CU0 -> FMU2
    /// -> store.
    fn mm_program(m: u32, k: u32, n: u32) -> Program {
        let mut p = Program::new();
        let a = TileView::full(m, k);
        let b = TileView::full(k, n);
        let c = TileView::full(m, n);
        p.push(
            UnitId::IomLoader,
            Instr::IomLoad(IomLoadInstr {
                is_last: false,
                ddr_addr: 0,
                des_fmu: 0,
                m,
                n: k,
                view: a,
            }),
        );
        p.push(
            UnitId::IomLoader,
            Instr::IomLoad(IomLoadInstr {
                is_last: false,
                ddr_addr: 0x1000,
                des_fmu: 1,
                m: k,
                n,
                view: b,
            }),
        );
        p.push(
            UnitId::Fmu(0),
            Instr::Fmu(FmuInstr {
                is_last: false,
                ping_op: FmuOp::RecvFromIom,
                pong_op: FmuOp::Idle,
                src_cu: 0,
                des_cu: 0,
                count: a.elements() as u32,
                view: a,
            }),
        );
        p.push(
            UnitId::Fmu(0),
            Instr::Fmu(FmuInstr {
                is_last: false,
                ping_op: FmuOp::SendToCu,
                pong_op: FmuOp::Idle,
                src_cu: 0,
                des_cu: 0,
                count: 0,
                view: a,
            }),
        );
        p.push(
            UnitId::Fmu(1),
            Instr::Fmu(FmuInstr {
                is_last: false,
                ping_op: FmuOp::RecvFromIom,
                pong_op: FmuOp::Idle,
                src_cu: 0,
                des_cu: 0,
                count: b.elements() as u32,
                view: b,
            }),
        );
        p.push(
            UnitId::Fmu(1),
            Instr::Fmu(FmuInstr {
                is_last: false,
                ping_op: FmuOp::SendToCu,
                pong_op: FmuOp::Idle,
                src_cu: 0,
                des_cu: 0,
                count: 0,
                view: b,
            }),
        );
        p.push(
            UnitId::Cu(0),
            Instr::Cu(CuInstr {
                is_last: false,
                ping_op: CuOp::ComputeMm,
                pong_op: CuOp::WriteBack,
                src_fmu: 0,
                des_fmu: 2,
                count: 2,
                m,
                k,
                n,
            }),
        );
        p.push(
            UnitId::Fmu(2),
            Instr::Fmu(FmuInstr {
                is_last: false,
                ping_op: FmuOp::RecvFromCu,
                pong_op: FmuOp::SendToIom,
                src_cu: 0,
                des_cu: 0,
                count: 0,
                view: c,
            }),
        );
        p.push(
            UnitId::IomStorer,
            Instr::IomStore(IomStoreInstr {
                is_last: false,
                ddr_addr: 0x2000,
                src_fmu: 2,
                m,
                n,
                view: c,
            }),
        );
        p.seal();
        p
    }

    #[test]
    fn single_mm_runs_to_completion() {
        let (p, f) = default_engine();
        let r = simulate_ok(&p, &f, &mm_program(64, 64, 64));
        assert!(r.makespan_s > 0.0);
        assert_eq!(r.ddr_in_bytes, (64 * 64 + 64 * 64) * 4);
        assert_eq!(r.ddr_out_bytes, 64 * 64 * 4);
        assert_eq!(r.instructions, 9);
    }

    fn simulate_ok(p: &Platform, f: &Fabric, prog: &Program) -> SimReport {
        super::super::simulate(p, f, prog).expect("sim must not deadlock")
    }

    #[test]
    fn bigger_mm_takes_longer() {
        let (p, f) = default_engine();
        let small = simulate_ok(&p, &f, &mm_program(32, 32, 32)).makespan_s;
        let big = simulate_ok(&p, &f, &mm_program(256, 256, 256)).makespan_s;
        assert!(big > small, "big {big} small {small}");
    }

    #[test]
    fn deadlock_detected() {
        // CU waits for 2 packets but only one FMU ever sends.
        let mut prog = mm_program(16, 16, 16);
        // Remove FMU1's stream entirely by rebuilding without it.
        let mut broken = Program::new();
        for u in prog.units() {
            if u == UnitId::Fmu(1) {
                continue;
            }
            for i in prog.stream(u) {
                broken.push(u, *i);
            }
        }
        broken.seal();
        let (p, f) = default_engine();
        let err = super::super::simulate(&p, &f, &broken).unwrap_err();
        assert!(err.contains("deadlock"), "err: {err}");
        let _ = &mut prog;
    }

    #[test]
    fn utilization_bounded() {
        let (p, f) = default_engine();
        let r = simulate_ok(&p, &f, &mm_program(128, 128, 128));
        for (u, busy) in &r.busy {
            let util = busy / r.makespan_s;
            assert!((0.0..=1.0 + 1e-9).contains(&util), "{u}: util {util}");
        }
    }

    #[test]
    fn trace_records_events() {
        let (p, f) = default_engine();
        let mut eng = Engine::new(p, f);
        eng.trace_enabled = true;
        let (r, t) = eng.run_traced(&mm_program(32, 32, 32)).unwrap();
        assert_eq!(t.events.len() as u64, r.instructions);
        // Events are internally consistent.
        for e in &t.events {
            assert!(e.end_s >= e.start_s);
            assert!(e.end_s <= r.makespan_s + 1e-12);
        }
    }
}
