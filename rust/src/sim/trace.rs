//! Per-instruction event traces from the simulator (debugging +
//! utilization visualisation in the examples).

use crate::isa::UnitId;

/// One fired instruction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub unit: UnitId,
    /// Index into the unit's instruction stream.
    pub pc: usize,
    pub start_s: f64,
    pub end_s: f64,
}

/// Ordered collection of events (firing order).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<Event>,
}

impl Trace {
    pub fn push(&mut self, e: Event) {
        self.events.push(e);
    }

    /// Events of one unit, in time order.
    pub fn unit_events(&self, unit: UnitId) -> Vec<Event> {
        let mut v: Vec<Event> = self.events.iter().filter(|e| e.unit == unit).copied().collect();
        v.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
        v
    }

    /// ASCII Gantt rendering (one row per unit, `width` columns).
    pub fn gantt(&self, width: usize) -> String {
        if self.events.is_empty() {
            return String::new();
        }
        let t_max = self.events.iter().map(|e| e.end_s).fold(0.0f64, f64::max).max(1e-30);
        let mut units: Vec<UnitId> = self.events.iter().map(|e| e.unit).collect();
        units.sort();
        units.dedup();
        let mut out = String::new();
        for u in units {
            let mut row = vec![b'.'; width];
            for e in self.unit_events(u) {
                let a = ((e.start_s / t_max) * width as f64) as usize;
                let b = (((e.end_s / t_max) * width as f64).ceil() as usize).min(width);
                for c in row.iter_mut().take(b).skip(a.min(width)) {
                    *c = b'#';
                }
            }
            out.push_str(&format!("{:>6} |{}|\n", u.to_string(), String::from_utf8(row).unwrap()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_events_sorted() {
        let mut t = Trace::default();
        t.push(Event { unit: UnitId::Cu(0), pc: 1, start_s: 2.0, end_s: 3.0 });
        t.push(Event { unit: UnitId::Cu(0), pc: 0, start_s: 0.0, end_s: 1.0 });
        t.push(Event { unit: UnitId::Fmu(0), pc: 0, start_s: 0.5, end_s: 1.5 });
        let ev = t.unit_events(UnitId::Cu(0));
        assert_eq!(ev.len(), 2);
        assert!(ev[0].start_s <= ev[1].start_s);
    }

    #[test]
    fn gantt_renders_rows() {
        let mut t = Trace::default();
        t.push(Event { unit: UnitId::Cu(0), pc: 0, start_s: 0.0, end_s: 0.5 });
        t.push(Event { unit: UnitId::Fmu(1), pc: 0, start_s: 0.5, end_s: 1.0 });
        let g = t.gantt(20);
        assert!(g.contains("CU0"));
        assert!(g.contains("FMU1"));
        assert!(g.contains('#'));
    }

    #[test]
    fn empty_trace_empty_gantt() {
        assert!(Trace::default().gantt(10).is_empty());
    }
}
