//! Cycle-approximate fabric simulator — the stand-in for running FILCO's
//! generated binaries on the VCK190 board.
//!
//! The simulator executes real [`crate::isa::Program`]s (the exact
//! instruction streams the [`crate::coordinator::instrgen`] emits) over
//! a transaction-level model of the data plane:
//!
//! * **IOM** loader/storer — DDR transfers timed by the profiled
//!   bandwidth-vs-burst curve ([`crate::platform::DdrProfile`]);
//! * **FMU** — 1-D double buffers; ping/pong ops on the two halves may
//!   overlap (that's the point of the double buffer); sends are timed by
//!   the PLIO stream bandwidth;
//! * **CU** — the flexible/static AIE kernel cycle model
//!   ([`crate::analytical::aie::AieKernelModel`]) scaled over the CU's K
//!   AIEs, fed by operand packets from FMUs.
//!
//! Units communicate through timestamped packet channels mirroring the
//! pre-routed stream topology. [`engine::Engine::run`] returns a
//! [`SimReport`] with makespan, per-unit busy time and traffic counters;
//! [`trace`] captures per-instruction events.

pub mod engine;
pub mod trace;

use crate::analytical::aie::AieKernelModel;
use crate::platform::Platform;

/// Static fabric description for a simulation run.
#[derive(Debug, Clone)]
pub struct Fabric {
    pub n_fmus: u32,
    pub m_cus: u32,
    pub aies_per_cu: u32,
    /// fp32 elements per FMU buffer half.
    pub fmu_elems: u64,
    pub kernel: AieKernelModel,
}

impl Fabric {
    pub fn from_config(cfg: &crate::arch::FilcoConfig) -> Self {
        Self {
            n_fmus: cfg.n_fmus,
            m_cus: cfg.m_cus,
            aies_per_cu: cfg.aies_per_cu,
            fmu_elems: cfg.fmu_elems(),
            kernel: if cfg.features.fp { AieKernelModel::Flexible } else { AieKernelModel::Static },
        }
    }
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end time, seconds.
    pub makespan_s: f64,
    /// Busy seconds per unit (same indexing as `UnitId::code()` order:
    /// loader, storer, FMUs, CUs).
    pub busy: Vec<(crate::isa::UnitId, f64)>,
    /// Total DDR bytes moved in / out.
    pub ddr_in_bytes: u64,
    pub ddr_out_bytes: u64,
    /// Executed instruction count.
    pub instructions: u64,
}

impl SimReport {
    /// Utilization of a unit over the makespan.
    pub fn utilization(&self, unit: crate::isa::UnitId) -> f64 {
        if self.makespan_s <= 0.0 {
            return 0.0;
        }
        self.busy
            .iter()
            .find(|(u, _)| *u == unit)
            .map(|(_, b)| b / self.makespan_s)
            .unwrap_or(0.0)
    }

    /// Aggregate CU utilization (mean over CUs that appear).
    pub fn mean_cu_utilization(&self) -> f64 {
        let cus: Vec<f64> = self
            .busy
            .iter()
            .filter(|(u, _)| matches!(u, crate::isa::UnitId::Cu(_)))
            .map(|(_, b)| b / self.makespan_s.max(1e-30))
            .collect();
        if cus.is_empty() {
            0.0
        } else {
            cus.iter().sum::<f64>() / cus.len() as f64
        }
    }
}

/// Convenience: simulate a program on a fabric/platform pair.
pub fn simulate(
    p: &Platform,
    fabric: &Fabric,
    program: &crate::isa::Program,
) -> Result<SimReport, String> {
    engine::Engine::new(p.clone(), fabric.clone()).run(program)
}
