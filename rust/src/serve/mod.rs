//! Multi-tenant serving on a live re-composable fabric — the paper's
//! "reconfigured in real-time and flexibly composed into a unified or
//! multiple independent accelerators" exercised *online*, not as an
//! offline what-if.
//!
//! See `ARCHITECTURE.md` at the repository root for the full
//! paper-to-code map and a data-flow walkthrough of this subsystem
//! (queues → engine ← clock; drivers as shells), including the
//! cursor/interleaver lifecycle diagram.
//!
//! # One engine, two clocks
//!
//! The fabric exists once, so it is modelled once: the
//! [`FabricEngine`] is a deterministic state machine over *fabric
//! time* that owns the partitions, the in-flight [`BatchCursor`]s and
//! per-partition [`Interleaver`]s, the admission state (queue depths
//! and fabric-time [`TokenBucket`]s), the schedule cache handle, and
//! every composition transition — resplit, mid-DAG preemption, pack,
//! unpack — applied through one [`Transition`] enum at one site.
//! What differs between deployment modes is only the [`Clock`] that
//! paces the driver loop:
//!
//! * [`sim`] drains the engine on a [`VirtualClock`] (instant jumps):
//!   deterministic what-if runs comparing unified time-sharing vs. a
//!   static equal split vs. dynamic re-composition on the same trace;
//! * [`scheduler`] drives the *same* engine from worker thread shells
//!   on a [`WallClock`] (deadline-paced sleeps), with producers
//!   pushing live requests into the engine's queues — in any of the
//!   three compositions ([`LiveMode`], `filco serve --strategy`).
//!
//! All three strategies are engine compositions — the *unified*
//! baseline included: [`Transition::Unify`] puts every tenant into a
//! permanent round-robin group on the whole-fabric slice, reproducing
//! the retired closed-form unified model bit-for-bit (oracle in
//! `rust/tests/serve_engine.rs`). Unified-vs-partitioned comparisons
//! therefore share one cost model and one event-trace format.
//!
//! Engine decisions never read the wall clock, so a live run replays
//! the simulator's event trace bit-for-bit — "live and sim agree" is
//! structural, not a test-enforced convention (though
//! `rust/tests/serve_engine.rs` enforces it anyway).
//!
//! # The cursor execution model
//!
//! FILCO's runtime parameters arrive per layer via instruction decode,
//! so a re-composition does not have to wait for a whole DAG to drain.
//! The engine therefore accounts execution as a *steppable timeline*,
//! not an opaque per-batch blob:
//!
//! * a slice's cached schedule exposes per-layer
//!   [`LayerStep`](crate::dse::LayerStep)s with cumulative offsets;
//! * an in-flight batch is a [`BatchCursor`] walking that timeline once
//!   per request (batch amortization applied); undisturbed, the walk
//!   reproduces the batch-atomic closed form [`batch_fabric_s`]
//!   bit-for-bit;
//! * when the backlog policy re-splits the fabric, tenants whose
//!   projected saving clears the switch-cost margin
//!   ([`should_preempt`], fed by *exact* cursor positions in both
//!   drivers) are *preempted at the next layer boundary*: the cursor
//!   pays `switch_cost_s` mid-DAG and resumes the remaining layers on
//!   the new slice's cached schedule;
//! * light tenants that together fit one partition ([`should_pack`]
//!   over first-fit-decreasing [`pack_groups`]) are *packed*: their
//!   cursors time-multiplex one slice through an [`Interleaver`], a
//!   quantum of layer steps at a time, paying `switch_cost_s` per
//!   context swap — fabric-time conservation holds exactly. A member
//!   caught mid-batch is handed off *mid-flight*: its cursor is
//!   checkpointed at a layer boundary and resumed inside the shared
//!   partition's interleaver, losing no fabric time.
//!
//! # Layering
//!
//! * [`queue`] — bounded MPMC request queues with admission control
//!   ([`PushError`] classifications; monotonic-deadline batch pops).
//! * [`tenant`] — tenant specs (queue depth, max batch, optional
//!   [`RateLimit`], [`SloClass`] latency/throughput tiers), the
//!   [`BatchCursor`] / [`TokenBucket`] building blocks, and
//!   deterministic Poisson / phased traffic generators.
//! * [`scenario`] — the scenario zoo: named, seeded, scale-free
//!   workload shapes ([`Shape`]: steady / diurnal / flash-crowd /
//!   ramp / epoch-locked bursts), per-tenant SLO deadlines, trace
//!   replay ([`replay_arrivals`]), and a JSON codec for
//!   `filco serve --scenario-file`.
//! * [`interleave`] — the per-partition [`Interleaver`]: two or more
//!   cursors on one slice, swap charges, exact conservation.
//! * [`cache`] — the schedule cache: two-stage DSE results memoized on
//!   `(FilcoConfig, Dag)` with their step timelines, persistable to
//!   disk (JSON) so restarts skip the GA/MILP entirely.
//! * [`policy`] — pure decision terms: backlog-time → partition-weight
//!   mapping with hysteresis, the preemption benefit and the
//!   migration-discounted in-flight signal ([`inflight_backlog_s`]),
//!   the packing fit/amortization terms and the multi-way
//!   first-fit-decreasing group proposal ([`pack_groups`]).
//! * [`engine`] — the deterministic execution core shared by both
//!   drivers (see above).
//! * [`cluster`] — M boards, one engine each: share-driven first-fit
//!   placement, per-epoch imbalance-driven cross-board migration
//!   (lossless mid-DAG cursor checkpointing through one
//!   [`ClusterTransition`] site), and the order-stable deterministic
//!   merge of per-board event streams. A cluster of one board runs
//!   bit-for-bit identical to the bare engine.
//! * [`clock`] — the [`Clock`] trait with its [`VirtualClock`] and
//!   [`WallClock`]/[`Pacer`] implementations.
//! * [`sim`] — the virtual-time driver and the [`ServeReport`]
//!   comparison harness.
//! * [`scheduler`] — the live driver: producer ingress, worker and
//!   policy thread shells, wall-clock latency accounting,
//!   [`LiveReport`].
//! * [`telemetry`] — observability over everything above: the
//!   persistent [`EngineEvent`] trace format ([`TraceSink`] /
//!   [`RecordedTrace`], JSONL, replayable bit-for-bit into the
//!   originating [`ServeReport`]), the per-epoch metrics timeline
//!   ([`TimelineReport`]), and step-loop profiling ([`StepProfile`]).
//!
//! The single-model serving leader ([`Server`]) and its building blocks
//! ([`Servable`], [`Request`], [`RequestQueue`], [`Metrics`]) are
//! re-exported here: the serve layer generalizes them to N tenants.
#![warn(missing_docs)]

pub mod cache;
pub mod clock;
pub mod cluster;
pub mod engine;
pub mod interleave;
pub mod policy;
pub mod queue;
pub mod scenario;
pub mod scheduler;
pub mod sim;
pub mod telemetry;
pub mod tenant;

pub use crate::coordinator::metrics::{LatencyHistogram, Metrics};
pub use crate::coordinator::serving::{Request, RequestQueue, Response, Servable, Server};

pub use cache::{
    dag_fingerprint, BackgroundSolver, CachedSchedule, DseTuning, ScheduleCache, SolveRequest,
};
pub use clock::{Clock, Pacer, VirtualClock, WallClock};
pub use cluster::{
    first_fit_placement, merge_board_streams, BoardId, ClusterPolicy, ClusterReport,
    ClusterTransition, FabricCluster,
};
pub use engine::{EngineEvent, FabricEngine, Transition};
pub use interleave::{InterleaveEvent, Interleaver};
pub use policy::{
    backlog_weights, inflight_backlog_s, pack_groups, pack_quantum_s, reduce_weights, should_pack,
    should_preempt, should_resplit, should_unpack, slo_backlog_boost, PolicyConfig,
};
pub use queue::{BoundedQueue, PushError};
pub use scenario::{
    builtin, builtin_names, generate_arrivals, model_dag, replay_arrivals, MaterializedScenario,
    ScenarioSpec, ScenarioTenant, Shape,
};
pub use scheduler::{
    FabricScheduler, LiveConfig, LiveMode, LiveReport, LiveRequest, SchedulerSnapshot,
    TenantReport,
};
pub use sim::{
    equal_split_per_request, simulate, simulate_cluster, simulate_cluster_traced,
    simulate_instrumented, simulate_traced, Scenario, ServeReport, Strategy,
};
pub use telemetry::{
    event_from_json, event_to_json, report_from_json, report_to_json, trace_to_jsonl, write_trace,
    DecisionKind, DecisionSample, EpochSample, LockMeter, RecordedTrace, RunTelemetry,
    StallStats, StepProfile, TelemetryConfig, TenantSample, TimelineReport, TraceSink,
    TRACE_VERSION,
};
pub use tenant::{
    batch_fabric_s, phased_trace, poisson_trace, Arrival, BatchCursor, CursorCheckpoint,
    RateLimit, RetargetError, SloClass, StepEvent, TenantSpec, TokenBucket,
};
