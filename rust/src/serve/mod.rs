//! Multi-tenant serving on a live re-composable fabric — the paper's
//! "reconfigured in real-time and flexibly composed into a unified or
//! multiple independent accelerators" exercised *online*, not as an
//! offline what-if.
//!
//! See `ARCHITECTURE.md` at the repository root for the full
//! paper-to-code map and a data-flow walkthrough of this subsystem
//! (queue → policy → scheduler/sim → report), including the
//! cursor/interleaver lifecycle diagram.
//!
//! # The cursor execution model
//!
//! FILCO's runtime parameters arrive per layer via instruction decode,
//! so a re-composition does not have to wait for a whole DAG to drain.
//! The serve layer therefore accounts execution as a *steppable
//! timeline*, not an opaque per-batch blob:
//!
//! * a slice's cached schedule exposes per-layer
//!   [`LayerStep`](crate::dse::LayerStep)s with cumulative offsets;
//! * an in-flight batch is a [`BatchCursor`] walking that timeline once
//!   per request (batch amortization applied); undisturbed, the walk
//!   reproduces the batch-atomic closed form [`batch_fabric_s`]
//!   bit-for-bit;
//! * when the backlog policy re-splits the fabric, tenants whose
//!   projected saving clears the switch-cost margin
//!   ([`should_preempt`]) are *preempted at the next layer boundary*:
//!   the cursor pays `switch_cost_s` mid-DAG and resumes the remaining
//!   layers on the new slice's cached schedule. Everyone else drains
//!   on the old composition and switches at the batch boundary;
//! * two low-backlog tenants that together fit one partition
//!   ([`should_pack`]) are *packed*: their cursors time-multiplex one
//!   slice through an [`Interleaver`], a quantum of layer steps at a
//!   time, paying `switch_cost_s` per context swap — fabric-time
//!   conservation holds exactly (interleaved walk == solo walks + swap
//!   charges, bit-for-bit), and the freed partition goes to whoever is
//!   actually backlogged.
//!
//! The live threaded scheduler and the virtual-time simulator share
//! this one execution model, so simulated what-ifs and live runs agree
//! by construction.
//!
//! # Layering
//!
//! * [`queue`] — bounded MPMC request queues with admission control
//!   (single lock for items + closed flag; [`PushError::Throttled`]
//!   for fabric-time rate limits).
//! * [`tenant`] — tenant specs (queue depth, max batch, optional
//!   [`RateLimit`]), the [`BatchCursor`] / [`TokenBucket`] building
//!   blocks, and deterministic Poisson / phased traffic generators.
//! * [`interleave`] — the per-partition [`Interleaver`]: two or more
//!   cursors on one slice, swap charges, exact conservation.
//! * [`cache`] — the schedule cache: two-stage DSE results memoized on
//!   `(FilcoConfig, Dag)` with their step timelines, persistable to
//!   disk (JSON) so restarts skip the GA/MILP entirely.
//! * [`policy`] — backlog-time → partition-weight mapping with
//!   hysteresis, the preemption-benefit term weighing remaining
//!   in-flight work against the mid-DAG switch cost, and the packing
//!   fit/amortization terms ([`should_pack`] / [`should_unpack`]).
//! * [`sim`] — deterministic virtual-time serving simulator comparing
//!   unified time-sharing vs. a static equal split vs. dynamic
//!   re-composition (preemptive or batch-boundary, packed or not) on
//!   the same trace.
//! * [`scheduler`] — the live threaded scheduler: one worker per
//!   tenant stepping an interleaver layer-by-layer (solo tenants are
//!   the one-slot case), a policy thread driving
//!   [`Reconfigurator::split`] from observed queue depths and in-flight
//!   remaining work, preemptions landing at worker step boundaries,
//!   pack/unpack transitions landing at batch boundaries, switch costs
//!   charged into the per-tenant fabric-time accounting.
//!
//! The single-model serving leader ([`Server`]) and its building blocks
//! ([`Servable`], [`Request`], [`RequestQueue`], [`Metrics`]) are
//! re-exported here: the serve layer generalizes them to N tenants.
//!
//! [`Reconfigurator::split`]: crate::coordinator::reconfig::Reconfigurator::split
#![warn(missing_docs)]

pub mod cache;
pub mod interleave;
pub mod policy;
pub mod queue;
pub mod scheduler;
pub mod sim;
pub mod tenant;

pub use crate::coordinator::metrics::{LatencyHistogram, Metrics};
pub use crate::coordinator::serving::{Request, RequestQueue, Response, Servable, Server};

pub use cache::{dag_fingerprint, CachedSchedule, ScheduleCache};
pub use interleave::{InterleaveEvent, Interleaver};
pub use policy::{
    backlog_weights, pack_candidates, pack_quantum_s, reduce_weights, should_pack,
    should_preempt, should_resplit, should_unpack, PolicyConfig,
};
pub use queue::{BoundedQueue, PushError};
pub use scheduler::{FabricScheduler, LiveConfig, LiveReport, LiveRequest, TenantReport};
pub use sim::{equal_split_per_request, simulate, Scenario, ServeReport, Strategy};
pub use tenant::{
    batch_fabric_s, phased_trace, poisson_trace, Arrival, BatchCursor, CursorCheckpoint,
    RateLimit, StepEvent, TenantSpec, TokenBucket,
};
