//! Multi-tenant serving on a live re-composable fabric — the paper's
//! "reconfigured in real-time and flexibly composed into a unified or
//! multiple independent accelerators" exercised *online*, not as an
//! offline what-if.
//!
//! Layering:
//!
//! * [`queue`] — bounded MPMC request queues with admission control
//!   (single lock for items + closed flag).
//! * [`tenant`] — tenant specs, the batch fabric-time model, and
//!   deterministic Poisson / phased traffic generators.
//! * [`cache`] — the schedule cache: two-stage DSE results memoized on
//!   `(FilcoConfig, Dag)`, so re-partitioning never re-runs the GA/MILP
//!   on the hot path once a composition has been seen.
//! * [`policy`] — backlog-time → partition-weight mapping with
//!   hysteresis; decides when a re-split pays for its switch cost.
//! * [`sim`] — deterministic virtual-time serving simulator comparing
//!   unified time-sharing vs. a static equal split vs. dynamic
//!   re-composition on the same trace.
//! * [`scheduler`] — the live threaded scheduler: one worker per
//!   tenant owning its current [`Partition`], a policy thread driving
//!   [`Reconfigurator::split`] from observed queue depths, switch
//!   costs charged into the per-tenant fabric-time accounting.
//!
//! The single-model serving leader ([`Server`]) and its building blocks
//! ([`Servable`], [`Request`], [`RequestQueue`], [`Metrics`]) are
//! re-exported here: the serve layer generalizes them to N tenants.
//!
//! [`Partition`]: crate::coordinator::reconfig::Partition
//! [`Reconfigurator::split`]: crate::coordinator::reconfig::Reconfigurator::split

pub mod cache;
pub mod policy;
pub mod queue;
pub mod scheduler;
pub mod sim;
pub mod tenant;

pub use crate::coordinator::metrics::{LatencyHistogram, Metrics};
pub use crate::coordinator::serving::{Request, RequestQueue, Response, Servable, Server};

pub use cache::{dag_fingerprint, CachedSchedule, ScheduleCache};
pub use policy::{backlog_weights, reduce_weights, should_resplit, PolicyConfig};
pub use queue::{BoundedQueue, PushError};
pub use scheduler::{FabricScheduler, LiveConfig, LiveReport, LiveRequest, TenantReport};
pub use sim::{equal_split_per_request, simulate, Scenario, ServeReport, Strategy};
pub use tenant::{batch_fabric_s, phased_trace, poisson_trace, Arrival, TenantSpec};
