//! Tenants and traffic: who is being served, how requests arrive, and
//! the [`BatchCursor`] — the steppable per-batch execution state that
//! replaced the old batch-atomic `batch_fabric_s` accounting in both
//! the live scheduler and the virtual-time simulator.

use std::collections::VecDeque;
use std::sync::Arc;

use super::cache::CachedSchedule;
use super::queue::PushError;
use crate::util::rng::SplitMix64;
use crate::workload::Dag;

/// Weight-reload amortization within a batch: requests after the first
/// reuse the operand layouts already resident in the FMUs, so they pay
/// this fraction of the full schedule makespan. Applies identically to
/// every composition strategy, so comparisons are unaffected by it.
pub const BATCH_AMORTIZATION: f64 = 0.9;

/// Fabric seconds a batch of `batch` requests takes on a slice whose
/// single-request schedule makespan is `per_request_s` — the closed
/// form a [`BatchCursor`] walks incrementally. An undisturbed cursor
/// reproduces this value bit-for-bit.
pub fn batch_fabric_s(per_request_s: f64, batch: usize) -> f64 {
    if batch == 0 {
        return 0.0;
    }
    per_request_s * (1.0 + BATCH_AMORTIZATION * (batch - 1) as f64)
}

/// One retired layer step of an in-flight batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepEvent {
    /// DAG layer index that retired.
    pub layer: usize,
    /// Candidate mode the schedule chose for it.
    pub mode: usize,
    /// FMUs the step occupied.
    pub fmus: u32,
    /// CUs the step occupied.
    pub cus: u32,
    /// Fabric seconds this step consumed.
    pub dur_s: f64,
    /// Total fabric seconds the batch has consumed after this step
    /// (monotone; includes any mid-DAG switch charges).
    pub consumed_s: f64,
}

/// Why a [`BatchCursor::retarget`] was refused: the proposed schedule
/// walks a different timeline than the cursor's current one.
///
/// A cursor's position is a *step index* into its schedule's per-layer
/// timeline. Re-solving the same DAG for a different slice always
/// yields the same step count (one step per layer), so a mismatch
/// means the caller handed over a schedule for a different DAG — and
/// re-basing onto it would silently mis-position the cursor (the old
/// code clamped `step` to the new last step, shrinking the
/// remaining-work accounting and misaligning the segment anchor). The
/// cursor is left untouched when this error is returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetargetError {
    /// Steps per request on the cursor's current schedule.
    pub expected_steps: usize,
    /// Steps per request on the schedule the caller proposed.
    pub got_steps: usize,
}

impl std::fmt::Display for RetargetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "retarget refused: proposed schedule has {} steps per request, cursor walks {} \
             (different DAG timeline)",
            self.got_steps, self.expected_steps
        )
    }
}

impl std::error::Error for RetargetError {}

/// Saved [`BatchCursor`] state. Resuming restores the cursor exactly
/// (same schedule, same position, same consumed time) — losslessness is
/// what lets a worker park an in-flight batch across a re-composition.
#[derive(Debug, Clone)]
pub struct CursorCheckpoint {
    sched: Arc<CachedSchedule>,
    batch: usize,
    req: usize,
    step: usize,
    base_s: f64,
    seg_req: usize,
    seg_step: usize,
    hwm_s: f64,
}

/// Steppable execution state of one batch on one fabric slice.
///
/// A batch of `b` requests traverses the slice's [`CachedSchedule`]
/// timeline `b` times (requests after the first pay
/// [`BATCH_AMORTIZATION`] of each step). The cursor yields one
/// [`StepEvent`] per layer step and tracks consumed fabric time in
/// closed form against the schedule's cumulative offsets, so:
///
/// * an undisturbed run consumes exactly [`batch_fabric_s`] — the
///   pre-cursor batch-atomic accounting, bit-for-bit;
/// * [`Self::retarget`] re-bases the *remaining* steps onto a different
///   slice's schedule at a layer boundary (mid-DAG preemption),
///   optionally charging the reconfiguration switch cost into the
///   batch's timeline;
/// * [`Self::checkpoint`] / [`Self::resume`] park and restore the state
///   losslessly.
#[derive(Debug, Clone)]
pub struct BatchCursor {
    sched: Arc<CachedSchedule>,
    batch: usize,
    /// Requests fully retired.
    req: usize,
    /// Steps retired within the current request.
    step: usize,
    /// Fabric time consumed before the current segment began (earlier
    /// segments on previous schedules, plus mid-DAG switch charges).
    base_s: f64,
    /// Position at which the current segment began.
    seg_req: usize,
    seg_step: usize,
    /// High-water mark on emitted consumed values (guards monotonicity
    /// across the per-request closed-form seams).
    hwm_s: f64,
}

impl BatchCursor {
    /// Cursor at the start of a `batch`-request walk over `sched`.
    /// Single-threaded: callers (one worker thread, or the simulator)
    /// own the cursor exclusively; no internal locking.
    pub fn new(sched: Arc<CachedSchedule>, batch: usize) -> Self {
        Self { sched, batch, req: 0, step: 0, base_s: 0.0, seg_req: 0, seg_step: 0, hwm_s: 0.0 }
    }

    /// Closed-form fabric time from batch start to position `(req,
    /// step)` under schedule `sched`: completed requests at the
    /// batch-amortized rate, plus the current request's progress scaled
    /// by its amortization factor.
    fn elapsed_for(sched: &CachedSchedule, batch: usize, req: usize, step: usize) -> f64 {
        let done = req.min(batch);
        let scale = if done == 0 { 1.0 } else { BATCH_AMORTIZATION };
        let within = if step == 0 {
            0.0
        } else {
            sched.steps[(step - 1).min(sched.steps.len() - 1)].end_s
        };
        batch_fabric_s(sched.per_request_s, done) + scale * within
    }

    fn elapsed_at(&self, req: usize, step: usize) -> f64 {
        Self::elapsed_for(&self.sched, self.batch, req, step)
    }

    /// Number of requests in the batch this cursor walks.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Has every request in the batch traversed the whole timeline?
    pub fn is_done(&self) -> bool {
        self.req >= self.batch
    }

    /// Requests that have fully retired so far.
    pub fn requests_completed(&self) -> usize {
        self.req.min(self.batch)
    }

    /// Layer steps per request on the current schedule.
    pub fn steps_per_request(&self) -> usize {
        self.sched.steps.len()
    }

    /// Fabric seconds consumed so far (monotone; includes charges).
    pub fn consumed_s(&self) -> f64 {
        let raw =
            self.base_s + (self.elapsed_at(self.req, self.step)
                - self.elapsed_at(self.seg_req, self.seg_step));
        raw.max(self.hwm_s)
    }

    /// Total fabric seconds the batch will have consumed at completion
    /// if it stays on the current schedule.
    pub fn projected_total_s(&self) -> f64 {
        let total = self.base_s
            + (self.elapsed_at(self.batch, 0) - self.elapsed_at(self.seg_req, self.seg_step));
        total.max(self.hwm_s)
    }

    /// Fabric seconds left on the current schedule.
    pub fn remaining_s(&self) -> f64 {
        (self.projected_total_s() - self.consumed_s()).max(0.0)
    }

    /// Fabric seconds the remaining steps would take if re-based onto
    /// `sched` at the current boundary (what the preemption policy
    /// weighs against the switch cost).
    pub fn remaining_on(&self, sched: &CachedSchedule) -> f64 {
        let l = sched.steps.len();
        let step = self.step.min(l);
        let here = Self::elapsed_for(sched, self.batch, self.req, step);
        let end = Self::elapsed_for(sched, self.batch, self.batch, 0);
        (end - here).max(0.0)
    }

    /// Consumed total after the next step retires, without committing
    /// it (`None` when the batch is done) — lets callers find the next
    /// layer boundary before deciding to land a preemption there.
    pub fn peek_consumed_s(&self) -> Option<f64> {
        let mut probe = self.clone();
        probe.advance().map(|ev| ev.consumed_s)
    }

    /// Retire the next layer step. Returns `None` once every request in
    /// the batch has traversed the whole timeline.
    pub fn advance(&mut self) -> Option<StepEvent> {
        if self.is_done() {
            return None;
        }
        let l = self.sched.steps.len();
        let cur = self.sched.steps[self.step.min(l - 1)];
        let before = self.consumed_s();
        if self.step + 1 >= l {
            self.req += 1;
            self.step = 0;
        } else {
            self.step += 1;
        }
        let after = self.consumed_s();
        self.hwm_s = after;
        Some(StepEvent {
            layer: cur.layer,
            mode: cur.mode,
            fmus: cur.fmus,
            cus: cur.cus,
            dur_s: (after - before).max(0.0),
            consumed_s: after,
        })
    }

    /// Re-base the remaining steps onto `sched` at the current layer
    /// boundary, charging `switch_charge_s` (the mid-DAG reconfiguration
    /// cost) into the batch's consumed time. Completed work keeps its
    /// old-schedule accounting. Two callers rely on this invariance:
    /// mid-DAG preemption onto a re-split slice, and cross-board
    /// migration (the charge is then the
    /// [`ClusterPolicy::migration_cost_s`](super::cluster::ClusterPolicy::migration_cost_s)
    /// landing on the destination board's slice).
    ///
    /// `sched` must walk the same DAG timeline (one step per layer, so
    /// the step counts must match); a mismatched schedule is refused
    /// with a [`RetargetError`] and the cursor is left untouched —
    /// never silently clamped onto a foreign timeline.
    pub fn retarget(
        &mut self,
        sched: Arc<CachedSchedule>,
        switch_charge_s: f64,
    ) -> Result<(), RetargetError> {
        if sched.steps.len() != self.sched.steps.len() {
            return Err(RetargetError {
                expected_steps: self.sched.steps.len(),
                got_steps: sched.steps.len(),
            });
        }
        let consumed = self.consumed_s();
        self.base_s = consumed + switch_charge_s.max(0.0);
        self.hwm_s = self.hwm_s.max(self.base_s);
        self.seg_req = self.req;
        self.seg_step = self.step;
        self.sched = sched;
        Ok(())
    }

    /// Snapshot the full cursor state.
    pub fn checkpoint(&self) -> CursorCheckpoint {
        CursorCheckpoint {
            sched: self.sched.clone(),
            batch: self.batch,
            req: self.req,
            step: self.step,
            base_s: self.base_s,
            seg_req: self.seg_req,
            seg_step: self.seg_step,
            hwm_s: self.hwm_s,
        }
    }

    /// Restore a cursor exactly as checkpointed.
    pub fn resume(ck: CursorCheckpoint) -> Self {
        Self {
            sched: ck.sched,
            batch: ck.batch,
            req: ck.req,
            step: ck.step,
            base_s: ck.base_s,
            seg_req: ck.seg_req,
            seg_step: ck.seg_step,
            hwm_s: ck.hwm_s,
        }
    }
}

/// Per-tenant bound on fabric-time share: a token bucket refilled at
/// `fabric_share` fabric-seconds per second, holding at most `burst_s`.
/// Admission charges each request its estimated fabric cost, so a
/// tenant's *time on the fabric* is bounded — not just its queue depth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RateLimit {
    /// Sustained fabric seconds granted per second of (virtual or wall)
    /// time.
    pub fabric_share: f64,
    /// Burst allowance in fabric seconds (bucket capacity).
    pub burst_s: f64,
}

/// Deterministic token bucket over an externally supplied clock, so the
/// same code limits both the live scheduler (wall time) and the
/// simulator (virtual fabric time).
#[derive(Debug, Clone)]
pub struct TokenBucket {
    rate_per_s: f64,
    burst: f64,
    tokens: f64,
    last_s: f64,
}

impl TokenBucket {
    /// Bucket starts full (tenants may burst immediately).
    pub fn new(rate_per_s: f64, burst: f64) -> Self {
        let burst = burst.max(0.0);
        Self { rate_per_s: rate_per_s.max(0.0), burst, tokens: burst, last_s: 0.0 }
    }

    /// Bucket configured from a tenant's [`RateLimit`].
    pub fn from_limit(rl: RateLimit) -> Self {
        Self::new(rl.fabric_share, rl.burst_s)
    }

    /// Refill to `now_s`, then take `cost` tokens if available.
    pub fn try_take(&mut self, cost: f64, now_s: f64) -> bool {
        if now_s > self.last_s {
            self.tokens = (self.tokens + (now_s - self.last_s) * self.rate_per_s).min(self.burst);
            self.last_s = now_s;
        }
        if self.tokens >= cost {
            self.tokens -= cost;
            true
        } else {
            false
        }
    }

    /// Return tokens taken for a request that was then refused elsewhere.
    pub fn refund(&mut self, cost: f64) {
        self.tokens = (self.tokens + cost.max(0.0)).min(self.burst);
    }

    /// Fabric seconds currently available in the bucket.
    pub fn tokens(&self) -> f64 {
        self.tokens
    }
}

/// Per-tenant service-level objective class, in the spirit of Herald's
/// multi-DNN serving tiers: latency-tier tenants carry a per-request
/// completion deadline that feeds SLO-attainment accounting, optional
/// deadline-aware admission shedding, and the policy's backlog
/// weighting; throughput-tier tenants (the default) carry no deadline
/// and behave exactly as before this type existed.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum SloClass {
    /// Each served request should finish within `deadline_s` fabric
    /// seconds of its arrival; requests beyond it count as SLO misses.
    LatencyTier {
        /// Per-request completion deadline in fabric seconds.
        deadline_s: f64,
    },
    /// No per-request deadline — only aggregate throughput matters.
    #[default]
    ThroughputTier,
}

impl SloClass {
    /// The per-request deadline when this is a latency tier. Non-finite
    /// or non-positive deadlines are treated as "no deadline" so an
    /// `INFINITY` tier degenerates to throughput semantics instead of
    /// marking every request met vacuously.
    pub fn deadline_s(&self) -> Option<f64> {
        match *self {
            SloClass::LatencyTier { deadline_s } if deadline_s > 0.0 && deadline_s.is_finite() => {
                Some(deadline_s)
            }
            _ => None,
        }
    }
}

/// Classify one arrival against a tenant's admission state: queue
/// depth first (reject as [`PushError::Full`]), then the optional
/// deadline shed (refuse as [`PushError::Deadline`] when the queue-wait
/// estimate already exceeds the tenant's latency-SLO deadline — checked
/// before the bucket so a doomed request never consumes fabric-time
/// tokens), then the fabric-time token bucket (refuse as
/// [`PushError::Throttled`]) — the single admission-order site behind
/// the engine's push path (and therefore behind every composition mode,
/// unified included), so refusal classification can never diverge
/// between deployment modes.
pub(crate) fn admit_arrival(
    pending: &mut VecDeque<(u64, f64)>,
    cap: usize,
    bucket: &mut Option<TokenBucket>,
    per_request_s: f64,
    shed_deadline_s: Option<f64>,
    id: u64,
    arr_s: f64,
) -> Result<(), PushError> {
    if pending.len() >= cap {
        return Err(PushError::Full);
    }
    if let Some(d) = shed_deadline_s {
        // Conservative wait estimate: everything already queued, served
        // one request at a time on the current slice. Deliberately
        // ignores in-flight work and batching so the bound is cheap,
        // deterministic, and composition-mode-independent.
        if pending.len() as f64 * per_request_s > d {
            return Err(PushError::Deadline);
        }
    }
    if let Some(b) = bucket {
        if !b.try_take(per_request_s, arr_s) {
            return Err(PushError::Throttled);
        }
    }
    pending.push_back((id, arr_s));
    Ok(())
}

/// One tenant of the fabric: a model (layer DAG) plus its serving knobs.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Display / partition name (unique per scheduler).
    pub name: String,
    /// The tenant's model as a layer DAG.
    pub dag: Dag,
    /// Bounded-queue depth; pushes beyond it are rejected (admission
    /// control).
    pub queue_capacity: usize,
    /// Max requests drained per worker batch.
    pub max_batch: usize,
    /// Optional bound on this tenant's share of *fabric time* (token
    /// bucket); `None` leaves only the queue-depth bound.
    pub rate_limit: Option<RateLimit>,
    /// Service-level objective class (default: throughput tier, which
    /// leaves every pre-existing behavior untouched).
    pub slo: SloClass,
    /// When `true` and the tenant is a latency tier, arrivals whose
    /// queue-wait estimate already exceeds the deadline are shed at
    /// admission ([`PushError::Deadline`]) instead of queued to miss.
    pub deadline_admission: bool,
}

impl TenantSpec {
    /// Spec with default serving knobs (4096-deep queue, batches of 8,
    /// no rate limit, throughput-tier SLO).
    pub fn new(name: impl Into<String>, dag: Dag) -> Self {
        Self {
            name: name.into(),
            dag,
            queue_capacity: 4096,
            max_batch: 8,
            rate_limit: None,
            slo: SloClass::ThroughputTier,
            deadline_admission: false,
        }
    }

    /// Bound the tenant's queue to `cap` requests (min 1); pushes
    /// beyond it are rejected at admission.
    pub fn with_queue_capacity(mut self, cap: usize) -> Self {
        self.queue_capacity = cap.max(1);
        self
    }

    /// Cap the requests drained per worker batch (min 1).
    pub fn with_max_batch(mut self, b: usize) -> Self {
        self.max_batch = b.max(1);
        self
    }

    /// Bound the tenant to `fabric_share` fabric-seconds per second with
    /// a `burst_s` allowance; excess requests are throttled at admission.
    pub fn with_fabric_share(mut self, fabric_share: f64, burst_s: f64) -> Self {
        self.rate_limit = Some(RateLimit { fabric_share, burst_s });
        self
    }

    /// Attach a service-level objective class.
    pub fn with_slo(mut self, slo: SloClass) -> Self {
        self.slo = slo;
        self
    }

    /// Enable deadline-aware admission shedding (only effective on a
    /// latency-tier tenant).
    pub fn with_deadline_admission(mut self) -> Self {
        self.deadline_admission = true;
        self
    }

    /// The deadline used for admission shedding: the SLO deadline when
    /// this tenant is a latency tier with shedding enabled, else `None`.
    pub(crate) fn shed_deadline_s(&self) -> Option<f64> {
        if self.deadline_admission {
            self.slo.deadline_s()
        } else {
            None
        }
    }
}

/// One request arrival in a (virtual-time) traffic trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Arrival time in (virtual) fabric seconds from trace start.
    pub t_s: f64,
    /// Index of the tenant this request belongs to.
    pub tenant: usize,
    /// Global arrival-order id (assigned by the trace generators).
    pub id: u64,
}

/// Sort a merged trace by (time, tenant) and renumber ids to the
/// global arrival order — shared epilogue of every trace generator
/// (the scenario zoo's shape generators included).
pub(crate) fn finalize_trace(all: &mut [Arrival]) {
    all.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap().then(a.tenant.cmp(&b.tenant)));
    for (i, a) in all.iter_mut().enumerate() {
        a.id = i as u64;
    }
}

/// Deterministic Poisson-process trace: per-tenant exponential
/// inter-arrival times at `rates_rps[i]` requests/second, merged and
/// sorted. A rate of 0 produces no arrivals for that tenant.
pub fn poisson_trace(rates_rps: &[f64], duration_s: f64, seed: u64) -> Vec<Arrival> {
    let mut rng = SplitMix64::new(seed);
    let mut all: Vec<Arrival> = Vec::new();
    for (tenant, &rate) in rates_rps.iter().enumerate() {
        if rate <= 0.0 {
            continue;
        }
        let mut fork = rng.fork();
        let mut t = 0.0f64;
        loop {
            let u = fork.next_f64();
            t += -(1.0 - u).ln() / rate;
            if t >= duration_s {
                break;
            }
            all.push(Arrival { t_s: t, tenant, id: 0 });
        }
    }
    finalize_trace(&mut all);
    all
}

/// A piecewise trace: concatenates phases, each with its own per-tenant
/// rates, so load skew can move between tenants over time (the regime
/// the dynamic re-composer exploits and a static split cannot).
pub fn phased_trace(phases: &[(&[f64], f64)], seed: u64) -> Vec<Arrival> {
    let mut all: Vec<Arrival> = Vec::new();
    let mut t0 = 0.0f64;
    for (k, &(rates, dur)) in phases.iter().enumerate() {
        let mut phase = poisson_trace(rates, dur, seed.wrapping_add(k as u64 * 0x9E37_79B9));
        for a in &mut phase {
            a.t_s += t0;
        }
        all.extend(phase);
        t0 += dur;
    }
    finalize_trace(&mut all);
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn batch_amortizes() {
        assert_eq!(batch_fabric_s(1.0, 0), 0.0);
        assert!((batch_fabric_s(1.0, 1) - 1.0).abs() < 1e-12);
        let b4 = batch_fabric_s(1.0, 4);
        assert!(b4 < 4.0 && b4 > 1.0, "batching must amortize: {b4}");
    }

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let a = poisson_trace(&[100.0, 10.0], 1.0, 42);
        let b = poisson_trace(&[100.0, 10.0], 1.0, 42);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        assert!(a.iter().zip(&b).all(|(x, y)| x == y));
        // Rate skew shows up in counts (100:10 within ~3x tolerance).
        let n0 = a.iter().filter(|x| x.tenant == 0).count();
        let n1 = a.iter().filter(|x| x.tenant == 1).count();
        assert!(n0 > n1 * 3, "skewed rates must skew counts: {n0} vs {n1}");
    }

    #[test]
    fn phased_trace_moves_skew() {
        let heavy_a: &[f64] = &[100.0, 5.0];
        let heavy_b: &[f64] = &[5.0, 100.0];
        let tr = phased_trace(&[(heavy_a, 1.0), (heavy_b, 1.0)], 7);
        let first: Vec<_> = tr.iter().filter(|x| x.t_s < 1.0).collect();
        let second: Vec<_> = tr.iter().filter(|x| x.t_s >= 1.0).collect();
        let frac_a_first =
            first.iter().filter(|x| x.tenant == 0).count() as f64 / first.len() as f64;
        let frac_a_second =
            second.iter().filter(|x| x.tenant == 0).count() as f64 / second.len() as f64;
        assert!(frac_a_first > 0.8 && frac_a_second < 0.2);
        // ids are the global arrival order.
        assert!(tr.windows(2).all(|w| w[0].id < w[1].id));
    }

    #[test]
    fn tenant_spec_builders() {
        let t = TenantSpec::new("mlp", zoo::mlp_s())
            .with_queue_capacity(16)
            .with_max_batch(4)
            .with_fabric_share(0.5, 2.0);
        assert_eq!(t.queue_capacity, 16);
        assert_eq!(t.max_batch, 4);
        assert_eq!(t.name, "mlp");
        assert_eq!(t.rate_limit, Some(RateLimit { fabric_share: 0.5, burst_s: 2.0 }));
    }

    // ---- BatchCursor -----------------------------------------------------

    use crate::dse::{Schedule, ScheduleEntry};
    use crate::serve::cache::CachedSchedule;

    /// A synthetic chain schedule: `durs[i]` seconds per layer, serial.
    fn chain_sched(durs: &[f64]) -> Arc<CachedSchedule> {
        let mut entries = Vec::new();
        let mut t = 0.0;
        for (i, &d) in durs.iter().enumerate() {
            entries.push(ScheduleEntry {
                layer: i,
                mode: 0,
                start: t,
                end: t + d,
                fmus: vec![0],
                cus: vec![0],
            });
            t += d;
        }
        Arc::new(CachedSchedule::new(Schedule { entries, makespan: t }))
    }

    #[test]
    fn undisturbed_cursor_reproduces_batch_fabric_s_exactly() {
        let sched = chain_sched(&[0.3, 0.7, 0.15, 0.85]);
        for batch in [1usize, 2, 5, 8] {
            let mut c = BatchCursor::new(sched.clone(), batch);
            assert_eq!(c.projected_total_s(), batch_fabric_s(sched.per_request_s, batch));
            let mut n_steps = 0;
            let mut last = 0.0;
            while let Some(ev) = c.advance() {
                n_steps += 1;
                assert!(ev.dur_s >= 0.0);
                assert!(ev.consumed_s >= last, "consumed must be monotone");
                last = ev.consumed_s;
            }
            assert_eq!(n_steps, batch * 4);
            assert!(c.is_done());
            assert_eq!(c.requests_completed(), batch);
            // Bit-for-bit: the steppable walk lands exactly on the old
            // batch-atomic total.
            assert_eq!(c.consumed_s(), batch_fabric_s(sched.per_request_s, batch));
            assert_eq!(c.remaining_s(), 0.0);
        }
    }

    #[test]
    fn cursor_step_events_follow_the_timeline() {
        let sched = chain_sched(&[1.0, 2.0]);
        let mut c = BatchCursor::new(sched, 2);
        let e0 = c.advance().unwrap();
        assert_eq!((e0.layer, e0.fmus, e0.cus), (0, 1, 1));
        assert!((e0.dur_s - 1.0).abs() < 1e-12);
        let e1 = c.advance().unwrap();
        assert_eq!(e1.layer, 1);
        assert!((e1.dur_s - 2.0).abs() < 1e-12);
        // Second request pays the amortized rate.
        let e2 = c.advance().unwrap();
        assert_eq!(e2.layer, 0);
        assert!((e2.dur_s - BATCH_AMORTIZATION).abs() < 1e-12);
        let e3 = c.advance().unwrap();
        assert!((e3.dur_s - 2.0 * BATCH_AMORTIZATION).abs() < 1e-12);
        assert!(c.advance().is_none());
    }

    #[test]
    fn retarget_charges_one_switch_and_recosts_remaining_layers() {
        let slow = chain_sched(&[1.0, 1.0, 1.0, 1.0]);
        let fast = chain_sched(&[0.25, 0.25, 0.25, 0.25]);
        let switch = 0.125;
        let mut c = BatchCursor::new(slow.clone(), 1);
        c.advance().unwrap();
        c.advance().unwrap(); // 2 of 4 layers done on the slow slice
        let consumed_before = c.consumed_s();
        assert!((consumed_before - 2.0).abs() < 1e-12);
        c.retarget(fast.clone(), switch).unwrap();
        assert!((c.consumed_s() - (2.0 + switch)).abs() < 1e-12, "switch charged at the boundary");
        let mut total_after = 0.0;
        while let Some(ev) = c.advance() {
            total_after = ev.consumed_s;
        }
        // old part + exactly one switch + remaining layers at new speed
        let expect = 2.0 + switch + 0.5;
        assert!((total_after - expect).abs() < 1e-12, "{total_after} vs {expect}");
        assert!(c.is_done());
    }

    #[test]
    fn retarget_mid_request_in_a_batch_scales_remaining_by_amortization() {
        let slow = chain_sched(&[1.0, 1.0]);
        let fast = chain_sched(&[0.5, 0.5]);
        let mut c = BatchCursor::new(slow.clone(), 2);
        // Finish request 0 (2 steps) and one step of request 1.
        c.advance().unwrap();
        c.advance().unwrap();
        c.advance().unwrap();
        let at_boundary = c.consumed_s();
        assert!((at_boundary - (2.0 + 0.9)).abs() < 1e-12);
        c.retarget(fast, 0.0).unwrap();
        let mut last = at_boundary;
        while let Some(ev) = c.advance() {
            last = ev.consumed_s;
        }
        // Remaining: request 1's second layer on the fast slice, amortized.
        assert!((last - (2.9 + 0.5 * 0.9)).abs() < 1e-12, "got {last}");
    }

    #[test]
    fn retarget_refuses_mismatched_step_counts() {
        // Retargeting onto a schedule with a different step count used
        // to clamp `step` silently, mis-positioning the cursor; it must
        // now refuse with a structured error and change nothing.
        let four = chain_sched(&[1.0, 1.0, 1.0, 1.0]);
        let three = chain_sched(&[1.0, 1.0, 1.0]);
        let mut c = BatchCursor::new(four.clone(), 2);
        for _ in 0..3 {
            c.advance().unwrap();
        }
        let consumed_before = c.consumed_s();
        let remaining_before = c.remaining_s();
        let err = c.retarget(three, 0.25).unwrap_err();
        assert_eq!((err.expected_steps, err.got_steps), (4, 3));
        let msg = err.to_string();
        assert!(msg.contains('4') && msg.contains('3'), "error must name both counts: {msg}");
        // No charge, no re-base, no clamp: the cursor is untouched…
        assert_eq!(c.consumed_s(), consumed_before);
        assert_eq!(c.remaining_s(), remaining_before);
        // …and still walks its original schedule to the exact closed form.
        while c.advance().is_some() {}
        assert_eq!(c.consumed_s(), batch_fabric_s(four.per_request_s, 2));
        // A same-length schedule is accepted as before.
        let other = chain_sched(&[0.5, 0.5, 0.5, 0.5]);
        let mut c = BatchCursor::new(four, 1);
        c.advance().unwrap();
        assert!(c.retarget(other, 0.0).is_ok());
    }

    #[test]
    fn checkpoint_resume_is_lossless() {
        let sched = chain_sched(&[0.4, 0.6, 1.1]);
        let mut a = BatchCursor::new(sched.clone(), 3);
        for _ in 0..4 {
            a.advance().unwrap();
        }
        let ck = a.checkpoint();
        let mut b = BatchCursor::resume(ck);
        assert_eq!(a.consumed_s(), b.consumed_s());
        assert_eq!(a.remaining_s(), b.remaining_s());
        // Both cursors finish identically, event by event.
        loop {
            match (a.advance(), b.advance()) {
                (None, None) => break,
                (Some(x), Some(y)) => assert_eq!(x, y),
                (x, y) => panic!("cursors diverged: {x:?} vs {y:?}"),
            }
        }
        assert_eq!(a.consumed_s(), b.consumed_s());
        assert_eq!(a.consumed_s(), batch_fabric_s(sched.per_request_s, 3));
    }

    #[test]
    fn remaining_on_estimates_the_new_slice() {
        let slow = chain_sched(&[1.0, 1.0]);
        let fast = chain_sched(&[0.25, 0.25]);
        let mut c = BatchCursor::new(slow, 1);
        c.advance().unwrap();
        assert!((c.remaining_s() - 1.0).abs() < 1e-12);
        assert!((c.remaining_on(&fast) - 0.25).abs() < 1e-12);
    }

    // ---- TokenBucket -----------------------------------------------------

    #[test]
    fn token_bucket_bounds_sustained_rate() {
        let mut b = TokenBucket::new(1.0, 2.0);
        // Burst: two 1-second requests pass immediately.
        assert!(b.try_take(1.0, 0.0));
        assert!(b.try_take(1.0, 0.0));
        assert!(!b.try_take(1.0, 0.0), "bucket exhausted");
        // Refill at 1 fabric-second per second.
        assert!(b.try_take(1.0, 1.0));
        assert!(!b.try_take(1.0, 1.0));
        // A refund restores capacity (up to the burst cap).
        b.refund(0.5);
        assert!(b.try_take(0.5, 1.0));
        // Clock going backwards never mints tokens.
        assert!(!b.try_take(0.5, 0.5));
    }

    // ---- SLO classes + deadline-aware admission --------------------------

    #[test]
    fn slo_deadline_ignores_degenerate_tiers() {
        assert_eq!(SloClass::ThroughputTier.deadline_s(), None);
        assert_eq!(SloClass::LatencyTier { deadline_s: 0.25 }.deadline_s(), Some(0.25));
        assert_eq!(SloClass::LatencyTier { deadline_s: 0.0 }.deadline_s(), None);
        assert_eq!(SloClass::LatencyTier { deadline_s: -1.0 }.deadline_s(), None);
        assert_eq!(SloClass::LatencyTier { deadline_s: f64::INFINITY }.deadline_s(), None);
    }

    #[test]
    fn shed_deadline_requires_both_tier_and_opt_in() {
        let base = TenantSpec::new("t", zoo::mlp_s());
        assert_eq!(base.shed_deadline_s(), None);
        let tier = TenantSpec::new("t", zoo::mlp_s()).with_slo(SloClass::LatencyTier {
            deadline_s: 0.5,
        });
        assert_eq!(tier.shed_deadline_s(), None, "shedding is opt-in");
        assert_eq!(tier.with_deadline_admission().shed_deadline_s(), Some(0.5));
        let thr = TenantSpec::new("t", zoo::mlp_s()).with_deadline_admission();
        assert_eq!(thr.shed_deadline_s(), None, "throughput tiers have no deadline");
    }

    #[test]
    fn admission_sheds_past_deadline_before_the_bucket() {
        let mut pending: VecDeque<(u64, f64)> = VecDeque::new();
        let mut bucket = Some(TokenBucket::new(0.0, 10.0));
        // per-request 1 s, deadline 2.5 s: depths 0..=2 admit (wait
        // estimate 0,1,2 s), depth 3 sheds (estimate 3 s > 2.5 s).
        for id in 0..3 {
            assert_eq!(
                admit_arrival(&mut pending, 16, &mut bucket, 1.0, Some(2.5), id, 0.0),
                Ok(())
            );
        }
        let before = bucket.as_ref().unwrap().tokens();
        assert_eq!(
            admit_arrival(&mut pending, 16, &mut bucket, 1.0, Some(2.5), 3, 0.0),
            Err(PushError::Deadline)
        );
        assert_eq!(
            bucket.as_ref().unwrap().tokens(),
            before,
            "a shed request must not consume fabric-time tokens"
        );
        assert_eq!(pending.len(), 3);
        // Without a shed deadline the same push is admitted.
        assert_eq!(admit_arrival(&mut pending, 16, &mut bucket, 1.0, None, 3, 0.0), Ok(()));
    }
}
