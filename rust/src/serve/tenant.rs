//! Tenants and traffic: who is being served, and how requests arrive.

use crate::util::rng::SplitMix64;
use crate::workload::Dag;

/// Weight-reload amortization within a batch: requests after the first
/// reuse the operand layouts already resident in the FMUs, so they pay
/// this fraction of the full schedule makespan. Applies identically to
/// every composition strategy, so comparisons are unaffected by it.
pub const BATCH_AMORTIZATION: f64 = 0.9;

/// Fabric seconds a batch of `batch` requests takes on a slice whose
/// single-request schedule makespan is `per_request_s`.
pub fn batch_fabric_s(per_request_s: f64, batch: usize) -> f64 {
    if batch == 0 {
        return 0.0;
    }
    per_request_s * (1.0 + BATCH_AMORTIZATION * (batch - 1) as f64)
}

/// One tenant of the fabric: a model (layer DAG) plus its serving knobs.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub dag: Dag,
    /// Bounded-queue depth; pushes beyond it are rejected (admission
    /// control).
    pub queue_capacity: usize,
    /// Max requests drained per worker batch.
    pub max_batch: usize,
}

impl TenantSpec {
    pub fn new(name: impl Into<String>, dag: Dag) -> Self {
        Self { name: name.into(), dag, queue_capacity: 4096, max_batch: 8 }
    }

    pub fn with_queue_capacity(mut self, cap: usize) -> Self {
        self.queue_capacity = cap.max(1);
        self
    }

    pub fn with_max_batch(mut self, b: usize) -> Self {
        self.max_batch = b.max(1);
        self
    }
}

/// One request arrival in a (virtual-time) traffic trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    pub t_s: f64,
    pub tenant: usize,
    pub id: u64,
}

/// Sort a merged trace by (time, tenant) and renumber ids to the
/// global arrival order — shared epilogue of every trace generator.
fn finalize_trace(all: &mut [Arrival]) {
    all.sort_by(|a, b| a.t_s.partial_cmp(&b.t_s).unwrap().then(a.tenant.cmp(&b.tenant)));
    for (i, a) in all.iter_mut().enumerate() {
        a.id = i as u64;
    }
}

/// Deterministic Poisson-process trace: per-tenant exponential
/// inter-arrival times at `rates_rps[i]` requests/second, merged and
/// sorted. A rate of 0 produces no arrivals for that tenant.
pub fn poisson_trace(rates_rps: &[f64], duration_s: f64, seed: u64) -> Vec<Arrival> {
    let mut rng = SplitMix64::new(seed);
    let mut all: Vec<Arrival> = Vec::new();
    for (tenant, &rate) in rates_rps.iter().enumerate() {
        if rate <= 0.0 {
            continue;
        }
        let mut fork = rng.fork();
        let mut t = 0.0f64;
        loop {
            let u = fork.next_f64();
            t += -(1.0 - u).ln() / rate;
            if t >= duration_s {
                break;
            }
            all.push(Arrival { t_s: t, tenant, id: 0 });
        }
    }
    finalize_trace(&mut all);
    all
}

/// A piecewise trace: concatenates phases, each with its own per-tenant
/// rates, so load skew can move between tenants over time (the regime
/// the dynamic re-composer exploits and a static split cannot).
pub fn phased_trace(phases: &[(&[f64], f64)], seed: u64) -> Vec<Arrival> {
    let mut all: Vec<Arrival> = Vec::new();
    let mut t0 = 0.0f64;
    for (k, &(rates, dur)) in phases.iter().enumerate() {
        let mut phase = poisson_trace(rates, dur, seed.wrapping_add(k as u64 * 0x9E37_79B9));
        for a in &mut phase {
            a.t_s += t0;
        }
        all.extend(phase);
        t0 += dur;
    }
    finalize_trace(&mut all);
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn batch_amortizes() {
        assert_eq!(batch_fabric_s(1.0, 0), 0.0);
        assert!((batch_fabric_s(1.0, 1) - 1.0).abs() < 1e-12);
        let b4 = batch_fabric_s(1.0, 4);
        assert!(b4 < 4.0 && b4 > 1.0, "batching must amortize: {b4}");
    }

    #[test]
    fn trace_is_deterministic_and_sorted() {
        let a = poisson_trace(&[100.0, 10.0], 1.0, 42);
        let b = poisson_trace(&[100.0, 10.0], 1.0, 42);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].t_s <= w[1].t_s));
        assert!(a.iter().zip(&b).all(|(x, y)| x == y));
        // Rate skew shows up in counts (100:10 within ~3x tolerance).
        let n0 = a.iter().filter(|x| x.tenant == 0).count();
        let n1 = a.iter().filter(|x| x.tenant == 1).count();
        assert!(n0 > n1 * 3, "skewed rates must skew counts: {n0} vs {n1}");
    }

    #[test]
    fn phased_trace_moves_skew() {
        let heavy_a: &[f64] = &[100.0, 5.0];
        let heavy_b: &[f64] = &[5.0, 100.0];
        let tr = phased_trace(&[(heavy_a, 1.0), (heavy_b, 1.0)], 7);
        let first: Vec<_> = tr.iter().filter(|x| x.t_s < 1.0).collect();
        let second: Vec<_> = tr.iter().filter(|x| x.t_s >= 1.0).collect();
        let frac_a_first =
            first.iter().filter(|x| x.tenant == 0).count() as f64 / first.len() as f64;
        let frac_a_second =
            second.iter().filter(|x| x.tenant == 0).count() as f64 / second.len() as f64;
        assert!(frac_a_first > 0.8 && frac_a_second < 0.2);
        // ids are the global arrival order.
        assert!(tr.windows(2).all(|w| w[0].id < w[1].id));
    }

    #[test]
    fn tenant_spec_builders() {
        let t = TenantSpec::new("mlp", zoo::mlp_s()).with_queue_capacity(16).with_max_batch(4);
        assert_eq!(t.queue_capacity, 16);
        assert_eq!(t.max_batch, 4);
        assert_eq!(t.name, "mlp");
    }
}
