//! Schedule cache: memoized two-stage DSE results keyed on
//! `(FilcoConfig, Dag)`, persistable to disk.
//!
//! Live re-composition changes each tenant's fabric slice every policy
//! epoch, but the set of distinct `(slice config, tenant DAG)` pairs a
//! serving process ever sees is tiny — weights oscillate between a few
//! load regimes. Caching the Stage-1 + Stage-2 result means the GA/MILP
//! never runs on the re-partition hot path after the first time a
//! composition is seen: a repartition into a previously-seen shape is a
//! hash lookup (~ns) instead of a DSE run (~ms–s).
//!
//! Entries carry the steppable [`LayerStep`](crate::dse::LayerStep)
//! timeline alongside the raw
//! [`Schedule`], so the serving layer can drive batches layer-by-layer
//! (preemption at step boundaries) without recomputing the view.
//!
//! [`Self::save_to`] / [`Self::load_from`] serialize the whole table
//! through [`crate::util::json`] (deterministic key order), so a
//! restarted serving process warms from disk instead of re-running the
//! GA/MILP for every composition it had already seen.
//!
//! Concurrent misses on the *same* key are **single-flight**: the first
//! caller becomes the leader and runs the DSE; later callers block on
//! the leader's in-flight marker and share its result, so the expensive
//! solve runs exactly once per key no matter how many threads race on
//! it. Stall time spent waiting on someone else's solve is counted
//! separately ([`ScheduleCache::stalls`] / [`ScheduleCache::stall_ns`]).
//!
//! For callers that must never block on a solve at all (the async-DSE
//! policy path), [`ScheduleCache::get_cached`] probes for a ready entry
//! without counting or waiting, and [`BackgroundSolver`] runs the
//! solves on a dedicated thread fed by a [`SolveRequest`] channel.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};

use crate::arch::{Features, FilcoConfig};
use crate::dse::{self, Schedule, ScheduleEntry, Solver};
use crate::platform::Platform;
use crate::util::json::Json;
use crate::workload::Dag;

/// Stable 64-bit FNV-1a. Fingerprints are persisted to disk by the
/// cache (and must match after restarts on any toolchain), so they
/// cannot use std's `DefaultHasher`, whose algorithm is explicitly not
/// guaranteed across Rust releases.
struct StableHasher(u64);

impl StableHasher {
    fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    fn u32(&mut self, x: u32) {
        self.bytes(&x.to_le_bytes());
    }

    fn u64(&mut self, x: u64) {
        self.bytes(&x.to_le_bytes());
    }

    fn f64(&mut self, x: f64) {
        self.u64(x.to_bits());
    }

    /// Length-prefixed so concatenated strings can't collide.
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// Structural fingerprint of a DAG: name, layer names/shapes and edges.
/// Two DAGs with the same fingerprint get the same schedule.
pub fn dag_fingerprint(dag: &Dag) -> u64 {
    let mut h = StableHasher::new();
    h.str(&dag.name);
    h.u64(dag.layers.len() as u64);
    for l in &dag.layers {
        h.str(&l.name);
        h.u32(l.shape.batch);
        h.u32(l.shape.m);
        h.u32(l.shape.k);
        h.u32(l.shape.n);
    }
    h.u64(dag.edges.len() as u64);
    for &(a, b) in &dag.edges {
        h.u64(a as u64);
        h.u64(b as u64);
    }
    h.finish()
}

/// Fingerprint of the platform model a schedule was computed against.
/// `Platform`'s fields are public and tunable (DDR-bandwidth what-ifs
/// etc.), so the key must not assume one cache == one platform. Fields
/// are hashed directly — no allocation on the lookup hot path.
fn platform_fingerprint(p: &Platform) -> u64 {
    let mut h = StableHasher::new();
    h.str(&p.name);
    h.u32(p.aie_tiles);
    h.f64(p.aie_ghz);
    h.u32(p.aie_macs_per_cycle);
    h.u64(p.aie_local_bytes);
    h.u64(p.aie_pm_bytes);
    h.f64(p.pl_mhz);
    h.u64(p.pl_sram_bytes);
    h.u32(p.plio_bits);
    h.u32(p.plio_ports);
    h.f64(p.ddr.peak_bytes_per_sec);
    h.f64(p.ddr.txn_latency_s);
    for &(burst, frac) in &p.ddr.efficiency_points {
        h.u64(burst);
        h.f64(frac);
    }
    h.finish()
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct Key {
    cfg: FilcoConfig,
    platform: u64,
    dag: u64,
}

/// Deterministic ordering for configs: persistence and warm-start seed
/// selection both sort by this tuple so their output never depends on
/// `HashMap` iteration order.
fn cfg_sort_key(c: &FilcoConfig) -> (u32, u32, u32, u64, u64, bool, bool, bool) {
    (
        c.n_fmus,
        c.m_cus,
        c.aies_per_cu,
        c.fmu_bytes,
        c.cu_buf_bytes,
        c.features.fp,
        c.features.fmf,
        c.features.fmv,
    )
}

/// At most this many neighbor schedules seed a warm-started GA
/// population (more would crowd out the random individuals that keep
/// the search exploring).
const MAX_WARM_SEEDS: usize = 4;

/// Performance knobs for the solves a [`ScheduleCache`] runs on misses.
///
/// The default is the legacy behaviour — serial evaluation, no
/// convergence cutoff, no warm starts — so existing callers see
/// bit-for-bit identical schedules. [`DseTuning::accelerated`] opts a
/// cache into the fast path (the `--dse-workers N` CLI flag and the
/// serving benches use it).
#[derive(Debug, Clone, PartialEq)]
pub struct DseTuning {
    /// Worker threads per solve: Stage 1 spreads distinct layer shapes
    /// and the GA spreads fitness evaluation over this many threads.
    /// 1 means fully serial. Worker count never changes the schedule.
    pub workers: usize,
    /// Stop the GA after this many generations without relative
    /// improvement (0 disables the cutoff).
    pub stall_generations: usize,
    /// Relative improvement below which a generation counts as stalled.
    pub stall_epsilon: f64,
    /// Seed GA populations from ready schedules of the same DAG under
    /// other fabric slices (see [`ScheduleCache::neighbors`]).
    pub warm_start: bool,
}

impl Default for DseTuning {
    fn default() -> Self {
        Self { workers: 1, stall_generations: 0, stall_epsilon: 1e-4, warm_start: false }
    }
}

impl DseTuning {
    /// The fast profile: `workers` threads, cutoff after 6 stalled
    /// generations at 0.1% relative improvement, warm starts on.
    pub fn accelerated(workers: usize) -> Self {
        Self {
            workers: workers.max(1),
            stall_generations: 6,
            stall_epsilon: 1e-3,
            warm_start: true,
        }
    }
}

/// One memoized DSE result.
#[derive(Debug, Clone)]
pub struct CachedSchedule {
    /// The memoized two-stage DSE result.
    pub schedule: Schedule,
    /// Fabric seconds one request (one DAG traversal) takes on this
    /// slice — the schedule makespan.
    pub per_request_s: f64,
    /// Steppable timeline view of the schedule (never empty: a
    /// degenerate entry-less schedule gets one synthetic whole-request
    /// step so cursors always have a boundary to land on).
    pub steps: Vec<crate::dse::LayerStep>,
}

impl CachedSchedule {
    /// Wrap a schedule with its precomputed steppable timeline view.
    pub fn new(schedule: Schedule) -> Self {
        let mut steps = schedule.steps();
        if steps.is_empty() {
            steps.push(crate::dse::LayerStep {
                layer: 0,
                mode: 0,
                dur_s: schedule.makespan,
                end_s: schedule.makespan,
                fmus: 0,
                cus: 0,
            });
        }
        Self { per_request_s: schedule.makespan, steps, schedule }
    }
}

/// Rendezvous between the one thread running a solve (the leader) and
/// any threads that missed on the same key while it was in flight.
struct Flight {
    done: Mutex<Option<Arc<CachedSchedule>>>,
    cv: Condvar,
}

/// Map slot: either a finished schedule or a marker for a solve some
/// thread is currently running (single-flight dedupe). Each slot
/// remembers the board that first computed (or is computing) it, so
/// multi-board serving stacks can count how often one board's solve
/// warmed another board's lookup.
enum Slot {
    Ready(Arc<CachedSchedule>, usize),
    Pending(Arc<Flight>, usize),
}

/// Thread-safe memo table for two-stage DSE results.
pub struct ScheduleCache {
    solver: Solver,
    tuning: DseTuning,
    inner: Mutex<HashMap<Key, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
    stalls: AtomicU64,
    stall_ns: AtomicU64,
    lookup_ns: AtomicU64,
    solve_ns: AtomicU64,
    solve_count: AtomicU64,
    coalesced: AtomicU64,
    cross_board: AtomicU64,
}

impl ScheduleCache {
    /// Empty cache that resolves misses with `solver`. Thread-safe: the
    /// internal map is mutex-guarded and misses compute outside it.
    pub fn new(solver: Solver) -> Self {
        Self {
            solver,
            tuning: DseTuning::default(),
            inner: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            stall_ns: AtomicU64::new(0),
            lookup_ns: AtomicU64::new(0),
            solve_ns: AtomicU64::new(0),
            solve_count: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            cross_board: AtomicU64::new(0),
        }
    }

    /// Builder: resolve misses with these performance knobs instead of
    /// the legacy serial defaults.
    pub fn with_tuning(mut self, tuning: DseTuning) -> Self {
        self.tuning = tuning;
        self
    }

    /// The performance knobs this cache solves misses with.
    pub fn tuning(&self) -> &DseTuning {
        &self.tuning
    }

    /// A solver sized for serving-time re-scheduling: small GA, fixed
    /// seed (deterministic across runs).
    pub fn serving_solver() -> Solver {
        Solver::Ga { population: 24, generations: 40, seed: 0xF11C0 }
    }

    /// Look up the schedule for `dag` on fabric slice `cfg`, running the
    /// two-stage DSE on a miss. Misses compute outside the map lock so
    /// concurrent lookups of *different* keys don't serialize, and
    /// concurrent misses on the *same* key are single-flight: exactly
    /// one caller (the leader) runs the DSE, everyone else blocks on
    /// its in-flight marker and shares the result. Waiters count as
    /// misses (the table had no ready entry for them) and as stalls.
    pub fn get_or_compute(
        &self,
        platform: &Platform,
        cfg: &FilcoConfig,
        dag: &Dag,
    ) -> Arc<CachedSchedule> {
        self.get_or_compute_from(platform, cfg, dag, 0)
    }

    /// [`Self::get_or_compute`] with the caller's board identity. A hit
    /// on an entry first computed by a *different* board additionally
    /// counts into [`Self::cross_board_hits`] — the multi-board warm
    /// path where one board's solve spares another board a cold DSE
    /// run. Single-board callers use origin 0 everywhere, so the
    /// counter stays at zero for them.
    pub fn get_or_compute_from(
        &self,
        platform: &Platform,
        cfg: &FilcoConfig,
        dag: &Dag,
        origin: usize,
    ) -> Arc<CachedSchedule> {
        let key = Key {
            cfg: cfg.clone(),
            platform: platform_fingerprint(platform),
            dag: dag_fingerprint(dag),
        };
        enum Probe {
            Hit(Arc<CachedSchedule>, usize),
            Wait(Arc<Flight>),
            Lead(Arc<Flight>, Vec<dse::GaSeed>),
        }
        // Timing below is observability-only: the counters are never
        // read by any scheduling decision, so wall-clock jitter cannot
        // perturb the deterministic fabric-time trace.
        let t0 = std::time::Instant::now();
        // One lock acquisition decides this caller's role; the solve
        // and the wait both happen outside the map lock. Warm-start
        // seeds are captured under the same lock acquisition, so the
        // seed set is exactly the ready neighbors at leadership time.
        let probe = {
            let mut map = self.inner.lock().unwrap();
            match map.get(&key) {
                Some(Slot::Ready(hit, org)) => Probe::Hit(hit.clone(), *org),
                Some(Slot::Pending(flight, _)) => Probe::Wait(flight.clone()),
                None => {
                    let seeds = if self.tuning.warm_start {
                        Self::neighbor_seeds(&map, &key, dag.len())
                    } else {
                        Vec::new()
                    };
                    let flight =
                        Arc::new(Flight { done: Mutex::new(None), cv: Condvar::new() });
                    map.insert(key.clone(), Slot::Pending(flight.clone(), origin));
                    Probe::Lead(flight, seeds)
                }
            }
        };
        self.lookup_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        match probe {
            Probe::Hit(hit, org) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                if org != origin {
                    self.cross_board.fetch_add(1, Ordering::Relaxed);
                }
                hit
            }
            Probe::Wait(flight) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                self.stalls.fetch_add(1, Ordering::Relaxed);
                let t1 = std::time::Instant::now();
                let mut done = flight.done.lock().unwrap();
                while done.is_none() {
                    done = flight.cv.wait(done).unwrap();
                }
                self.stall_ns.fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
                done.clone().expect("flight signalled without a result")
            }
            Probe::Lead(flight, seeds) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                let t1 = std::time::Instant::now();
                let tuning = dse::SolveTuning {
                    workers: self.tuning.workers,
                    stall_generations: self.tuning.stall_generations,
                    stall_epsilon: self.tuning.stall_epsilon,
                    seeds,
                };
                let schedule = dse::two_stage_tuned(platform, cfg, dag, self.solver, &tuning);
                self.solve_ns.fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
                self.solve_count.fetch_add(1, Ordering::Relaxed);
                let cached = Arc::new(CachedSchedule::new(schedule));
                // Publish to waiters first, then flip the slot to Ready
                // so later lookups hit without touching the flight.
                *flight.done.lock().unwrap() = Some(cached.clone());
                flight.cv.notify_all();
                self.inner.lock().unwrap().insert(key, Slot::Ready(cached.clone(), origin));
                cached
            }
        }
    }

    /// Non-blocking probe: the ready entry for `(cfg, dag)` if one is
    /// memoized, `None` on a cold or still-solving key. Counts neither
    /// a hit nor a miss — the async-DSE policy path uses this to decide
    /// whether a resplit can land this epoch without skewing the
    /// hit/miss series the timeline reports.
    pub fn get_cached(
        &self,
        platform: &Platform,
        cfg: &FilcoConfig,
        dag: &Dag,
    ) -> Option<Arc<CachedSchedule>> {
        let key = Key {
            cfg: cfg.clone(),
            platform: platform_fingerprint(platform),
            dag: dag_fingerprint(dag),
        };
        match self.inner.lock().unwrap().get(&key) {
            Some(Slot::Ready(hit, _)) => Some(hit.clone()),
            _ => None,
        }
    }

    /// Ready schedules for the *same* `(platform, dag)` under other
    /// fabric slices, in deterministic config order. A re-split moves a
    /// tenant between adjacent slice shapes, so these are near-optimal
    /// starting points: the warm-start path re-encodes their layer
    /// orders and mode picks as initial GA individuals. Counts neither
    /// hits nor misses.
    pub fn neighbors(
        &self,
        platform: &Platform,
        cfg: &FilcoConfig,
        dag: &Dag,
    ) -> Vec<Arc<CachedSchedule>> {
        let (pfp, dfp) = (platform_fingerprint(platform), dag_fingerprint(dag));
        let map = self.inner.lock().unwrap();
        let mut found: Vec<(&Key, &Arc<CachedSchedule>)> = map
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Ready(v, _) if k.platform == pfp && k.dag == dfp && k.cfg != *cfg => {
                    Some((k, v))
                }
                _ => None,
            })
            .collect();
        found.sort_by_key(|(k, _)| cfg_sort_key(&k.cfg));
        found.into_iter().map(|(_, v)| v.clone()).collect()
    }

    /// Warm-start seeds for `key`, read from a map the caller already
    /// holds locked: neighbor schedules in deterministic config order,
    /// re-encoded as GA individuals, capped at [`MAX_WARM_SEEDS`].
    fn neighbor_seeds(map: &HashMap<Key, Slot>, key: &Key, n_layers: usize) -> Vec<dse::GaSeed> {
        let mut found: Vec<(&Key, &Arc<CachedSchedule>)> = map
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Ready(v, _)
                    if k.platform == key.platform && k.dag == key.dag && k.cfg != key.cfg =>
                {
                    Some((k, v))
                }
                _ => None,
            })
            .collect();
        found.sort_by_key(|(k, _)| cfg_sort_key(&k.cfg));
        found
            .into_iter()
            .filter_map(|(_, v)| dse::GaSeed::from_schedule(&v.schedule, n_layers))
            .take(MAX_WARM_SEEDS)
            .collect()
    }

    /// Lookups served from the memo table so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to run the two-stage DSE so far (including
    /// waiters that blocked on another thread's in-flight solve).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Lookups that blocked on *someone else's* in-flight solve
    /// (single-flight waiters). A subset of [`Self::misses`].
    pub fn stalls(&self) -> u64 {
        self.stalls.load(Ordering::Relaxed)
    }

    /// Cumulative wall time waiters spent blocked on in-flight solves,
    /// nanoseconds. Profiling only — never read by decisions.
    pub fn stall_ns(&self) -> u64 {
        self.stall_ns.load(Ordering::Relaxed)
    }

    /// Cumulative wall time spent in map lookups (both hits and
    /// misses), nanoseconds. Profiling only — never read by decisions.
    pub fn lookup_ns(&self) -> u64 {
        self.lookup_ns.load(Ordering::Relaxed)
    }

    /// Cumulative wall time spent inside the two-stage DSE on misses,
    /// nanoseconds. Profiling only — never read by decisions.
    pub fn solve_ns(&self) -> u64 {
        self.solve_ns.load(Ordering::Relaxed)
    }

    /// Number of DSE solves timed into [`Self::solve_ns`] (one per
    /// miss, counted when the solve finishes).
    pub fn solve_count(&self) -> u64 {
        self.solve_count.load(Ordering::Relaxed)
    }

    /// Duplicate [`SolveRequest`]s a [`BackgroundSolver`] dropped
    /// before they reached the cache: requests queued for a key already
    /// in the same drained batch. Re-deferrals that arrive in *later*
    /// batches show up as hits or single-flight stalls instead.
    pub fn coalesced_solves(&self) -> u64 {
        self.coalesced.load(Ordering::Relaxed)
    }

    /// Hits served from an entry another board first computed (or was
    /// first to start computing): cold solves a board skipped because a
    /// peer board warmed the shared cache. A subset of [`Self::hits`].
    /// Zero unless lookups arrive through
    /// [`Self::get_or_compute_from`] with distinct origins.
    pub fn cross_board_hits(&self) -> u64 {
        self.cross_board.load(Ordering::Relaxed)
    }

    /// Number of distinct `(config, dag)` schedules held (ready
    /// entries only; in-flight solves don't count until they land).
    pub fn len(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .values()
            .filter(|s| matches!(s, Slot::Ready(..)))
            .count()
    }

    /// Does the cache hold no schedules at all?
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// One-line entry/hit/miss summary for logs.
    pub fn stats(&self) -> String {
        format!("{} entries, {} hits, {} misses", self.len(), self.hits(), self.misses())
    }

    // ---- persistence -----------------------------------------------------

    /// Serialize every ready entry (key + schedule) to a JSON value.
    /// Keys are the same `(FilcoConfig, platform fp, dag fp)` triple as
    /// the in-memory map; fingerprints are hex strings (u64 does not
    /// fit an f64 exactly). Deterministic: entries sorted by key.
    /// In-flight solves are skipped — they have no result to persist.
    pub fn to_json(&self) -> Json {
        let map = self.inner.lock().unwrap();
        let mut sorted: Vec<(&Key, &Arc<CachedSchedule>)> = map
            .iter()
            .filter_map(|(k, s)| match s {
                Slot::Ready(v, _) => Some((k, v)),
                Slot::Pending(..) => None,
            })
            .collect();
        sorted.sort_by_key(|(k, _)| (k.platform, k.dag, cfg_sort_key(&k.cfg)));
        let entries: Vec<Json> = sorted
            .into_iter()
            .map(|(k, v)| {
                let mut e = BTreeMap::new();
                e.insert("cfg".to_string(), config_to_json(&k.cfg));
                e.insert("platform".to_string(), Json::Str(format!("{:016x}", k.platform)));
                e.insert("dag".to_string(), Json::Str(format!("{:016x}", k.dag)));
                e.insert("makespan".to_string(), Json::Num(v.schedule.makespan));
                e.insert(
                    "entries".to_string(),
                    Json::Arr(v.schedule.entries.iter().map(entry_to_json).collect()),
                );
                Json::Obj(e)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("version".to_string(), Json::Num(1.0));
        root.insert("entries".to_string(), Json::Arr(entries));
        Json::Obj(root)
    }

    /// Merge entries from a JSON value previously produced by
    /// [`Self::to_json`]. Existing in-memory entries win on key clash.
    /// Returns the number of entries inserted; counts as neither hits
    /// nor misses.
    pub fn load_json(&self, v: &Json) -> Result<usize, String> {
        match v.get("version").and_then(Json::as_u64) {
            Some(1) => {}
            other => return Err(format!("unsupported schedule-cache version {other:?}")),
        }
        let entries = v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| "missing entries array".to_string())?;
        // Parse everything before touching the map: a malformed file
        // (e.g. truncated mid-write) must not leave the cache
        // half-warmed from data we then report as ignored.
        let mut parsed = Vec::with_capacity(entries.len());
        for e in entries {
            let cfg = config_from_json(e.get("cfg").ok_or("entry missing cfg")?)?;
            let platform = hex_u64(e.get("platform"))?;
            let dag = hex_u64(e.get("dag"))?;
            let makespan =
                e.get("makespan").and_then(Json::as_f64).ok_or("entry missing makespan")?;
            let raw = e.get("entries").and_then(Json::as_arr).ok_or("entry missing entries")?;
            let sched_entries = raw
                .iter()
                .map(entry_from_json)
                .collect::<Result<Vec<ScheduleEntry>, String>>()?;
            let schedule = Schedule { entries: sched_entries, makespan };
            parsed.push((Key { cfg, platform, dag }, schedule));
        }
        let mut loaded = 0usize;
        let mut map = self.inner.lock().unwrap();
        for (key, schedule) in parsed {
            if let std::collections::hash_map::Entry::Vacant(slot) = map.entry(key) {
                slot.insert(Slot::Ready(Arc::new(CachedSchedule::new(schedule)), 0));
                loaded += 1;
            }
        }
        Ok(loaded)
    }

    /// Write the cache to `path` (compact JSON). Writes a sibling temp
    /// file and renames it into place, so a crash mid-save never leaves
    /// a truncated cache behind.
    pub fn save_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut tmp = path.as_os_str().to_owned();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, self.to_json().to_string_compact())?;
        std::fs::rename(&tmp, path)
    }

    /// Load entries from `path`, merging into the in-memory table. A
    /// missing file is not an error (fresh start): returns `Ok(0)`.
    /// A malformed file is reported as `InvalidData`.
    pub fn load_from(&self, path: &Path) -> std::io::Result<usize> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(e),
        };
        let parsed = Json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string()))?;
        self.load_json(&parsed)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }
}

/// A cold-composition solve request for the [`BackgroundSolver`]: the
/// fabric slice a planned resplit would give some tenant, plus that
/// tenant's workload DAG.
pub struct SolveRequest {
    /// Fabric slice to schedule (a planned partition's config).
    pub cfg: FilcoConfig,
    /// The tenant's workload DAG.
    pub dag: Dag,
}

/// Dedicated DSE dispatcher taking cold-composition solves off the
/// serving hot path: each wake it drains *every* pending
/// [`SolveRequest`] from its channel, dedupes the batch by
/// `(cfg, dag)` key (counting drops into
/// [`ScheduleCache::coalesced_solves`]), and resolves the distinct
/// requests through [`ScheduleCache::get_or_compute`] — concurrently
/// on a scoped worker pool when spawned with
/// [`BackgroundSolver::spawn_pool`]. The engine's policy epoch can
/// defer a resplit whose slices are not yet cached and re-propose it
/// once the background solves land. Duplicates that slip into later
/// batches still collapse into cache hits or single-flight waits — the
/// GA/MILP runs once per key no matter what.
pub struct BackgroundSolver {
    tx: Option<mpsc::Sender<SolveRequest>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl BackgroundSolver {
    /// Spawn a single-threaded solver (drain + dedupe, solves run
    /// serially). It exits when every requester handle (including this
    /// struct's own) has been dropped.
    pub fn spawn(platform: Platform, cache: Arc<ScheduleCache>) -> Self {
        Self::spawn_pool(platform, cache, 1)
    }

    /// Spawn the solver dispatcher with `workers` solve threads: each
    /// drained batch's distinct requests fan out round-robin over a
    /// scoped pool, so a resplit waiting on several cold slices pays
    /// one solve's latency instead of their sum. `workers <= 1` solves
    /// serially in batch order.
    pub fn spawn_pool(platform: Platform, cache: Arc<ScheduleCache>, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<SolveRequest>();
        let handle = std::thread::Builder::new()
            .name("filco-dse".into())
            .spawn(move || {
                while let Ok(first) = rx.recv() {
                    // Drain everything already queued: the same key
                    // re-deferred across epochs coalesces into one
                    // lookup instead of paying a solve (or stall) per
                    // duplicate.
                    let mut batch = vec![first];
                    while let Ok(req) = rx.try_recv() {
                        batch.push(req);
                    }
                    let before = batch.len();
                    let mut seen = std::collections::HashSet::new();
                    batch.retain(|r| seen.insert((r.cfg.clone(), dag_fingerprint(&r.dag))));
                    cache
                        .coalesced
                        .fetch_add((before - batch.len()) as u64, Ordering::Relaxed);
                    let k = workers.min(batch.len());
                    if k <= 1 {
                        for req in &batch {
                            let _ = cache.get_or_compute(&platform, &req.cfg, &req.dag);
                        }
                    } else {
                        std::thread::scope(|s| {
                            for lane in 0..k {
                                let (platform, cache, batch) = (&platform, &cache, &batch);
                                s.spawn(move || {
                                    for req in batch.iter().skip(lane).step_by(k) {
                                        let _ =
                                            cache.get_or_compute(platform, &req.cfg, &req.dag);
                                    }
                                });
                            }
                        });
                    }
                }
            })
            .expect("spawn background DSE solver thread");
        Self { tx: Some(tx), handle: Some(handle) }
    }

    /// A cloneable handle for submitting solve requests (e.g. to hand
    /// to a [`FabricEngine`](super::engine::FabricEngine)).
    pub fn requester(&self) -> mpsc::Sender<SolveRequest> {
        self.tx.as_ref().expect("solver not shut down").clone()
    }
}

impl Drop for BackgroundSolver {
    /// Closes the request channel and joins the thread, so every
    /// submitted solve has landed in the cache by the time drop
    /// returns. Any outstanding [`Self::requester`] clones must be
    /// dropped first or the join blocks until they are.
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

fn config_to_json(cfg: &FilcoConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("n_fmus".to_string(), Json::Num(cfg.n_fmus as f64));
    m.insert("m_cus".to_string(), Json::Num(cfg.m_cus as f64));
    m.insert("aies_per_cu".to_string(), Json::Num(cfg.aies_per_cu as f64));
    m.insert("fmu_bytes".to_string(), Json::Num(cfg.fmu_bytes as f64));
    m.insert("cu_buf_bytes".to_string(), Json::Num(cfg.cu_buf_bytes as f64));
    m.insert("fp".to_string(), Json::Bool(cfg.features.fp));
    m.insert("fmf".to_string(), Json::Bool(cfg.features.fmf));
    m.insert("fmv".to_string(), Json::Bool(cfg.features.fmv));
    Json::Obj(m)
}

fn config_from_json(v: &Json) -> Result<FilcoConfig, String> {
    let u64_of = |k: &str| {
        v.get(k).and_then(Json::as_u64).ok_or_else(|| format!("cfg missing field {k}"))
    };
    let bool_of = |k: &str| {
        v.get(k).and_then(Json::as_bool).ok_or_else(|| format!("cfg missing field {k}"))
    };
    Ok(FilcoConfig {
        n_fmus: u64_of("n_fmus")? as u32,
        m_cus: u64_of("m_cus")? as u32,
        aies_per_cu: u64_of("aies_per_cu")? as u32,
        fmu_bytes: u64_of("fmu_bytes")?,
        cu_buf_bytes: u64_of("cu_buf_bytes")?,
        features: Features { fp: bool_of("fp")?, fmf: bool_of("fmf")?, fmv: bool_of("fmv")? },
    })
}

fn entry_to_json(e: &ScheduleEntry) -> Json {
    let mut m = BTreeMap::new();
    m.insert("layer".to_string(), Json::Num(e.layer as f64));
    m.insert("mode".to_string(), Json::Num(e.mode as f64));
    m.insert("start".to_string(), Json::Num(e.start));
    m.insert("end".to_string(), Json::Num(e.end));
    m.insert("fmus".to_string(), Json::Arr(e.fmus.iter().map(|&f| Json::Num(f as f64)).collect()));
    m.insert("cus".to_string(), Json::Arr(e.cus.iter().map(|&c| Json::Num(c as f64)).collect()));
    Json::Obj(m)
}

fn entry_from_json(v: &Json) -> Result<ScheduleEntry, String> {
    let ids = |k: &str| -> Result<Vec<u32>, String> {
        v.get(k)
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("schedule entry missing {k}"))?
            .iter()
            .map(|x| x.as_u64().map(|u| u as u32).ok_or_else(|| format!("bad id in {k}")))
            .collect()
    };
    Ok(ScheduleEntry {
        layer: v.get("layer").and_then(Json::as_u64).ok_or("entry missing layer")? as usize,
        mode: v.get("mode").and_then(Json::as_u64).ok_or("entry missing mode")? as usize,
        start: v.get("start").and_then(Json::as_f64).ok_or("entry missing start")?,
        end: v.get("end").and_then(Json::as_f64).ok_or("entry missing end")?,
        fmus: ids("fmus")?,
        cus: ids("cus")?,
    })
}

fn hex_u64(v: Option<&Json>) -> Result<u64, String> {
    let s = v.and_then(Json::as_str).ok_or("missing fingerprint")?;
    u64::from_str_radix(s, 16).map_err(|e| format!("bad fingerprint {s:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn hit_on_second_lookup() {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        let a = cache.get_or_compute(&p, &cfg, &dag);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.get_or_compute(&p, &cfg, &dag);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the memoized Arc");
        assert!(a.per_request_s > 0.0);
    }

    #[test]
    fn concurrent_misses_are_single_flight() {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        const N: usize = 4;
        let results: Vec<Arc<CachedSchedule>> = std::thread::scope(|s| {
            let handles: Vec<_> =
                (0..N).map(|_| s.spawn(|| cache.get_or_compute(&p, &cfg, &dag))).collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // However the threads interleave, the expensive DSE ran once:
        // one leader solved, everyone else hit or waited on its flight.
        assert_eq!(cache.solve_count(), 1, "concurrent same-key misses must share one solve");
        assert_eq!(cache.hits() + cache.misses(), N as u64);
        assert!(cache.misses() >= 1);
        assert_eq!(cache.stalls(), cache.misses() - 1, "every non-leader miss is a stall");
        assert_eq!(cache.len(), 1);
        for r in &results[1..] {
            assert!(Arc::ptr_eq(&results[0], r), "all callers must share the leader's Arc");
        }
    }

    #[test]
    fn get_cached_probes_without_counting() {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        assert!(cache.get_cached(&p, &cfg, &dag).is_none());
        assert_eq!((cache.hits(), cache.misses()), (0, 0), "a probe is not a lookup");
        let solved = cache.get_or_compute(&p, &cfg, &dag);
        let probed = cache.get_cached(&p, &cfg, &dag).expect("ready after solve");
        assert!(Arc::ptr_eq(&solved, &probed));
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
    }

    #[test]
    fn cross_board_hit_skips_the_cold_solve() {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        // Board 0 pays the cold solve.
        let a = cache.get_or_compute_from(&p, &cfg, &dag, 0);
        assert_eq!((cache.solve_count(), cache.cross_board_hits()), (1, 0));
        // Board 1's first lookup of the same (slice, DAG) key is a warm
        // hit on board 0's entry: no second solve, one cross-board hit.
        let b = cache.get_or_compute_from(&p, &cfg, &dag, 1);
        assert!(Arc::ptr_eq(&a, &b), "board 1 must share board 0's Arc");
        assert_eq!(cache.solve_count(), 1, "board 1's cold solve must be avoided");
        assert_eq!((cache.hits(), cache.cross_board_hits()), (1, 1));
        // Same-board re-lookups are plain hits, not cross-board ones.
        let _ = cache.get_or_compute_from(&p, &cfg, &dag, 0);
        assert_eq!((cache.hits(), cache.cross_board_hits()), (2, 1));
    }

    #[test]
    fn background_solver_lands_requests_in_the_cache() {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = Arc::new(ScheduleCache::new(ScheduleCache::serving_solver()));
        let solver = BackgroundSolver::spawn(p.clone(), cache.clone());
        let tx = solver.requester();
        tx.send(SolveRequest { cfg: cfg.clone(), dag: dag.clone() }).unwrap();
        // Re-deferring the same key must not re-run the GA.
        tx.send(SolveRequest { cfg: cfg.clone(), dag: dag.clone() }).unwrap();
        drop(tx);
        drop(solver); // join: both requests fully processed
        assert!(cache.get_cached(&p, &cfg, &dag).is_some());
        assert_eq!(cache.solve_count(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_configs_distinct_entries() {
        let p = Platform::vck190();
        let base = FilcoConfig::default_for(&p);
        let mut half = base.clone();
        half.m_cus = base.m_cus / 2;
        half.n_fmus = base.n_fmus / 2;
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        let full = cache.get_or_compute(&p, &base, &dag);
        let small = cache.get_or_compute(&p, &half, &dag);
        assert_eq!(cache.len(), 2);
        // Fewer CUs can never make the schedule faster.
        assert!(small.per_request_s >= full.per_request_s * 0.999);
    }

    #[test]
    fn platform_changes_miss_the_cache() {
        let p = Platform::vck190();
        let mut slower = Platform::vck190();
        slower.ddr.peak_bytes_per_sec /= 2.0;
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        let a = cache.get_or_compute(&p, &cfg, &dag);
        let b = cache.get_or_compute(&slower, &cfg, &dag);
        assert_eq!(cache.len(), 2, "a different platform model must be a distinct entry");
        // Half the DDR bandwidth can never speed a schedule up.
        assert!(b.per_request_s >= a.per_request_s * 0.999);
    }

    #[test]
    fn persistence_roundtrip_warms_a_fresh_cache() {
        let p = Platform::vck190();
        let base = FilcoConfig::default_for(&p);
        let mut half = base.clone();
        half.m_cus = base.m_cus / 2;
        half.n_fmus = base.n_fmus / 2;
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        let a = cache.get_or_compute(&p, &base, &dag);
        let b = cache.get_or_compute(&p, &half, &dag);

        // Per-process name: concurrent test runs must not race on it.
        let path = std::env::temp_dir()
            .join(format!("filco_sched_cache_test_{}.json", std::process::id()));
        cache.save_to(&path).expect("save");

        let warm = ScheduleCache::new(ScheduleCache::serving_solver());
        let loaded = warm.load_from(&path).expect("load");
        assert_eq!(loaded, 2);
        assert_eq!(warm.len(), 2);
        // Lookups after a warm start are pure hits: the GA never runs.
        let a2 = warm.get_or_compute(&p, &base, &dag);
        let b2 = warm.get_or_compute(&p, &half, &dag);
        assert_eq!((warm.hits(), warm.misses()), (2, 0));
        assert_eq!(a2.per_request_s, a.per_request_s, "makespan must survive the roundtrip");
        assert_eq!(b2.per_request_s, b.per_request_s);
        assert_eq!(a2.schedule.entries.len(), a.schedule.entries.len());
        assert_eq!(a2.steps.len(), a.steps.len());
        assert_eq!(a2.steps.last().unwrap().end_s, a.steps.last().unwrap().end_s);
        // Loading again merges idempotently.
        assert_eq!(warm.load_from(&path).expect("reload"), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn load_missing_file_is_a_fresh_start() {
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        let path = std::env::temp_dir().join("filco_sched_cache_does_not_exist.json");
        assert_eq!(cache.load_from(&path).expect("missing file tolerated"), 0);
        assert!(cache.is_empty());
    }

    #[test]
    fn neighbors_returns_same_dag_other_slices_only() {
        let p = Platform::vck190();
        let base = FilcoConfig::default_for(&p);
        let mut half = base.clone();
        half.m_cus = (base.m_cus / 2).max(1);
        half.n_fmus = (base.n_fmus / 2).max(1);
        let dag = zoo::mlp_s();
        let other_dag = zoo::mlp_l();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        assert!(cache.neighbors(&p, &base, &dag).is_empty(), "cold cache has no neighbors");
        cache.get_or_compute(&p, &base, &dag);
        cache.get_or_compute(&p, &half, &dag);
        cache.get_or_compute(&p, &base, &other_dag);
        // Probing for `dag` under `base` sees only `half`'s entry: the
        // same-config entry and the other DAG's entry are excluded.
        let n = cache.neighbors(&p, &base, &dag);
        assert_eq!(n.len(), 1);
        let expect = cache.get_cached(&p, &half, &dag).unwrap();
        assert!(Arc::ptr_eq(&n[0], &expect));
        // And symmetrically from the other slice's point of view.
        assert_eq!(cache.neighbors(&p, &half, &dag).len(), 1);
    }

    #[test]
    fn warm_started_cache_solves_are_equal_or_better() {
        let p = Platform::vck190();
        let base = FilcoConfig::default_for(&p);
        let mut half = base.clone();
        half.m_cus = (base.m_cus / 2).max(1);
        half.n_fmus = (base.n_fmus / 2).max(1);
        let dag = zoo::mlp_s();
        let cold = ScheduleCache::new(ScheduleCache::serving_solver());
        let cold_half = cold.get_or_compute(&p, &half, &dag);
        // Same solver, warm-start enabled, with `base`'s schedule ready
        // to seed the `half` solve.
        let warm = ScheduleCache::new(ScheduleCache::serving_solver())
            .with_tuning(DseTuning { warm_start: true, ..DseTuning::default() });
        warm.get_or_compute(&p, &base, &dag);
        let warm_half = warm.get_or_compute(&p, &half, &dag);
        // mlp-s is a chain, where both runs converge onto per-layer
        // fastest modes: the warm solve must not lose makespan.
        assert!(
            warm_half.per_request_s <= cold_half.per_request_s * 1.000_001,
            "warm {} vs cold {}",
            warm_half.per_request_s,
            cold_half.per_request_s
        );
        let table = crate::dse::stage1::optimize(&p, &half, &dag);
        warm_half.schedule.validate(&dag, &table, half.n_fmus, half.m_cus).unwrap();
    }

    #[test]
    fn background_pool_coalesces_duplicates_and_accounts_for_them() {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = Arc::new(
            ScheduleCache::new(ScheduleCache::serving_solver())
                .with_tuning(DseTuning::accelerated(2)),
        );
        const N: u64 = 6;
        {
            let solver = BackgroundSolver::spawn_pool(p.clone(), cache.clone(), 2);
            let tx = solver.requester();
            for _ in 0..N {
                tx.send(SolveRequest { cfg: cfg.clone(), dag: dag.clone() }).unwrap();
            }
            drop(tx);
            // Dropping the solver joins the dispatcher: every request
            // was either coalesced in a batch or reached the cache.
        }
        assert!(cache.get_cached(&p, &cfg, &dag).is_some());
        assert_eq!(cache.solve_count(), 1, "one key must solve once");
        // Conservation: however the dispatcher batched the stream,
        // each of the N duplicates was dropped by dedupe or became a
        // cache lookup (hit, leader miss, or single-flight stall).
        assert_eq!(cache.coalesced_solves() + cache.hits() + cache.misses(), N);
        assert!(cache.misses() >= 1);
    }

    #[test]
    fn pooled_solver_lands_distinct_requests() {
        let p = Platform::vck190();
        let base = FilcoConfig::default_for(&p);
        let mut half = base.clone();
        half.m_cus = (base.m_cus / 2).max(1);
        half.n_fmus = (base.n_fmus / 2).max(1);
        let dag = zoo::mlp_s();
        let cache = Arc::new(ScheduleCache::new(ScheduleCache::serving_solver()));
        {
            let solver = BackgroundSolver::spawn_pool(p.clone(), cache.clone(), 4);
            let tx = solver.requester();
            tx.send(SolveRequest { cfg: base.clone(), dag: dag.clone() }).unwrap();
            tx.send(SolveRequest { cfg: half.clone(), dag: dag.clone() }).unwrap();
            drop(tx);
        }
        assert!(cache.get_cached(&p, &base, &dag).is_some());
        assert!(cache.get_cached(&p, &half, &dag).is_some());
        assert_eq!(cache.solve_count(), 2);
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn fingerprint_sensitive_to_structure() {
        let a = zoo::mlp_s();
        let mut b = zoo::mlp_s();
        b.edges.pop();
        assert_ne!(dag_fingerprint(&a), dag_fingerprint(&b));
        assert_eq!(dag_fingerprint(&a), dag_fingerprint(&zoo::mlp_s()));
    }
}
