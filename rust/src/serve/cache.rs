//! Schedule cache: memoized two-stage DSE results keyed on
//! `(FilcoConfig, Dag)`.
//!
//! Live re-composition changes each tenant's fabric slice every policy
//! epoch, but the set of distinct `(slice config, tenant DAG)` pairs a
//! serving process ever sees is tiny — weights oscillate between a few
//! load regimes. Caching the Stage-1 + Stage-2 result means the GA/MILP
//! never runs on the re-partition hot path after the first time a
//! composition is seen: a repartition into a previously-seen shape is a
//! hash lookup (~ns) instead of a DSE run (~ms–s).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::arch::FilcoConfig;
use crate::dse::{self, Schedule, Solver};
use crate::platform::Platform;
use crate::workload::Dag;

/// Structural fingerprint of a DAG: name, layer names/shapes and edges.
/// Two DAGs with the same fingerprint get the same schedule.
pub fn dag_fingerprint(dag: &Dag) -> u64 {
    let mut h = DefaultHasher::new();
    dag.name.hash(&mut h);
    dag.layers.len().hash(&mut h);
    for l in &dag.layers {
        l.name.hash(&mut h);
        l.shape.hash(&mut h);
    }
    dag.edges.hash(&mut h);
    h.finish()
}

/// Fingerprint of the platform model a schedule was computed against.
/// `Platform`'s fields are public and tunable (DDR-bandwidth what-ifs
/// etc.), so the key must not assume one cache == one platform. Fields
/// are hashed directly — no allocation on the lookup hot path.
fn platform_fingerprint(p: &Platform) -> u64 {
    let mut h = DefaultHasher::new();
    p.name.hash(&mut h);
    p.aie_tiles.hash(&mut h);
    p.aie_ghz.to_bits().hash(&mut h);
    p.aie_macs_per_cycle.hash(&mut h);
    p.aie_local_bytes.hash(&mut h);
    p.aie_pm_bytes.hash(&mut h);
    p.pl_mhz.to_bits().hash(&mut h);
    p.pl_sram_bytes.hash(&mut h);
    p.plio_bits.hash(&mut h);
    p.plio_ports.hash(&mut h);
    p.ddr.peak_bytes_per_sec.to_bits().hash(&mut h);
    p.ddr.txn_latency_s.to_bits().hash(&mut h);
    for &(burst, frac) in &p.ddr.efficiency_points {
        burst.hash(&mut h);
        frac.to_bits().hash(&mut h);
    }
    h.finish()
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct Key {
    cfg: FilcoConfig,
    platform: u64,
    dag: u64,
}

/// One memoized DSE result.
#[derive(Debug, Clone)]
pub struct CachedSchedule {
    pub schedule: Schedule,
    /// Fabric seconds one request (one DAG traversal) takes on this
    /// slice — the schedule makespan.
    pub per_request_s: f64,
}

/// Thread-safe memo table for two-stage DSE results.
pub struct ScheduleCache {
    solver: Solver,
    inner: Mutex<HashMap<Key, Arc<CachedSchedule>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScheduleCache {
    pub fn new(solver: Solver) -> Self {
        Self {
            solver,
            inner: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// A solver sized for serving-time re-scheduling: small GA, fixed
    /// seed (deterministic across runs).
    pub fn serving_solver() -> Solver {
        Solver::Ga { population: 24, generations: 40, seed: 0xF11C0 }
    }

    /// Look up the schedule for `dag` on fabric slice `cfg`, running the
    /// two-stage DSE on a miss. Misses compute outside the map lock so
    /// concurrent lookups of *different* keys don't serialize.
    pub fn get_or_compute(
        &self,
        platform: &Platform,
        cfg: &FilcoConfig,
        dag: &Dag,
    ) -> Arc<CachedSchedule> {
        let key = Key {
            cfg: cfg.clone(),
            platform: platform_fingerprint(platform),
            dag: dag_fingerprint(dag),
        };
        if let Some(hit) = self.inner.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit.clone();
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // Known trade-off: two threads missing on the same key both run
        // the DSE and one result is discarded. In practice one policy
        // thread is the only writer; if that changes, add an in-flight
        // marker so the second caller waits instead of recomputing.
        let schedule = dse::two_stage(platform, cfg, dag, self.solver);
        let cached = Arc::new(CachedSchedule { per_request_s: schedule.makespan, schedule });
        let mut map = self.inner.lock().unwrap();
        // A racing thread may have inserted meanwhile; keep one copy.
        map.entry(key).or_insert_with(|| cached.clone()).clone()
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of distinct `(config, dag)` schedules held.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> String {
        format!("{} entries, {} hits, {} misses", self.len(), self.hits(), self.misses())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn hit_on_second_lookup() {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        let a = cache.get_or_compute(&p, &cfg, &dag);
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let b = cache.get_or_compute(&p, &cfg, &dag);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the memoized Arc");
        assert!(a.per_request_s > 0.0);
    }

    #[test]
    fn distinct_configs_distinct_entries() {
        let p = Platform::vck190();
        let base = FilcoConfig::default_for(&p);
        let mut half = base.clone();
        half.m_cus = base.m_cus / 2;
        half.n_fmus = base.n_fmus / 2;
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        let full = cache.get_or_compute(&p, &base, &dag);
        let small = cache.get_or_compute(&p, &half, &dag);
        assert_eq!(cache.len(), 2);
        // Fewer CUs can never make the schedule faster.
        assert!(small.per_request_s >= full.per_request_s * 0.999);
    }

    #[test]
    fn platform_changes_miss_the_cache() {
        let p = Platform::vck190();
        let mut slower = Platform::vck190();
        slower.ddr.peak_bytes_per_sec /= 2.0;
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::mlp_s();
        let cache = ScheduleCache::new(ScheduleCache::serving_solver());
        let a = cache.get_or_compute(&p, &cfg, &dag);
        let b = cache.get_or_compute(&slower, &cfg, &dag);
        assert_eq!(cache.len(), 2, "a different platform model must be a distinct entry");
        // Half the DDR bandwidth can never speed a schedule up.
        assert!(b.per_request_s >= a.per_request_s * 0.999);
    }

    #[test]
    fn fingerprint_sensitive_to_structure() {
        let a = zoo::mlp_s();
        let mut b = zoo::mlp_s();
        b.edges.pop();
        assert_ne!(dag_fingerprint(&a), dag_fingerprint(&b));
        assert_eq!(dag_fingerprint(&a), dag_fingerprint(&zoo::mlp_s()));
    }
}
