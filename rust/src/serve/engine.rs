//! The one fabric engine: a deterministic execution core shared by the
//! virtual-time simulator and the live threaded scheduler.
//!
//! FILCO's fabric exists once; this module models it once. The engine
//! owns everything that used to be duplicated between
//! [`sim`](super::sim) and [`scheduler`](super::scheduler): per-tenant
//! pending queues with admission control (queue depth and fabric-time
//! [`TokenBucket`]s), the fabric partitions with their in-flight
//! [`BatchCursor`]s, the per-partition [`Interleaver`]s of packed
//! groups, the [`Reconfigurator`] and weight state, and — crucially —
//! every composition transition. Resplit, mid-DAG preemption, pack and
//! unpack all land through the [`Transition`] enum applied at exactly
//! one site ([`FabricEngine::apply`]), so the live path and the
//! simulated path cannot drift apart: they *are* the same path.
//!
//! # Time model
//!
//! The engine advances only in *fabric seconds* and only when a driver
//! calls [`FabricEngine::step`] with a fabric instant. Between steps it
//! is inert. [`FabricEngine::next_time`] reports the earliest fabric
//! instant at which anything can happen (a trace arrival, a batch
//! completion, a packed interleaver step, a policy epoch), so a driver
//! is a loop of `next_time` → advance its [`Clock`](super::Clock) →
//! `step`:
//!
//! * the simulator runs the loop on a
//!   [`VirtualClock`](super::VirtualClock) (instant jumps);
//! * the live scheduler's worker shells run the same loop on a
//!   [`WallClock`](super::WallClock) (deadline-paced sleeps), feeding
//!   external requests in through [`FabricEngine::push`].
//!
//! Because no decision reads the wall clock, a paced live run and a
//! simulated run of the same scenario produce identical
//! [`EngineEvent`] traces (asserted by `rust/tests/serve_engine.rs`).
//!
//! # Execution accounting
//!
//! Solo partitions account batches in closed form: an in-flight batch's
//! completion is `start + projected_total_s()`, bit-for-bit the
//! batch-atomic [`batch_fabric_s`](super::batch_fabric_s) when
//! undisturbed — which is what keeps the pre-refactor simulator oracles
//! (`rust/tests/serve_preempt.rs`) binding. Packed partitions execute
//! step-by-step through their interleaver on a per-group fabric clock.
//! A policy epoch reads *exact* cursor positions (the epoch sync
//! commits retired layer steps first), so `remaining_on` feeds the
//! preemption benefit term precisely in both drivers.
//!
//! # Mid-flight pack handoff
//!
//! A pack transition no longer waits for its members to go idle: a
//! member with an in-flight solo batch has its cursor committed to the
//! last layer boundary, checkpointed, and resumed inside the new shared
//! partition's interleaver ([`EngineEvent::PackHandoff`]). The cursor's
//! consumed-time ledger is positional, so the handed-off batch's final
//! consumed fabric time equals the undisturbed solo walk bit-for-bit —
//! no fabric time is lost or minted by the migration (asserted on
//! `f64`s in `rust/tests/serve_engine.rs`).
//!
//! # The unified composition
//!
//! The paper's other headline shape — the whole fabric composed into
//! *one* accelerator — is an engine mode too, not a separate model:
//! [`Transition::Unify`] (applied once, at construction, by
//! [`FabricEngine::new_unified`]) puts every tenant into a permanent
//! round-robin group on the whole-fabric slice. The group serves one
//! batch at a time with the same closed-form accounting as a solo
//! partition (`start + projected_total_s()`), picks the next tenant by
//! scanning from a rotating cursor that advances past the served
//! tenant, and admits arrivals *before* the pick at any given instant
//! — exactly the retired closed-form baseline's event order, which the
//! oracle in `rust/tests/serve_engine.rs` holds it to bit-for-bit
//! (`completion_s`, served/rejected/throttled, every histogram value).
//! While unified, every other transition is refused and no policy
//! runs: there are no partitions to re-split, pack or preempt across.
//!
//! # Sharded stepping
//!
//! Partitions share no execution state — that is FILCO's whole pitch —
//! so the partitioned step decomposes into *partition units*: each
//! packed group (with its members' lanes) and each solo tenant's lane
//! is one unit, moved wholesale into an owned task, stepped
//! independently, and merged back in a fixed unit order. With
//! [`FabricEngine::set_shards`] above 1 the units run on a pool of
//! shard worker threads; at 1 they run inline through the *same* unit
//! functions. Every float operation happens inside a unit and the
//! merge only concatenates, so the emitted event stream is bit-for-bit
//! identical for any shard count (the sharded-vs-serial differential
//! in `rust/tests/serve_engine.rs` holds it there). Composition
//! transitions and the policy epoch stay global barriers at the single
//! [`FabricEngine::apply`] site, after every unit has merged.
//!
//! # Off-hot-path DSE (async solve)
//!
//! With [`PolicyConfig::async_solve`] set and a background solver
//! attached ([`FabricEngine::set_solve_channel`]), a re-split whose new
//! slices are not all memoized yet is *deferred*: the missing
//! `(config, DAG)` keys are handed to the
//! [`BackgroundSolver`](super::cache::BackgroundSolver) channel, the
//! epoch keeps the last cached split, and the re-split is re-proposed
//! at a later epoch boundary once the solves have landed — so the step
//! and push hot paths never wait on a GA/MILP run.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc};

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::reconfig::Reconfigurator;
use crate::platform::Platform;

use super::cache::{CachedSchedule, ScheduleCache, SolveRequest};
use super::interleave::Interleaver;
use super::policy::{
    backlog_weights, inflight_backlog_s, pack_groups, pack_quantum_s, should_pack,
    should_preempt, should_resplit, should_unpack, slo_backlog_boost, PolicyConfig,
};
use super::queue::PushError;
use super::telemetry::{DecisionKind, DecisionSample, EpochSample, LockMeter, TenantSample};
use super::tenant::{admit_arrival, Arrival, BatchCursor, TenantSpec, TokenBucket};

/// One observable state change of the engine, stamped with the fabric
/// instant it is accounted at. Event traces are bit-comparable between
/// drivers: every `f64` in here is produced by the engine's own
/// deterministic arithmetic, never by a driver's clock.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineEvent {
    /// A request passed admission control and joined its tenant's
    /// pending queue. Recorded into the trace only (never in a step's
    /// returned event buffer, like the refusal events): together with
    /// [`Self::BatchDone`] it makes a recorded trace self-contained —
    /// per-tenant FIFO pairing of admissions with completions
    /// reproduces every latency record bit-for-bit (see
    /// [`telemetry`](super::telemetry)).
    Admitted {
        /// Tenant whose request was admitted.
        tenant: usize,
        /// The request's caller-assigned id.
        id: u64,
        /// Fabric instant the request arrived at.
        at_s: f64,
    },
    /// A batch left a tenant's pending queue and began executing.
    BatchStarted {
        /// Tenant whose batch started.
        tenant: usize,
        /// Requests in the batch.
        n: usize,
        /// Fabric instant the batch was admitted at.
        at_s: f64,
    },
    /// A batch finished; its requests' latencies were recorded.
    BatchDone {
        /// Tenant whose batch finished.
        tenant: usize,
        /// Requests in the batch.
        n: usize,
        /// Fabric instant the batch completed at.
        at_s: f64,
        /// The batch cursor's final consumed fabric seconds (solo walk
        /// total plus any mid-DAG switch charges) — what the handoff
        /// conservation test asserts on.
        consumed_s: f64,
    },
    /// A request was refused by queue-depth admission control.
    Rejected {
        /// Tenant whose request was rejected.
        tenant: usize,
        /// Fabric instant of the refusal.
        at_s: f64,
    },
    /// A request was refused by the tenant's fabric-time token bucket.
    Throttled {
        /// Tenant whose request was throttled.
        tenant: usize,
        /// Fabric instant of the refusal.
        at_s: f64,
    },
    /// The fabric was re-split onto new partition weights.
    Resplit {
        /// The (reduced) per-group weights applied.
        weights: Vec<u32>,
        /// Fabric instant of the re-composition.
        at_s: f64,
    },
    /// An in-flight batch was preempted at a layer boundary and
    /// re-based onto its tenant's new slice.
    Preempted {
        /// Tenant whose in-flight batch was preempted.
        tenant: usize,
        /// Fabric instant of the policy epoch that approved it.
        at_s: f64,
    },
    /// Tenants were packed onto one shared time-multiplexed partition.
    Packed {
        /// Member tenant indices, ascending; the first leads.
        members: Vec<usize>,
        /// Fabric instant of the transition.
        at_s: f64,
    },
    /// A running solo cursor was checkpointed and resumed inside the
    /// shared partition's interleaver (step-granular pack handoff).
    PackHandoff {
        /// Tenant whose in-flight batch migrated.
        tenant: usize,
        /// The cursor's consumed fabric seconds at the handoff
        /// boundary (continuity anchor for the conservation check).
        consumed_s: f64,
        /// Fabric instant of the handoff.
        at_s: f64,
    },
    /// A packed group drained and dissolved back onto solo partitions.
    Unpacked {
        /// The dissolved group's member tenant indices.
        members: Vec<usize>,
        /// Fabric instant of the transition.
        at_s: f64,
    },
    /// The whole fabric was composed into one unified accelerator:
    /// every tenant time-shares it round-robin at batch granularity
    /// from here on (the one-way [`Transition::Unify`]). Emitted into
    /// the caller's event buffer by [`FabricEngine::apply`]; note that
    /// the stock drivers apply the transition at *construction*,
    /// before trace recording is enabled, so this event never appears
    /// in a driver-recorded trace — a unified trace is recognizable by
    /// containing only batch and admission events.
    Unified {
        /// Fabric instant of the composition.
        at_s: f64,
    },
    /// A tenant was migrated across boards: its pending queue, token
    /// bucket and (possibly mid-DAG, checkpoint/resumed) in-flight
    /// batch moved wholesale from board `from` to board `to`. Emitted
    /// by the cluster layer (see [`super::cluster::FabricCluster`])
    /// into the merged trace — a single engine never emits it, so
    /// single-board traces are unchanged.
    Migrated {
        /// The migrated tenant (cluster-global index in merged traces).
        tenant: usize,
        /// Source board.
        from: usize,
        /// Destination board.
        to: usize,
        /// Consumed fabric seconds of the checkpointed in-flight
        /// batch at the migration instant (0.0 if the tenant was
        /// idle) — the continuity anchor for conservation checks.
        consumed_s: f64,
        /// Fabric instant of the migration.
        at_s: f64,
    },
}

/// A composition transition. Every way the fabric can change shape is
/// one of these, and all of them are applied at exactly one site —
/// [`FabricEngine::apply`] — by both drivers.
///
/// Mid-DAG preemption is not a standalone variant: its benefit term
/// weighs remaining work *re-costed on the new slice*, which only
/// exists while a [`Transition::Resplit`] is being applied, so the
/// preemption decision and landing live inside that one site.
#[derive(Debug, Clone, PartialEq)]
pub enum Transition {
    /// Merge `members` onto one shared partition (interleaved), with
    /// step-granular handoff of any in-flight member batches.
    Pack {
        /// Member tenant indices, ascending; the first leads.
        members: Vec<usize>,
    },
    /// Dissolve the drained packed group led by `leader` back onto
    /// solo partitions.
    Unpack {
        /// Leader (first member) of the group to dissolve.
        leader: usize,
    },
    /// Re-split the fabric onto new per-group weights; in-flight
    /// batches whose projected saving clears the switch-cost margin
    /// are preempted at their next layer boundary as part of the
    /// application.
    Resplit {
        /// Proposed per-group partition weights (one per leader).
        weights: Vec<u32>,
    },
    /// Compose the whole fabric into one accelerator hosting every
    /// tenant in a permanent round-robin group at batch granularity —
    /// the paper's "unified" shape. One-way: applied once on an idle
    /// engine (at construction, by [`FabricEngine::new_unified`]);
    /// while unified every other transition is refused and no policy
    /// runs, so the engine's walk reproduces the closed-form unified
    /// baseline bit-for-bit.
    Unify,
}

/// A tenant's complete serving state, checkpointed out of one board's
/// engine by [`FabricEngine::remove_tenant`] for re-installation on
/// another board through [`FabricEngine::install_tenant`]. Opaque: it
/// carries the tenant spec, pending queue, latency histogram,
/// served/SLO/refusal counters, the fabric-time token bucket, and —
/// when a batch was mid-DAG — the in-flight [`BatchCursor`] with its
/// consumed-time ledger intact, so the move is lossless.
pub struct TenantExtract {
    spec: TenantSpec,
    cap: usize,
    bucket: Option<TokenBucket>,
    lane: TenantLane,
    rejected: u64,
    throttled: u64,
}

impl TenantExtract {
    /// The migrating tenant's display name.
    pub fn name(&self) -> &str {
        &self.spec.name
    }

    /// Consumed fabric seconds of the checkpointed in-flight batch
    /// (0.0 when the tenant was idle at extraction).
    pub fn inflight_consumed_s(&self) -> f64 {
        self.lane.busy.as_ref().map_or(0.0, |fl| fl.cursor.consumed_s())
    }
}

/// One in-flight batch on a solo partition (closed-form accounting).
struct InFlight {
    cursor: BatchCursor,
    start_s: f64,
    /// Arrival times of the batch's requests (latency recording).
    arrived: Vec<f64>,
}

impl InFlight {
    /// Projected completion time on the cursor's current schedule.
    fn fin_s(&self) -> f64 {
        self.start_s + self.cursor.projected_total_s()
    }
}

/// A packed group's shared partition: an interleaved walk over its
/// members' in-flight batches, advanced lazily as fabric time passes
/// step boundaries.
struct PackedGroup {
    /// Member tenant indices, ascending; `members[0]` leads the group.
    members: Vec<usize>,
    il: Interleaver,
    /// Arrival times of each live slot's requests, keyed by tenant.
    arrived: Vec<(usize, Vec<f64>)>,
    /// Fabric time the shared slice has been simulated through; its
    /// next step retires at `t + il.peek_next_s()`.
    t: f64,
    /// Unpack in progress: no new batches are admitted; the pack
    /// dissolves once the interleaver drains.
    unpacking: bool,
}

/// The unified composition's execution state: the whole fabric as one
/// accelerator, every tenant time-sharing it round-robin at batch
/// granularity. Mirrors the retired closed-form baseline exactly —
/// one batch in flight at a time, accounted like a solo slice
/// ([`InFlight::fin_s`], so an undisturbed batch is the closed form
/// bit-for-bit), with the round-robin cursor advanced past the served
/// tenant after every pick.
///
/// Deliberately *not* an [`Interleaver`] group: an interleaver
/// advances a per-group clock by summing individual step durations,
/// and `t0 + Σ(cᵢ − cᵢ₋₁)` is not `t0 + cₙ` on `f64`s — the
/// step-accumulated clock would drift from the closed form in the
/// last bits and break the bit-for-bit oracle. At batch granularity
/// with zero swap cost the interleaved walk degenerates to one cursor
/// at a time anyway, so the closed-form completion (`start +
/// projected_total_s()`) is both the exact and the simpler model.
struct UnifiedGroup {
    /// Tenant index the next round-robin pick scans from.
    rr: usize,
    /// The one in-flight batch: owning tenant plus its closed-form
    /// execution state.
    busy: Option<(usize, InFlight)>,
    /// Fabric instant the whole-fabric slice frees up (the last
    /// batch's projected completion; the run's completion at drain).
    avail_s: f64,
}

// ---- sharded stepping ----------------------------------------------------

/// Per-tenant mutable serving state, grouped so a partition unit's
/// step can move it wholesale into a shard task and back: ownership is
/// the synchronization — no locks, no sharing, no atomics on the step
/// path.
struct TenantLane {
    /// Admitted requests waiting to be batched, as `(id, arrival_s)`.
    pending: VecDeque<(u64, f64)>,
    /// Fabric latency histogram (queueing + service).
    hist: LatencyHistogram,
    /// Requests served.
    served: u64,
    /// Fabric seconds consumed on this tenant's behalf.
    fabric_s: f64,
    /// The in-flight solo batch, if any (closed-form accounting).
    busy: Option<InFlight>,
    /// Fabric instant the tenant's solo slice frees up.
    avail: f64,
    /// Latency-SLO deadline copied from the tenant's [`SloClass`]
    /// (`None` for throughput tiers — accounting is then inert).
    deadline_s: Option<f64>,
    /// Served requests whose fabric latency met the deadline.
    slo_met: u64,
    /// Served requests whose fabric latency missed the deadline.
    slo_missed: u64,
}

impl Default for TenantLane {
    fn default() -> Self {
        Self {
            pending: VecDeque::new(),
            hist: LatencyHistogram::new(),
            served: 0,
            fabric_s: 0.0,
            busy: None,
            avail: 0.0,
            deadline_s: None,
            slo_met: 0,
            slo_missed: 0,
        }
    }
}

/// Record one served request's SLO outcome on its lane — the single
/// accounting site both retirement paths (solo/unified closed-form and
/// packed interleaver drain) call, so attainment can never diverge
/// between composition modes. A no-op for throughput tiers.
fn record_slo(lane: &mut TenantLane, latency_s: f64) {
    if let Some(d) = lane.deadline_s {
        if latency_s <= d {
            lane.slo_met += 1;
        } else {
            lane.slo_missed += 1;
        }
    }
}

/// One partition unit's owned state for a step: a solo tenant's lane,
/// or a packed group with its members' lanes. Disjointness is
/// structural — every tenant's lane is moved into at most one unit —
/// so units can step on any thread without observing each other.
enum UnitTask {
    /// A non-packed tenant's solo slice.
    Solo {
        /// The tenant's index.
        t: usize,
        /// The tenant's serving state, moved out of the engine.
        lane: TenantLane,
        /// The tenant's current schedule.
        sched: Arc<CachedSchedule>,
        /// The tenant's batch cap.
        max_batch: usize,
    },
    /// A packed group: the shared slice plus each member's lane,
    /// schedule and batch cap (all parallel to `pk.members`).
    Group {
        /// The group's shared-slice state, moved out of the engine.
        pk: PackedGroup,
        /// Each member's `(tenant, lane)`, in member order.
        lanes: Vec<(usize, TenantLane)>,
        /// Each member's current schedule, in member order.
        scheds: Vec<Arc<CachedSchedule>>,
        /// Each member's batch cap, in member order.
        max_batches: Vec<usize>,
    },
}

/// What one unit's step produced, plus the state to reinstall.
struct UnitOutcome {
    /// Group progress and solo retirement events (merged first, in
    /// unit order — the serial phase-1/phase-2 stream).
    events: Vec<EngineEvent>,
    /// Solo batch starts (merged after every unit's `events`, matching
    /// the serial retire-everyone-then-start-everyone phase order).
    start_events: Vec<EngineEvent>,
    /// Batches admitted into the unit's interleaver this step.
    packed_batches: u64,
    /// The unit's state, handed back for reinstallation.
    task: UnitTask,
}

/// Execute one partition unit's step on its owned state — the one
/// function both the inline path and the shard workers run. Every
/// float operation is unit-local, so the outcome is bit-identical
/// regardless of which thread computes it.
fn run_unit(mut unit: UnitTask, now: f64) -> UnitOutcome {
    let mut events = Vec::new();
    let mut start_events = Vec::new();
    let mut packed_batches = 0u64;
    match &mut unit {
        UnitTask::Solo { t, lane, sched, max_batch } => {
            // Retire, then start: a batch completing at `now` frees the
            // slice for its tenant's next batch at the same instant,
            // exactly like the serial retire/start phases.
            if lane.busy.as_ref().is_some_and(|fl| fl.fin_s() <= now) {
                let Some(fl) = lane.busy.take() else {
                    panic!("tenant {t}: in-flight batch vanished after its completion check")
                };
                retire_inflight_lane(*t, lane, fl, &mut events);
            }
            if lane.busy.is_none() && lane.avail <= now {
                if let Some(fl) = take_batch_lane(lane, sched, *max_batch, now) {
                    lane.avail = fl.fin_s();
                    start_events.push(EngineEvent::BatchStarted {
                        tenant: *t,
                        n: fl.arrived.len(),
                        at_s: now,
                    });
                    lane.busy = Some(fl);
                }
            }
        }
        UnitTask::Group { pk, lanes, scheds, max_batches } => {
            packed_batches = group_unit_step(pk, lanes, scheds, max_batches, now, &mut events);
        }
    }
    UnitOutcome { events, start_events, packed_batches, task: unit }
}

/// One packed group's step on owned state: admit member batches into
/// free interleaver slots and retire due steps, alternating until no
/// progress — so a tenant's next batch starts the moment its previous
/// one drains, exactly like a solo slice at the same fabric instant.
/// Returns the number of batches admitted.
fn group_unit_step(
    pk: &mut PackedGroup,
    lanes: &mut [(usize, TenantLane)],
    scheds: &[Arc<CachedSchedule>],
    max_batches: &[usize],
    now: f64,
    out: &mut Vec<EngineEvent>,
) -> u64 {
    let mut admitted = 0u64;
    loop {
        let mut progressed = false;
        if !pk.unpacking {
            for i in 0..lanes.len() {
                let m = lanes[i].0;
                let lane = &mut lanes[i].1;
                if !pk.il.contains(m) && !lane.pending.is_empty() {
                    let take = lane.pending.len().min(max_batches[i]);
                    let mut arrived = Vec::with_capacity(take);
                    for _ in 0..take {
                        let (_id, arr) = lane
                            .pending
                            .pop_front()
                            .expect("group admission: pending length was checked");
                        arrived.push(arr);
                    }
                    if pk.il.is_empty() {
                        // Idle slice: its clock catches up to now
                        // before the new batch's first step.
                        pk.t = pk.t.max(now);
                    }
                    pk.il.add(m, BatchCursor::new(scheds[i].clone(), take));
                    pk.arrived.push((m, arrived));
                    admitted += 1;
                    out.push(EngineEvent::BatchStarted { tenant: m, n: take, at_s: now });
                    progressed = true;
                }
            }
        }
        if drain_group_steps_lane(pk, lanes, now, out) > 0 {
            progressed = true;
        }
        if !progressed {
            break;
        }
    }
    admitted
}

/// Retire a group's interleaver steps whose end lies at or before
/// `bound_s`, advancing the group clock, charging fabric time, and
/// recording completed batches against the members' lanes. Returns how
/// many batches completed — the one accounting site for packed
/// retirement, used by [`group_unit_step`] (bounded by the step
/// instant) and [`FabricEngine::finish`] (bound opened).
fn drain_group_steps_lane(
    pk: &mut PackedGroup,
    lanes: &mut [(usize, TenantLane)],
    bound_s: f64,
    out: &mut Vec<EngineEvent>,
) -> usize {
    let mut completed = 0;
    loop {
        let Some(d) = pk.il.peek_next_s() else { break };
        if pk.t + d > bound_s {
            break;
        }
        let ev = pk
            .il
            .advance()
            .expect("interleaver peeked a next step, so a live slot must advance");
        pk.t += ev.swap_charge_s + ev.step.dur_s;
        let t_done = pk.t;
        let Some(li) = lanes.iter().position(|(m, _)| *m == ev.tenant) else {
            panic!(
                "tenant {} stepped in a group it is no member of (members {:?})",
                ev.tenant, pk.members
            )
        };
        lanes[li].1.fabric_s += ev.swap_charge_s + ev.step.dur_s;
        if ev.done {
            let Some(pos) = pk.arrived.iter().position(|(m, _)| *m == ev.tenant) else {
                panic!(
                    "tenant {} completed a packed batch with no arrival record in its \
                     group (members {:?})",
                    ev.tenant, pk.members
                )
            };
            let (_, arrs) = pk.arrived.remove(pos);
            let lane = &mut lanes[li].1;
            for &arr in &arrs {
                let lat = (t_done - arr).max(0.0);
                lane.hist.record(lat);
                lane.served += 1;
                record_slo(lane, lat);
            }
            out.push(EngineEvent::BatchDone {
                tenant: ev.tenant,
                n: arrs.len(),
                at_s: t_done,
                consumed_s: ev.step.consumed_s,
            });
            completed += 1;
        }
    }
    completed
}

/// Retire one closed-form in-flight batch against its tenant's lane —
/// the single accounting site shared by solo, unified and end-of-run
/// retirement: record each request's fabric latency, bump `served`,
/// charge the fabric-time ledger, emit [`EngineEvent::BatchDone`].
fn retire_inflight_lane(t: usize, lane: &mut TenantLane, fl: InFlight, out: &mut Vec<EngineEvent>) {
    let fin = fl.fin_s();
    for &arr in &fl.arrived {
        let lat = (fin - arr).max(0.0);
        lane.hist.record(lat);
        lane.served += 1;
        record_slo(lane, lat);
    }
    lane.fabric_s += fl.cursor.projected_total_s();
    out.push(EngineEvent::BatchDone {
        tenant: t,
        n: fl.arrived.len(),
        at_s: fin,
        consumed_s: fl.cursor.projected_total_s(),
    });
}

/// Drain up to `max_batch` queued requests of a lane into a fresh
/// closed-form batch starting at `now` — the single batch-assembly
/// site shared by the solo and unified starts. `None` when nothing is
/// queued.
fn take_batch_lane(
    lane: &mut TenantLane,
    sched: &Arc<CachedSchedule>,
    max_batch: usize,
    now: f64,
) -> Option<InFlight> {
    let take = lane.pending.len().min(max_batch);
    if take == 0 {
        return None;
    }
    let mut arrived = Vec::with_capacity(take);
    for _ in 0..take {
        let (_id, arr) = lane
            .pending
            .pop_front()
            .expect("batch assembly: pending length was checked against the take");
        arrived.push(arr);
    }
    let cursor = BatchCursor::new(sched.clone(), take);
    Some(InFlight { cursor, start_s: now, arrived })
}

/// A unit-step job for a shard worker: which unit, stepped to what
/// instant, and where its outcome sits in the merge order.
struct ShardTask {
    seq: usize,
    now: f64,
    unit: UnitTask,
}

struct ShardResult {
    seq: usize,
    outcome: UnitOutcome,
}

/// A fixed pool of shard worker threads stepping partition units in
/// parallel. Tasks are distributed round-robin by merge sequence and
/// results collected back into their sequence slots, so the merged
/// outcome is a pure function of the tasks — thread interleaving can
/// reorder *completion*, never the merge.
struct ShardPool {
    txs: Vec<mpsc::Sender<ShardTask>>,
    results: mpsc::Receiver<ShardResult>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ShardPool {
    fn new(workers: usize) -> Self {
        let (res_tx, results) = mpsc::channel::<ShardResult>();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = mpsc::channel::<ShardTask>();
            let res = res_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("filco-shard-{i}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        let outcome = run_unit(task.unit, task.now);
                        if res.send(ShardResult { seq: task.seq, outcome }).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn shard worker thread");
            txs.push(tx);
            handles.push(handle);
        }
        Self { txs, results, handles }
    }

    /// Run every task to completion and return the outcomes in task
    /// sequence order (a barrier: all units finish before the merge).
    fn run(&self, tasks: Vec<ShardTask>) -> Vec<UnitOutcome> {
        let n = tasks.len();
        for task in tasks {
            let w = task.seq % self.txs.len();
            self.txs[w].send(task).expect("shard worker hung up mid-run");
        }
        let mut slots: Vec<Option<UnitOutcome>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let r = self.results.recv().expect("shard worker died mid-step");
            slots[r.seq] = Some(r.outcome);
        }
        slots.into_iter().map(|s| s.expect("every sequence slot was filled")).collect()
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // Closing the task channels ends the workers' recv loops.
        self.txs.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// The deterministic fabric execution core. See the module docs for
/// the full story; drivers interact through [`Self::push`],
/// [`Self::next_time`], [`Self::step`] and [`Self::finish`], and read
/// results through the accessor methods.
pub struct FabricEngine {
    platform: Platform,
    base: FilcoConfig,
    policy: Option<PolicyConfig>,
    recon: Reconfigurator,
    specs: Vec<TenantSpec>,
    caps: Vec<usize>,
    weights: Vec<u32>,
    scheds: Vec<Arc<CachedSchedule>>,
    per_req: Vec<f64>,
    dims: Vec<(u32, u32)>,
    buckets: Vec<Option<TokenBucket>>,
    /// Per-tenant serving state, one lane per tenant; lanes move
    /// wholesale into partition-unit tasks during a step (see the
    /// module docs' sharded-stepping section).
    lanes: Vec<TenantLane>,
    rejected: Vec<u64>,
    throttled: Vec<u64>,
    packs: Vec<PackedGroup>,
    /// Configured shard count (1 = step units inline).
    shards: usize,
    /// The shard worker pool, spawned while `shards > 1`.
    pool: Option<ShardPool>,
    /// Background-solver request channel; with it attached and
    /// [`PolicyConfig::async_solve`] set, re-splits onto uncached
    /// slices are deferred instead of solved on the hot path.
    solve_tx: Option<mpsc::Sender<SolveRequest>>,
    /// Re-splits deferred to the background solver.
    deferred: u64,
    /// Engine-mutex hold-time meter shared with the live scheduler;
    /// sampled into each [`EpochSample`] (zero when absent).
    lock_meter: Option<Arc<LockMeter>>,
    /// `Some` while the fabric is composed as one unified accelerator
    /// ([`Transition::Unify`]); the partitioned state above is then
    /// inert (no solo slices, no packs, no policy).
    unified: Option<UnifiedGroup>,
    arrivals: Vec<Arrival>,
    ai: usize,
    now: f64,
    next_epoch: f64,
    setup_switches: u64,
    epochs: u64,
    preemptions: u64,
    pack_count: u64,
    unpacks: u64,
    retired_swaps: u64,
    packed_batches: u64,
    pack_group_sizes: Vec<usize>,
    drained_completion: f64,
    /// Schedule solo-batch completion events even when no queue is
    /// waiting and preemption is off (live drivers want timely
    /// retirement; the simulator keeps the oracle's lazier gating).
    eager_completions: bool,
    trace: Option<Vec<EngineEvent>>,
    /// `Some` while timeline sampling is on: one [`EpochSample`] per
    /// policy epoch evaluated.
    timeline: Option<Vec<EpochSample>>,
    /// Decisions evaluated since the current epoch's sample was built
    /// — bridges [`Self::apply_resplit`]'s per-tenant preemption
    /// verdicts into the epoch's sample.
    epoch_decisions: Vec<DecisionSample>,
    /// Which board of a multi-board cluster this engine is (0 for
    /// single-engine drivers). Tags shared-cache lookups (cross-board
    /// warm-hit accounting) and epoch samples.
    board: usize,
    /// External arrivals still pending beyond the engine's own trace —
    /// the cluster's stand-in for [`Self::trace_pending`] in the epoch
    /// gating term (see [`Self::set_external_pending`]).
    external_pending: bool,
}

impl FabricEngine {
    /// Build the engine on an equal initial split (every tenant leads
    /// its own partition). `arrivals` is an optional virtual-time
    /// traffic trace the engine ingests itself (sorted by `t_s`, as
    /// the trace generators produce); live drivers pass an empty trace
    /// and feed [`Self::push`] instead. `switch_cost_s` overrides the
    /// modelled composition-switch cost.
    pub fn new(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        policy: Option<PolicyConfig>,
        switch_cost_s: Option<f64>,
        arrivals: Vec<Arrival>,
        cache: &ScheduleCache,
    ) -> Result<Self, String> {
        Self::new_on_board(platform, base, specs, policy, switch_cost_s, arrivals, cache, 0)
    }

    /// [`Self::new`] for board `board` of a multi-board cluster: the
    /// engine tags its shared-cache lookups (including the setup
    /// solves here) with its board identity, so a solve one board paid
    /// for shows up as a cross-board warm hit when a peer board looks
    /// the same `(slice, DAG)` key up. Board 0 is bit-for-bit
    /// [`Self::new`].
    #[allow(clippy::too_many_arguments)]
    pub fn new_on_board(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        policy: Option<PolicyConfig>,
        switch_cost_s: Option<f64>,
        arrivals: Vec<Arrival>,
        cache: &ScheduleCache,
        board: usize,
    ) -> Result<Self, String> {
        if specs.is_empty() {
            return Err("no tenants".into());
        }
        let mut recon = Reconfigurator::new(base.clone());
        if let Some(s) = switch_cost_s {
            recon.set_switch_cost_s(s);
        }
        let named: Vec<(&str, u32)> = specs.iter().map(|s| (s.name.as_str(), 1)).collect();
        let parts = recon.split(&named)?;
        recon.validate()?;
        let scheds: Vec<Arc<CachedSchedule>> = parts
            .iter()
            .zip(&specs)
            .map(|(part, t)| {
                cache.get_or_compute_from(&platform, &part.config(&base), &t.dag, board)
            })
            .collect();
        let dims: Vec<(u32, u32)> = parts.iter().map(|p| (p.n_fmus(), p.m_cus())).collect();
        let mut eng = Self::scaffold(platform, base, specs, policy, recon, scheds, dims, arrivals);
        eng.board = board;
        Ok(eng)
    }

    /// Build the engine in the *unified* composition: the whole fabric
    /// as one accelerator, every tenant in a permanent round-robin
    /// group at batch granularity ([`Transition::Unify`], applied here
    /// through the one transition site). Tenant schedules are solved
    /// against the whole-fabric config; no policy ever runs and no
    /// other transition is accepted, so the run reproduces the
    /// closed-form unified baseline bit-for-bit. `arrivals` and
    /// `switch_cost_s` behave as in [`Self::new`].
    pub fn new_unified(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        switch_cost_s: Option<f64>,
        arrivals: Vec<Arrival>,
        cache: &ScheduleCache,
    ) -> Result<Self, String> {
        if specs.is_empty() {
            return Err("no tenants".into());
        }
        let mut recon = Reconfigurator::new(base.clone());
        if let Some(s) = switch_cost_s {
            recon.set_switch_cost_s(s);
        }
        // Scaffold against the same whole-fabric schedules the Unify
        // transition resolves (one shared site, so the pre- and
        // post-apply state cannot disagree; the apply's lookups are
        // cache hits of these).
        let scheds = Self::unified_scheds(&platform, &base, &specs, cache);
        let dims = vec![(base.n_fmus, base.m_cus); specs.len()];
        let mut eng = Self::scaffold(platform, base, specs, None, recon, scheds, dims, arrivals);
        // The composition is established through the one transition
        // site, like every other shape change.
        let mut out = Vec::new();
        if !eng.apply(Transition::Unify, 0.0, cache, &mut out) {
            return Err("unified composition rejected".into());
        }
        eng.setup_switches = eng.recon.switches;
        Ok(eng)
    }

    /// The whole-fabric schedule of every tenant — the single
    /// resolution site shared by [`Self::new_unified`] and the
    /// [`Transition::Unify`] application.
    fn unified_scheds(
        platform: &Platform,
        base: &FilcoConfig,
        specs: &[TenantSpec],
        cache: &ScheduleCache,
    ) -> Vec<Arc<CachedSchedule>> {
        specs.iter().map(|t| cache.get_or_compute(platform, base, &t.dag)).collect()
    }

    /// Shared constructor tail: the per-tenant admission / accounting
    /// state every composition mode starts from. `recon` and `scheds`
    /// arrive already shaped by the caller (equal split or unified).
    #[allow(clippy::too_many_arguments)]
    fn scaffold(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        policy: Option<PolicyConfig>,
        recon: Reconfigurator,
        scheds: Vec<Arc<CachedSchedule>>,
        dims: Vec<(u32, u32)>,
        arrivals: Vec<Arrival>,
    ) -> Self {
        let t_n = specs.len();
        let per_req: Vec<f64> = scheds.iter().map(|s| s.per_request_s).collect();
        let buckets: Vec<Option<TokenBucket>> =
            specs.iter().map(|t| t.rate_limit.map(TokenBucket::from_limit)).collect();
        let caps: Vec<usize> = specs.iter().map(|t| t.queue_capacity).collect();
        let next_epoch = policy.as_ref().map(|p| p.epoch_s).unwrap_or(f64::INFINITY);
        let setup_switches = recon.switches;
        Self {
            platform,
            base,
            policy,
            recon,
            caps,
            weights: vec![1; t_n],
            scheds,
            per_req,
            dims,
            buckets,
            lanes: specs
                .iter()
                .map(|t| TenantLane { deadline_s: t.slo.deadline_s(), ..TenantLane::default() })
                .collect(),
            rejected: vec![0; t_n],
            throttled: vec![0; t_n],
            packs: Vec::new(),
            shards: 1,
            pool: None,
            solve_tx: None,
            deferred: 0,
            lock_meter: None,
            unified: None,
            arrivals,
            ai: 0,
            now: 0.0,
            next_epoch,
            setup_switches,
            epochs: 0,
            preemptions: 0,
            pack_count: 0,
            unpacks: 0,
            retired_swaps: 0,
            packed_batches: 0,
            pack_group_sizes: Vec::new(),
            drained_completion: 0.0,
            eager_completions: false,
            trace: None,
            timeline: None,
            epoch_decisions: Vec::new(),
            board: 0,
            external_pending: false,
            specs,
        }
    }

    // ---- driver knobs ----------------------------------------------------

    /// Record every emitted [`EngineEvent`] for later retrieval with
    /// [`Self::take_trace`] (off by default; traces grow with the run).
    pub fn record_trace(&mut self, on: bool) {
        self.trace = if on { Some(Vec::new()) } else { None };
    }

    /// The recorded event trace so far (empty unless
    /// [`Self::record_trace`] was enabled).
    pub fn take_trace(&mut self) -> Vec<EngineEvent> {
        self.trace.take().unwrap_or_default()
    }

    /// Drain the recorded trace so far, leaving recording enabled —
    /// the cluster's per-step collection point (unlike
    /// [`Self::take_trace`], which detaches the recorder).
    pub fn drain_trace(&mut self) -> Vec<EngineEvent> {
        self.trace.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Which board of a multi-board cluster this engine is (0 unless
    /// built with [`Self::new_on_board`]).
    pub fn board(&self) -> usize {
        self.board
    }

    /// Tell the engine whether external arrivals are still pending
    /// beyond its own trace. The cluster holds the global arrival
    /// stream and feeds boards through [`Self::push`], so this flag
    /// stands in for [`Self::trace_pending`] in the epoch gating term
    /// — keeping a cluster board's epoch schedule identical to a
    /// single engine ingesting the same arrivals itself.
    pub fn set_external_pending(&mut self, pending: bool) {
        self.external_pending = pending;
    }

    /// Sample engine state and policy decisions at every epoch into an
    /// [`EpochSample`] timeline, retrievable with
    /// [`Self::take_timeline`] (off by default). Sampling reads state
    /// the epoch already computed and never feeds anything back, so it
    /// cannot change any decision.
    pub fn record_timeline(&mut self, on: bool) {
        self.timeline = if on { Some(Vec::new()) } else { None };
        self.epoch_decisions.clear();
    }

    /// The epoch samples recorded so far (empty unless
    /// [`Self::record_timeline`] was enabled).
    pub fn take_timeline(&mut self) -> Vec<EpochSample> {
        self.timeline.take().unwrap_or_default()
    }

    /// Schedule completion events for in-flight solo batches even when
    /// their queues are empty and preemption is off. Live drivers turn
    /// this on so batches retire (and latencies record) as soon as
    /// they complete; the simulator leaves it off to keep the
    /// pre-refactor oracle's event gating bit-for-bit. Extra wakeups
    /// never change any decision — only when already-determined
    /// retirements are observed.
    pub fn eager_completions(&mut self, on: bool) {
        self.eager_completions = on;
    }

    /// Step partition units on `n` parallel shard workers (`n <= 1`
    /// steps them inline, through the same unit functions). The
    /// emitted event stream is bit-for-bit identical for any shard
    /// count — the merge order is fixed and all arithmetic is
    /// unit-local — so this is purely a throughput knob.
    pub fn set_shards(&mut self, n: usize) {
        let n = n.max(1);
        self.shards = n;
        self.pool = (n > 1).then(|| ShardPool::new(n));
    }

    /// The configured shard count (1 = inline stepping).
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Attach a background-solver request channel (see
    /// [`BackgroundSolver::requester`](super::cache::BackgroundSolver::requester)).
    /// Only consulted while [`PolicyConfig::async_solve`] is set:
    /// re-splits whose new slices are not all memoized send the
    /// missing keys here and defer instead of solving on the hot path.
    pub fn set_solve_channel(&mut self, tx: mpsc::Sender<SolveRequest>) {
        self.solve_tx = Some(tx);
    }

    /// Attach the live scheduler's engine-mutex hold-time meter; each
    /// epoch's [`EpochSample`] then carries the cumulative hold time
    /// (zero when detached, e.g. in the simulator).
    pub fn set_lock_meter(&mut self, meter: Arc<LockMeter>) {
        self.lock_meter = Some(meter);
    }

    // ---- admission -------------------------------------------------------

    /// Admit one external request for `tenant` arriving at fabric
    /// instant `arr_s`: queue depth first (reject as full), then the
    /// optional deadline shed, then the fabric-time token bucket
    /// (throttle) — the same classification order as trace ingest, so
    /// both drivers count refusals identically. A deadline shed is
    /// traced as a `Rejected` event (callers still see the distinct
    /// [`PushError::Deadline`]), so the trace format is unchanged.
    pub fn push(&mut self, tenant: usize, id: u64, arr_s: f64) -> Result<(), PushError> {
        let res = admit_arrival(
            &mut self.lanes[tenant].pending,
            self.caps[tenant],
            &mut self.buckets[tenant],
            self.per_req[tenant],
            self.specs[tenant].shed_deadline_s(),
            id,
            arr_s,
        );
        match res {
            Err(PushError::Full) | Err(PushError::Deadline) => {
                self.rejected[tenant] += 1;
                self.emit(EngineEvent::Rejected { tenant, at_s: arr_s });
            }
            Err(PushError::Throttled) => {
                self.throttled[tenant] += 1;
                self.emit(EngineEvent::Throttled { tenant, at_s: arr_s });
            }
            Ok(()) => {
                self.emit(EngineEvent::Admitted { tenant, id, at_s: arr_s });
            }
            Err(PushError::Closed) => {}
        }
        res
    }

    /// Ingest own-trace arrivals up to `now` (same classification
    /// order as [`Self::push`]).
    fn ingest(&mut self, now: f64) {
        while self.ai < self.arrivals.len() && self.arrivals[self.ai].t_s <= now {
            let a = self.arrivals[self.ai];
            self.ai += 1;
            let _ = self.push(a.tenant, a.id, a.t_s);
        }
    }

    // ---- stepping --------------------------------------------------------

    fn emit(&mut self, ev: EngineEvent) {
        if let Some(tr) = self.trace.as_mut() {
            tr.push(ev);
        }
    }

    /// Advance the engine to fabric instant `now`: ingest due trace
    /// arrivals, progress packed partitions through their step
    /// boundaries, retire and start solo batches, and run the policy
    /// epoch if one is due. Returns the events of this step (also
    /// appended to the trace when recording). Idempotent at a given
    /// instant once everything due has been processed.
    ///
    /// Fabric time is monotone: a `now` behind the engine's clock is
    /// clamped to it. A live driver can legitimately propose a stale
    /// instant — [`Self::next_time`] reports an idle tenant's old
    /// `avail` once an external push lands in its queue — and without
    /// the clamp that batch would start (and instantly retire) in the
    /// past, skipping wall pacing entirely. The simulator's instants
    /// are monotone already, so the clamp is the identity there.
    pub fn step(&mut self, now: f64, cache: &ScheduleCache) -> Vec<EngineEvent> {
        let now = now.max(self.now);
        self.now = now;
        // Whether a due epoch may fire is decided by the state at the
        // *start* of the step — exactly the condition under which the
        // event horizon would have scheduled the epoch instant. Live
        // drivers step at extra instants (external pushes, eager
        // completions) the simulator never visits; without this guard
        // those instants could fire epochs the simulator's gating
        // would never schedule, breaking trace equivalence.
        let epoch_armed = self.epoch_relevant();
        let mut out = Vec::new();
        self.ingest(now);
        if self.unified.is_some() {
            // Unified composition: the ingest above lands every
            // arrival at or before `now` *first* — the closed-form
            // baseline's documented tie-break (admission before
            // service at the same instant) — then retirement frees
            // the fabric for the next round-robin pick.
            self.retire_unified(now, &mut out);
            self.start_unified(now, &mut out);
        } else {
            self.step_partitioned(now, &mut out);
            if epoch_armed {
                self.maybe_epoch(now, cache, &mut out);
            }
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.extend(out.iter().cloned());
        }
        out
    }

    /// The policy-epoch gating term, shared between [`Self::next_time`]
    /// (should an epoch instant be scheduled?) and [`Self::step`]
    /// (may a due epoch fire?): queued work, preemptible in-flight
    /// work, live packed slots, or unconsumed trace arrivals.
    fn epoch_relevant(&self) -> bool {
        let preempt_on = self.policy.as_ref().is_some_and(PolicyConfig::preemption_enabled);
        self.lanes.iter().any(|l| !l.pending.is_empty())
            || (preempt_on && self.lanes.iter().any(|l| l.busy.is_some()))
            || self.packs.iter().any(|pk| !pk.il.is_empty())
            || self.trace_pending()
            || self.external_pending
    }

    /// The partitioned-mode step body: decompose the fabric into
    /// partition units — packed groups in group order, then active
    /// solo tenants ascending — step each unit (inline, or on the
    /// shard pool when one is attached), and merge outcomes in unit
    /// order. The merge performs no float arithmetic and the unit
    /// order is fixed, so the event stream is bit-for-bit identical
    /// for any shard count (held there by the sharded-vs-serial
    /// differential in `rust/tests/serve_engine.rs`).
    fn step_partitioned(&mut self, now: f64, out: &mut Vec<EngineEvent>) {
        let t_n = self.specs.len();
        let packs = std::mem::take(&mut self.packs);
        let mut lane_slots: Vec<Option<TenantLane>> =
            std::mem::take(&mut self.lanes).into_iter().map(Some).collect();
        let mut tasks: Vec<ShardTask> = Vec::new();
        for pk in packs {
            let lanes: Vec<(usize, TenantLane)> = pk
                .members
                .iter()
                .map(|&m| (m, lane_slots[m].take().expect("a tenant sits in at most one pack")))
                .collect();
            let scheds = pk.members.iter().map(|&m| self.scheds[m].clone()).collect();
            let max_batches = pk.members.iter().map(|&m| self.specs[m].max_batch).collect();
            tasks.push(ShardTask {
                seq: tasks.len(),
                now,
                unit: UnitTask::Group { pk, lanes, scheds, max_batches },
            });
        }
        for t in 0..t_n {
            // Packed members' lanes are already owned by their group
            // task; idle solo lanes (nothing in flight, nothing
            // queued) step as provable no-ops and are skipped.
            let active = matches!(
                &lane_slots[t],
                Some(lane) if lane.busy.is_some() || !lane.pending.is_empty()
            );
            if !active {
                continue;
            }
            let lane = lane_slots[t].take().expect("solo activity was just observed");
            tasks.push(ShardTask {
                seq: tasks.len(),
                now,
                unit: UnitTask::Solo {
                    t,
                    lane,
                    sched: self.scheds[t].clone(),
                    max_batch: self.specs[t].max_batch,
                },
            });
        }
        let outcomes: Vec<UnitOutcome> = match &self.pool {
            Some(pool) if tasks.len() > 1 => pool.run(tasks),
            _ => tasks.into_iter().map(|task| run_unit(task.unit, task.now)).collect(),
        };
        // Deterministic merge: every unit's progress/retire events in
        // unit order, then every unit's start events in unit order —
        // the serial phase order — while the moved state reinstalls.
        let mut packs = Vec::new();
        let mut starts: Vec<Vec<EngineEvent>> = Vec::with_capacity(outcomes.len());
        for oc in outcomes {
            out.extend(oc.events);
            starts.push(oc.start_events);
            self.packed_batches += oc.packed_batches;
            match oc.task {
                UnitTask::Group { pk, lanes, .. } => {
                    for (m, lane) in lanes {
                        lane_slots[m] = Some(lane);
                    }
                    packs.push(pk);
                }
                UnitTask::Solo { t, lane, .. } => {
                    lane_slots[t] = Some(lane);
                }
            }
        }
        for s in starts {
            out.extend(s);
        }
        self.packs = packs;
        self.lanes = lane_slots
            .into_iter()
            .map(|s| s.expect("every lane reinstalled after the merge"))
            .collect();
    }

    /// Serial wrapper over [`drain_group_steps_lane`] for group `gi`
    /// against the engine's own lanes — used by [`Self::finish`],
    /// which drains without admitting (never through the unit step).
    fn drain_group_steps(&mut self, gi: usize, bound_s: f64, out: &mut Vec<EngineEvent>) -> usize {
        let members = self.packs[gi].members.clone();
        let mut lanes: Vec<(usize, TenantLane)> =
            members.iter().map(|&m| (m, std::mem::take(&mut self.lanes[m]))).collect();
        let completed = drain_group_steps_lane(&mut self.packs[gi], &mut lanes, bound_s, out);
        for (m, lane) in lanes {
            self.lanes[m] = lane;
        }
        completed
    }

    /// Retire the unified group's in-flight batch once its closed-form
    /// completion has been reached — the same accounting as a solo
    /// slice (`start + projected_total_s()`), so an undisturbed
    /// batch's latencies and completion are the batch-atomic closed
    /// form bit-for-bit, which is what keeps the unified oracle in
    /// `rust/tests/serve_engine.rs` binding.
    fn retire_unified(&mut self, now: f64, out: &mut Vec<EngineEvent>) {
        let Some(u) = self.unified.as_mut() else { return };
        let due = u.busy.as_ref().is_some_and(|(_, fl)| fl.fin_s() <= now);
        if !due {
            return;
        }
        let (t, fl) = u.busy.take().expect("unified batch was checked in flight just above");
        self.retire_inflight(t, fl, out);
    }

    /// Retire one closed-form in-flight batch against the engine's own
    /// lanes (see [`retire_inflight_lane`] for the accounting) — the
    /// unified composition's retirement site.
    fn retire_inflight(&mut self, t: usize, fl: InFlight, out: &mut Vec<EngineEvent>) {
        retire_inflight_lane(t, &mut self.lanes[t], fl, out);
    }

    /// Assemble tenant `t`'s next batch from the engine's own lanes
    /// (see [`take_batch_lane`]) — the unified composition's
    /// batch-assembly site.
    fn take_batch(&mut self, t: usize, now: f64) -> Option<InFlight> {
        take_batch_lane(&mut self.lanes[t], &self.scheds[t], self.specs[t].max_batch, now)
    }

    /// The unified round-robin pick: when the whole-fabric slice is
    /// free, scan from the rotating cursor for the next tenant with
    /// queued work, start one batch, and advance the cursor past the
    /// served tenant — the closed-form baseline's scheduling order,
    /// verbatim. At most one batch is ever in flight: the next pick
    /// happens at this batch's completion instant, with the queue
    /// contents (and arrivals) of *that* instant.
    fn start_unified(&mut self, now: f64, out: &mut Vec<EngineEvent>) {
        let t_n = self.specs.len();
        let Some(u) = self.unified.as_ref() else { return };
        if u.busy.is_some() || u.avail_s > now {
            return;
        }
        let rr = u.rr;
        for k in 0..t_n {
            let t = (rr + k) % t_n;
            let Some(fl) = self.take_batch(t, now) else { continue };
            let u = self.unified.as_mut().expect("unified mode was checked at entry");
            u.avail_s = fl.fin_s();
            u.rr = (t + 1) % t_n;
            out.push(EngineEvent::BatchStarted { tenant: t, n: fl.arrived.len(), at_s: now });
            u.busy = Some((t, fl));
            return;
        }
    }

    // ---- policy epoch ----------------------------------------------------

    /// Run the policy epoch if one is due at `now`.
    fn maybe_epoch(&mut self, now: f64, cache: &ScheduleCache, out: &mut Vec<EngineEvent>) {
        if self.policy.is_none() || now < self.next_epoch {
            return;
        }
        self.run_epoch(now, cache, out);
        let epoch_s = self.policy.as_ref().expect("policy presence was checked at entry").epoch_s;
        while self.next_epoch <= now {
            self.next_epoch += epoch_s;
        }
    }

    /// Force a policy evaluation at the engine's current fabric
    /// instant, regardless of the epoch schedule — the live
    /// scheduler's `policy_step` entry point. Returns true when the
    /// composition changed (a grouping transition or a re-split
    /// landed).
    pub fn epoch_now(&mut self, cache: &ScheduleCache) -> bool {
        if self.policy.is_none() {
            return false;
        }
        let mut out = Vec::new();
        let changed = self.run_epoch(self.now, cache, &mut out);
        if let Some(tr) = self.trace.as_mut() {
            tr.extend(out.iter().cloned());
        }
        changed
    }

    /// One policy evaluation: observe backlog (queued work, plus
    /// migration-discounted in-flight work when preemption is
    /// enabled), decide pack/unpack transitions, and re-split if
    /// warranted — every decision applied through [`Self::apply`].
    fn run_epoch(&mut self, now: f64, cache: &ScheduleCache, out: &mut Vec<EngineEvent>) -> bool {
        let p = self.policy.clone().expect("run_epoch requires a policy");
        let preempt_on = p.preemption_enabled();
        let pack_on = p.packing_enabled();
        let t_n = self.specs.len();
        self.epochs += 1;
        if preempt_on {
            // Sync in-flight cursors to fabric time (packed slices
            // advance eagerly; solo slices account in closed form, so
            // commit the layer steps that retired by `now`) — the
            // remaining-work signals and preemption decisions below
            // then reflect *exact* cursor positions, not batch-start
            // estimates, in both drivers.
            for fl in self.lanes.iter_mut().filter_map(|l| l.busy.as_mut()) {
                while fl.cursor.peek_consumed_s().is_some_and(|c| fl.start_s + c <= now) {
                    let _ = fl.cursor.advance();
                }
            }
        }
        let switch_cost = self.recon.switch_cost_s();
        let backlog: Vec<f64> = (0..t_n)
            .map(|t| {
                let queued = self.lanes[t].pending.len() as f64 * self.per_req[t];
                let inflight = if preempt_on {
                    self.lanes[t]
                        .busy
                        .as_ref()
                        .map(|fl| inflight_backlog_s(fl.cursor.remaining_s(), switch_cost, &p))
                        .unwrap_or(0.0)
                } else {
                    0.0
                };
                // Packed slots' remaining work is always movable (they
                // re-base on every re-split) and is counted, without a
                // migration discount, whenever packing is live.
                let packed_inflight = self
                    .packs
                    .iter()
                    .find(|pk| pk.members.contains(&t))
                    .map(|pk| pk.il.slot_remaining_s(t))
                    .unwrap_or(0.0);
                // Latency-tier tenants see their backlog scaled by the
                // SLO urgency boost; throughput tiers multiply by
                // exactly 1.0, so every no-SLO run keeps its signal
                // (and therefore its trace) bit-for-bit.
                (queued + inflight + packed_inflight)
                    * slo_backlog_boost(self.lanes[t].deadline_s, p.epoch_s)
            })
            .collect();
        let total_backlog: f64 = backlog.iter().sum();
        let mut grouping_changed = false;
        let sample_on = self.timeline.is_some();
        if pack_on {
            // Unpack transitions: mark overloaded groups, dissolve the
            // drained ones.
            for pk in &mut self.packs {
                if pk.unpacking {
                    continue;
                }
                let combined: f64 = pk.members.iter().map(|&m| backlog[m]).sum();
                let approved = should_unpack(combined, p.epoch_s, &p);
                if sample_on {
                    // Signed distance past the unpack hysteresis bound
                    // (`should_unpack`'s terms, both sides in scaled
                    // fabric seconds).
                    self.epoch_decisions.push(DecisionSample {
                        kind: DecisionKind::Unpack,
                        tenants: pk.members.clone(),
                        margin_s: combined * p.pack_headroom_factor
                            - p.pack_unpack_factor * p.epoch_s,
                        approved,
                    });
                }
                if approved {
                    pk.unpacking = true;
                }
            }
            let drained: Vec<usize> = self
                .packs
                .iter()
                .filter(|pk| pk.unpacking && pk.il.is_empty())
                .map(|pk| pk.members[0])
                .collect();
            for leader in drained {
                grouping_changed |= self.apply(Transition::Unpack { leader }, now, cache, out);
            }
            // New packs among unpacked tenants: first-fit-decreasing
            // bin packing against the fit bound, each proposed group
            // re-validated by the shared fit + amortization terms. A
            // tenant's in-flight batch is only movable (mid-flight
            // handoff) when preemption is enabled — with it disabled
            // the work is immovable (and invisible to the fit gate),
            // so a busy tenant must not be packed at all.
            let eligible: Vec<bool> = (0..t_n)
                .map(|t| !self.in_pack(t) && (preempt_on || self.lanes[t].busy.is_none()))
                .collect();
            let capacity_s = p.epoch_s / p.pack_headroom_factor;
            for members in pack_groups(&backlog, &eligible, capacity_s) {
                let combined: f64 = members.iter().map(|&m| backlog[m]).sum();
                let cand: Vec<(f64, usize)> = members
                    .iter()
                    .map(|&m| (self.per_req[m], self.scheds[m].steps.len()))
                    .collect();
                let quantum_s = pack_quantum_s(p.pack_quantum_steps, &cand);
                let approved = should_pack(combined, p.epoch_s, quantum_s, switch_cost, &p);
                if sample_on {
                    // The fit margin (`should_pack`'s first gate); the
                    // swap-amortization gate can still decline a
                    // positive fit, reflected in `approved`.
                    self.epoch_decisions.push(DecisionSample {
                        kind: DecisionKind::Pack,
                        tenants: members.clone(),
                        margin_s: p.epoch_s - combined * p.pack_headroom_factor,
                        approved,
                    });
                }
                if approved {
                    grouping_changed |= self.apply(Transition::Pack { members }, now, cache, out);
                }
            }
        }
        // One group per partition leader; weights proposed from the
        // grouped backlog signal.
        let groups = self.leader_groups();
        let group_backlog: Vec<f64> =
            groups.iter().map(|g| g.iter().map(|&t| backlog[t]).sum()).collect();
        let proposed = backlog_weights(&group_backlog, p.max_weight);
        let resplit = grouping_changed
            || should_resplit(&self.weights, &proposed, total_backlog, switch_cost, &p);
        if sample_on {
            // The backlog-hysteresis margin; an equal-split restore or
            // a grouping change approves the re-split regardless.
            self.epoch_decisions.push(DecisionSample {
                kind: DecisionKind::Resplit,
                tenants: Vec::new(),
                margin_s: total_backlog - p.min_backlog_factor * switch_cost,
                approved: resplit,
            });
        }
        let mut applied = false;
        if resplit {
            applied = self.apply(Transition::Resplit { weights: proposed }, now, cache, out);
        }
        if sample_on {
            // Built at the end of the epoch: the weights and pack
            // shapes reflect this epoch's transitions, while the
            // backlog vector is the pre-transition signal the
            // decisions above actually ran on.
            let sample = EpochSample {
                epoch: self.epochs,
                at_s: now,
                tenants: (0..t_n)
                    .map(|t| TenantSample {
                        queue_depth: self.lanes[t].pending.len(),
                        backlog_s: backlog[t],
                        bucket_tokens: self.buckets[t].as_ref().map(TokenBucket::tokens),
                        slo_met: self.lanes[t].slo_met,
                        slo_missed: self.lanes[t].slo_missed,
                    })
                    .collect(),
                weights: self.weights.clone(),
                pack_shapes: self.packs.iter().map(|pk| pk.members.clone()).collect(),
                cache_hits: cache.hits(),
                cache_misses: cache.misses(),
                lock_held_ns: self.lock_meter.as_ref().map_or(0, |m| m.held_ns()),
                dse_stall_ns: cache.stall_ns(),
                coalesced_solves: cache.coalesced_solves(),
                cross_board_hits: cache.cross_board_hits(),
                board: self.board,
                decisions: std::mem::take(&mut self.epoch_decisions),
            };
            if let Some(tl) = self.timeline.as_mut() {
                tl.push(sample);
            }
        }
        grouping_changed || applied
    }

    // ---- transitions: the one site ---------------------------------------

    /// Apply a composition [`Transition`] — the single site where the
    /// fabric changes shape for both drivers. Returns false when the
    /// transition could not be applied (an invalid split proposal is
    /// logged and skipped; the fabric keeps its current shape; a
    /// unified fabric refuses everything — the unified composition is
    /// permanent).
    pub fn apply(
        &mut self,
        tr: Transition,
        now: f64,
        cache: &ScheduleCache,
        out: &mut Vec<EngineEvent>,
    ) -> bool {
        if self.unified.is_some() {
            log::warn!("transition rejected: the unified composition is permanent");
            return false;
        }
        match tr {
            Transition::Pack { members } => self.apply_pack(members, now, out),
            Transition::Unpack { leader } => self.apply_unpack(leader, out),
            Transition::Resplit { weights } => self.apply_resplit(weights, now, cache, out),
            Transition::Unify => self.apply_unify(now, cache, out),
        }
    }

    /// Compose the whole fabric into one accelerator hosting every
    /// tenant in a permanent round-robin group. Refused (false) unless
    /// the partitioned fabric is idle — the constructor applies it
    /// before any work exists, and there is no inverse transition.
    fn apply_unify(&mut self, now: f64, cache: &ScheduleCache, out: &mut Vec<EngineEvent>) -> bool {
        if self.lanes.iter().any(|l| l.busy.is_some())
            || self.packs.iter().any(|pk| !pk.il.is_empty())
        {
            log::warn!("unify rejected: in-flight work on partitioned slices");
            return false;
        }
        self.packs.clear();
        let part = self.recon.compose_unified();
        debug_assert!(self.recon.validate().is_ok());
        let dims = (part.n_fmus(), part.m_cus());
        let scheds = Self::unified_scheds(&self.platform, &self.base, &self.specs, cache);
        for (t, ns) in scheds.into_iter().enumerate() {
            self.per_req[t] = ns.per_request_s;
            self.scheds[t] = ns;
            self.dims[t] = dims;
        }
        out.push(EngineEvent::Unified { at_s: now });
        self.unified = Some(UnifiedGroup { rr: 0, busy: None, avail_s: now });
        true
    }

    /// Merge `members` onto one shared partition. Members with an
    /// in-flight solo batch are handed off mid-flight: the cursor is
    /// committed to its last layer boundary, checkpointed, and resumed
    /// inside the group's interleaver — the in-flight step between the
    /// boundary and `now` re-runs on the shared slice (the same
    /// at-most-one-step conservative bias as preemption), and the
    /// cursor's consumed-time ledger carries over exactly.
    fn apply_pack(&mut self, members: Vec<usize>, now: f64, out: &mut Vec<EngineEvent>) -> bool {
        debug_assert!(members.len() >= 2);
        debug_assert!(members.iter().all(|&m| !self.in_pack(m)));
        let quantum_steps =
            self.policy.as_ref().expect("packing requires a policy").pack_quantum_steps;
        let mut il = Interleaver::new(self.recon.switch_cost_s(), quantum_steps);
        let mut arrived: Vec<(usize, Vec<f64>)> = Vec::new();
        // The shared slice inherits the members' outstanding
        // availability charges (and starts no earlier than now once a
        // handoff seeds it with live work).
        let mut t0 = now;
        for &m in &members {
            match self.lanes[m].busy.take() {
                None => t0 = t0.max(self.lanes[m].avail),
                Some(mut fl) => {
                    // Commit the layer steps that retired by `now`
                    // (idempotent with the epoch sync), then move the
                    // cursor — checkpoint/resume keeps the consumed
                    // ledger bit-for-bit.
                    while fl.cursor.peek_consumed_s().is_some_and(|c| fl.start_s + c <= now) {
                        let _ = fl.cursor.advance();
                    }
                    debug_assert!(!fl.cursor.is_done(), "a done batch would have retired");
                    // Reprogram charges parked on `avail` by earlier
                    // re-splits are still owed after the migration.
                    let extra = (self.lanes[m].avail - fl.fin_s()).max(0.0);
                    t0 = t0.max(now + extra);
                    // The solo projection is void once the batch
                    // migrates; `avail` is rewritten at unpack and must
                    // not carry a stale (possibly later) completion
                    // into `completion_s`.
                    self.lanes[m].avail = now + extra;
                    // Solo batches charge fabric_s at retirement; a
                    // handed-off batch retires through the interleaver,
                    // which charges only the *remaining* steps — so the
                    // pre-handoff work is charged here, keeping the
                    // per-tenant ledger whole.
                    self.lanes[m].fabric_s += fl.cursor.consumed_s();
                    out.push(EngineEvent::PackHandoff {
                        tenant: m,
                        consumed_s: fl.cursor.consumed_s(),
                        at_s: now,
                    });
                    let ck = fl.cursor.checkpoint();
                    il.add(m, BatchCursor::resume(ck));
                    arrived.push((m, fl.arrived));
                    self.packed_batches += 1;
                }
            }
        }
        self.pack_count += 1;
        self.pack_group_sizes.push(members.len());
        out.push(EngineEvent::Packed { members: members.clone(), at_s: now });
        self.packs.push(PackedGroup { members, il, arrived, t: t0, unpacking: false });
        self.packs.sort_by_key(|pk| pk.members[0]);
        true
    }

    /// Dissolve the drained group led by `leader`: members resume solo
    /// where the shared slice clock left off (owed charges carry
    /// over).
    fn apply_unpack(&mut self, leader: usize, out: &mut Vec<EngineEvent>) -> bool {
        let Some(gi) = self.packs.iter().position(|pk| pk.members[0] == leader) else {
            return false;
        };
        debug_assert!(self.packs[gi].il.is_empty(), "unpack only lands on a drained group");
        let pk = self.packs.remove(gi);
        for &m in &pk.members {
            self.lanes[m].avail = pk.t;
        }
        self.retired_swaps += pk.il.swaps();
        self.unpacks += 1;
        out.push(EngineEvent::Unpacked { members: pk.members, at_s: self.now });
        true
    }

    /// Re-split the fabric onto `proposed` per-group weights. Shared
    /// slices reprogram once (live slots re-base at the current step
    /// boundary, the charge on the group clock); solo slices either
    /// preempt their in-flight batch at its next layer boundary — when
    /// re-costing the remainder on the new slice beats draining on the
    /// old one by the margin — or drain first and pay the reprogram
    /// cost on availability.
    fn apply_resplit(
        &mut self,
        proposed: Vec<u32>,
        now: f64,
        cache: &ScheduleCache,
        out: &mut Vec<EngineEvent>,
    ) -> bool {
        let p = self.policy.clone().expect("re-split requires a policy");
        let preempt_on = p.preemption_enabled();
        let groups = self.leader_groups();
        let named: Vec<(&str, u32)> = groups
            .iter()
            .zip(&proposed)
            .map(|(g, &w)| (self.specs[g[0]].name.as_str(), w))
            .collect();
        if p.async_solve {
            if let Some(tx) = self.solve_tx.clone() {
                // Off-hot-path DSE: plan the layout without committing,
                // probe the cache for every new slice's schedule, and
                // defer the whole re-split if any is missing — the
                // missing keys go to the background solver and the
                // epoch keeps the last cached split. A later epoch
                // re-proposes the re-split; once every solve has
                // landed, the probe passes and the commit below runs
                // on pure cache hits.
                let parts = match self.recon.plan(&named) {
                    Ok(parts) => parts,
                    Err(e) => {
                        log::warn!("re-split rejected: {e}");
                        return false;
                    }
                };
                let mut cold: Vec<(usize, FilcoConfig)> = Vec::new();
                for (gi, g) in groups.iter().enumerate() {
                    let slice = parts[gi].config(&self.base);
                    for &m in g {
                        if cache.get_cached(&self.platform, &slice, &self.specs[m].dag).is_none() {
                            cold.push((m, slice.clone()));
                        }
                    }
                }
                if !cold.is_empty() {
                    if self.timeline.is_some() {
                        // Margin carries how many schedules are still
                        // being solved (a count, not seconds).
                        self.epoch_decisions.push(DecisionSample {
                            kind: DecisionKind::Defer,
                            tenants: cold.iter().map(|(m, _)| *m).collect(),
                            margin_s: cold.len() as f64,
                            approved: false,
                        });
                    }
                    self.deferred += 1;
                    for (m, slice) in cold {
                        let _ = tx
                            .send(SolveRequest { cfg: slice, dag: self.specs[m].dag.clone() });
                    }
                    return false;
                }
            }
        }
        let parts = match self.recon.split(&named) {
            Ok(parts) => parts,
            Err(e) => {
                log::warn!("re-split rejected: {e}");
                return false;
            }
        };
        debug_assert!(self.recon.validate().is_ok());
        let switch = self.recon.switch_cost_s();
        for (gi, g) in groups.iter().enumerate() {
            let slice = parts[gi].config(&self.base);
            let dims = (parts[gi].n_fmus(), parts[gi].m_cus());
            if g.len() > 1 {
                // The shared slice reprograms once; live slots re-base
                // onto their tenants' new schedules at the current
                // step boundary (the charge sits on the group clock).
                let pki = self.packs.iter().position(|pk| pk.members == *g);
                let pki = pki.expect("multi-member group is the pack");
                self.packs[pki].t = self.packs[pki].t.max(now) + switch;
                self.lanes[g[0]].fabric_s += switch;
                for &m in g {
                    let ns = cache.get_or_compute_from(
                        &self.platform,
                        &slice,
                        &self.specs[m].dag,
                        self.board,
                    );
                    // Parked members (no live slot) report Ok(false);
                    // a step-count mismatch would mean the cache handed
                    // back a schedule for a different DAG.
                    self.packs[pki]
                        .il
                        .retarget(m, ns.clone(), 0.0)
                        .expect("packed slot re-bases onto its own tenant's re-solved DAG");
                    self.per_req[m] = ns.per_request_s;
                    self.scheds[m] = ns;
                    self.dims[m] = dims;
                }
                continue;
            }
            let t = g[0];
            let new_sched =
                cache.get_or_compute_from(&self.platform, &slice, &self.specs[t].dag, self.board);
            let mut preempt = false;
            if preempt_on {
                if let Some(fl) = self.lanes[t].busy.as_ref() {
                    // A potential switch lands at the next layer
                    // boundary; everything before it runs on the old
                    // slice either way, so compare the paths from
                    // there. (The in-flight step is also still counted
                    // in `remaining_on` — at most one step of
                    // conservative bias.) Charges parked on `avail` by
                    // earlier re-splits are owed on either path and
                    // excluded.
                    let boundary_s =
                        fl.cursor.peek_consumed_s().map_or(fl.fin_s(), |c| fl.start_s + c);
                    let rem_old = (fl.fin_s() - boundary_s).max(0.0);
                    let rem_new = fl.cursor.remaining_on(&new_sched);
                    preempt = should_preempt(rem_old, rem_new, switch, &p);
                    if self.timeline.is_some() {
                        // `should_preempt`'s benefit term minus its
                        // margin threshold, in fabric seconds.
                        self.epoch_decisions.push(DecisionSample {
                            kind: DecisionKind::Preempt,
                            tenants: vec![t],
                            margin_s: rem_old
                                - rem_new
                                - switch
                                - p.preempt_margin_factor * switch,
                            approved: preempt,
                        });
                    }
                }
            }
            if preempt {
                // Land the switch at the next layer boundary: steps
                // that retired by `now` stay on the old slice's
                // accounting (the epoch sync committed them), the
                // in-flight step finishes on it, then the cursor
                // re-bases onto the new schedule with the mid-DAG
                // switch charged.
                let lane = &mut self.lanes[t];
                let Some(fl) = lane.busy.as_mut() else {
                    panic!("tenant {t}: preemption approved with no batch in flight")
                };
                let extra = (lane.avail - fl.fin_s()).max(0.0);
                let _ = fl.cursor.advance();
                fl.cursor
                    .retarget(new_sched.clone(), switch)
                    .expect("preempted cursor re-bases onto its own tenant's re-solved DAG");
                lane.avail = fl.fin_s() + extra;
                self.preemptions += 1;
                out.push(EngineEvent::Preempted { tenant: t, at_s: now });
            } else {
                // In-flight batches finish on the old composition,
                // then every slice pays the reprogram cost.
                let lane = &mut self.lanes[t];
                lane.avail = lane.avail.max(now) + switch;
                lane.fabric_s += switch;
            }
            self.per_req[t] = new_sched.per_request_s;
            self.scheds[t] = new_sched;
            self.dims[t] = dims;
        }
        out.push(EngineEvent::Resplit { weights: proposed.clone(), at_s: now });
        self.weights = proposed;
        true
    }

    // ---- event horizon ---------------------------------------------------

    /// The earliest fabric instant at which anything can happen: a
    /// trace arrival, a solo batch completion that matters, a packed
    /// interleaver step, or a due policy epoch (scheduled exactly when
    /// [`Self::epoch_relevant`] holds — the same gate [`Self::step`]
    /// fires on, so a scheduled epoch always fires and advances).
    /// `None` means the engine is quiescent — a driver then either
    /// waits for external input or calls [`Self::finish`].
    pub fn next_time(&self) -> Option<f64> {
        let mut next = f64::INFINITY;
        if self.ai < self.arrivals.len() {
            next = next.min(self.arrivals[self.ai].t_s);
        }
        if let Some(u) = &self.unified {
            // The unified fabric frees at `avail_s`: that is the next
            // round-robin pick when a batch is running or work is
            // queued. Scheduling the completion instant even with
            // empty queues is a harmless extra wakeup (no decision
            // depends on it — retirement values are closed-form) that
            // keeps both drivers stepping at identical instants. A
            // live push onto a free fabric between steps wakes
            // immediately (`self.now`), like the drained-group branch
            // below — the simulator picks within the arrival's own
            // step, so that instant never fires there.
            if u.busy.is_some() || self.lanes.iter().any(|l| !l.pending.is_empty()) {
                next = next.min(u.avail_s.max(self.now));
            }
            return next.is_finite().then_some(next);
        }
        let inflight_left = self.lanes.iter().any(|l| l.busy.is_some());
        let preempt_on = self.policy.as_ref().is_some_and(PolicyConfig::preemption_enabled);
        for t in 0..self.specs.len() {
            if self.in_pack(t) {
                // Packed members have no solo slice; their events come
                // from the interleaver below.
                continue;
            }
            if !self.lanes[t].pending.is_empty() {
                next = next.min(self.lanes[t].avail);
            }
        }
        if (preempt_on || self.eager_completions) && inflight_left {
            // Completion events matter even with empty queues: later
            // epochs may still preempt the in-flight work (and live
            // drivers retire eagerly either way).
            for t in 0..self.specs.len() {
                if self.lanes[t].busy.is_some() {
                    next = next.min(self.lanes[t].avail);
                }
            }
        }
        for pk in &self.packs {
            if let Some(d) = pk.il.peek_next_s() {
                next = next.min(pk.t + d);
            } else if !pk.unpacking
                && pk.members.iter().any(|&m| !self.lanes[m].pending.is_empty())
            {
                // A drained group with queued member work can admit a
                // batch immediately. Only a live push between steps
                // creates this state — the simulator admits within the
                // arrival's own step — so this instant never fires
                // there and trace equivalence is untouched.
                next = next.min(self.now);
            }
        }
        if self.policy.is_some() && self.epoch_relevant() {
            next = next.min(self.next_epoch);
        }
        next.is_finite().then_some(next)
    }

    /// Retire whatever is still in flight (its completion needed no
    /// further events) and drain any remaining interleaved work.
    /// Called once by a driver after [`Self::next_time`] returns
    /// `None` and no further external input is coming.
    pub fn finish(&mut self) -> Vec<EngineEvent> {
        let mut out = Vec::new();
        // A unified in-flight batch retires unconditionally: its
        // completion (and every latency in it) was determined at the
        // pick, exactly like the closed form's eager recording.
        self.retire_unified(f64::INFINITY, &mut out);
        // Solo leftovers retire unconditionally — the same accounting
        // as a step, with the time bound opened (every in-flight
        // batch's projected completion is `<= INFINITY`).
        for t in 0..self.specs.len() {
            if let Some(fl) = self.lanes[t].busy.take() {
                retire_inflight_lane(t, &mut self.lanes[t], fl, &mut out);
            }
        }
        // Packed leftovers drain their interleavers with the bound
        // opened. This is *not* the unit step: end-of-run drains
        // never admit still-pending member batches, matching the
        // pre-engine simulator's final drain exactly.
        let mut gi = 0;
        while gi < self.packs.len() {
            self.drain_group_steps(gi, f64::INFINITY, &mut out);
            gi += 1;
        }
        for pk in &self.packs {
            self.drained_completion = self.drained_completion.max(pk.t);
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.extend(out.iter().cloned());
        }
        out
    }

    // ---- cross-board migration -------------------------------------------

    /// May this engine release a tenant right now? True when the
    /// engine is partitioned (not unified), has no packed groups, no
    /// unconsumed own-trace arrivals, and more than one tenant — the
    /// preconditions [`Self::remove_tenant`] enforces. Cluster
    /// placement uses this to filter migration candidates without
    /// mutating anything.
    pub fn migratable(&self) -> bool {
        self.unified.is_none()
            && self.packs.is_empty()
            && !self.trace_pending()
            && self.specs.len() > 1
    }

    /// May this engine accept a migrated tenant right now? True when
    /// the engine is partitioned (not unified) and has no packed
    /// groups — the preconditions [`Self::install_tenant`] enforces.
    /// Checked *before* the source board extracts, so a migration can
    /// never strand a [`TenantExtract`] between boards.
    pub fn can_host_migrant(&self) -> bool {
        self.unified.is_none() && self.packs.is_empty()
    }

    /// Checkpoint tenant `t` out of this engine for cross-board
    /// migration: commit its in-flight cursor's retired layer steps,
    /// detach its spec, queue, token bucket, counters and (possibly
    /// mid-DAG) batch, and re-split the remaining tenants over the
    /// freed fabric at their current weights. The re-split is
    /// setup-like: it neither counts into [`Self::switches`] nor
    /// charges incumbents — the migration cost is charged where the
    /// tenant lands ([`Self::install_tenant`]). Refused while unified,
    /// while any pack exists, while own-trace arrivals are unconsumed,
    /// or for the last tenant (see [`Self::migratable`]).
    pub fn remove_tenant(
        &mut self,
        t: usize,
        now: f64,
        cache: &ScheduleCache,
    ) -> Result<TenantExtract, String> {
        if self.unified.is_some() {
            return Err("cannot extract a tenant from the unified composition".into());
        }
        if !self.packs.is_empty() {
            return Err("cannot extract a tenant while packed groups exist".into());
        }
        if self.trace_pending() {
            return Err("cannot extract a tenant with unconsumed trace arrivals".into());
        }
        if t >= self.specs.len() {
            return Err(format!("no tenant {t}"));
        }
        if self.specs.len() == 1 {
            return Err("cannot extract the last tenant".into());
        }
        let mut lane = self.lanes.remove(t);
        if let Some(fl) = lane.busy.as_mut() {
            // Commit the layer steps that retired by `now` (idempotent
            // with the epoch sync), so the checkpoint's consumed-time
            // ledger is exact at the migration instant.
            while fl.cursor.peek_consumed_s().is_some_and(|c| fl.start_s + c <= now) {
                let _ = fl.cursor.advance();
            }
            debug_assert!(!fl.cursor.is_done(), "a done batch would have retired in the step");
        }
        let ex = TenantExtract {
            spec: self.specs.remove(t),
            cap: self.caps.remove(t),
            bucket: self.buckets.remove(t),
            lane,
            rejected: self.rejected.remove(t),
            throttled: self.throttled.remove(t),
        };
        self.weights.remove(t);
        self.scheds.remove(t);
        self.per_req.remove(t);
        self.dims.remove(t);
        self.resplit_residents(cache)?;
        Ok(ex)
    }

    /// Install a tenant checkpointed off another board: append its
    /// spec, queue, bucket and (possibly mid-DAG) batch, re-split the
    /// fabric over all residents (the newcomer at weight 1), and
    /// charge `migration_cost_s` to the newcomer only — onto its
    /// in-flight cursor's ledger when a batch is mid-DAG (its final
    /// [`EngineEvent::BatchDone`] `consumed_s` then carries the
    /// charge, like a preemption's switch cost), or onto its
    /// availability and fabric-time ledger when idle. Incumbents'
    /// in-flight batches keep draining on their old schedules (the
    /// non-preempt re-split semantics, minus the reprogram charge,
    /// which the migration cost subsumes). Returns the tenant's index
    /// on this engine. Refused while unified or while packs exist.
    pub fn install_tenant(
        &mut self,
        ex: TenantExtract,
        now: f64,
        migration_cost_s: f64,
        cache: &ScheduleCache,
    ) -> Result<usize, String> {
        if self.unified.is_some() {
            return Err("cannot install a tenant into the unified composition".into());
        }
        if !self.packs.is_empty() {
            return Err("cannot install a tenant while packed groups exist".into());
        }
        let t = self.specs.len();
        self.specs.push(ex.spec);
        self.caps.push(ex.cap);
        self.buckets.push(ex.bucket);
        self.lanes.push(ex.lane);
        self.rejected.push(ex.rejected);
        self.throttled.push(ex.throttled);
        self.weights.push(1);
        // Placeholders; `resplit_residents` rewrites all three.
        self.scheds.push(self.scheds[0].clone());
        self.per_req.push(0.0);
        self.dims.push((0, 0));
        self.resplit_residents(cache)?;
        let lane = &mut self.lanes[t];
        if let Some(fl) = lane.busy.as_mut() {
            let extra = (lane.avail - fl.fin_s()).max(0.0);
            fl.cursor
                .retarget(self.scheds[t].clone(), migration_cost_s)
                .map_err(|e| format!("migrated cursor re-base failed: {e:?}"))?;
            lane.avail = fl.fin_s() + extra;
        } else {
            lane.avail = lane.avail.max(now) + migration_cost_s;
            lane.fabric_s += migration_cost_s;
        }
        Ok(t)
    }

    /// Re-split every current tenant over the whole fabric at the
    /// current weights without charging anyone — the migration
    /// bookkeeping split shared by [`Self::remove_tenant`] and
    /// [`Self::install_tenant`]. Counts as setup (`setup_switches`),
    /// so [`Self::switches`] is unchanged.
    fn resplit_residents(&mut self, cache: &ScheduleCache) -> Result<(), String> {
        let named: Vec<(&str, u32)> =
            self.specs.iter().zip(&self.weights).map(|(s, &w)| (s.name.as_str(), w)).collect();
        let parts = self.recon.split(&named)?;
        debug_assert!(self.recon.validate().is_ok());
        self.setup_switches += 1;
        for (i, part) in parts.iter().enumerate() {
            let slice = part.config(&self.base);
            let ns =
                cache.get_or_compute_from(&self.platform, &slice, &self.specs[i].dag, self.board);
            self.per_req[i] = ns.per_request_s;
            self.scheds[i] = ns;
            self.dims[i] = (part.n_fmus(), part.m_cus());
        }
        Ok(())
    }

    // ---- accessors -------------------------------------------------------

    fn in_pack(&self, t: usize) -> bool {
        self.packs.iter().any(|pk| pk.members.contains(&t))
    }

    /// One group per partition leader, in leader order: packed groups
    /// at their leader's position, everyone else a singleton.
    fn leader_groups(&self) -> Vec<Vec<usize>> {
        (0..self.specs.len())
            .filter_map(|t| match self.packs.iter().find(|pk| pk.members.contains(&t)) {
                Some(pk) => (pk.members[0] == t).then(|| pk.members.clone()),
                None => Some(vec![t]),
            })
            .collect()
    }

    /// Number of tenants the engine serves.
    pub fn num_tenants(&self) -> usize {
        self.specs.len()
    }

    /// The tenant leading `t`'s partition (`t` itself unless packed
    /// onto another's slice; in the unified composition every tenant
    /// "leads" the one whole-fabric slice, reported as itself).
    pub fn host(&self, t: usize) -> usize {
        self.packs.iter().find(|pk| pk.members.contains(&t)).map_or(t, |pk| pk.members[0])
    }

    /// Tenant `t`'s current slice dimensions as `(fmus, cus)`.
    pub fn dims(&self, t: usize) -> (u32, u32) {
        self.dims[t]
    }

    /// Tenant `t`'s display name.
    pub fn tenant_name(&self, t: usize) -> &str {
        &self.specs[t].name
    }

    /// Fabric seconds one request currently costs tenant `t`.
    pub fn per_request_s(&self, t: usize) -> f64 {
        self.per_req[t]
    }

    /// Requests waiting in tenant `t`'s pending queue.
    pub fn pending_len(&self, t: usize) -> usize {
        self.lanes[t].pending.len()
    }

    /// Drop every request pending for tenant `t`, returning how many
    /// were discarded (test and shutdown aid; no latency is recorded).
    pub fn drain_pending(&mut self, t: usize) -> usize {
        let n = self.lanes[t].pending.len();
        self.lanes[t].pending.clear();
        n
    }

    /// Does the engine hold any work at all (pending requests,
    /// in-flight solo batches, or live interleaver slots)?
    pub fn has_work(&self) -> bool {
        self.lanes.iter().any(|l| !l.pending.is_empty() || l.busy.is_some())
            || self.unified.as_ref().is_some_and(|u| u.busy.is_some())
            || self.packs.iter().any(|pk| !pk.il.is_empty())
    }

    /// Are there still unconsumed arrivals in the engine's own trace?
    pub fn trace_pending(&self) -> bool {
        self.ai < self.arrivals.len()
    }

    /// Is the current composition the equal split?
    pub fn weights_equal(&self) -> bool {
        self.weights.windows(2).all(|w| w[0] == w[1])
    }

    /// Fabric instant the engine has been stepped to.
    pub fn now_s(&self) -> f64 {
        self.now
    }

    /// Fabric time at which the last work finished (max over solo
    /// availability and packed group clocks; the whole-fabric slice's
    /// availability when unified).
    pub fn completion_s(&self) -> f64 {
        if let Some(u) = &self.unified {
            return u.avail_s;
        }
        let solo = self.lanes.iter().map(|l| l.avail).fold(0.0f64, f64::max);
        let packed = self.packs.iter().map(|pk| pk.t).fold(self.drained_completion, f64::max);
        solo.max(packed)
    }

    /// Requests served, per tenant.
    pub fn served(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.served).collect()
    }

    /// Requests rejected by queue-depth admission control, per tenant.
    pub fn rejected(&self) -> &[u64] {
        &self.rejected
    }

    /// Requests refused by fabric-time token buckets, per tenant.
    pub fn throttled(&self) -> &[u64] {
        &self.throttled
    }

    /// Served requests that met their tenant's latency-SLO deadline,
    /// per tenant (always 0 for throughput tiers).
    pub fn slo_met(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.slo_met).collect()
    }

    /// Served requests that missed their tenant's latency-SLO
    /// deadline, per tenant (always 0 for throughput tiers).
    pub fn slo_missed(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.slo_missed).collect()
    }

    /// Each tenant's effective latency-SLO deadline (`None` for
    /// throughput tiers and degenerate deadlines).
    pub fn slo_deadlines(&self) -> Vec<Option<f64>> {
        self.lanes.iter().map(|l| l.deadline_s).collect()
    }

    /// Fabric seconds consumed on each tenant's behalf (layer steps,
    /// swap charges while packed, switch charges while leading).
    pub fn fabric_s(&self, t: usize) -> f64 {
        self.lanes[t].fabric_s
    }

    /// Per-tenant fabric latency histograms (queueing + service).
    pub fn histograms(&self) -> Vec<LatencyHistogram> {
        self.lanes.iter().map(|l| l.hist.clone()).collect()
    }

    /// Re-compositions performed (the setup split is not counted).
    pub fn switches(&self) -> u64 {
        self.recon.switches - self.setup_switches
    }

    /// In-flight batches preempted at a layer boundary.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Re-splits deferred because a new slice's schedule was still
    /// being solved in the background (async-DSE mode only).
    pub fn deferred_resplits(&self) -> u64 {
        self.deferred
    }

    /// Policy epochs evaluated.
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Pack transitions applied.
    pub fn packs(&self) -> u64 {
        self.pack_count
    }

    /// Unpack transitions applied.
    pub fn unpacks(&self) -> u64 {
        self.unpacks
    }

    /// Cursor context swaps charged by partition interleavers
    /// (dissolved groups plus live ones).
    pub fn pack_swaps(&self) -> u64 {
        self.retired_swaps + self.packs.iter().map(|pk| pk.il.swaps()).sum::<u64>()
    }

    /// Batches that executed inside a packed group's interleaver
    /// (admissions and mid-flight handoffs).
    pub fn packed_batches(&self) -> u64 {
        self.packed_batches
    }

    /// Size of every pack group formed, in transition order.
    pub fn pack_group_sizes(&self) -> &[usize] {
        &self.pack_group_sizes
    }
}
