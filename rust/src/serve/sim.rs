//! Deterministic virtual-time serving simulator.
//!
//! Drives the full serving data path — per-tenant bounded queues with
//! admission control, per-partition workers with batching, the backlog
//! re-composition policy, and the schedule cache — over a traffic trace
//! in *fabric time*, with no threads and no wall clock. Every run is
//! exactly reproducible, which is what the comparison harness (example,
//! bench, acceptance test) needs to claim "dynamic strictly beats the
//! static split".
//!
//! Time model: each tenant's worker owns one fabric slice and serves
//! one batch at a time; a batch of `b` requests costs
//! [`batch_fabric_s`] of the slice's cached schedule makespan.
//! A re-composition charges [`Reconfigurator::switch_cost_s`] to every
//! slice (all units reprogram before their next batch).

use std::collections::VecDeque;

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::reconfig::Reconfigurator;
use crate::platform::Platform;

use super::cache::ScheduleCache;
use super::policy::{backlog_weights, should_resplit, PolicyConfig};
use super::tenant::{batch_fabric_s, Arrival, TenantSpec};

/// How the fabric is composed for the tenants.
#[derive(Debug, Clone)]
pub enum Strategy {
    /// One unified accelerator; tenants time-share it round-robin.
    Unified,
    /// One equal-weight partition per tenant, fixed for the whole run.
    StaticEqual,
    /// Live re-composition driven by the backlog policy.
    Dynamic(PolicyConfig),
}

impl Strategy {
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Unified => "unified",
            Strategy::StaticEqual => "static-equal",
            Strategy::Dynamic(_) => "dynamic",
        }
    }
}

/// A serving scenario: fabric, tenants, and a traffic trace.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub platform: Platform,
    pub base: FilcoConfig,
    pub tenants: Vec<TenantSpec>,
    /// Must be sorted by `t_s` (as produced by the trace generators).
    pub arrivals: Vec<Arrival>,
}

/// Outcome of one simulated serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub strategy: String,
    /// Fabric time at which the last batch finishes.
    pub completion_s: f64,
    pub served: Vec<u64>,
    pub rejected: Vec<u64>,
    /// Re-compositions performed (the setup split is not counted).
    pub switches: u64,
    /// Policy epochs evaluated.
    pub epochs: u64,
    /// Per-tenant fabric latency (queueing + service).
    pub histograms: Vec<LatencyHistogram>,
}

impl ServeReport {
    pub fn total_served(&self) -> u64 {
        self.served.iter().sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.rejected.iter().sum()
    }

    /// Worst per-tenant p99 fabric latency.
    pub fn worst_p99_s(&self) -> f64 {
        self.histograms.iter().map(|h| h.p99()).fold(0.0, f64::max)
    }

    /// Served requests per fabric second.
    pub fn throughput_rps(&self) -> f64 {
        self.total_served() as f64 / self.completion_s.max(1e-12)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<12} completion {:.4e} s | {} served, {} rejected | {:.0} req/s | \
             worst p99 {:.3e} s | {} switches",
            self.strategy,
            self.completion_s,
            self.total_served(),
            self.total_rejected(),
            self.throughput_rps(),
            self.worst_p99_s(),
            self.switches,
        )
    }
}

/// Per-request fabric seconds for each tenant on the equal-weight
/// split — the calibration baseline the example, bench, CLI and tests
/// share to derive traffic rates that are independent of the
/// analytical model's absolute latency scale.
pub fn equal_split_per_request(
    platform: &Platform,
    base: &FilcoConfig,
    tenants: &[TenantSpec],
    cache: &ScheduleCache,
) -> Vec<f64> {
    let mut recon = Reconfigurator::new(base.clone());
    let named: Vec<(&str, u32)> = tenants.iter().map(|t| (t.name.as_str(), 1)).collect();
    let parts = recon.split(&named).expect("equal split");
    parts
        .iter()
        .zip(tenants)
        .map(|(p, t)| cache.get_or_compute(platform, &p.config(base), &t.dag).per_request_s)
        .collect()
}

/// Admit arrivals up to virtual time `now` into the per-tenant queues.
fn ingest(
    arrivals: &[Arrival],
    ai: &mut usize,
    now: f64,
    pending: &mut [VecDeque<(u64, f64)>],
    rejected: &mut [u64],
    caps: &[usize],
) {
    while *ai < arrivals.len() && arrivals[*ai].t_s <= now {
        let a = &arrivals[*ai];
        if pending[a.tenant].len() >= caps[a.tenant] {
            rejected[a.tenant] += 1;
        } else {
            pending[a.tenant].push_back((a.id, a.t_s));
        }
        *ai += 1;
    }
}

/// Run `scenario` under `strategy`, resolving schedules through `cache`.
pub fn simulate(scenario: &Scenario, strategy: &Strategy, cache: &ScheduleCache) -> ServeReport {
    match strategy {
        Strategy::Unified => simulate_unified(scenario, cache),
        Strategy::StaticEqual => simulate_partitioned(scenario, cache, None),
        Strategy::Dynamic(p) => simulate_partitioned(scenario, cache, Some(p)),
    }
}

fn simulate_unified(sc: &Scenario, cache: &ScheduleCache) -> ServeReport {
    let t_n = sc.tenants.len();
    let caps: Vec<usize> = sc.tenants.iter().map(|t| t.queue_capacity).collect();
    let per_req: Vec<f64> = sc
        .tenants
        .iter()
        .map(|t| cache.get_or_compute(&sc.platform, &sc.base, &t.dag).per_request_s)
        .collect();

    let mut pending: Vec<VecDeque<(u64, f64)>> = vec![VecDeque::new(); t_n];
    let mut hist = vec![LatencyHistogram::new(); t_n];
    let mut served = vec![0u64; t_n];
    let mut rejected = vec![0u64; t_n];
    let mut free = 0.0f64;
    let mut now = 0.0f64;
    let mut ai = 0usize;
    let mut rr = 0usize;

    loop {
        ingest(&sc.arrivals, &mut ai, now, &mut pending, &mut rejected, &caps);
        if free <= now {
            // The single worker picks the next non-empty tenant round-robin.
            for k in 0..t_n {
                let t = (rr + k) % t_n;
                let take = pending[t].len().min(sc.tenants[t].max_batch);
                if take == 0 {
                    continue;
                }
                let done = now + batch_fabric_s(per_req[t], take);
                for _ in 0..take {
                    let (_id, arr) = pending[t].pop_front().unwrap();
                    hist[t].record(done - arr);
                    served[t] += 1;
                }
                free = done;
                rr = (t + 1) % t_n;
                break;
            }
        }
        let mut next = f64::INFINITY;
        if ai < sc.arrivals.len() {
            next = next.min(sc.arrivals[ai].t_s);
        }
        if pending.iter().any(|q| !q.is_empty()) {
            next = next.min(free);
        }
        if !next.is_finite() {
            break;
        }
        now = next;
    }

    ServeReport {
        strategy: Strategy::Unified.label().to_string(),
        completion_s: free,
        served,
        rejected,
        switches: 0,
        epochs: 0,
        histograms: hist,
    }
}

fn simulate_partitioned(
    sc: &Scenario,
    cache: &ScheduleCache,
    policy: Option<&PolicyConfig>,
) -> ServeReport {
    let t_n = sc.tenants.len();
    let names: Vec<&str> = sc.tenants.iter().map(|t| t.name.as_str()).collect();
    let caps: Vec<usize> = sc.tenants.iter().map(|t| t.queue_capacity).collect();

    let mut recon = Reconfigurator::new(sc.base.clone());
    let mut weights: Vec<u32> = vec![1; t_n];
    let named: Vec<(&str, u32)> = names.iter().zip(&weights).map(|(&n, &w)| (n, w)).collect();
    let parts = recon.split(&named).expect("equal split");
    recon.validate().expect("equal split tiles the fabric");
    let setup_switches = recon.switches;
    let mut per_req: Vec<f64> = parts
        .iter()
        .zip(&sc.tenants)
        .map(|(part, t)| {
            cache.get_or_compute(&sc.platform, &part.config(&sc.base), &t.dag).per_request_s
        })
        .collect();

    let mut pending: Vec<VecDeque<(u64, f64)>> = vec![VecDeque::new(); t_n];
    let mut hist = vec![LatencyHistogram::new(); t_n];
    let mut served = vec![0u64; t_n];
    let mut rejected = vec![0u64; t_n];
    let mut free = vec![0.0f64; t_n];
    let mut now = 0.0f64;
    let mut ai = 0usize;
    let mut epochs = 0u64;
    let mut next_epoch = policy.map(|p| p.epoch_s).unwrap_or(f64::INFINITY);

    loop {
        ingest(&sc.arrivals, &mut ai, now, &mut pending, &mut rejected, &caps);

        // Each tenant's worker starts its next batch if idle.
        for t in 0..t_n {
            if free[t] > now {
                continue;
            }
            let take = pending[t].len().min(sc.tenants[t].max_batch);
            if take == 0 {
                continue;
            }
            let done = now + batch_fabric_s(per_req[t], take);
            for _ in 0..take {
                let (_id, arr) = pending[t].pop_front().unwrap();
                hist[t].record(done - arr);
                served[t] += 1;
            }
            free[t] = done;
        }

        // Policy epoch: observe backlog, maybe re-compose.
        if let Some(p) = policy {
            if now >= next_epoch {
                epochs += 1;
                let backlog: Vec<f64> =
                    (0..t_n).map(|t| pending[t].len() as f64 * per_req[t]).collect();
                let total_backlog: f64 = backlog.iter().sum();
                let proposed = backlog_weights(&backlog, p.max_weight);
                if should_resplit(&weights, &proposed, total_backlog, recon.switch_cost_s(), p) {
                    let named: Vec<(&str, u32)> =
                        names.iter().zip(&proposed).map(|(&n, &w)| (n, w)).collect();
                    let parts = recon.split(&named).expect("re-split");
                    debug_assert!(recon.validate().is_ok());
                    for t in 0..t_n {
                        let slice = parts[t].config(&sc.base);
                        per_req[t] = cache
                            .get_or_compute(&sc.platform, &slice, &sc.tenants[t].dag)
                            .per_request_s;
                        // In-flight batches finish on the old composition,
                        // then every slice pays the reprogram cost.
                        free[t] = free[t].max(now) + recon.switch_cost_s();
                    }
                    weights = proposed;
                }
                while next_epoch <= now {
                    next_epoch += p.epoch_s;
                }
            }
        }

        // Advance to the next event.
        let mut next = f64::INFINITY;
        if ai < sc.arrivals.len() {
            next = next.min(sc.arrivals[ai].t_s);
        }
        let work_left = pending.iter().any(|q| !q.is_empty());
        for t in 0..t_n {
            if !pending[t].is_empty() {
                next = next.min(free[t]);
            }
        }
        if policy.is_some() && (ai < sc.arrivals.len() || work_left) {
            next = next.min(next_epoch);
        }
        if !next.is_finite() {
            break;
        }
        now = next;
    }

    let label = if policy.is_some() { "dynamic" } else { "static-equal" };
    ServeReport {
        strategy: label.to_string(),
        completion_s: free.iter().cloned().fold(0.0f64, f64::max),
        served,
        rejected,
        switches: recon.switches - setup_switches,
        epochs,
        histograms: hist,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::serve::tenant::poisson_trace;
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 3 }
    }

    /// Two-tenant scenario with rates calibrated to the measured
    /// equal-split service time: tenant `a` overloaded (2x its slice's
    /// service rate), tenant `b` lightly loaded. Absolute makespan scale
    /// cancels out, so the test is robust to model changes.
    fn calibrated_scenario(
        cache: &ScheduleCache,
        caps: usize,
        duration_reqs: f64,
        seed: u64,
    ) -> (Scenario, f64) {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let tenants = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let per = equal_split_per_request(&platform, &base, &tenants, cache)[0];
        let arrivals = poisson_trace(&[2.0 / per, 0.2 / per], duration_reqs * per, seed);
        (Scenario { platform, base, tenants, arrivals }, per)
    }

    fn test_policy(per: f64) -> PolicyConfig {
        PolicyConfig::calibrated(per)
    }

    #[test]
    fn all_strategies_serve_everything() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 40.0, 9);
        let n = sc.arrivals.len() as u64;
        assert!(n > 10, "calibrated trace too small: {n}");
        for strat in
            [Strategy::Unified, Strategy::StaticEqual, Strategy::Dynamic(test_policy(per))]
        {
            let r = simulate(&sc, &strat, &cache);
            assert_eq!(r.total_served(), n, "{} dropped requests", r.strategy);
            assert_eq!(r.total_rejected(), 0);
            assert!(r.completion_s > 0.0);
            let hist_n: u64 = r.histograms.iter().map(|h| h.count()).sum();
            assert_eq!(hist_n, n);
            assert!(r.worst_p99_s() > 0.0);
        }
    }

    #[test]
    fn simulation_is_deterministic() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 30.0, 11);
        let strat = Strategy::Dynamic(test_policy(per));
        let a = simulate(&sc, &strat, &cache);
        let b = simulate(&sc, &strat, &cache);
        assert_eq!(a.completion_s, b.completion_s);
        assert_eq!(a.served, b.served);
        assert_eq!(a.switches, b.switches);
    }

    #[test]
    fn admission_control_rejects_floods() {
        // Burst of simultaneous arrivals against a 2-deep queue.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, _per) = calibrated_scenario(&cache, 2, 0.0, 13);
        sc.arrivals = (0..10).map(|i| Arrival { t_s: 0.0, tenant: 0, id: i }).collect();
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        assert_eq!(r.total_served() + r.total_rejected(), 10);
        assert!(r.total_rejected() > 0, "2-deep queue must reject part of a 10-burst");
    }

    #[test]
    fn dynamic_resplits_and_reuses_cache() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 200.0, 17);
        let policy = test_policy(per);
        let r = simulate(&sc, &Strategy::Dynamic(policy.clone()), &cache);
        assert!(r.epochs > 0, "policy must have evaluated");
        assert!(r.switches >= 1, "2x overload on tenant a must trigger a re-split");
        assert!(cache.misses() >= 2);
        let before = cache.misses();
        let r2 = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(cache.misses(), before, "second identical run must be all cache hits");
        assert_eq!(r2.completion_s, r.completion_s);
    }
}
