//! Deterministic virtual-time serving simulator on the steppable
//! cursor execution model.
//!
//! Drives the full serving data path — per-tenant bounded queues with
//! admission control (queue depth *and* optional fabric-time token
//! buckets), per-partition workers with batching, the backlog
//! re-composition policy with mid-DAG preemption and cross-tenant
//! packing, and the schedule cache — over a traffic trace in *fabric
//! time*, with no threads and no wall clock. Every run is exactly
//! reproducible, which is what the comparison harness (example, bench,
//! acceptance tests) needs to claim "dynamic strictly beats the static
//! split", "preemptive strictly beats batch-boundary", and "packed
//! strictly beats unpacked".
//!
//! Time model: each tenant's worker owns one fabric slice and serves
//! one batch at a time through a [`BatchCursor`] over the slice's
//! cached [`LayerStep`](crate::dse::LayerStep) timeline. An undisturbed
//! batch consumes exactly
//! [`batch_fabric_s`](super::tenant::batch_fabric_s) of fabric time —
//! the pre-cursor batch-atomic accounting, bit-for-bit — so runs with
//! preemption disabled reproduce the old simulator's makespans, and
//! runs with packing disabled (the default) reproduce the pre-packing
//! simulator exactly: the packed code paths below are guarded so no
//! floating-point operation changes when
//! [`PolicyConfig::packing_enabled`] is false.
//!
//! A re-composition charges
//! [`Reconfigurator::switch_cost_s`] to every slice. Idle slices and
//! non-preempted busy slices pay it on availability (in-flight batches
//! finish on the old composition first); a *preempted* slice lands the
//! switch at the in-flight batch's next layer boundary and resumes the
//! remaining layer steps on the new slice's cached schedule.
//!
//! Cross-tenant packing ([`should_pack`]) merges the two lightest
//! tenants onto one shared partition, executed through an
//! [`Interleaver`] at layer-step granularity with the switch cost
//! charged per cursor swap. A pack lands only while both candidates
//! have no in-flight solo batch; an unpack ([`should_unpack`]) drains
//! the interleaver before dissolving, so batches never migrate between
//! execution models mid-flight. Both transitions force a re-split.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::reconfig::Reconfigurator;
use crate::platform::Platform;

use super::cache::{CachedSchedule, ScheduleCache};
use super::interleave::Interleaver;
use super::policy::{
    backlog_weights, pack_candidates, pack_quantum_s, should_pack, should_preempt,
    should_resplit, should_unpack, PolicyConfig,
};
use super::tenant::{Arrival, BatchCursor, TenantSpec, TokenBucket};

/// How the fabric is composed for the tenants.
#[derive(Debug, Clone)]
pub enum Strategy {
    /// One unified accelerator; tenants time-share it round-robin.
    Unified,
    /// One equal-weight partition per tenant, fixed for the whole run.
    StaticEqual,
    /// Live re-composition driven by the backlog policy (mid-DAG
    /// preemption per [`PolicyConfig::preempt_margin_factor`],
    /// cross-tenant packing per [`PolicyConfig::pack_headroom_factor`]).
    Dynamic(PolicyConfig),
}

impl Strategy {
    /// Short stable label for reports and tables.
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Unified => "unified",
            Strategy::StaticEqual => "static-equal",
            Strategy::Dynamic(_) => "dynamic",
        }
    }
}

/// A serving scenario: fabric, tenants, and a traffic trace.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Hardware model the analytical schedules are computed against.
    pub platform: Platform,
    /// Whole-fabric FILCO configuration that gets partitioned.
    pub base: FilcoConfig,
    /// The tenants sharing the fabric.
    pub tenants: Vec<TenantSpec>,
    /// Must be sorted by `t_s` (as produced by the trace generators).
    pub arrivals: Vec<Arrival>,
    /// Override the modelled composition-switch cost (`None` keeps the
    /// [`Reconfigurator`] default) — what-if studies on slower control
    /// planes.
    pub switch_cost_s: Option<f64>,
}

/// Outcome of one simulated serving run. All times are fabric seconds
/// (virtual device time), never wall-clock.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Label of the strategy that produced this report.
    pub strategy: String,
    /// Fabric time at which the last batch finishes.
    pub completion_s: f64,
    /// Requests served, per tenant.
    pub served: Vec<u64>,
    /// Requests rejected by queue-depth admission control, per tenant.
    pub rejected: Vec<u64>,
    /// Requests refused by per-tenant fabric-time token buckets.
    pub throttled: Vec<u64>,
    /// Re-compositions performed (the setup split is not counted).
    pub switches: u64,
    /// In-flight batches preempted at a layer boundary.
    pub preemptions: u64,
    /// Pack transitions (two tenants merged onto one partition).
    pub packs: u64,
    /// Unpack transitions (a packed pair dissolved after draining).
    pub unpacks: u64,
    /// Cursor context swaps charged by the partition interleaver.
    pub pack_swaps: u64,
    /// Policy epochs evaluated.
    pub epochs: u64,
    /// Per-tenant fabric latency (queueing + service).
    pub histograms: Vec<LatencyHistogram>,
}

impl ServeReport {
    /// Requests served across every tenant.
    pub fn total_served(&self) -> u64 {
        self.served.iter().sum()
    }

    /// Requests rejected (queue depth) across every tenant.
    pub fn total_rejected(&self) -> u64 {
        self.rejected.iter().sum()
    }

    /// Requests throttled (token buckets) across every tenant.
    pub fn total_throttled(&self) -> u64 {
        self.throttled.iter().sum()
    }

    /// Worst per-tenant p99 fabric latency.
    pub fn worst_p99_s(&self) -> f64 {
        self.histograms.iter().map(|h| h.p99()).fold(0.0, f64::max)
    }

    /// Served requests per fabric second.
    pub fn throughput_rps(&self) -> f64 {
        self.total_served() as f64 / self.completion_s.max(1e-12)
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<12} completion {:.4e} s | {} served, {} rejected, {} throttled | \
             {:.0} req/s | worst p99 {:.3e} s | {} switches, {} preemptions | \
             {} packs, {} unpacks, {} swaps",
            self.strategy,
            self.completion_s,
            self.total_served(),
            self.total_rejected(),
            self.total_throttled(),
            self.throughput_rps(),
            self.worst_p99_s(),
            self.switches,
            self.preemptions,
            self.packs,
            self.unpacks,
            self.pack_swaps,
        )
    }
}

/// Per-request fabric seconds for each tenant on the equal-weight
/// split — the calibration baseline the example, bench, CLI and tests
/// share to derive traffic rates that are independent of the
/// analytical model's absolute latency scale.
pub fn equal_split_per_request(
    platform: &Platform,
    base: &FilcoConfig,
    tenants: &[TenantSpec],
    cache: &ScheduleCache,
) -> Vec<f64> {
    let mut recon = Reconfigurator::new(base.clone());
    let named: Vec<(&str, u32)> = tenants.iter().map(|t| (t.name.as_str(), 1)).collect();
    let parts = recon.split(&named).expect("equal split");
    parts
        .iter()
        .zip(tenants)
        .map(|(p, t)| cache.get_or_compute(platform, &p.config(base), &t.dag).per_request_s)
        .collect()
}

/// Admit arrivals up to virtual time `now` into the per-tenant queues:
/// queue depth first (reject as full), then the fabric-time token
/// bucket (throttle) — the same classification order as the live
/// scheduler's `push`.
#[allow(clippy::too_many_arguments)]
fn ingest(
    arrivals: &[Arrival],
    ai: &mut usize,
    now: f64,
    pending: &mut [VecDeque<(u64, f64)>],
    rejected: &mut [u64],
    throttled: &mut [u64],
    caps: &[usize],
    buckets: &mut [Option<TokenBucket>],
    per_req: &[f64],
) {
    while *ai < arrivals.len() && arrivals[*ai].t_s <= now {
        let a = &arrivals[*ai];
        *ai += 1;
        if pending[a.tenant].len() >= caps[a.tenant] {
            rejected[a.tenant] += 1;
            continue;
        }
        if let Some(b) = &mut buckets[a.tenant] {
            if !b.try_take(per_req[a.tenant], a.t_s) {
                throttled[a.tenant] += 1;
                continue;
            }
        }
        pending[a.tenant].push_back((a.id, a.t_s));
    }
}

/// Run `scenario` under `strategy`, resolving schedules through `cache`.
pub fn simulate(scenario: &Scenario, strategy: &Strategy, cache: &ScheduleCache) -> ServeReport {
    match strategy {
        Strategy::Unified => simulate_unified(scenario, cache),
        Strategy::StaticEqual => simulate_partitioned(scenario, cache, None),
        Strategy::Dynamic(p) => simulate_partitioned(scenario, cache, Some(p)),
    }
}

fn simulate_unified(sc: &Scenario, cache: &ScheduleCache) -> ServeReport {
    let t_n = sc.tenants.len();
    let caps: Vec<usize> = sc.tenants.iter().map(|t| t.queue_capacity).collect();
    let scheds: Vec<Arc<CachedSchedule>> = sc
        .tenants
        .iter()
        .map(|t| cache.get_or_compute(&sc.platform, &sc.base, &t.dag))
        .collect();
    let per_req: Vec<f64> = scheds.iter().map(|s| s.per_request_s).collect();
    let mut buckets: Vec<Option<TokenBucket>> =
        sc.tenants.iter().map(|t| t.rate_limit.map(TokenBucket::from_limit)).collect();

    let mut pending: Vec<VecDeque<(u64, f64)>> = vec![VecDeque::new(); t_n];
    let mut hist = vec![LatencyHistogram::new(); t_n];
    let mut served = vec![0u64; t_n];
    let mut rejected = vec![0u64; t_n];
    let mut throttled = vec![0u64; t_n];
    let mut free = 0.0f64;
    let mut now = 0.0f64;
    let mut ai = 0usize;
    let mut rr = 0usize;

    loop {
        ingest(
            &sc.arrivals,
            &mut ai,
            now,
            &mut pending,
            &mut rejected,
            &mut throttled,
            &caps,
            &mut buckets,
            &per_req,
        );
        if free <= now {
            // The single worker picks the next non-empty tenant round-robin.
            for k in 0..t_n {
                let t = (rr + k) % t_n;
                let take = pending[t].len().min(sc.tenants[t].max_batch);
                if take == 0 {
                    continue;
                }
                // One execution model everywhere: the unified worker
                // walks the same cursor; undisturbed, the projected
                // total is the closed-form batch time bit-for-bit.
                let done = now + BatchCursor::new(scheds[t].clone(), take).projected_total_s();
                for _ in 0..take {
                    let (_id, arr) = pending[t].pop_front().unwrap();
                    hist[t].record(done - arr);
                    served[t] += 1;
                }
                free = done;
                rr = (t + 1) % t_n;
                break;
            }
        }
        let mut next = f64::INFINITY;
        if ai < sc.arrivals.len() {
            next = next.min(sc.arrivals[ai].t_s);
        }
        if pending.iter().any(|q| !q.is_empty()) {
            next = next.min(free);
        }
        if !next.is_finite() {
            break;
        }
        now = next;
    }

    ServeReport {
        strategy: Strategy::Unified.label().to_string(),
        completion_s: free,
        served,
        rejected,
        throttled,
        switches: 0,
        preemptions: 0,
        packs: 0,
        unpacks: 0,
        pack_swaps: 0,
        epochs: 0,
        histograms: hist,
    }
}

/// One in-flight batch on a tenant's slice.
struct InFlight {
    cursor: BatchCursor,
    start_s: f64,
    /// Arrival times of the batch's requests (latency recording).
    arrived: Vec<f64>,
}

impl InFlight {
    /// Projected completion time on the cursor's current schedule.
    fn fin_s(&self) -> f64 {
        self.start_s + self.cursor.projected_total_s()
    }
}

/// The packed pair's shared partition in the simulator: an interleaved
/// walk over its members' in-flight batches, advanced lazily as
/// virtual time passes step boundaries.
struct PackedSim {
    /// Member tenant indices, ascending; `members[0]` leads the group.
    members: Vec<usize>,
    il: Interleaver,
    /// Arrival times of each live slot's requests, keyed by tenant.
    arrived: Vec<(usize, Vec<f64>)>,
    /// Fabric time the shared slice has been simulated through; its
    /// next step retires at `t + il.peek_next_s()`.
    t: f64,
    /// Unpack in progress: no new batches are admitted; the pack
    /// dissolves once the interleaver drains.
    unpacking: bool,
}

fn simulate_partitioned(
    sc: &Scenario,
    cache: &ScheduleCache,
    policy: Option<&PolicyConfig>,
) -> ServeReport {
    let t_n = sc.tenants.len();
    let names: Vec<&str> = sc.tenants.iter().map(|t| t.name.as_str()).collect();
    let caps: Vec<usize> = sc.tenants.iter().map(|t| t.queue_capacity).collect();
    let preempt_on = policy.is_some_and(PolicyConfig::preemption_enabled);
    let pack_on = policy.is_some_and(PolicyConfig::packing_enabled);

    let mut recon = Reconfigurator::new(sc.base.clone());
    if let Some(s) = sc.switch_cost_s {
        recon.set_switch_cost_s(s);
    }
    let mut weights: Vec<u32> = vec![1; t_n];
    let named: Vec<(&str, u32)> = names.iter().zip(&weights).map(|(&n, &w)| (n, w)).collect();
    let parts = recon.split(&named).expect("equal split");
    recon.validate().expect("equal split tiles the fabric");
    let setup_switches = recon.switches;
    let mut scheds: Vec<Arc<CachedSchedule>> = parts
        .iter()
        .zip(&sc.tenants)
        .map(|(part, t)| cache.get_or_compute(&sc.platform, &part.config(&sc.base), &t.dag))
        .collect();
    let mut per_req: Vec<f64> = scheds.iter().map(|s| s.per_request_s).collect();
    let mut buckets: Vec<Option<TokenBucket>> =
        sc.tenants.iter().map(|t| t.rate_limit.map(TokenBucket::from_limit)).collect();

    let mut pending: Vec<VecDeque<(u64, f64)>> = vec![VecDeque::new(); t_n];
    let mut hist = vec![LatencyHistogram::new(); t_n];
    let mut served = vec![0u64; t_n];
    let mut rejected = vec![0u64; t_n];
    let mut throttled = vec![0u64; t_n];
    let mut busy: Vec<Option<InFlight>> = (0..t_n).map(|_| None).collect();
    // Time each slice is next available for a new batch: batch
    // completion plus any switch charges taken while busy or idle.
    let mut avail = vec![0.0f64; t_n];
    let mut now = 0.0f64;
    let mut ai = 0usize;
    let mut epochs = 0u64;
    let mut preemptions = 0u64;
    let mut packs = 0u64;
    let mut unpacks = 0u64;
    let mut pack_swaps = 0u64;
    let mut packed: Option<PackedSim> = None;
    let mut next_epoch = policy.map(|p| p.epoch_s).unwrap_or(f64::INFINITY);

    loop {
        ingest(
            &sc.arrivals,
            &mut ai,
            now,
            &mut pending,
            &mut rejected,
            &mut throttled,
            &caps,
            &mut buckets,
            &per_req,
        );

        // The packed partition: admit member batches into interleaver
        // slots and retire the steps whose end has been reached.
        // Alternating admission and retirement lets a tenant's next
        // batch start the moment its previous one drains, exactly like
        // a solo slice at the same virtual instant.
        if let Some(pk) = packed.as_mut() {
            loop {
                let mut progressed = false;
                if !pk.unpacking {
                    let members = pk.members.clone();
                    for m in members {
                        if !pk.il.contains(m) && !pending[m].is_empty() {
                            let take = pending[m].len().min(sc.tenants[m].max_batch);
                            let mut arrived = Vec::with_capacity(take);
                            for _ in 0..take {
                                let (_id, arr) = pending[m].pop_front().unwrap();
                                arrived.push(arr);
                            }
                            if pk.il.is_empty() {
                                // Idle slice: its clock catches up to now
                                // before the new batch's first step.
                                pk.t = pk.t.max(now);
                            }
                            pk.il.add(m, BatchCursor::new(scheds[m].clone(), take));
                            pk.arrived.push((m, arrived));
                            progressed = true;
                        }
                    }
                }
                while let Some(d) = pk.il.peek_next_s() {
                    if pk.t + d > now {
                        break;
                    }
                    let ev = pk.il.advance().unwrap();
                    pk.t += ev.swap_charge_s + ev.step.dur_s;
                    if ev.done {
                        let pos =
                            pk.arrived.iter().position(|(m, _)| *m == ev.tenant).unwrap();
                        let (_, arrs) = pk.arrived.remove(pos);
                        for &arr in &arrs {
                            hist[ev.tenant].record(pk.t - arr);
                            served[ev.tenant] += 1;
                        }
                        progressed = true;
                    }
                }
                if !progressed {
                    break;
                }
            }
        }

        // Retire batches whose (projected) completion has been reached.
        // Recording at completion: an undisturbed cursor's total is the
        // closed-form batch time, so latencies match the batch-atomic
        // model exactly; a preempted batch records its actual
        // (re-costed, switch-charged) completion.
        for t in 0..t_n {
            let done = busy[t].as_ref().is_some_and(|fl| fl.fin_s() <= now);
            if done {
                let fl = busy[t].take().unwrap();
                let fin = fl.fin_s();
                for &arr in &fl.arrived {
                    hist[t].record(fin - arr);
                    served[t] += 1;
                }
            }
        }

        // Each tenant's worker starts its next batch if its slice is
        // free. Packed members have no slice of their own — their
        // batches are admitted by the interleaver block above.
        for t in 0..t_n {
            if packed.as_ref().is_some_and(|pk| pk.members.contains(&t)) {
                continue;
            }
            if busy[t].is_some() || avail[t] > now {
                continue;
            }
            let take = pending[t].len().min(sc.tenants[t].max_batch);
            if take == 0 {
                continue;
            }
            let mut arrived = Vec::with_capacity(take);
            for _ in 0..take {
                let (_id, arr) = pending[t].pop_front().unwrap();
                arrived.push(arr);
            }
            let fl = InFlight {
                cursor: BatchCursor::new(scheds[t].clone(), take),
                start_s: now,
                arrived,
            };
            avail[t] = fl.fin_s();
            busy[t] = Some(fl);
        }

        // Policy epoch: observe backlog, maybe pack/unpack, maybe
        // re-compose. With preemption enabled the signal includes
        // in-flight remaining work (that work is movable); with it
        // disabled only queued work counts — the pre-cursor behavior,
        // preserved exactly. Packed slots' remaining work is always
        // movable (they re-base on every re-split) and is counted
        // whenever packing is live.
        if let Some(p) = policy {
            if now >= next_epoch {
                epochs += 1;
                if preempt_on {
                    // Sync in-flight cursors to virtual time (live
                    // workers advance theirs continuously; the sim does
                    // it lazily at epochs): commit the layer steps that
                    // retired by `now`, so remaining-work signals and
                    // preemption decisions reflect actual progress
                    // rather than the batch-start position.
                    for fl in busy.iter_mut().flatten() {
                        while fl
                            .cursor
                            .peek_consumed_s()
                            .is_some_and(|c| fl.start_s + c <= now)
                        {
                            let _ = fl.cursor.advance();
                        }
                    }
                }
                let backlog: Vec<f64> = (0..t_n)
                    .map(|t| {
                        let queued = pending[t].len() as f64 * per_req[t];
                        let inflight = if preempt_on {
                            busy[t].as_ref().map(|fl| fl.cursor.remaining_s()).unwrap_or(0.0)
                        } else {
                            0.0
                        };
                        let packed_inflight = match &packed {
                            Some(pk) if pk.members.contains(&t) => pk.il.slot_remaining_s(t),
                            _ => 0.0,
                        };
                        queued + inflight + packed_inflight
                    })
                    .collect();
                // Pack / unpack transitions. At most one packed pair at
                // a time; a pack lands only when both candidates are
                // idle (no in-flight solo batch), an unpack only once
                // the interleaver has drained — batches never migrate
                // between execution models mid-flight.
                let total_backlog: f64 = backlog.iter().sum();
                let mut grouping_changed = false;
                if pack_on {
                    if packed.is_some() {
                        {
                            let pk = packed.as_mut().unwrap();
                            let combined: f64 =
                                pk.members.iter().map(|&m| backlog[m]).sum();
                            if !pk.unpacking && should_unpack(combined, p.epoch_s, p) {
                                pk.unpacking = true;
                            }
                        }
                        let drained =
                            packed.as_ref().is_some_and(|pk| pk.unpacking && pk.il.is_empty());
                        if drained {
                            let pk = packed.take().unwrap();
                            for &m in &pk.members {
                                // Members resume solo where the shared
                                // slice clock left off (owed charges
                                // carry over).
                                avail[m] = pk.t;
                            }
                            pack_swaps += pk.il.swaps();
                            unpacks += 1;
                            grouping_changed = true;
                        }
                    } else if let Some((a, b)) = pack_candidates(&backlog) {
                        // Candidate selection and the swap-amortization
                        // window are shared with the live scheduler
                        // (policy.rs) so the two paths cannot drift
                        // apart. The extra *idle* gate is sim-only: a
                        // pack lands only between solo batches, so in
                        // virtual time batches never migrate execution
                        // models mid-flight.
                        let idle = busy[a].is_none() && busy[b].is_none();
                        let quantum_s = pack_quantum_s(
                            p.pack_quantum_steps,
                            [
                                (per_req[a], scheds[a].steps.len()),
                                (per_req[b], scheds[b].steps.len()),
                            ],
                        );
                        if idle
                            && should_pack(
                                backlog[a] + backlog[b],
                                p.epoch_s,
                                quantum_s,
                                recon.switch_cost_s(),
                                p,
                            )
                        {
                            packed = Some(PackedSim {
                                members: vec![a, b],
                                il: Interleaver::new(
                                    recon.switch_cost_s(),
                                    p.pack_quantum_steps,
                                ),
                                arrived: Vec::new(),
                                // The shared slice inherits the members'
                                // outstanding availability charges.
                                t: avail[a].max(avail[b]),
                                unpacking: false,
                            });
                            packs += 1;
                            grouping_changed = true;
                        }
                    }
                }
                // One group per partition leader; all singletons unless
                // a pair is packed, in which case the pack sits at its
                // leader's position.
                let groups: Vec<Vec<usize>> = (0..t_n)
                    .filter_map(|t| match &packed {
                        Some(pk) if pk.members.contains(&t) => {
                            (pk.members[0] == t).then(|| pk.members.clone())
                        }
                        _ => Some(vec![t]),
                    })
                    .collect();
                let group_backlog: Vec<f64> =
                    groups.iter().map(|g| g.iter().map(|&t| backlog[t]).sum()).collect();
                let proposed = backlog_weights(&group_backlog, p.max_weight);
                if grouping_changed
                    || should_resplit(&weights, &proposed, total_backlog, recon.switch_cost_s(), p)
                {
                    let named: Vec<(&str, u32)> =
                        groups.iter().zip(&proposed).map(|(g, &w)| (names[g[0]], w)).collect();
                    let parts = recon.split(&named).expect("re-split");
                    debug_assert!(recon.validate().is_ok());
                    let switch = recon.switch_cost_s();
                    for (gi, g) in groups.iter().enumerate() {
                        let slice = parts[gi].config(&sc.base);
                        if g.len() > 1 {
                            // The shared slice reprograms once; live
                            // slots re-base onto their tenants' new
                            // schedules at the current step boundary
                            // (the charge sits on the group clock).
                            let pk = packed.as_mut().expect("multi-member group is the pack");
                            pk.t = pk.t.max(now) + switch;
                            for &m in g {
                                let ns =
                                    cache.get_or_compute(&sc.platform, &slice, &sc.tenants[m].dag);
                                pk.il.retarget(m, ns.clone(), 0.0);
                                per_req[m] = ns.per_request_s;
                                scheds[m] = ns;
                            }
                            continue;
                        }
                        let t = g[0];
                        let new_sched =
                            cache.get_or_compute(&sc.platform, &slice, &sc.tenants[t].dag);
                        let preempt = preempt_on
                            && busy[t].as_ref().is_some_and(|fl| {
                                // A potential switch lands at the next
                                // layer boundary; everything before it
                                // runs on the old slice either way, so
                                // compare the paths from there. (The
                                // in-flight step is also still counted
                                // in `remaining_on` — at most one step
                                // of conservative bias.) Charges parked
                                // on `avail` by earlier re-splits are
                                // owed on either path and excluded.
                                let boundary_s = fl
                                    .cursor
                                    .peek_consumed_s()
                                    .map_or(fl.fin_s(), |c| fl.start_s + c);
                                let rem_old = (fl.fin_s() - boundary_s).max(0.0);
                                let rem_new = fl.cursor.remaining_on(&new_sched);
                                should_preempt(rem_old, rem_new, switch, p)
                            });
                        if preempt {
                            // Land the switch at the next layer
                            // boundary: steps that retired by `now`
                            // stay on the old slice's accounting (the
                            // epoch sync committed them), the in-flight
                            // step finishes on it, then the cursor
                            // re-bases onto the new schedule with the
                            // mid-DAG switch charged.
                            let fl = busy[t].as_mut().unwrap();
                            // Reprogram charges from earlier re-splits
                            // while this batch was in flight are still
                            // owed after the re-basing.
                            let extra = (avail[t] - fl.fin_s()).max(0.0);
                            let _ = fl.cursor.advance();
                            fl.cursor.retarget(new_sched.clone(), switch);
                            avail[t] = fl.fin_s() + extra;
                            preemptions += 1;
                        } else {
                            // In-flight batches finish on the old
                            // composition, then every slice pays the
                            // reprogram cost.
                            avail[t] = avail[t].max(now) + switch;
                        }
                        per_req[t] = new_sched.per_request_s;
                        scheds[t] = new_sched;
                    }
                    weights = proposed;
                }
                while next_epoch <= now {
                    next_epoch += p.epoch_s;
                }
            }
        }

        // Advance to the next event.
        let mut next = f64::INFINITY;
        if ai < sc.arrivals.len() {
            next = next.min(sc.arrivals[ai].t_s);
        }
        let work_left = pending.iter().any(|q| !q.is_empty());
        let inflight_left = busy.iter().any(|b| b.is_some());
        for t in 0..t_n {
            if packed.as_ref().is_some_and(|pk| pk.members.contains(&t)) {
                // Packed members have no solo slice; their events come
                // from the interleaver below.
                continue;
            }
            if !pending[t].is_empty() {
                next = next.min(avail[t]);
            }
        }
        if preempt_on && inflight_left {
            // Completion events matter even with empty queues: later
            // epochs may still preempt the in-flight work.
            for t in 0..t_n {
                if busy[t].is_some() {
                    next = next.min(avail[t]);
                }
            }
        }
        if let Some(pk) = &packed {
            if let Some(d) = pk.il.peek_next_s() {
                next = next.min(pk.t + d);
            }
        }
        let preemptible = preempt_on && inflight_left;
        let packed_active = packed.as_ref().is_some_and(|pk| !pk.il.is_empty());
        if policy.is_some()
            && (ai < sc.arrivals.len() || work_left || preemptible || packed_active)
        {
            next = next.min(next_epoch);
        }
        if !next.is_finite() {
            break;
        }
        now = next;
    }

    // Retire whatever is still in flight (its completion needed no
    // further events).
    for t in 0..t_n {
        if let Some(fl) = busy[t].take() {
            let fin = fl.fin_s();
            for &arr in &fl.arrived {
                hist[t].record(fin - arr);
                served[t] += 1;
            }
        }
    }
    let mut packed_completion = 0.0f64;
    if let Some(mut pk) = packed.take() {
        // Drain any remaining interleaved work (the event loop schedules
        // packed steps, so this is normally already empty) and fold the
        // pack's swap count into the run totals.
        while let Some(ev) = pk.il.advance() {
            pk.t += ev.swap_charge_s + ev.step.dur_s;
            if ev.done {
                let pos = pk.arrived.iter().position(|(m, _)| *m == ev.tenant).unwrap();
                let (_, arrs) = pk.arrived.remove(pos);
                for &arr in &arrs {
                    hist[ev.tenant].record(pk.t - arr);
                    served[ev.tenant] += 1;
                }
            }
        }
        pack_swaps += pk.il.swaps();
        packed_completion = pk.t;
    }

    let label = if policy.is_some() { "dynamic" } else { "static-equal" };
    ServeReport {
        strategy: label.to_string(),
        completion_s: avail.iter().cloned().fold(0.0f64, f64::max).max(packed_completion),
        served,
        rejected,
        throttled,
        switches: recon.switches - setup_switches,
        preemptions,
        packs,
        unpacks,
        pack_swaps,
        epochs,
        histograms: hist,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::serve::tenant::{batch_fabric_s, poisson_trace};
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 3 }
    }

    /// Two-tenant scenario with rates calibrated to the measured
    /// equal-split service time: tenant `a` overloaded (2x its slice's
    /// service rate), tenant `b` lightly loaded. Absolute makespan scale
    /// cancels out, so the test is robust to model changes.
    fn calibrated_scenario(
        cache: &ScheduleCache,
        caps: usize,
        duration_reqs: f64,
        seed: u64,
    ) -> (Scenario, f64) {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let tenants = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let per = equal_split_per_request(&platform, &base, &tenants, cache)[0];
        let arrivals = poisson_trace(&[2.0 / per, 0.2 / per], duration_reqs * per, seed);
        (Scenario { platform, base, tenants, arrivals, switch_cost_s: None }, per)
    }

    fn test_policy(per: f64) -> PolicyConfig {
        PolicyConfig::calibrated(per)
    }

    #[test]
    fn all_strategies_serve_everything() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 40.0, 9);
        let n = sc.arrivals.len() as u64;
        assert!(n > 10, "calibrated trace too small: {n}");
        for strat in [
            Strategy::Unified,
            Strategy::StaticEqual,
            Strategy::Dynamic(test_policy(per)),
            Strategy::Dynamic(test_policy(per).without_preemption()),
        ] {
            let r = simulate(&sc, &strat, &cache);
            assert_eq!(r.total_served(), n, "{} dropped requests", r.strategy);
            assert_eq!(r.total_rejected(), 0);
            assert_eq!(r.total_throttled(), 0);
            assert!(r.completion_s > 0.0);
            let hist_n: u64 = r.histograms.iter().map(|h| h.count()).sum();
            assert_eq!(hist_n, n);
            assert!(r.worst_p99_s() > 0.0);
            // Packing is off by default in every one of these runs.
            assert_eq!((r.packs, r.unpacks, r.pack_swaps), (0, 0, 0));
        }
    }

    #[test]
    fn simulation_is_deterministic() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 30.0, 11);
        let strat = Strategy::Dynamic(test_policy(per));
        let a = simulate(&sc, &strat, &cache);
        let b = simulate(&sc, &strat, &cache);
        assert_eq!(a.completion_s, b.completion_s);
        assert_eq!(a.served, b.served);
        assert_eq!(a.switches, b.switches);
        assert_eq!(a.preemptions, b.preemptions);
    }

    #[test]
    fn admission_control_rejects_floods() {
        // Burst of simultaneous arrivals against a 2-deep queue.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, _per) = calibrated_scenario(&cache, 2, 0.0, 13);
        sc.arrivals = (0..10).map(|i| Arrival { t_s: 0.0, tenant: 0, id: i }).collect();
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        assert_eq!(r.total_served() + r.total_rejected(), 10);
        assert!(r.total_rejected() > 0, "2-deep queue must reject part of a 10-burst");
    }

    #[test]
    fn token_bucket_throttles_fabric_share() {
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, per) = calibrated_scenario(&cache, 100_000, 0.0, 15);
        // Tenant a may burst 2 requests' worth of fabric time and then
        // earns 10% of a slice; a 10-burst must lose most requests to
        // the bucket while tenant b (unlimited) is untouched.
        sc.tenants[0].rate_limit =
            Some(crate::serve::tenant::RateLimit { fabric_share: 0.1, burst_s: 2.0 * per });
        sc.arrivals = (0..12)
            .map(|i| Arrival { t_s: 0.0, tenant: (i % 6 == 5) as usize, id: i })
            .collect();
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        assert_eq!(r.throttled[0], 8, "10-burst minus 2-request burst allowance");
        assert_eq!(r.throttled[1], 0);
        assert_eq!(r.total_served(), 4);
    }

    #[test]
    fn dynamic_resplits_and_reuses_cache() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 200.0, 17);
        let policy = test_policy(per);
        let r = simulate(&sc, &Strategy::Dynamic(policy.clone()), &cache);
        assert!(r.epochs > 0, "policy must have evaluated");
        assert!(r.switches >= 1, "2x overload on tenant a must trigger a re-split");
        assert!(cache.misses() >= 2);
        let before = cache.misses();
        let r2 = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(cache.misses(), before, "second identical run must be all cache hits");
        assert_eq!(r2.completion_s, r.completion_s);
    }

    #[test]
    fn preemption_never_loses_to_batch_boundary_switching() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 120.0, 19);
        let pre = simulate(&sc, &Strategy::Dynamic(test_policy(per)), &cache);
        let bb =
            simulate(&sc, &Strategy::Dynamic(test_policy(per).without_preemption()), &cache);
        assert_eq!(pre.total_served(), bb.total_served());
        assert_eq!(bb.preemptions, 0, "without_preemption must never preempt");
        // The two runs see slightly different backlog signals, so exact
        // dominance is not guaranteed on an arbitrary trace — but
        // preemption must stay in the same ballpark (the crafted
        // acceptance scenario in rust/tests asserts the strict win).
        assert!(
            pre.completion_s <= bb.completion_s * 1.1,
            "preemption must not meaningfully slow completion: {:.6e} vs {:.6e}",
            pre.completion_s,
            bb.completion_s
        );
    }

    #[test]
    fn undisturbed_batch_costs_match_the_closed_form() {
        // One tenant, one burst, static split: completion must be the
        // closed-form batch cost chain (bit-for-bit), demonstrating the
        // cursor model preserves the batch-atomic accounting.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, _per) = calibrated_scenario(&cache, 100_000, 0.0, 21);
        sc.arrivals = (0..12).map(|i| Arrival { t_s: 0.0, tenant: 0, id: i }).collect();
        sc.tenants[0] = sc.tenants[0].clone().with_max_batch(8);
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        let per0 = equal_split_per_request(&sc.platform, &sc.base, &sc.tenants, &cache)[0];
        let expect = batch_fabric_s(per0, 8) + batch_fabric_s(per0, 4);
        assert_eq!(r.completion_s, expect, "cursor walk must equal batch-atomic accounting");
    }

    /// Three tenants: one overloaded, two light — the packing regime.
    fn packable_scenario(cache: &ScheduleCache, seed: u64) -> (Scenario, PolicyConfig) {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let tenants = vec![
            TenantSpec::new("heavy", zoo::mlp_l()).with_queue_capacity(1 << 20),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(1 << 20),
            TenantSpec::new("s2", zoo::pointnet()).with_queue_capacity(1 << 20),
        ];
        let per = equal_split_per_request(&platform, &base, &tenants, cache);
        let arrivals =
            poisson_trace(&[2.5 / per[0], 0.05 / per[1], 0.05 / per[2]], 120.0 * per[0], seed);
        let policy = PolicyConfig {
            // Decouple the swap-amortization gate from the model's
            // absolute scale; the interleave tests pin its semantics.
            pack_swap_margin: 10.0,
            ..PolicyConfig::calibrated(per[0]).with_packing()
        };
        (Scenario { platform, base, tenants, arrivals, switch_cost_s: None }, policy)
    }

    #[test]
    fn packing_engages_and_serves_everything() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, policy) = packable_scenario(&cache, 23);
        let n = sc.arrivals.len() as u64;
        assert!(n > 50, "trace too small: {n}");
        let r = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(r.total_served(), n, "packing must not drop requests");
        assert!(r.packs >= 1, "two light tenants must pack");
        assert!(r.pack_swaps >= 1, "packed batches must time-multiplex");
        let hist_n: u64 = r.histograms.iter().map(|h| h.count()).sum();
        assert_eq!(hist_n, n);
    }

    #[test]
    fn packed_runs_are_deterministic() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, policy) = packable_scenario(&cache, 29);
        let a = simulate(&sc, &Strategy::Dynamic(policy.clone()), &cache);
        let b = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(a.completion_s, b.completion_s);
        assert_eq!(a.served, b.served);
        assert_eq!(a.switches, b.switches);
        assert_eq!((a.packs, a.unpacks, a.pack_swaps), (b.packs, b.unpacks, b.pack_swaps));
        for (x, y) in a.histograms.iter().zip(&b.histograms) {
            assert_eq!(x.p99(), y.p99());
        }
    }

    #[test]
    fn overloaded_pair_unpacks_again() {
        // Both light tenants pack at the start, then a mid-trace flood
        // on one of them blows past the unpack hysteresis.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, policy) = packable_scenario(&cache, 31);
        let per = equal_split_per_request(&sc.platform, &sc.base, &sc.tenants, &cache);
        let t_end = sc.arrivals.last().map(|a| a.t_s).unwrap_or(0.0);
        let mut extra: Vec<Arrival> = (0..2000)
            .map(|i| Arrival { t_s: 0.5 * t_end, tenant: 1, id: 1_000_000 + i })
            .collect();
        sc.arrivals.append(&mut extra);
        sc.arrivals.sort_by(|a, b| {
            a.t_s.partial_cmp(&b.t_s).unwrap().then(a.tenant.cmp(&b.tenant))
        });
        assert!(per[1] > 0.0);
        let r = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert!(r.packs >= 1, "light pair must pack before the flood");
        assert!(r.unpacks >= 1, "a 2000-request flood must dissolve the pack");
        assert_eq!(r.total_served(), sc.arrivals.len() as u64);
    }
}
