//! Deterministic virtual-time serving simulator: a thin driver loop
//! that drains the shared [`FabricEngine`] on a [`VirtualClock`].
//!
//! All execution semantics — per-tenant bounded queues with admission
//! control (queue depth *and* optional fabric-time token buckets),
//! solo batches in closed-form accounting, packed partitions
//! interleaved at layer-step granularity, the backlog re-composition
//! policy with mid-DAG preemption, mid-flight pack handoff and
//! cross-tenant packing, the unified whole-fabric composition, and
//! the schedule cache — live in [`FabricEngine`](super::FabricEngine).
//! This module only supplies the clock (virtual: jump to the next
//! event) and the traffic trace, then shapes the engine's state into
//! a [`ServeReport`]. The live scheduler drives the *same* engine on
//! a wall clock, which is why simulated what-ifs and live runs agree
//! by construction. Every strategy — unified included — runs through
//! the engine, so the three-way comparison shares one cost model and
//! one event-trace format; there is no separate closed-form baseline
//! left to drift.
//!
//! Every run is exactly reproducible, which is what the comparison
//! harness (example, bench, acceptance tests) needs to claim "dynamic
//! strictly beats the static split", "preemptive strictly beats
//! batch-boundary", and "packed strictly beats unpacked". Runs with
//! preemption disabled reproduce the pre-cursor batch-atomic
//! simulator's makespans bit-for-bit, runs with packing disabled
//! (the default) reproduce the pre-packing simulator exactly, and
//! unified runs reproduce the retired closed-form unified baseline
//! bit-for-bit — the oracle tests in `rust/tests/serve_preempt.rs`,
//! `rust/tests/serve_pack.rs` and `rust/tests/serve_engine.rs` hold
//! the engine to it.

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::reconfig::Reconfigurator;
use crate::platform::Platform;

use super::cache::ScheduleCache;
use super::clock::{Clock, VirtualClock};
use super::cluster::{ClusterPolicy, ClusterReport, FabricCluster};
use super::engine::{EngineEvent, FabricEngine};
use super::policy::PolicyConfig;
use super::telemetry::{RunTelemetry, StallStats, TelemetryConfig, TimelineReport};
use super::tenant::{Arrival, TenantSpec};

/// How the fabric is composed for the tenants.
#[derive(Debug, Clone)]
pub enum Strategy {
    /// One unified accelerator; tenants time-share it round-robin at
    /// batch granularity (the engine's unified composition mode —
    /// [`FabricEngine::new_unified`] — which reproduces the retired
    /// closed-form baseline bit-for-bit).
    Unified,
    /// One equal-weight partition per tenant, fixed for the whole run.
    StaticEqual,
    /// Live re-composition driven by the backlog policy (mid-DAG
    /// preemption per [`PolicyConfig::preempt_margin_factor`],
    /// cross-tenant packing per [`PolicyConfig::pack_headroom_factor`]).
    Dynamic(PolicyConfig),
}

impl Strategy {
    /// Short stable label for reports and tables.
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Unified => "unified",
            Strategy::StaticEqual => "static-equal",
            Strategy::Dynamic(_) => "dynamic",
        }
    }
}

/// A serving scenario: fabric, tenants, and a traffic trace.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Hardware model the analytical schedules are computed against.
    pub platform: Platform,
    /// Whole-fabric FILCO configuration that gets partitioned.
    pub base: FilcoConfig,
    /// The tenants sharing the fabric.
    pub tenants: Vec<TenantSpec>,
    /// Must be sorted by `t_s` (as produced by the trace generators).
    pub arrivals: Vec<Arrival>,
    /// Override the modelled composition-switch cost (`None` keeps the
    /// [`Reconfigurator`] default) — what-if studies on slower control
    /// planes.
    pub switch_cost_s: Option<f64>,
    /// Shard workers stepping partition units in parallel (1 = step
    /// inline). Purely a throughput knob: the event trace and report
    /// are bit-for-bit identical for any value
    /// ([`FabricEngine::set_shards`]).
    pub shards: usize,
}

/// Outcome of one simulated serving run. All times are fabric seconds
/// (virtual device time), never wall-clock.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Label of the strategy that produced this report.
    pub strategy: String,
    /// Fabric time at which the last batch finishes.
    pub completion_s: f64,
    /// Requests served, per tenant.
    pub served: Vec<u64>,
    /// Requests rejected by queue-depth admission control, per tenant.
    pub rejected: Vec<u64>,
    /// Requests refused by per-tenant fabric-time token buckets.
    pub throttled: Vec<u64>,
    /// Re-compositions performed (the setup split is not counted).
    pub switches: u64,
    /// In-flight batches preempted at a layer boundary.
    pub preemptions: u64,
    /// Pack transitions (tenants merged onto one partition).
    pub packs: u64,
    /// Unpack transitions (a packed group dissolved after draining).
    pub unpacks: u64,
    /// Cursor context swaps charged by partition interleavers.
    pub pack_swaps: u64,
    /// Size of every pack group formed, in transition order (pairs and
    /// wider multi-way groups from the first-fit-decreasing proposal).
    pub pack_group_sizes: Vec<usize>,
    /// Policy epochs evaluated.
    pub epochs: u64,
    /// Per-tenant fabric latency (queueing + service).
    pub histograms: Vec<LatencyHistogram>,
    /// Each tenant's effective latency-SLO deadline (`None` for
    /// throughput tiers), carried so attainment is computable from the
    /// report alone.
    pub slo_deadline_s: Vec<Option<f64>>,
    /// Served requests that met their tenant's deadline, per tenant
    /// (always 0 for throughput tiers).
    pub slo_met: Vec<u64>,
    /// Served requests that missed it, per tenant.
    pub slo_missed: Vec<u64>,
}

impl ServeReport {
    /// Requests served across every tenant.
    pub fn total_served(&self) -> u64 {
        self.served.iter().sum()
    }

    /// Requests rejected (queue depth) across every tenant.
    pub fn total_rejected(&self) -> u64 {
        self.rejected.iter().sum()
    }

    /// Requests throttled (token buckets) across every tenant.
    pub fn total_throttled(&self) -> u64 {
        self.throttled.iter().sum()
    }

    /// Worst per-tenant p99 fabric latency.
    pub fn worst_p99_s(&self) -> f64 {
        self.histograms.iter().map(|h| h.p99()).fold(0.0, f64::max)
    }

    /// Served requests per fabric second.
    pub fn throughput_rps(&self) -> f64 {
        self.total_served() as f64 / self.completion_s.max(1e-12)
    }

    /// Fraction of tenant `t`'s served requests that met its
    /// latency-SLO deadline. `1.0` for throughput tiers (no deadline —
    /// vacuously attained) and for latency tiers that served nothing.
    pub fn slo_attainment(&self, t: usize) -> f64 {
        let met = self.slo_met.get(t).copied().unwrap_or(0);
        let missed = self.slo_missed.get(t).copied().unwrap_or(0);
        if met + missed == 0 {
            1.0
        } else {
            met as f64 / (met + missed) as f64
        }
    }

    /// Worst per-tenant SLO attainment across the latency-tier tenants
    /// (`1.0` when no tenant carries a deadline).
    pub fn worst_slo_attainment(&self) -> f64 {
        (0..self.served.len())
            .filter(|&t| self.slo_deadline_s.get(t).copied().flatten().is_some())
            .map(|t| self.slo_attainment(t))
            .fold(1.0, f64::min)
    }

    /// One-line human-readable summary (SLO attainment appended only
    /// when some tenant carries a latency deadline).
    pub fn summary(&self) -> String {
        let slo = if self.slo_deadline_s.iter().any(Option::is_some) {
            format!(" | slo {:.3}", self.worst_slo_attainment())
        } else {
            String::new()
        };
        format!(
            "{:<12} completion {:.4e} s | {} served, {} rejected, {} throttled | \
             {:.0} req/s | worst p99 {:.3e} s | {} switches, {} preemptions | \
             {} packs {:?}, {} unpacks, {} swaps{}",
            self.strategy,
            self.completion_s,
            self.total_served(),
            self.total_rejected(),
            self.total_throttled(),
            self.throughput_rps(),
            self.worst_p99_s(),
            self.switches,
            self.preemptions,
            self.packs,
            self.pack_group_sizes,
            self.unpacks,
            self.pack_swaps,
            slo,
        )
    }
}

/// Per-request fabric seconds for each tenant on the equal-weight
/// split — the calibration baseline the example, bench, CLI and tests
/// share to derive traffic rates that are independent of the
/// analytical model's absolute latency scale.
pub fn equal_split_per_request(
    platform: &Platform,
    base: &FilcoConfig,
    tenants: &[TenantSpec],
    cache: &ScheduleCache,
) -> Vec<f64> {
    let mut recon = Reconfigurator::new(base.clone());
    let named: Vec<(&str, u32)> = tenants.iter().map(|t| (t.name.as_str(), 1)).collect();
    let parts = recon.split(&named).expect("equal split");
    parts
        .iter()
        .zip(tenants)
        .map(|(p, t)| cache.get_or_compute(platform, &p.config(base), &t.dag).per_request_s)
        .collect()
}

/// Run `scenario` under `strategy`, resolving schedules through `cache`.
pub fn simulate(scenario: &Scenario, strategy: &Strategy, cache: &ScheduleCache) -> ServeReport {
    simulate_traced(scenario, strategy, cache, false).0
}

/// Like [`simulate`], optionally recording the engine's event trace —
/// what the live-vs-sim differential test compares bit-for-bit. Every
/// strategy runs through the engine: [`Strategy::Unified`] drains the
/// unified composition mode and emits a real event trace like the
/// partitioned strategies do.
pub fn simulate_traced(
    scenario: &Scenario,
    strategy: &Strategy,
    cache: &ScheduleCache,
    record_trace: bool,
) -> (ServeReport, Vec<EngineEvent>) {
    let tcfg = TelemetryConfig { trace: record_trace, timeline: false };
    let (report, telemetry) = simulate_instrumented(scenario, strategy, cache, &tcfg);
    (report, telemetry.trace.unwrap_or_default())
}

/// Like [`simulate`], recording whatever `telemetry` asks for: the
/// full [`EngineEvent`] trace, the per-epoch metrics timeline, and
/// (always) the wall-time step profile. The profile times each
/// `FabricEngine::step` call around the otherwise-identical driver
/// loop; nothing it measures is ever read by a decision, so an
/// instrumented run's report and trace are bit-identical to an
/// uninstrumented one's.
pub fn simulate_instrumented(
    scenario: &Scenario,
    strategy: &Strategy,
    cache: &ScheduleCache,
    telemetry: &TelemetryConfig,
) -> (ServeReport, RunTelemetry) {
    let mut engine = match strategy {
        Strategy::Unified => FabricEngine::new_unified(
            scenario.platform.clone(),
            scenario.base.clone(),
            scenario.tenants.clone(),
            scenario.switch_cost_s,
            scenario.arrivals.clone(),
            cache,
        ),
        Strategy::StaticEqual | Strategy::Dynamic(_) => {
            let policy = match strategy {
                Strategy::Dynamic(p) => Some(p.clone()),
                _ => None,
            };
            FabricEngine::new(
                scenario.platform.clone(),
                scenario.base.clone(),
                scenario.tenants.clone(),
                policy,
                scenario.switch_cost_s,
                scenario.arrivals.clone(),
                cache,
            )
        }
    }
    .expect("engine setup");
    engine.set_shards(scenario.shards);
    engine.record_trace(telemetry.trace);
    engine.record_timeline(telemetry.timeline);
    let stalls0 =
        (cache.stalls(), cache.stall_ns(), cache.coalesced_solves(), cache.cross_board_hits());
    let mut profile = super::telemetry::StepProfile::default();
    let mut timed_step = |engine: &mut FabricEngine, now: f64| {
        let t0 = std::time::Instant::now();
        engine.step(now, cache);
        profile.record_ns(t0.elapsed().as_nanos() as u64);
    };
    // The thin driver loop: the engine decides *what* happens at each
    // fabric instant; the virtual clock merely jumps there.
    let mut clock = VirtualClock::new();
    timed_step(&mut engine, clock.now_s());
    while let Some(t) = engine.next_time() {
        clock.advance_to(t);
        timed_step(&mut engine, clock.now_s());
    }
    engine.finish();
    let report = report_from_engine(&engine, strategy.label());
    let timeline = telemetry.timeline.then(|| TimelineReport {
        tenants: scenario.tenants.iter().map(|t| t.name.clone()).collect(),
        samples: engine.take_timeline(),
    });
    let trace = telemetry.trace.then(|| engine.take_trace());
    // The simulator drives the engine without a mutex, so only the
    // DSE-stall half of the stall ledger is meaningful here (and a
    // warm-cache run reports zeros).
    let stalls = StallStats {
        lock_held_ns: 0,
        lock_holds: 0,
        dse_stall_ns: cache.stall_ns() - stalls0.1,
        dse_stalls: cache.stalls() - stalls0.0,
        coalesced_solves: cache.coalesced_solves() - stalls0.2,
        cross_board_hits: cache.cross_board_hits() - stalls0.3,
    };
    (report, RunTelemetry { trace, timeline, step_profile: profile, stalls })
}

/// Run `scenario` on a `boards`-board [`FabricCluster`] under
/// `strategy`. Tenants are placed by declared fabric share
/// ([`first_fit_placement`](super::cluster::first_fit_placement));
/// `cluster_policy` enables per-epoch imbalance-driven cross-board
/// migration (ignored on one board). The driver loop is the same
/// thin shell as [`simulate`]: the cluster decides *what* happens at
/// each fabric instant, the virtual clock merely jumps there. On one
/// board, `report` in the returned [`ClusterReport`] is bit-for-bit
/// the single-engine [`simulate`] report (the cluster-of-1 guarantee;
/// `rust/tests/serve_cluster.rs` asserts it with `==` on every f64).
pub fn simulate_cluster(
    scenario: &Scenario,
    strategy: &Strategy,
    boards: usize,
    cluster_policy: Option<ClusterPolicy>,
    cache: &ScheduleCache,
) -> ClusterReport {
    simulate_cluster_traced(scenario, strategy, boards, cluster_policy, cache, false).0
}

/// Like [`simulate_cluster`], optionally recording the cluster-global
/// event trace — the deterministic merge of every board's stream plus
/// `Migrated` markers — which the cluster-of-1 differential compares
/// bit-for-bit against [`simulate_traced`]'s.
pub fn simulate_cluster_traced(
    scenario: &Scenario,
    strategy: &Strategy,
    boards: usize,
    cluster_policy: Option<ClusterPolicy>,
    cache: &ScheduleCache,
    record_trace: bool,
) -> (ClusterReport, Vec<EngineEvent>) {
    let mut cluster = FabricCluster::new(
        scenario.platform.clone(),
        scenario.base.clone(),
        scenario.tenants.clone(),
        strategy,
        scenario.switch_cost_s,
        scenario.arrivals.clone(),
        boards,
        cluster_policy,
        cache,
    )
    .expect("cluster setup");
    cluster.set_shards(scenario.shards);
    cluster.record_trace(record_trace);
    let mut clock = VirtualClock::new();
    cluster.step(clock.now_s(), cache);
    while let Some(t) = cluster.next_time() {
        clock.advance_to(t);
        cluster.step(clock.now_s(), cache);
    }
    cluster.finish();
    let report = cluster.cluster_report();
    let trace = cluster.take_trace();
    (report, trace)
}

pub(crate) fn report_from_engine(engine: &FabricEngine, label: &str) -> ServeReport {
    ServeReport {
        strategy: label.to_string(),
        completion_s: engine.completion_s(),
        served: engine.served(),
        rejected: engine.rejected().to_vec(),
        throttled: engine.throttled().to_vec(),
        switches: engine.switches(),
        preemptions: engine.preemptions(),
        packs: engine.packs(),
        unpacks: engine.unpacks(),
        pack_swaps: engine.pack_swaps(),
        pack_group_sizes: engine.pack_group_sizes().to_vec(),
        epochs: engine.epochs(),
        histograms: engine.histograms(),
        slo_deadline_s: engine.slo_deadlines(),
        slo_met: engine.slo_met(),
        slo_missed: engine.slo_missed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::serve::tenant::{batch_fabric_s, poisson_trace};
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 3 }
    }

    /// Two-tenant scenario with rates calibrated to the measured
    /// equal-split service time: tenant `a` overloaded (2x its slice's
    /// service rate), tenant `b` lightly loaded. Absolute makespan scale
    /// cancels out, so the test is robust to model changes.
    fn calibrated_scenario(
        cache: &ScheduleCache,
        caps: usize,
        duration_reqs: f64,
        seed: u64,
    ) -> (Scenario, f64) {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let tenants = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let per = equal_split_per_request(&platform, &base, &tenants, cache)[0];
        let arrivals = poisson_trace(&[2.0 / per, 0.2 / per], duration_reqs * per, seed);
        (Scenario { platform, base, tenants, arrivals, switch_cost_s: None, shards: 1 }, per)
    }

    fn test_policy(per: f64) -> PolicyConfig {
        PolicyConfig::calibrated(per)
    }

    #[test]
    fn all_strategies_serve_everything() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 40.0, 9);
        let n = sc.arrivals.len() as u64;
        assert!(n > 10, "calibrated trace too small: {n}");
        for strat in [
            Strategy::Unified,
            Strategy::StaticEqual,
            Strategy::Dynamic(test_policy(per)),
            Strategy::Dynamic(test_policy(per).without_preemption()),
        ] {
            let r = simulate(&sc, &strat, &cache);
            assert_eq!(r.total_served(), n, "{} dropped requests", r.strategy);
            assert_eq!(r.total_rejected(), 0);
            assert_eq!(r.total_throttled(), 0);
            assert!(r.completion_s > 0.0);
            let hist_n: u64 = r.histograms.iter().map(|h| h.count()).sum();
            assert_eq!(hist_n, n);
            assert!(r.worst_p99_s() > 0.0);
            // Packing is off by default in every one of these runs.
            assert_eq!((r.packs, r.unpacks, r.pack_swaps), (0, 0, 0));
            assert!(r.pack_group_sizes.is_empty());
        }
    }

    #[test]
    fn simulation_is_deterministic() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 30.0, 11);
        let strat = Strategy::Dynamic(test_policy(per));
        let a = simulate(&sc, &strat, &cache);
        let b = simulate(&sc, &strat, &cache);
        assert_eq!(a.completion_s, b.completion_s);
        assert_eq!(a.served, b.served);
        assert_eq!(a.switches, b.switches);
        assert_eq!(a.preemptions, b.preemptions);
    }

    #[test]
    fn admission_control_rejects_floods() {
        // Burst of simultaneous arrivals against a 2-deep queue.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, _per) = calibrated_scenario(&cache, 2, 0.0, 13);
        sc.arrivals = (0..10).map(|i| Arrival { t_s: 0.0, tenant: 0, id: i }).collect();
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        assert_eq!(r.total_served() + r.total_rejected(), 10);
        assert!(r.total_rejected() > 0, "2-deep queue must reject part of a 10-burst");
    }

    #[test]
    fn token_bucket_throttles_fabric_share() {
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, per) = calibrated_scenario(&cache, 100_000, 0.0, 15);
        // Tenant a may burst 2 requests' worth of fabric time and then
        // earns 10% of a slice; a 10-burst must lose most requests to
        // the bucket while tenant b (unlimited) is untouched.
        sc.tenants[0].rate_limit =
            Some(crate::serve::tenant::RateLimit { fabric_share: 0.1, burst_s: 2.0 * per });
        sc.arrivals = (0..12)
            .map(|i| Arrival { t_s: 0.0, tenant: (i % 6 == 5) as usize, id: i })
            .collect();
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        assert_eq!(r.throttled[0], 8, "10-burst minus 2-request burst allowance");
        assert_eq!(r.throttled[1], 0);
        assert_eq!(r.total_served(), 4);
    }

    #[test]
    fn dynamic_resplits_and_reuses_cache() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 200.0, 17);
        let policy = test_policy(per);
        let r = simulate(&sc, &Strategy::Dynamic(policy.clone()), &cache);
        assert!(r.epochs > 0, "policy must have evaluated");
        assert!(r.switches >= 1, "2x overload on tenant a must trigger a re-split");
        assert!(cache.misses() >= 2);
        let before = cache.misses();
        let r2 = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(cache.misses(), before, "second identical run must be all cache hits");
        assert_eq!(r2.completion_s, r.completion_s);
    }

    #[test]
    fn preemption_never_loses_to_batch_boundary_switching() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 120.0, 19);
        let pre = simulate(&sc, &Strategy::Dynamic(test_policy(per)), &cache);
        let bb =
            simulate(&sc, &Strategy::Dynamic(test_policy(per).without_preemption()), &cache);
        assert_eq!(pre.total_served(), bb.total_served());
        assert_eq!(bb.preemptions, 0, "without_preemption must never preempt");
        // The two runs see slightly different backlog signals, so exact
        // dominance is not guaranteed on an arbitrary trace — but
        // preemption must stay in the same ballpark (the crafted
        // acceptance scenario in rust/tests asserts the strict win).
        assert!(
            pre.completion_s <= bb.completion_s * 1.1,
            "preemption must not meaningfully slow completion: {:.6e} vs {:.6e}",
            pre.completion_s,
            bb.completion_s
        );
    }

    #[test]
    fn undisturbed_batch_costs_match_the_closed_form() {
        // One tenant, one burst, static split: completion must be the
        // closed-form batch cost chain (bit-for-bit), demonstrating the
        // cursor model preserves the batch-atomic accounting.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, _per) = calibrated_scenario(&cache, 100_000, 0.0, 21);
        sc.arrivals = (0..12).map(|i| Arrival { t_s: 0.0, tenant: 0, id: i }).collect();
        sc.tenants[0] = sc.tenants[0].clone().with_max_batch(8);
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        let per0 = equal_split_per_request(&sc.platform, &sc.base, &sc.tenants, &cache)[0];
        let expect = batch_fabric_s(per0, 8) + batch_fabric_s(per0, 4);
        assert_eq!(r.completion_s, expect, "cursor walk must equal batch-atomic accounting");
    }

    /// Three tenants: one overloaded, two light — the packing regime.
    fn packable_scenario(cache: &ScheduleCache, seed: u64) -> (Scenario, PolicyConfig) {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let tenants = vec![
            TenantSpec::new("heavy", zoo::mlp_l()).with_queue_capacity(1 << 20),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(1 << 20),
            TenantSpec::new("s2", zoo::pointnet()).with_queue_capacity(1 << 20),
        ];
        let per = equal_split_per_request(&platform, &base, &tenants, cache);
        let arrivals =
            poisson_trace(&[2.5 / per[0], 0.05 / per[1], 0.05 / per[2]], 120.0 * per[0], seed);
        let policy = PolicyConfig {
            // Decouple the swap-amortization gate from the model's
            // absolute scale; the interleave tests pin its semantics.
            pack_swap_margin: 10.0,
            ..PolicyConfig::calibrated(per[0]).with_packing()
        };
        (Scenario { platform, base, tenants, arrivals, switch_cost_s: None, shards: 1 }, policy)
    }

    #[test]
    fn packing_engages_and_serves_everything() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, policy) = packable_scenario(&cache, 23);
        let n = sc.arrivals.len() as u64;
        assert!(n > 50, "trace too small: {n}");
        let r = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(r.total_served(), n, "packing must not drop requests");
        assert!(r.packs >= 1, "two light tenants must pack");
        assert!(r.pack_swaps >= 1, "packed batches must time-multiplex");
        assert_eq!(r.pack_group_sizes.len(), r.packs as usize);
        assert!(r.pack_group_sizes.iter().all(|&s| s >= 2));
        let hist_n: u64 = r.histograms.iter().map(|h| h.count()).sum();
        assert_eq!(hist_n, n);
    }

    #[test]
    fn packed_runs_are_deterministic() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, policy) = packable_scenario(&cache, 29);
        let a = simulate(&sc, &Strategy::Dynamic(policy.clone()), &cache);
        let b = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(a.completion_s, b.completion_s);
        assert_eq!(a.served, b.served);
        assert_eq!(a.switches, b.switches);
        assert_eq!((a.packs, a.unpacks, a.pack_swaps), (b.packs, b.unpacks, b.pack_swaps));
        for (x, y) in a.histograms.iter().zip(&b.histograms) {
            assert_eq!(x.p99(), y.p99());
        }
    }

    #[test]
    fn overloaded_pair_unpacks_again() {
        // Both light tenants pack at the start, then a mid-trace flood
        // on one of them blows past the unpack hysteresis.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, policy) = packable_scenario(&cache, 31);
        let per = equal_split_per_request(&sc.platform, &sc.base, &sc.tenants, &cache);
        let t_end = sc.arrivals.last().map(|a| a.t_s).unwrap_or(0.0);
        let mut extra: Vec<Arrival> = (0..2000)
            .map(|i| Arrival { t_s: 0.5 * t_end, tenant: 1, id: 1_000_000 + i })
            .collect();
        sc.arrivals.append(&mut extra);
        sc.arrivals.sort_by(|a, b| {
            a.t_s.partial_cmp(&b.t_s).unwrap().then(a.tenant.cmp(&b.tenant))
        });
        assert!(per[1] > 0.0);
        let r = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert!(r.packs >= 1, "light pair must pack before the flood");
        assert!(r.unpacks >= 1, "a 2000-request flood must dissolve the pack");
        assert_eq!(r.total_served(), sc.arrivals.len() as u64);
    }

    #[test]
    fn four_light_tenants_form_a_multiway_group() {
        // One heavy tenant, three near-idle light ones: the FFD
        // proposal packs all three lights into one shared partition.
        let cache = ScheduleCache::new(tiny_solver());
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let tenants = vec![
            TenantSpec::new("heavy", zoo::mlp_l()).with_queue_capacity(1 << 20),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(1 << 20),
            TenantSpec::new("s2", zoo::mlp_s()).with_queue_capacity(1 << 20),
            TenantSpec::new("s3", zoo::pointnet()).with_queue_capacity(1 << 20),
        ];
        let per = equal_split_per_request(&platform, &base, &tenants, &cache);
        let arrivals = poisson_trace(
            &[2.5 / per[0], 0.02 / per[1], 0.02 / per[2], 0.02 / per[3]],
            100.0 * per[0],
            37,
        );
        let policy = PolicyConfig {
            pack_swap_margin: 10.0,
            ..PolicyConfig::calibrated(per[0]).with_packing()
        };
        let sc = Scenario { platform, base, tenants, arrivals, switch_cost_s: None, shards: 1 };
        let n = sc.arrivals.len() as u64;
        let r = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(r.total_served(), n, "multi-way packing must not drop requests");
        assert!(r.packs >= 1);
        assert!(
            r.pack_group_sizes.iter().any(|&s| s >= 3),
            "three light tenants must form one multi-way group: {:?}",
            r.pack_group_sizes
        );
    }
}
