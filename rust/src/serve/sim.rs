//! Deterministic virtual-time serving simulator on the steppable
//! cursor execution model.
//!
//! Drives the full serving data path — per-tenant bounded queues with
//! admission control (queue depth *and* optional fabric-time token
//! buckets), per-partition workers with batching, the backlog
//! re-composition policy with mid-DAG preemption, and the schedule
//! cache — over a traffic trace in *fabric time*, with no threads and
//! no wall clock. Every run is exactly reproducible, which is what the
//! comparison harness (example, bench, acceptance tests) needs to claim
//! "dynamic strictly beats the static split" and "preemptive strictly
//! beats batch-boundary".
//!
//! Time model: each tenant's worker owns one fabric slice and serves
//! one batch at a time through a [`BatchCursor`] over the slice's
//! cached [`LayerStep`](crate::dse::LayerStep) timeline. An undisturbed
//! batch consumes exactly [`batch_fabric_s`] of fabric time — the
//! pre-cursor batch-atomic accounting, bit-for-bit — so runs with
//! preemption disabled reproduce the old simulator's makespans.
//!
//! A re-composition charges
//! [`Reconfigurator::switch_cost_s`] to every slice. Idle slices and
//! non-preempted busy slices pay it on availability (in-flight batches
//! finish on the old composition first); a *preempted* slice lands the
//! switch at the in-flight batch's next layer boundary and resumes the
//! remaining layer steps on the new slice's cached schedule.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::reconfig::Reconfigurator;
use crate::platform::Platform;

use super::cache::{CachedSchedule, ScheduleCache};
use super::policy::{backlog_weights, should_preempt, should_resplit, PolicyConfig};
use super::tenant::{Arrival, BatchCursor, TenantSpec, TokenBucket};

/// How the fabric is composed for the tenants.
#[derive(Debug, Clone)]
pub enum Strategy {
    /// One unified accelerator; tenants time-share it round-robin.
    Unified,
    /// One equal-weight partition per tenant, fixed for the whole run.
    StaticEqual,
    /// Live re-composition driven by the backlog policy (mid-DAG
    /// preemption per [`PolicyConfig::preempt_margin_factor`]).
    Dynamic(PolicyConfig),
}

impl Strategy {
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::Unified => "unified",
            Strategy::StaticEqual => "static-equal",
            Strategy::Dynamic(_) => "dynamic",
        }
    }
}

/// A serving scenario: fabric, tenants, and a traffic trace.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub platform: Platform,
    pub base: FilcoConfig,
    pub tenants: Vec<TenantSpec>,
    /// Must be sorted by `t_s` (as produced by the trace generators).
    pub arrivals: Vec<Arrival>,
    /// Override the modelled composition-switch cost (`None` keeps the
    /// [`Reconfigurator`] default) — what-if studies on slower control
    /// planes.
    pub switch_cost_s: Option<f64>,
}

/// Outcome of one simulated serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub strategy: String,
    /// Fabric time at which the last batch finishes.
    pub completion_s: f64,
    pub served: Vec<u64>,
    pub rejected: Vec<u64>,
    /// Requests refused by per-tenant fabric-time token buckets.
    pub throttled: Vec<u64>,
    /// Re-compositions performed (the setup split is not counted).
    pub switches: u64,
    /// In-flight batches preempted at a layer boundary.
    pub preemptions: u64,
    /// Policy epochs evaluated.
    pub epochs: u64,
    /// Per-tenant fabric latency (queueing + service).
    pub histograms: Vec<LatencyHistogram>,
}

impl ServeReport {
    pub fn total_served(&self) -> u64 {
        self.served.iter().sum()
    }

    pub fn total_rejected(&self) -> u64 {
        self.rejected.iter().sum()
    }

    pub fn total_throttled(&self) -> u64 {
        self.throttled.iter().sum()
    }

    /// Worst per-tenant p99 fabric latency.
    pub fn worst_p99_s(&self) -> f64 {
        self.histograms.iter().map(|h| h.p99()).fold(0.0, f64::max)
    }

    /// Served requests per fabric second.
    pub fn throughput_rps(&self) -> f64 {
        self.total_served() as f64 / self.completion_s.max(1e-12)
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<12} completion {:.4e} s | {} served, {} rejected, {} throttled | \
             {:.0} req/s | worst p99 {:.3e} s | {} switches, {} preemptions",
            self.strategy,
            self.completion_s,
            self.total_served(),
            self.total_rejected(),
            self.total_throttled(),
            self.throughput_rps(),
            self.worst_p99_s(),
            self.switches,
            self.preemptions,
        )
    }
}

/// Per-request fabric seconds for each tenant on the equal-weight
/// split — the calibration baseline the example, bench, CLI and tests
/// share to derive traffic rates that are independent of the
/// analytical model's absolute latency scale.
pub fn equal_split_per_request(
    platform: &Platform,
    base: &FilcoConfig,
    tenants: &[TenantSpec],
    cache: &ScheduleCache,
) -> Vec<f64> {
    let mut recon = Reconfigurator::new(base.clone());
    let named: Vec<(&str, u32)> = tenants.iter().map(|t| (t.name.as_str(), 1)).collect();
    let parts = recon.split(&named).expect("equal split");
    parts
        .iter()
        .zip(tenants)
        .map(|(p, t)| cache.get_or_compute(platform, &p.config(base), &t.dag).per_request_s)
        .collect()
}

/// Admit arrivals up to virtual time `now` into the per-tenant queues:
/// queue depth first (reject as full), then the fabric-time token
/// bucket (throttle) — the same classification order as the live
/// scheduler's `push`.
#[allow(clippy::too_many_arguments)]
fn ingest(
    arrivals: &[Arrival],
    ai: &mut usize,
    now: f64,
    pending: &mut [VecDeque<(u64, f64)>],
    rejected: &mut [u64],
    throttled: &mut [u64],
    caps: &[usize],
    buckets: &mut [Option<TokenBucket>],
    per_req: &[f64],
) {
    while *ai < arrivals.len() && arrivals[*ai].t_s <= now {
        let a = &arrivals[*ai];
        *ai += 1;
        if pending[a.tenant].len() >= caps[a.tenant] {
            rejected[a.tenant] += 1;
            continue;
        }
        if let Some(b) = &mut buckets[a.tenant] {
            if !b.try_take(per_req[a.tenant], a.t_s) {
                throttled[a.tenant] += 1;
                continue;
            }
        }
        pending[a.tenant].push_back((a.id, a.t_s));
    }
}

/// Run `scenario` under `strategy`, resolving schedules through `cache`.
pub fn simulate(scenario: &Scenario, strategy: &Strategy, cache: &ScheduleCache) -> ServeReport {
    match strategy {
        Strategy::Unified => simulate_unified(scenario, cache),
        Strategy::StaticEqual => simulate_partitioned(scenario, cache, None),
        Strategy::Dynamic(p) => simulate_partitioned(scenario, cache, Some(p)),
    }
}

fn simulate_unified(sc: &Scenario, cache: &ScheduleCache) -> ServeReport {
    let t_n = sc.tenants.len();
    let caps: Vec<usize> = sc.tenants.iter().map(|t| t.queue_capacity).collect();
    let scheds: Vec<Arc<CachedSchedule>> = sc
        .tenants
        .iter()
        .map(|t| cache.get_or_compute(&sc.platform, &sc.base, &t.dag))
        .collect();
    let per_req: Vec<f64> = scheds.iter().map(|s| s.per_request_s).collect();
    let mut buckets: Vec<Option<TokenBucket>> =
        sc.tenants.iter().map(|t| t.rate_limit.map(TokenBucket::from_limit)).collect();

    let mut pending: Vec<VecDeque<(u64, f64)>> = vec![VecDeque::new(); t_n];
    let mut hist = vec![LatencyHistogram::new(); t_n];
    let mut served = vec![0u64; t_n];
    let mut rejected = vec![0u64; t_n];
    let mut throttled = vec![0u64; t_n];
    let mut free = 0.0f64;
    let mut now = 0.0f64;
    let mut ai = 0usize;
    let mut rr = 0usize;

    loop {
        ingest(
            &sc.arrivals,
            &mut ai,
            now,
            &mut pending,
            &mut rejected,
            &mut throttled,
            &caps,
            &mut buckets,
            &per_req,
        );
        if free <= now {
            // The single worker picks the next non-empty tenant round-robin.
            for k in 0..t_n {
                let t = (rr + k) % t_n;
                let take = pending[t].len().min(sc.tenants[t].max_batch);
                if take == 0 {
                    continue;
                }
                // One execution model everywhere: the unified worker
                // walks the same cursor; undisturbed, the projected
                // total is the closed-form batch time bit-for-bit.
                let done = now + BatchCursor::new(scheds[t].clone(), take).projected_total_s();
                for _ in 0..take {
                    let (_id, arr) = pending[t].pop_front().unwrap();
                    hist[t].record(done - arr);
                    served[t] += 1;
                }
                free = done;
                rr = (t + 1) % t_n;
                break;
            }
        }
        let mut next = f64::INFINITY;
        if ai < sc.arrivals.len() {
            next = next.min(sc.arrivals[ai].t_s);
        }
        if pending.iter().any(|q| !q.is_empty()) {
            next = next.min(free);
        }
        if !next.is_finite() {
            break;
        }
        now = next;
    }

    ServeReport {
        strategy: Strategy::Unified.label().to_string(),
        completion_s: free,
        served,
        rejected,
        throttled,
        switches: 0,
        preemptions: 0,
        epochs: 0,
        histograms: hist,
    }
}

/// One in-flight batch on a tenant's slice.
struct InFlight {
    cursor: BatchCursor,
    start_s: f64,
    /// Arrival times of the batch's requests (latency recording).
    arrived: Vec<f64>,
}

impl InFlight {
    /// Projected completion time on the cursor's current schedule.
    fn fin_s(&self) -> f64 {
        self.start_s + self.cursor.projected_total_s()
    }
}

fn simulate_partitioned(
    sc: &Scenario,
    cache: &ScheduleCache,
    policy: Option<&PolicyConfig>,
) -> ServeReport {
    let t_n = sc.tenants.len();
    let names: Vec<&str> = sc.tenants.iter().map(|t| t.name.as_str()).collect();
    let caps: Vec<usize> = sc.tenants.iter().map(|t| t.queue_capacity).collect();
    let preempt_on = policy.is_some_and(PolicyConfig::preemption_enabled);

    let mut recon = Reconfigurator::new(sc.base.clone());
    if let Some(s) = sc.switch_cost_s {
        recon.set_switch_cost_s(s);
    }
    let mut weights: Vec<u32> = vec![1; t_n];
    let named: Vec<(&str, u32)> = names.iter().zip(&weights).map(|(&n, &w)| (n, w)).collect();
    let parts = recon.split(&named).expect("equal split");
    recon.validate().expect("equal split tiles the fabric");
    let setup_switches = recon.switches;
    let mut scheds: Vec<Arc<CachedSchedule>> = parts
        .iter()
        .zip(&sc.tenants)
        .map(|(part, t)| cache.get_or_compute(&sc.platform, &part.config(&sc.base), &t.dag))
        .collect();
    let mut per_req: Vec<f64> = scheds.iter().map(|s| s.per_request_s).collect();
    let mut buckets: Vec<Option<TokenBucket>> =
        sc.tenants.iter().map(|t| t.rate_limit.map(TokenBucket::from_limit)).collect();

    let mut pending: Vec<VecDeque<(u64, f64)>> = vec![VecDeque::new(); t_n];
    let mut hist = vec![LatencyHistogram::new(); t_n];
    let mut served = vec![0u64; t_n];
    let mut rejected = vec![0u64; t_n];
    let mut throttled = vec![0u64; t_n];
    let mut busy: Vec<Option<InFlight>> = (0..t_n).map(|_| None).collect();
    // Time each slice is next available for a new batch: batch
    // completion plus any switch charges taken while busy or idle.
    let mut avail = vec![0.0f64; t_n];
    let mut now = 0.0f64;
    let mut ai = 0usize;
    let mut epochs = 0u64;
    let mut preemptions = 0u64;
    let mut next_epoch = policy.map(|p| p.epoch_s).unwrap_or(f64::INFINITY);

    loop {
        ingest(
            &sc.arrivals,
            &mut ai,
            now,
            &mut pending,
            &mut rejected,
            &mut throttled,
            &caps,
            &mut buckets,
            &per_req,
        );

        // Retire batches whose (projected) completion has been reached.
        // Recording at completion: an undisturbed cursor's total is the
        // closed-form batch time, so latencies match the batch-atomic
        // model exactly; a preempted batch records its actual
        // (re-costed, switch-charged) completion.
        for t in 0..t_n {
            let done = busy[t].as_ref().is_some_and(|fl| fl.fin_s() <= now);
            if done {
                let fl = busy[t].take().unwrap();
                let fin = fl.fin_s();
                for &arr in &fl.arrived {
                    hist[t].record(fin - arr);
                    served[t] += 1;
                }
            }
        }

        // Each tenant's worker starts its next batch if its slice is
        // free.
        for t in 0..t_n {
            if busy[t].is_some() || avail[t] > now {
                continue;
            }
            let take = pending[t].len().min(sc.tenants[t].max_batch);
            if take == 0 {
                continue;
            }
            let mut arrived = Vec::with_capacity(take);
            for _ in 0..take {
                let (_id, arr) = pending[t].pop_front().unwrap();
                arrived.push(arr);
            }
            let fl = InFlight {
                cursor: BatchCursor::new(scheds[t].clone(), take),
                start_s: now,
                arrived,
            };
            avail[t] = fl.fin_s();
            busy[t] = Some(fl);
        }

        // Policy epoch: observe backlog, maybe re-compose. With
        // preemption enabled the signal includes in-flight remaining
        // work (that work is movable); with it disabled only queued
        // work counts — the pre-cursor behavior, preserved exactly.
        if let Some(p) = policy {
            if now >= next_epoch {
                epochs += 1;
                if preempt_on {
                    // Sync in-flight cursors to virtual time (live
                    // workers advance theirs continuously; the sim does
                    // it lazily at epochs): commit the layer steps that
                    // retired by `now`, so remaining-work signals and
                    // preemption decisions reflect actual progress
                    // rather than the batch-start position.
                    for fl in busy.iter_mut().flatten() {
                        while fl
                            .cursor
                            .peek_consumed_s()
                            .is_some_and(|c| fl.start_s + c <= now)
                        {
                            let _ = fl.cursor.advance();
                        }
                    }
                }
                let backlog: Vec<f64> = (0..t_n)
                    .map(|t| {
                        let queued = pending[t].len() as f64 * per_req[t];
                        let inflight = if preempt_on {
                            busy[t].as_ref().map(|fl| fl.cursor.remaining_s()).unwrap_or(0.0)
                        } else {
                            0.0
                        };
                        queued + inflight
                    })
                    .collect();
                let total_backlog: f64 = backlog.iter().sum();
                let proposed = backlog_weights(&backlog, p.max_weight);
                if should_resplit(&weights, &proposed, total_backlog, recon.switch_cost_s(), p) {
                    let named: Vec<(&str, u32)> =
                        names.iter().zip(&proposed).map(|(&n, &w)| (n, w)).collect();
                    let parts = recon.split(&named).expect("re-split");
                    debug_assert!(recon.validate().is_ok());
                    let switch = recon.switch_cost_s();
                    for t in 0..t_n {
                        let slice = parts[t].config(&sc.base);
                        let new_sched =
                            cache.get_or_compute(&sc.platform, &slice, &sc.tenants[t].dag);
                        let preempt = preempt_on
                            && busy[t].as_ref().is_some_and(|fl| {
                                // A potential switch lands at the next
                                // layer boundary; everything before it
                                // runs on the old slice either way, so
                                // compare the paths from there. (The
                                // in-flight step is also still counted
                                // in `remaining_on` — at most one step
                                // of conservative bias.) Charges parked
                                // on `avail` by earlier re-splits are
                                // owed on either path and excluded.
                                let boundary_s = fl
                                    .cursor
                                    .peek_consumed_s()
                                    .map_or(fl.fin_s(), |c| fl.start_s + c);
                                let rem_old = (fl.fin_s() - boundary_s).max(0.0);
                                let rem_new = fl.cursor.remaining_on(&new_sched);
                                should_preempt(rem_old, rem_new, switch, p)
                            });
                        if preempt {
                            // Land the switch at the next layer
                            // boundary: steps that retired by `now`
                            // stay on the old slice's accounting (the
                            // epoch sync committed them), the in-flight
                            // step finishes on it, then the cursor
                            // re-bases onto the new schedule with the
                            // mid-DAG switch charged.
                            let fl = busy[t].as_mut().unwrap();
                            // Reprogram charges from earlier re-splits
                            // while this batch was in flight are still
                            // owed after the re-basing.
                            let extra = (avail[t] - fl.fin_s()).max(0.0);
                            let _ = fl.cursor.advance();
                            fl.cursor.retarget(new_sched.clone(), switch);
                            avail[t] = fl.fin_s() + extra;
                            preemptions += 1;
                        } else {
                            // In-flight batches finish on the old
                            // composition, then every slice pays the
                            // reprogram cost.
                            avail[t] = avail[t].max(now) + switch;
                        }
                        per_req[t] = new_sched.per_request_s;
                        scheds[t] = new_sched;
                    }
                    weights = proposed;
                }
                while next_epoch <= now {
                    next_epoch += p.epoch_s;
                }
            }
        }

        // Advance to the next event.
        let mut next = f64::INFINITY;
        if ai < sc.arrivals.len() {
            next = next.min(sc.arrivals[ai].t_s);
        }
        let work_left = pending.iter().any(|q| !q.is_empty());
        let inflight_left = busy.iter().any(|b| b.is_some());
        for t in 0..t_n {
            if !pending[t].is_empty() {
                next = next.min(avail[t]);
            }
        }
        if preempt_on && inflight_left {
            // Completion events matter even with empty queues: later
            // epochs may still preempt the in-flight work.
            for t in 0..t_n {
                if busy[t].is_some() {
                    next = next.min(avail[t]);
                }
            }
        }
        let preemptible = preempt_on && inflight_left;
        if policy.is_some() && (ai < sc.arrivals.len() || work_left || preemptible) {
            next = next.min(next_epoch);
        }
        if !next.is_finite() {
            break;
        }
        now = next;
    }

    // Retire whatever is still in flight (its completion needed no
    // further events).
    for t in 0..t_n {
        if let Some(fl) = busy[t].take() {
            let fin = fl.fin_s();
            for &arr in &fl.arrived {
                hist[t].record(fin - arr);
                served[t] += 1;
            }
        }
    }

    let label = if policy.is_some() { "dynamic" } else { "static-equal" };
    ServeReport {
        strategy: label.to_string(),
        completion_s: avail.iter().cloned().fold(0.0f64, f64::max),
        served,
        rejected,
        throttled,
        switches: recon.switches - setup_switches,
        preemptions,
        epochs,
        histograms: hist,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::serve::tenant::{batch_fabric_s, poisson_trace};
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 3 }
    }

    /// Two-tenant scenario with rates calibrated to the measured
    /// equal-split service time: tenant `a` overloaded (2x its slice's
    /// service rate), tenant `b` lightly loaded. Absolute makespan scale
    /// cancels out, so the test is robust to model changes.
    fn calibrated_scenario(
        cache: &ScheduleCache,
        caps: usize,
        duration_reqs: f64,
        seed: u64,
    ) -> (Scenario, f64) {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let tenants = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let per = equal_split_per_request(&platform, &base, &tenants, cache)[0];
        let arrivals = poisson_trace(&[2.0 / per, 0.2 / per], duration_reqs * per, seed);
        (Scenario { platform, base, tenants, arrivals, switch_cost_s: None }, per)
    }

    fn test_policy(per: f64) -> PolicyConfig {
        PolicyConfig::calibrated(per)
    }

    #[test]
    fn all_strategies_serve_everything() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 40.0, 9);
        let n = sc.arrivals.len() as u64;
        assert!(n > 10, "calibrated trace too small: {n}");
        for strat in [
            Strategy::Unified,
            Strategy::StaticEqual,
            Strategy::Dynamic(test_policy(per)),
            Strategy::Dynamic(test_policy(per).without_preemption()),
        ] {
            let r = simulate(&sc, &strat, &cache);
            assert_eq!(r.total_served(), n, "{} dropped requests", r.strategy);
            assert_eq!(r.total_rejected(), 0);
            assert_eq!(r.total_throttled(), 0);
            assert!(r.completion_s > 0.0);
            let hist_n: u64 = r.histograms.iter().map(|h| h.count()).sum();
            assert_eq!(hist_n, n);
            assert!(r.worst_p99_s() > 0.0);
        }
    }

    #[test]
    fn simulation_is_deterministic() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 30.0, 11);
        let strat = Strategy::Dynamic(test_policy(per));
        let a = simulate(&sc, &strat, &cache);
        let b = simulate(&sc, &strat, &cache);
        assert_eq!(a.completion_s, b.completion_s);
        assert_eq!(a.served, b.served);
        assert_eq!(a.switches, b.switches);
        assert_eq!(a.preemptions, b.preemptions);
    }

    #[test]
    fn admission_control_rejects_floods() {
        // Burst of simultaneous arrivals against a 2-deep queue.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, _per) = calibrated_scenario(&cache, 2, 0.0, 13);
        sc.arrivals = (0..10).map(|i| Arrival { t_s: 0.0, tenant: 0, id: i }).collect();
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        assert_eq!(r.total_served() + r.total_rejected(), 10);
        assert!(r.total_rejected() > 0, "2-deep queue must reject part of a 10-burst");
    }

    #[test]
    fn token_bucket_throttles_fabric_share() {
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, per) = calibrated_scenario(&cache, 100_000, 0.0, 15);
        // Tenant a may burst 2 requests' worth of fabric time and then
        // earns 10% of a slice; a 10-burst must lose most requests to
        // the bucket while tenant b (unlimited) is untouched.
        sc.tenants[0].rate_limit =
            Some(crate::serve::tenant::RateLimit { fabric_share: 0.1, burst_s: 2.0 * per });
        sc.arrivals = (0..12)
            .map(|i| Arrival { t_s: 0.0, tenant: (i % 6 == 5) as usize, id: i })
            .collect();
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        assert_eq!(r.throttled[0], 8, "10-burst minus 2-request burst allowance");
        assert_eq!(r.throttled[1], 0);
        assert_eq!(r.total_served(), 4);
    }

    #[test]
    fn dynamic_resplits_and_reuses_cache() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 200.0, 17);
        let policy = test_policy(per);
        let r = simulate(&sc, &Strategy::Dynamic(policy.clone()), &cache);
        assert!(r.epochs > 0, "policy must have evaluated");
        assert!(r.switches >= 1, "2x overload on tenant a must trigger a re-split");
        assert!(cache.misses() >= 2);
        let before = cache.misses();
        let r2 = simulate(&sc, &Strategy::Dynamic(policy), &cache);
        assert_eq!(cache.misses(), before, "second identical run must be all cache hits");
        assert_eq!(r2.completion_s, r.completion_s);
    }

    #[test]
    fn preemption_never_loses_to_batch_boundary_switching() {
        let cache = ScheduleCache::new(tiny_solver());
        let (sc, per) = calibrated_scenario(&cache, 100_000, 120.0, 19);
        let pre = simulate(&sc, &Strategy::Dynamic(test_policy(per)), &cache);
        let bb =
            simulate(&sc, &Strategy::Dynamic(test_policy(per).without_preemption()), &cache);
        assert_eq!(pre.total_served(), bb.total_served());
        assert_eq!(bb.preemptions, 0, "without_preemption must never preempt");
        // The two runs see slightly different backlog signals, so exact
        // dominance is not guaranteed on an arbitrary trace — but
        // preemption must stay in the same ballpark (the crafted
        // acceptance scenario in rust/tests asserts the strict win).
        assert!(
            pre.completion_s <= bb.completion_s * 1.1,
            "preemption must not meaningfully slow completion: {:.6e} vs {:.6e}",
            pre.completion_s,
            bb.completion_s
        );
    }

    #[test]
    fn undisturbed_batch_costs_match_the_closed_form() {
        // One tenant, one burst, static split: completion must be the
        // closed-form batch cost chain (bit-for-bit), demonstrating the
        // cursor model preserves the batch-atomic accounting.
        let cache = ScheduleCache::new(tiny_solver());
        let (mut sc, _per) = calibrated_scenario(&cache, 100_000, 0.0, 21);
        sc.arrivals = (0..12).map(|i| Arrival { t_s: 0.0, tenant: 0, id: i }).collect();
        sc.tenants[0] = sc.tenants[0].clone().with_max_batch(8);
        let r = simulate(&sc, &Strategy::StaticEqual, &cache);
        let per0 = equal_split_per_request(&sc.platform, &sc.base, &sc.tenants, &cache)[0];
        let expect = batch_fabric_s(per0, 8) + batch_fabric_s(per0, 4);
        assert_eq!(r.completion_s, expect, "cursor walk must equal batch-atomic accounting");
    }
}
