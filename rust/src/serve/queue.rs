//! Bounded MPMC request queue with admission control.
//!
//! Generalizes the serving leader's FIFO
//! ([`crate::coordinator::serving::RequestQueue`], now a thin wrapper
//! over this type): one mutex guards *both* the deque and the closed
//! flag — the state transition "closed while waiters sleep" is visible
//! atomically with the emptiness check, so there is no two-lock dance
//! and no missed-wakeup window.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushError {
    /// Queue is at capacity — admission control rejected the request.
    Full,
    /// Queue was closed; no new work is accepted.
    Closed,
    /// The tenant's fabric-time token bucket is empty — its share of
    /// fabric time is exhausted even though the queue has room.
    Throttled,
    /// Deadline-aware admission shed the request: the queue-wait
    /// estimate already exceeds the tenant's latency-SLO deadline, so
    /// queuing it could only produce a late answer.
    Deadline,
}

impl std::fmt::Display for PushError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PushError::Full => write!(f, "queue full"),
            PushError::Closed => write!(f, "queue closed"),
            PushError::Throttled => write!(f, "fabric-time share exhausted"),
            PushError::Deadline => write!(f, "deadline unmeetable at admission"),
        }
    }
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded blocking FIFO. `capacity == usize::MAX` means unbounded.
pub struct BoundedQueue<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
    capacity: usize,
}

impl<T> Default for BoundedQueue<T> {
    fn default() -> Self {
        Self::unbounded()
    }
}

impl<T> BoundedQueue<T> {
    /// Queue admitting at most `capacity` items (min 1). All methods
    /// are safe to call from any thread; one internal mutex guards the
    /// deque and the closed flag together.
    pub fn new(capacity: usize) -> Self {
        Self {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Queue with no admission bound (`capacity == usize::MAX`).
    pub fn unbounded() -> Self {
        Self::new(usize::MAX)
    }

    /// Admission bound this queue was built with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admission-controlled push: rejects instead of blocking when the
    /// queue is full or closed.
    pub fn try_push(&self, item: T) -> Result<(), PushError> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(PushError::Closed);
        }
        if s.items.len() >= self.capacity {
            return Err(PushError::Full);
        }
        s.items.push_back(item);
        drop(s);
        self.cv.notify_one();
        Ok(())
    }

    /// Close the queue: pending items stay poppable, new pushes fail,
    /// blocked consumers wake up.
    pub fn close(&self) {
        let mut s = self.state.lock().unwrap();
        s.closed = true;
        drop(s);
        self.cv.notify_all();
    }

    /// Has [`Self::close`] been called? (Pending items may remain.)
    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Pop up to `max_batch` items; blocks until at least one is
    /// available, or returns `None` once the queue is closed and empty.
    pub fn pop_batch(&self, max_batch: usize) -> Option<Vec<T>> {
        self.pop_batch_deadline(max_batch, None)
    }

    /// Like [`Self::pop_batch`] but gives up at a deadline, returning
    /// `Some(vec![])` — lets worker loops periodically re-read their
    /// partition plan while idle. `None` still means closed and drained.
    ///
    /// The deadline is a *monotonic* instant computed once up front
    /// (`checked_add`: a timeout too large to represent waits
    /// unbounded instead of panicking), and every wakeup — notified,
    /// timed out, or spurious — re-evaluates items, closed flag, and
    /// deadline under the lock in that order, so a wakeup racing the
    /// deadline returns whatever items actually arrived rather than
    /// a stale empty batch.
    pub fn pop_batch_timeout(&self, max_batch: usize, timeout: Duration) -> Option<Vec<T>> {
        self.pop_batch_deadline(max_batch, Instant::now().checked_add(timeout))
    }

    fn pop_batch_deadline(&self, max_batch: usize, deadline: Option<Instant>) -> Option<Vec<T>> {
        let mut s = self.state.lock().unwrap();
        loop {
            if !s.items.is_empty() {
                let take = s.items.len().min(max_batch.max(1));
                return Some(s.items.drain(..take).collect());
            }
            if s.closed {
                return None;
            }
            match deadline {
                None => s = self.cv.wait(s).unwrap(),
                Some(d) => {
                    // Re-sample the monotonic clock on every pass: a
                    // spurious wakeup before the deadline goes back to
                    // sleep for exactly the remainder, never returns
                    // early, and never panics on remainder arithmetic
                    // (`now >= d` is checked first).
                    let now = Instant::now();
                    if now >= d {
                        return Some(Vec::new());
                    }
                    s = self.cv.wait_timeout(s, d - now).unwrap().0;
                }
            }
        }
    }

    /// Items currently queued (a racy snapshot under concurrency).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    /// Is the queue currently empty? (A racy snapshot, like [`Self::len`].)
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_batching() {
        let q = BoundedQueue::unbounded();
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        assert_eq!(q.pop_batch(3).unwrap(), vec![0, 1, 2]);
        assert_eq!(q.pop_batch(3).unwrap(), vec![3, 4]);
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let q = BoundedQueue::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(PushError::Full));
        // Draining reopens admission.
        assert_eq!(q.pop_batch(1).unwrap(), vec![1]);
        q.try_push(3).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn close_rejects_push_but_drains() {
        let q = BoundedQueue::unbounded();
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(PushError::Closed));
        assert_eq!(q.pop_batch(4).unwrap(), vec![7]);
        assert!(q.pop_batch(4).is_none());
    }

    #[test]
    fn close_unblocks_waiter() {
        let q = Arc::new(BoundedQueue::<u32>::unbounded());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch(4));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn timeout_returns_empty_batch() {
        let q = BoundedQueue::<u32>::unbounded();
        let got = q.pop_batch_timeout(4, Duration::from_millis(10));
        assert_eq!(got, Some(Vec::new()));
        q.try_push(1).unwrap();
        assert_eq!(q.pop_batch_timeout(4, Duration::from_millis(10)), Some(vec![1]));
    }

    #[test]
    fn timeout_deadline_is_monotonic_and_overflow_safe() {
        // Regression: `Instant::now() + timeout` panicked on a
        // deadline past the representable range; `checked_add` treats
        // it as an unbounded wait instead. Close from another thread
        // so the call returns.
        let q = Arc::new(BoundedQueue::<u32>::unbounded());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch_timeout(4, Duration::MAX));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none(), "closed-and-drained, not a timeout");

        // Regression: an empty timeout pop must wait out its full
        // monotonic deadline — wakeups (including the notify from a
        // push that a racing consumer steals) never return early.
        let q = Arc::new(BoundedQueue::<u32>::unbounded());
        let waiter = {
            let q = q.clone();
            std::thread::spawn(move || {
                let t0 = Instant::now();
                let got = q.pop_batch_timeout(4, Duration::from_millis(80));
                (got, t0.elapsed())
            })
        };
        // Push then immediately try to steal the item back on this
        // thread: the waiter may observe the notify with the queue
        // empty again (a spurious wakeup from its point of view).
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(7).unwrap();
        let _ = q.pop_batch_timeout(4, Duration::ZERO);
        let (got, waited) = waiter.join().unwrap();
        if got == Some(Vec::new()) {
            assert!(
                waited >= Duration::from_millis(80),
                "an empty return must mean the full deadline elapsed, waited {waited:?}"
            );
        } else {
            assert_eq!(got, Some(vec![7]), "or the waiter won the race for the item");
        }
    }

    #[test]
    fn cross_thread_producers() {
        let q = Arc::new(BoundedQueue::unbounded());
        let mut handles = Vec::new();
        for t in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    q.try_push(t * 100 + i).unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut n = 0;
        while let Some(b) = q.pop_batch(8) {
            n += b.len();
        }
        assert_eq!(n, 100);
    }
}
