//! Per-partition time multiplexing: the [`Interleaver`] runs two (or
//! more) [`BatchCursor`]s on *one* fabric slice, swapping between them
//! at layer-step boundaries and charging the composition-switch cost
//! for every context swap.
//!
//! This is the execution half of cross-tenant packing (Herald-style
//! co-scheduling): when the policy decides two low-backlog tenants fit
//! one partition, their batches no longer each strand a slice — they
//! share one, round-robin, a quantum of layer steps at a time.
//!
//! # Fabric-time conservation
//!
//! Interleaving reorders steps but never changes them: each slot's
//! cursor retires exactly the step sequence it would have retired solo,
//! so its final [`BatchCursor::consumed_s`] is *bit-for-bit* the solo
//! walk's total. The only extra fabric time is the swap charges:
//!
//! ```text
//! interleaved total == Σ (solo walk totals) + swaps() × swap_cost_s
//! ```
//!
//! [`Interleaver::consumed_s`] computes its left-hand side exactly that
//! way (per-slot closed forms plus the swap term), so the identity is
//! exact, not approximate — the conservation tests below and in
//! `rust/tests/serve_pack.rs` assert `==` on `f64`s.
//!
//! All durations in this module are **fabric seconds** (modelled device
//! time), never wall-clock seconds. The type is single-threaded; every
//! interleaver is owned by the [`FabricEngine`](super::FabricEngine),
//! which both drivers access under one lock (the live scheduler) or
//! from one thread (the simulator), so no locking is required or
//! provided.

use std::sync::Arc;

use super::cache::CachedSchedule;
use super::tenant::{BatchCursor, RetargetError, StepEvent};

/// One batch being multiplexed on the slice: the owning tenant's index
/// plus its in-flight cursor.
#[derive(Debug, Clone)]
struct Slot {
    tenant: usize,
    cursor: BatchCursor,
}

/// One retired layer step of an interleaved walk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InterleaveEvent {
    /// Tenant whose cursor retired this step.
    pub tenant: usize,
    /// Swap charge (fabric seconds) paid *before* this step because the
    /// slice had to load a different cursor's context; `0.0` when the
    /// step continues the previously active cursor.
    pub swap_charge_s: f64,
    /// The underlying cursor step (durations in fabric seconds;
    /// `step.consumed_s` is the owning *cursor's* total, excluding swap
    /// charges, so it stays comparable to a solo walk).
    pub step: StepEvent,
    /// True when this step completed the tenant's batch; the slot has
    /// been removed and the tenant may be admitted again.
    pub done: bool,
}

/// Time-multiplexes several [`BatchCursor`]s on one fabric slice.
///
/// Rotation is round-robin with a configurable quantum: the active
/// cursor runs up to `quantum_steps` layer steps, then the next live
/// cursor is swapped in (paying `swap_cost_s` fabric seconds). A slot
/// whose cursor completes is removed automatically and its tenant may
/// be re-admitted with a fresh batch via [`Self::add`].
///
/// A single-slot interleaver degenerates to a plain cursor walk with
/// zero swaps, which is how the live scheduler runs *un*packed tenants
/// through the same code path.
#[derive(Debug, Clone)]
pub struct Interleaver {
    slots: Vec<Slot>,
    /// Rotation position into `slots`.
    rr: usize,
    /// Steps the slot at `rr` has run in its current quantum
    /// (saturating at `quantum_steps`).
    ran: usize,
    /// Tenant whose context is resident on the slice (swap detection);
    /// survives slot removal — re-admitting the same tenant while its
    /// context is still resident costs no swap.
    active: Option<usize>,
    swap_cost_s: f64,
    quantum_steps: usize,
    swaps: u64,
    /// Σ final `consumed_s` of completed (removed) cursors, accumulated
    /// in completion order.
    retired_s: f64,
}

impl Interleaver {
    /// New empty interleaver charging `swap_cost_s` fabric seconds per
    /// context swap and rotating after `quantum_steps` layer steps
    /// (clamped to at least 1).
    pub fn new(swap_cost_s: f64, quantum_steps: usize) -> Self {
        Self {
            slots: Vec::new(),
            rr: 0,
            ran: 0,
            active: None,
            swap_cost_s: swap_cost_s.max(0.0),
            quantum_steps: quantum_steps.max(1),
            swaps: 0,
            retired_s: 0.0,
        }
    }

    /// Fabric seconds charged per context swap.
    pub fn swap_cost_s(&self) -> f64 {
        self.swap_cost_s
    }

    /// Layer steps a cursor runs before the rotation moves on.
    pub fn quantum_steps(&self) -> usize {
        self.quantum_steps
    }

    /// Context swaps performed so far.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Live (incomplete) slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no batch is in flight.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Is a batch of `tenant` currently in flight?
    pub fn contains(&self, tenant: usize) -> bool {
        self.slots.iter().any(|s| s.tenant == tenant)
    }

    /// Tenants with a live slot, in rotation-vector order.
    pub fn tenants(&self) -> Vec<usize> {
        self.slots.iter().map(|s| s.tenant).collect()
    }

    /// Tenant whose context is resident on the slice (the last one that
    /// retired a step), if any.
    pub fn active_tenant(&self) -> Option<usize> {
        self.active
    }

    /// Admit `tenant`'s batch. Panics if the tenant already has a live
    /// slot (one in-flight batch per tenant) or the cursor is already
    /// done — both are caller bugs, not runtime conditions.
    pub fn add(&mut self, tenant: usize, cursor: BatchCursor) {
        assert!(!self.contains(tenant), "tenant {tenant} already has a live slot");
        assert!(!cursor.is_done(), "cannot admit a completed cursor");
        self.slots.push(Slot { tenant, cursor });
    }

    /// Remove `tenant`'s in-flight cursor without completing it.
    /// Returns `None` when the tenant has no live slot.
    ///
    /// Note: the engine's unpack path drains a group before dissolving
    /// it (batches never migrate *out* of an interleaver mid-flight);
    /// mid-flight pack handoff migrates cursors *in*, via
    /// checkpoint/resume into [`Self::add`]. `take` remains the
    /// building block for the outbound direction.
    pub fn take(&mut self, tenant: usize) -> Option<BatchCursor> {
        let pos = self.slots.iter().position(|s| s.tenant == tenant)?;
        Some(self.remove_at(pos).cursor)
    }

    /// Fabric seconds left across every live slot (on each cursor's
    /// current schedule; excludes future swap charges).
    pub fn remaining_s(&self) -> f64 {
        self.slots.iter().map(|s| s.cursor.remaining_s()).sum()
    }

    /// Fabric seconds left on `tenant`'s in-flight batch (`0.0` when it
    /// has no live slot).
    pub fn slot_remaining_s(&self, tenant: usize) -> f64 {
        self.slots
            .iter()
            .find(|s| s.tenant == tenant)
            .map(|s| s.cursor.remaining_s())
            .unwrap_or(0.0)
    }

    /// Total fabric seconds the interleaved walk has consumed: retired
    /// cursors' closed-form totals, live cursors' progress, plus the
    /// accumulated swap charges. Computed so that, once every slot has
    /// drained, it equals the solo-walk totals plus `swaps × swap_cost`
    /// exactly (see the module docs).
    pub fn consumed_s(&self) -> f64 {
        let live: f64 = self.slots.iter().map(|s| s.cursor.consumed_s()).sum();
        self.retired_s + live + self.swaps as f64 * self.swap_cost_s
    }

    /// Re-base `tenant`'s remaining steps onto `sched` (the slice was
    /// re-composed), charging `switch_charge_s` into the cursor's own
    /// timeline — same contract as [`BatchCursor::retarget`], including
    /// the same-timeline check (a mismatched step count is refused with
    /// a [`RetargetError`] and the slot is untouched). Returns
    /// `Ok(false)` when the tenant has no live slot.
    pub fn retarget(
        &mut self,
        tenant: usize,
        sched: Arc<CachedSchedule>,
        switch_charge_s: f64,
    ) -> Result<bool, RetargetError> {
        match self.slots.iter_mut().find(|s| s.tenant == tenant) {
            Some(s) => s.cursor.retarget(sched, switch_charge_s).map(|()| true),
            None => Ok(false),
        }
    }

    /// Fabric seconds the next [`Self::advance`] will consume (swap
    /// charge plus step duration), without committing it — what the
    /// virtual-time simulator schedules its next event on. `None` when
    /// every slot has drained. Read-only: replays the rotation decision
    /// and probes only the chosen cursor (this sits on the simulator's
    /// per-step hot path, so it must not clone the slot vector).
    pub fn peek_next_s(&self) -> Option<f64> {
        if self.slots.is_empty() {
            return None;
        }
        let mut rr = self.rr;
        let mut ran = self.ran;
        if rr >= self.slots.len() {
            rr = 0;
            ran = 0;
        }
        if ran >= self.quantum_steps && self.slots.len() > 1 {
            rr = (rr + 1) % self.slots.len();
        }
        let slot = &self.slots[rr];
        let swap = match self.active {
            Some(t) if t == slot.tenant => 0.0,
            None => 0.0,
            Some(_) => self.swap_cost_s,
        };
        // Same arithmetic as advance(): the next step's duration is the
        // cursor's consumed delta across one step, clamped like
        // StepEvent::dur_s — bit-identical to what advance() will emit.
        let before = slot.cursor.consumed_s();
        let after = slot.cursor.peek_consumed_s()?;
        Some(swap + (after - before).max(0.0))
    }

    fn remove_at(&mut self, pos: usize) -> Slot {
        let slot = self.slots.remove(pos);
        if pos < self.rr {
            self.rr -= 1;
        } else if pos == self.rr {
            // The rotation now points at the next slot; give it a fresh
            // quantum.
            self.ran = 0;
        }
        if self.rr >= self.slots.len() {
            self.rr = 0;
        }
        slot
    }

    /// Retire one layer step of the multiplexed walk: rotate if the
    /// active slot's quantum is exhausted (charging the swap), advance
    /// the chosen cursor one step, and remove its slot if that
    /// completed the batch. Returns `None` once no slot is live.
    pub fn advance(&mut self) -> Option<InterleaveEvent> {
        if self.slots.is_empty() {
            return None;
        }
        if self.rr >= self.slots.len() {
            self.rr = 0;
            self.ran = 0;
        }
        if self.ran >= self.quantum_steps && self.slots.len() > 1 {
            self.rr = (self.rr + 1) % self.slots.len();
            self.ran = 0;
        }
        let tenant = self.slots[self.rr].tenant;
        let swap_charge_s = match self.active {
            Some(t) if t == tenant => 0.0,
            None => 0.0,
            Some(_) => {
                self.swaps += 1;
                self.swap_cost_s
            }
        };
        self.active = Some(tenant);
        let step = self.slots[self.rr].cursor.advance().expect("live slot has steps left");
        self.ran = (self.ran + 1).min(self.quantum_steps);
        let done = self.slots[self.rr].cursor.is_done();
        if done {
            let slot = self.remove_at(self.rr);
            self.retired_s += slot.cursor.consumed_s();
        }
        Some(InterleaveEvent { tenant, swap_charge_s, step, done })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::{Schedule, ScheduleEntry};
    use crate::serve::tenant::batch_fabric_s;

    /// A synthetic serial chain schedule: `durs[i]` seconds per layer.
    fn chain_sched(durs: &[f64]) -> Arc<CachedSchedule> {
        let mut entries = Vec::new();
        let mut t = 0.0;
        for (i, &d) in durs.iter().enumerate() {
            entries.push(ScheduleEntry {
                layer: i,
                mode: 0,
                start: t,
                end: t + d,
                fmus: vec![0],
                cus: vec![0],
            });
            t += d;
        }
        Arc::new(CachedSchedule::new(Schedule { entries, makespan: t }))
    }

    /// Walk a cursor solo to completion and return its final consumed.
    fn solo_total(sched: &Arc<CachedSchedule>, batch: usize) -> f64 {
        let mut c = BatchCursor::new(sched.clone(), batch);
        while c.advance().is_some() {}
        c.consumed_s()
    }

    #[test]
    fn single_slot_degenerates_to_a_plain_cursor_walk() {
        let sched = chain_sched(&[0.3, 0.7, 0.15]);
        let mut il = Interleaver::new(1e-3, 2);
        il.add(7, BatchCursor::new(sched.clone(), 3));
        let mut steps = 0;
        let mut last_done = false;
        while let Some(ev) = il.advance() {
            assert_eq!(ev.tenant, 7);
            assert_eq!(ev.swap_charge_s, 0.0, "solo walk never swaps");
            steps += 1;
            last_done = ev.done;
        }
        assert_eq!(steps, 9);
        assert!(last_done);
        assert_eq!(il.swaps(), 0);
        assert!(il.is_empty());
        // Conservation degenerates to the solo identity.
        assert_eq!(il.consumed_s(), solo_total(&sched, 3));
        assert_eq!(il.consumed_s(), batch_fabric_s(sched.per_request_s, 3));
    }

    #[test]
    fn conservation_holds_bit_for_bit_with_swap_charges() {
        let a = chain_sched(&[0.4, 0.6, 1.1]);
        let b = chain_sched(&[0.25, 0.25, 0.25, 0.25]);
        let swap = 0.0625; // exactly representable: charges add exactly
        for quantum in [1usize, 2, 3, 7] {
            let mut il = Interleaver::new(swap, quantum);
            il.add(0, BatchCursor::new(a.clone(), 2));
            il.add(1, BatchCursor::new(b.clone(), 3));
            let mut finals = [0.0f64; 2];
            while let Some(ev) = il.advance() {
                if ev.done {
                    finals[ev.tenant] = ev.step.consumed_s;
                }
            }
            assert!(il.is_empty());
            assert!(il.swaps() >= 1, "two live cursors must swap at least once");
            // Each cursor's interleaved walk is the solo walk bit-for-bit.
            assert_eq!(finals[0], solo_total(&a, 2), "quantum {quantum}");
            assert_eq!(finals[1], solo_total(&b, 3), "quantum {quantum}");
            // Sum of interleaved step durations + swap charges == sum of
            // solo walks + charges, exactly.
            let expect =
                solo_total(&a, 2) + solo_total(&b, 3) + il.swaps() as f64 * swap;
            assert_eq!(il.consumed_s(), expect, "quantum {quantum}");
        }
    }

    #[test]
    fn quantum_bounds_swap_frequency() {
        let a = chain_sched(&[1.0, 1.0]);
        let b = chain_sched(&[1.0, 1.0]);
        // Quantum 1: every step rotates -> swap per step (minus the
        // first activation). 2 requests x 2 steps x 2 tenants = 8 steps.
        let mut il1 = Interleaver::new(0.5, 1);
        il1.add(0, BatchCursor::new(a.clone(), 2));
        il1.add(1, BatchCursor::new(b.clone(), 2));
        while il1.advance().is_some() {}
        assert_eq!(il1.swaps(), 7);
        // Quantum 4: each tenant runs a whole batch's steps per turn.
        let mut il4 = Interleaver::new(0.5, 4);
        il4.add(0, BatchCursor::new(a, 2));
        il4.add(1, BatchCursor::new(b, 2));
        while il4.advance().is_some() {}
        assert_eq!(il4.swaps(), 1, "one swap: a's 4 steps, then b's 4 steps");
    }

    #[test]
    fn readmission_after_completion_reuses_resident_context() {
        let s = chain_sched(&[1.0]);
        let mut il = Interleaver::new(0.25, 8);
        il.add(0, BatchCursor::new(s.clone(), 1));
        let ev = il.advance().unwrap();
        assert!(ev.done);
        assert!(il.is_empty());
        // Same tenant again: its context never left the slice.
        il.add(0, BatchCursor::new(s.clone(), 1));
        let ev = il.advance().unwrap();
        assert_eq!(ev.swap_charge_s, 0.0);
        assert_eq!(il.swaps(), 0);
        // A different tenant does pay the swap.
        il.add(1, BatchCursor::new(s, 1));
        let ev = il.advance().unwrap();
        assert_eq!(ev.tenant, 1);
        assert_eq!(ev.swap_charge_s, 0.25);
        assert_eq!(il.swaps(), 1);
    }

    #[test]
    fn peek_matches_the_next_advance() {
        let a = chain_sched(&[0.5, 1.5]);
        let b = chain_sched(&[0.75]);
        let mut il = Interleaver::new(0.125, 1);
        il.add(0, BatchCursor::new(a, 1));
        il.add(1, BatchCursor::new(b, 1));
        while let Some(peek) = il.peek_next_s() {
            let ev = il.advance().unwrap();
            assert_eq!(peek, ev.swap_charge_s + ev.step.dur_s);
        }
        assert!(il.advance().is_none());
    }

    #[test]
    fn take_removes_a_live_cursor_for_unpacking() {
        let a = chain_sched(&[1.0, 1.0]);
        let b = chain_sched(&[1.0, 1.0]);
        let mut il = Interleaver::new(0.0, 1);
        il.add(0, BatchCursor::new(a, 1));
        il.add(1, BatchCursor::new(b, 1));
        il.advance().unwrap();
        assert!(il.contains(0) && il.contains(1));
        let cur = il.take(1).expect("tenant 1 has a live slot");
        assert!(cur.remaining_s() > 0.0);
        assert!(!il.contains(1));
        assert!(il.take(1).is_none());
        // The remaining slot still drains cleanly.
        let mut steps = 0;
        while il.advance().is_some() {
            steps += 1;
        }
        assert_eq!(steps, 1);
    }

    #[test]
    fn retarget_rebases_one_slot_mid_flight() {
        let slow = chain_sched(&[1.0, 1.0, 1.0, 1.0]);
        let fast = chain_sched(&[0.25, 0.25, 0.25, 0.25]);
        let mut il = Interleaver::new(0.0, 2);
        il.add(0, BatchCursor::new(slow.clone(), 1));
        il.advance().unwrap();
        il.advance().unwrap();
        assert!(il.retarget(0, fast, 0.5).unwrap());
        assert!(!il.retarget(9, chain_sched(&[1.0]), 0.0).unwrap());
        // A mismatched timeline is refused, not clamped.
        assert!(il.retarget(0, chain_sched(&[1.0]), 0.0).is_err());
        let mut last = 0.0;
        while let Some(ev) = il.advance() {
            last = ev.step.consumed_s;
        }
        // 2 slow layers + one 0.5 charge + 2 fast layers.
        assert!((last - (2.0 + 0.5 + 0.5)).abs() < 1e-12, "got {last}");
    }
}
