//! The two clocks of the unified fabric engine.
//!
//! The [`FabricEngine`](super::FabricEngine) is a deterministic state
//! machine over *fabric time* (modelled device seconds). What varies
//! between the virtual-time simulator and the live threaded scheduler
//! is only *when* the driver lets the engine reach a given fabric
//! instant:
//!
//! * [`VirtualClock`] jumps instantly — the simulator drains the engine
//!   as fast as the host can compute, one event at a time;
//! * [`WallClock`] maps fabric seconds to wall seconds through a
//!   `timescale` and sleeps toward each deadline using the [`Pacer`]
//!   discipline, so a paced live run behaves (queue depths, policy
//!   epochs, preemption opportunities) like it would on hardware.
//!
//! Because the engine's decisions depend only on the fabric instants it
//! is stepped to — never on the wall clock — the two drivers produce
//! identical engine event traces for the same scenario (asserted by
//! `rust/tests/serve_engine.rs`).

use std::time::{Duration, Instant};

/// Deadline-based pacing primitive: sleeps *toward* absolute wall
/// deadlines measured from an anchor instant, so per-sleep overshoot
/// (OS scheduler granularity) is absorbed by later deadlines instead of
/// accumulating — a run of thousands of sub-millisecond steps drifts by
/// at most one sleep's overshoot, not the sum of all of them.
#[derive(Debug, Clone)]
pub struct Pacer {
    anchor: Instant,
}

impl Default for Pacer {
    fn default() -> Self {
        Self::new()
    }
}

impl Pacer {
    /// Pacer anchored at the current instant.
    pub fn new() -> Self {
        Self { anchor: Instant::now() }
    }

    /// Wall seconds elapsed since the anchor.
    pub fn elapsed_s(&self) -> f64 {
        self.anchor.elapsed().as_secs_f64()
    }

    /// Sleep toward the absolute wall deadline `deadline_s` (seconds
    /// after the anchor), capped at `max_sleep` per call so an extreme
    /// or non-finite deadline throttles instead of hanging. Returns
    /// true once the deadline has been reached (callers loop until
    /// then, re-checking their own state between sleeps).
    pub fn sleep_toward(&self, deadline_s: f64, max_sleep: Duration) -> bool {
        let lead = deadline_s - self.elapsed_s();
        if lead <= 0.0 {
            return true;
        }
        std::thread::sleep(Duration::from_secs_f64(lead.min(max_sleep.as_secs_f64())));
        deadline_s - self.elapsed_s() <= 0.0
    }
}

/// A driver's view of time, in fabric seconds.
///
/// `advance_to` blocks (or jumps) until the clock has reached fabric
/// instant `t_s`; it may return `false` when only partial progress was
/// made (bounded sleep), in which case the driver re-checks its state
/// and calls again.
pub trait Clock {
    /// Current driver time in fabric seconds.
    fn now_s(&self) -> f64;

    /// Move toward fabric instant `t_s`. Returns true once reached.
    fn advance_to(&mut self, t_s: f64) -> bool;
}

/// Virtual time: `advance_to` jumps instantly. The simulator's clock.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now_s: f64,
}

impl VirtualClock {
    /// Virtual clock at fabric time zero.
    pub fn new() -> Self {
        Self::default()
    }
}

impl Clock for VirtualClock {
    fn now_s(&self) -> f64 {
        self.now_s
    }

    fn advance_to(&mut self, t_s: f64) -> bool {
        if t_s > self.now_s {
            self.now_s = t_s;
        }
        true
    }
}

/// Wall time paced at `timescale` wall seconds per fabric second
/// through a [`Pacer`]. A `timescale` of 0 drains at host speed: every
/// fabric instant is immediately due and `now_s` reports wall seconds
/// 1:1 (the only meaningful clock left for token-bucket refills).
///
/// The mapping is an anchor pair (wall anchor, fabric `origin_s`).
/// [`Self::resync`] re-anchors it — a driver whose fabric clock stood
/// still (idle engine, no producers) must re-anchor when work resumes,
/// or the idle wall time would be banked as pacing lead and the next
/// burst would drain unpaced at host speed.
#[derive(Debug, Clone)]
pub struct WallClock {
    pacer: Pacer,
    origin_s: f64,
    timescale: f64,
    max_sleep: Duration,
}

impl WallClock {
    /// Wall clock anchored now at fabric time zero, mapping 1 fabric
    /// second to `timescale` wall seconds; single sleeps are capped at
    /// `max_sleep`.
    pub fn new(timescale: f64, max_sleep: Duration) -> Self {
        Self { pacer: Pacer::new(), origin_s: 0.0, timescale: timescale.max(0.0), max_sleep }
    }

    /// The wall→fabric scale this clock was built with.
    pub fn timescale(&self) -> f64 {
        self.timescale
    }

    /// Re-anchor: fabric instant `fabric_now_s` maps to the current
    /// wall instant from here on, discarding any pacing lead banked
    /// while the fabric clock stood still.
    pub fn resync(&mut self, fabric_now_s: f64) {
        self.pacer = Pacer::new();
        self.origin_s = fabric_now_s;
    }

    /// Wall seconds until fabric instant `t_s` is due (`<= 0.0` means
    /// already due; always due when unpaced).
    pub fn lead_s(&self, t_s: f64) -> f64 {
        if self.timescale <= 0.0 {
            return 0.0;
        }
        (t_s - self.origin_s) * self.timescale - self.pacer.elapsed_s()
    }
}

impl Clock for WallClock {
    fn now_s(&self) -> f64 {
        if self.timescale > 0.0 {
            self.origin_s + self.pacer.elapsed_s() / self.timescale
        } else {
            self.pacer.elapsed_s()
        }
    }

    fn advance_to(&mut self, t_s: f64) -> bool {
        if self.timescale <= 0.0 {
            return true;
        }
        self.pacer.sleep_toward((t_s - self.origin_s) * self.timescale, self.max_sleep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_jumps_and_never_goes_backwards() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now_s(), 0.0);
        assert!(c.advance_to(1.5));
        assert_eq!(c.now_s(), 1.5);
        assert!(c.advance_to(0.5), "a past instant is already reached");
        assert_eq!(c.now_s(), 1.5);
    }

    #[test]
    fn unpaced_wall_clock_is_always_due() {
        let mut c = WallClock::new(0.0, Duration::from_millis(100));
        assert!(c.advance_to(1e9), "timescale 0 drains at host speed");
        assert_eq!(c.lead_s(1e9), 0.0);
    }

    #[test]
    fn resync_discards_banked_pacing_lead() {
        let mut c = WallClock::new(1.0, Duration::from_millis(100));
        std::thread::sleep(Duration::from_millis(30));
        // 30 ms of wall time passed with the fabric clock at 0: without
        // a resync, fabric instants up to ~0.03 are already "due".
        assert!(c.lead_s(0.02) < 0.0, "idle wall time banks as lead");
        c.resync(5.0);
        // After re-anchoring at fabric 5.0, an instant 20 ms of fabric
        // time ahead is 20 ms of wall time away again.
        let lead = c.lead_s(5.02);
        assert!(lead > 0.0 && lead <= 0.02 + 1e-3, "resync must restore pacing: {lead}");
        assert!(c.now_s() >= 5.0);
    }

    #[test]
    fn deadline_pacing_bounds_cumulative_drift() {
        // 5000 sub-millisecond deadlines, 0.1 s of paced fabric time in
        // total. A per-step sleeper accumulates one OS-granularity
        // overshoot per step (hundreds of ms in aggregate); the
        // deadline pacer absorbs overshoot into later deadlines, so the
        // total drift stays bounded by roughly one sleep's overshoot.
        let mut c = WallClock::new(1.0, Duration::from_millis(100));
        let steps = 5000usize;
        let dur = 2e-5f64;
        let t0 = Instant::now();
        for k in 1..=steps {
            while !c.advance_to(k as f64 * dur) {}
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let target = steps as f64 * dur;
        assert!(elapsed >= 0.9 * target, "pacer must actually pace: {elapsed:.3} s");
        assert!(
            elapsed < target + 0.35,
            "deadline pacing must not accumulate per-step jitter: {elapsed:.3} s vs {target:.3} s"
        );
    }
}
