//! Persistent fabric telemetry: recorded [`EngineEvent`] traces with
//! bit-exact replay, the per-epoch metrics timeline, and the step-loop
//! profiling counters behind the committed `BENCH_*.json` snapshots.
//!
//! # Trace format
//!
//! A trace is JSONL — one self-contained JSON object per line, written
//! through [`crate::util::json`] (so a line can never be malformed:
//! control characters are escaped and non-finite floats serialize as
//! `null`):
//!
//! 1. a `{"kind":"header",...}` line with the format version
//!    ([`TRACE_VERSION`]), the strategy label, and the tenant names;
//! 2. one `{"kind":"event",...}` line per [`EngineEvent`] in engine
//!    emission order, each stamped with its fabric instant;
//! 3. a `{"kind":"summary",...}` footer carrying the originating run's
//!    full [`ServeReport`], histograms included.
//!
//! # Replay guarantee
//!
//! [`RecordedTrace::replay`] reconstructs a [`ServeReport`] from the
//! event stream alone (plus the footer's few non-derivable fields, see
//! below), and [`RecordedTrace::verify`] holds it to the footer
//! *bit-for-bit*: served/rejected/throttled counts, every transition
//! counter, and every latency histogram bucket, sum, min and max must
//! match exactly — the same discipline as the live-vs-sim differential
//! in `rust/tests/serve_engine.rs`. Two properties make this possible:
//!
//! * the engine admits and retires batches per tenant in FIFO order,
//!   so pairing each [`EngineEvent::BatchDone`] with the oldest
//!   un-served [`EngineEvent::Admitted`] arrivals reproduces the exact
//!   latency each request's histogram record was computed from;
//! * every `f64` the engine stamps round-trips JSON exactly (shortest
//!   round-trip formatting on write, `str::parse::<f64>` on read).
//!
//! Three counters are carried from the footer rather than recomputed,
//! because the event stream does not determine them: `completion_s`
//! (trailing reprogram charges on slice availability can land after
//! the last `BatchDone`), `epochs` (an epoch that decides nothing
//! emits no event), and `pack_swaps` (interleaver context swaps sit
//! below event granularity). The per-tenant SLO deadlines
//! (`slo_deadline_s`) also ride the footer — they are configuration,
//! like the header's tenant names — but the `slo_met`/`slo_missed`
//! counters are *recomputed* from the replayed latencies against those
//! deadlines and verified like every other derived field.
//!
//! # Timeline
//!
//! The engine can additionally sample its state at every policy epoch
//! ([`EpochSample`]): per-tenant queue depth, backlog seconds and
//! token-bucket level, the partition weights and pack-group shapes in
//! force, schedule-cache hit/miss totals, and every policy decision
//! evaluated that epoch with the signed margin that approved or
//! declined it ([`DecisionSample`]). A run's samples are exposed as a
//! [`TimelineReport`], dumpable as JSONL alongside the trace.

use std::collections::{BTreeMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::metrics::LatencyHistogram;
use crate::util::json::Json;

use super::engine::EngineEvent;
use super::sim::ServeReport;

/// Format version written into trace headers; [`RecordedTrace::parse`]
/// refuses anything else.
pub const TRACE_VERSION: u64 = 1;

// ---- JSON helpers ----------------------------------------------------------

fn jnum(x: f64) -> Json {
    Json::Num(x)
}

fn junum(x: u64) -> Json {
    Json::Num(x as f64)
}

fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

fn f64_of(v: &Json, k: &str) -> Result<f64, String> {
    v.get(k).and_then(Json::as_f64).ok_or_else(|| format!("missing number {k:?}"))
}

fn u64_of(v: &Json, k: &str) -> Result<u64, String> {
    v.get(k).and_then(Json::as_u64).ok_or_else(|| format!("missing integer {k:?}"))
}

fn str_of(v: &Json, k: &str) -> Result<String, String> {
    v.get(k)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string {k:?}"))
}

fn u64_arr_of(v: &Json, k: &str) -> Result<Vec<u64>, String> {
    v.get(k)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("missing array {k:?}"))?
        .iter()
        .map(|x| x.as_u64().ok_or_else(|| format!("non-integer entry in {k:?}")))
        .collect()
}

fn usize_arr_of(v: &Json, k: &str) -> Result<Vec<usize>, String> {
    Ok(u64_arr_of(v, k)?.into_iter().map(|x| x as usize).collect())
}

// ---- event (de)serialization -----------------------------------------------

/// Serialize one [`EngineEvent`] to its `{"kind":"event",...}` trace
/// line value. Inverse of [`event_from_json`].
pub fn event_to_json(ev: &EngineEvent) -> Json {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), jstr("event"));
    let name = match ev {
        EngineEvent::Admitted { tenant, id, at_s } => {
            m.insert("tenant".to_string(), junum(*tenant as u64));
            m.insert("id".to_string(), junum(*id));
            m.insert("at_s".to_string(), jnum(*at_s));
            "admitted"
        }
        EngineEvent::BatchStarted { tenant, n, at_s } => {
            m.insert("tenant".to_string(), junum(*tenant as u64));
            m.insert("n".to_string(), junum(*n as u64));
            m.insert("at_s".to_string(), jnum(*at_s));
            "batch_started"
        }
        EngineEvent::BatchDone { tenant, n, at_s, consumed_s } => {
            m.insert("tenant".to_string(), junum(*tenant as u64));
            m.insert("n".to_string(), junum(*n as u64));
            m.insert("at_s".to_string(), jnum(*at_s));
            m.insert("consumed_s".to_string(), jnum(*consumed_s));
            "batch_done"
        }
        EngineEvent::Rejected { tenant, at_s } => {
            m.insert("tenant".to_string(), junum(*tenant as u64));
            m.insert("at_s".to_string(), jnum(*at_s));
            "rejected"
        }
        EngineEvent::Throttled { tenant, at_s } => {
            m.insert("tenant".to_string(), junum(*tenant as u64));
            m.insert("at_s".to_string(), jnum(*at_s));
            "throttled"
        }
        EngineEvent::Resplit { weights, at_s } => {
            m.insert(
                "weights".to_string(),
                Json::Arr(weights.iter().map(|&w| junum(w as u64)).collect()),
            );
            m.insert("at_s".to_string(), jnum(*at_s));
            "resplit"
        }
        EngineEvent::Preempted { tenant, at_s } => {
            m.insert("tenant".to_string(), junum(*tenant as u64));
            m.insert("at_s".to_string(), jnum(*at_s));
            "preempted"
        }
        EngineEvent::Packed { members, at_s } => {
            m.insert(
                "members".to_string(),
                Json::Arr(members.iter().map(|&t| junum(t as u64)).collect()),
            );
            m.insert("at_s".to_string(), jnum(*at_s));
            "packed"
        }
        EngineEvent::PackHandoff { tenant, consumed_s, at_s } => {
            m.insert("tenant".to_string(), junum(*tenant as u64));
            m.insert("consumed_s".to_string(), jnum(*consumed_s));
            m.insert("at_s".to_string(), jnum(*at_s));
            "pack_handoff"
        }
        EngineEvent::Unpacked { members, at_s } => {
            m.insert(
                "members".to_string(),
                Json::Arr(members.iter().map(|&t| junum(t as u64)).collect()),
            );
            m.insert("at_s".to_string(), jnum(*at_s));
            "unpacked"
        }
        EngineEvent::Unified { at_s } => {
            m.insert("at_s".to_string(), jnum(*at_s));
            "unified"
        }
        EngineEvent::Migrated { tenant, from, to, consumed_s, at_s } => {
            m.insert("tenant".to_string(), junum(*tenant as u64));
            m.insert("from".to_string(), junum(*from as u64));
            m.insert("to".to_string(), junum(*to as u64));
            m.insert("consumed_s".to_string(), jnum(*consumed_s));
            m.insert("at_s".to_string(), jnum(*at_s));
            "migrated"
        }
    };
    m.insert("ev".to_string(), jstr(name));
    Json::Obj(m)
}

/// Parse one `{"kind":"event",...}` trace line value back into an
/// [`EngineEvent`]. Inverse of [`event_to_json`].
pub fn event_from_json(v: &Json) -> Result<EngineEvent, String> {
    let ev = str_of(v, "ev")?;
    let tenant = || u64_of(v, "tenant").map(|t| t as usize);
    let at_s = f64_of(v, "at_s")?;
    match ev.as_str() {
        "admitted" => Ok(EngineEvent::Admitted { tenant: tenant()?, id: u64_of(v, "id")?, at_s }),
        "batch_started" => Ok(EngineEvent::BatchStarted {
            tenant: tenant()?,
            n: u64_of(v, "n")? as usize,
            at_s,
        }),
        "batch_done" => Ok(EngineEvent::BatchDone {
            tenant: tenant()?,
            n: u64_of(v, "n")? as usize,
            at_s,
            consumed_s: f64_of(v, "consumed_s")?,
        }),
        "rejected" => Ok(EngineEvent::Rejected { tenant: tenant()?, at_s }),
        "throttled" => Ok(EngineEvent::Throttled { tenant: tenant()?, at_s }),
        "resplit" => Ok(EngineEvent::Resplit {
            weights: u64_arr_of(v, "weights")?.into_iter().map(|w| w as u32).collect(),
            at_s,
        }),
        "preempted" => Ok(EngineEvent::Preempted { tenant: tenant()?, at_s }),
        "packed" => Ok(EngineEvent::Packed { members: usize_arr_of(v, "members")?, at_s }),
        "pack_handoff" => Ok(EngineEvent::PackHandoff {
            tenant: tenant()?,
            consumed_s: f64_of(v, "consumed_s")?,
            at_s,
        }),
        "unpacked" => Ok(EngineEvent::Unpacked { members: usize_arr_of(v, "members")?, at_s }),
        "unified" => Ok(EngineEvent::Unified { at_s }),
        "migrated" => Ok(EngineEvent::Migrated {
            tenant: tenant()?,
            from: u64_of(v, "from")? as usize,
            to: u64_of(v, "to")? as usize,
            consumed_s: f64_of(v, "consumed_s")?,
            at_s,
        }),
        other => Err(format!("unknown event kind {other:?}")),
    }
}

// ---- report (de)serialization ----------------------------------------------

fn hist_to_json(h: &LatencyHistogram) -> Json {
    let mut m = BTreeMap::new();
    // Trailing zero buckets are trimmed ([`LatencyHistogram::from_parts`]
    // zero-pads them back), keeping footer lines compact.
    let buckets = h.buckets();
    let last = buckets.iter().rposition(|&c| c != 0).map_or(0, |i| i + 1);
    m.insert(
        "buckets".to_string(),
        Json::Arr(buckets[..last].iter().map(|&c| junum(c)).collect()),
    );
    m.insert("sum_s".to_string(), jnum(h.sum_s()));
    if h.count() > 0 {
        // An empty histogram's min/max sentinels are ±inf, which would
        // serialize as null; omitting them round-trips cleanly instead.
        m.insert("min_s".to_string(), jnum(h.min_s()));
        m.insert("max_s".to_string(), jnum(h.max_s()));
    }
    Json::Obj(m)
}

fn hist_from_json(v: &Json) -> Result<LatencyHistogram, String> {
    let buckets = u64_arr_of(v, "buckets")?;
    let sum_s = f64_of(v, "sum_s")?;
    let nonempty = buckets.iter().any(|&c| c != 0);
    let (min_s, max_s) = if nonempty {
        (f64_of(v, "min_s")?, f64_of(v, "max_s")?)
    } else {
        (0.0, 0.0)
    };
    LatencyHistogram::from_parts(&buckets, sum_s, min_s, max_s)
        .ok_or_else(|| format!("histogram has {} buckets, more than the layout", buckets.len()))
}

/// Serialize a full [`ServeReport`] to the `{"kind":"summary",...}`
/// trace footer value. Inverse of [`report_from_json`].
pub fn report_to_json(r: &ServeReport) -> Json {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), jstr("summary"));
    m.insert("strategy".to_string(), jstr(&r.strategy));
    m.insert("completion_s".to_string(), jnum(r.completion_s));
    m.insert("served".to_string(), Json::Arr(r.served.iter().map(|&x| junum(x)).collect()));
    m.insert("rejected".to_string(), Json::Arr(r.rejected.iter().map(|&x| junum(x)).collect()));
    m.insert(
        "throttled".to_string(),
        Json::Arr(r.throttled.iter().map(|&x| junum(x)).collect()),
    );
    m.insert("switches".to_string(), junum(r.switches));
    m.insert("preemptions".to_string(), junum(r.preemptions));
    m.insert("packs".to_string(), junum(r.packs));
    m.insert("unpacks".to_string(), junum(r.unpacks));
    m.insert("pack_swaps".to_string(), junum(r.pack_swaps));
    m.insert(
        "pack_group_sizes".to_string(),
        Json::Arr(r.pack_group_sizes.iter().map(|&s| junum(s as u64)).collect()),
    );
    m.insert("epochs".to_string(), junum(r.epochs));
    m.insert("histograms".to_string(), Json::Arr(r.histograms.iter().map(hist_to_json).collect()));
    m.insert(
        "slo_deadline_s".to_string(),
        Json::Arr(r.slo_deadline_s.iter().map(|d| d.map_or(Json::Null, Json::Num)).collect()),
    );
    m.insert("slo_met".to_string(), Json::Arr(r.slo_met.iter().map(|&x| junum(x)).collect()));
    m.insert(
        "slo_missed".to_string(),
        Json::Arr(r.slo_missed.iter().map(|&x| junum(x)).collect()),
    );
    Json::Obj(m)
}

/// Parse the per-tenant deadline array: `null` entries are throughput
/// tiers. Absent key (a pre-SLO trace) → all throughput tiers.
fn deadlines_from_json(v: &Json, n: usize) -> Vec<Option<f64>> {
    match v.get("slo_deadline_s").and_then(Json::as_arr) {
        Some(arr) => arr.iter().map(Json::as_f64).collect(),
        None => vec![None; n],
    }
}

/// Parse an optional per-tenant counter array, defaulting to zeros for
/// traces recorded before SLO accounting existed.
fn u64_arr_or_zeros(v: &Json, key: &str, n: usize) -> Result<Vec<u64>, String> {
    if v.get(key).is_none() {
        return Ok(vec![0; n]);
    }
    u64_arr_of(v, key)
}

/// Parse a `{"kind":"summary",...}` trace footer value back into a
/// [`ServeReport`]. Inverse of [`report_to_json`].
pub fn report_from_json(v: &Json) -> Result<ServeReport, String> {
    let histograms = v
        .get("histograms")
        .and_then(Json::as_arr)
        .ok_or("summary missing histograms")?
        .iter()
        .map(hist_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    let served = u64_arr_of(v, "served")?;
    let n = served.len();
    Ok(ServeReport {
        strategy: str_of(v, "strategy")?,
        completion_s: f64_of(v, "completion_s")?,
        rejected: u64_arr_of(v, "rejected")?,
        throttled: u64_arr_of(v, "throttled")?,
        switches: u64_of(v, "switches")?,
        preemptions: u64_of(v, "preemptions")?,
        packs: u64_of(v, "packs")?,
        unpacks: u64_of(v, "unpacks")?,
        pack_swaps: u64_of(v, "pack_swaps")?,
        pack_group_sizes: usize_arr_of(v, "pack_group_sizes")?,
        epochs: u64_of(v, "epochs")?,
        histograms,
        slo_deadline_s: deadlines_from_json(v, n),
        slo_met: u64_arr_or_zeros(v, "slo_met", n)?,
        slo_missed: u64_arr_or_zeros(v, "slo_missed", n)?,
        served,
    })
}

// ---- the trace sink --------------------------------------------------------

/// Incremental JSONL trace writer: header first, then events as they
/// arrive, then the [`ServeReport`] footer at [`Self::finish`]. Both
/// drivers buffer events anyway (`FabricEngine::take_trace`), so the
/// one-shot [`trace_to_jsonl`] / [`write_trace`] wrappers are the
/// usual entry points; the sink exists for callers that want to
/// serialize incrementally.
pub struct TraceSink {
    text: String,
}

impl TraceSink {
    /// Start a trace: writes the header line for `strategy` and the
    /// tenant names.
    pub fn new(strategy: &str, tenants: &[String]) -> Self {
        let mut m = BTreeMap::new();
        m.insert("kind".to_string(), jstr("header"));
        m.insert("version".to_string(), junum(TRACE_VERSION));
        m.insert("strategy".to_string(), jstr(strategy));
        m.insert(
            "tenants".to_string(),
            Json::Arr(tenants.iter().map(|t| jstr(t)).collect()),
        );
        let mut text = Json::Obj(m).to_string_compact();
        text.push('\n');
        Self { text }
    }

    /// Append one event line.
    pub fn push(&mut self, ev: &EngineEvent) {
        self.text.push_str(&event_to_json(ev).to_string_compact());
        self.text.push('\n');
    }

    /// Append the summary footer and return the complete JSONL text.
    pub fn finish(mut self, report: &ServeReport) -> String {
        self.text.push_str(&report_to_json(report).to_string_compact());
        self.text.push('\n');
        self.text
    }
}

/// Serialize a complete recorded run (header + events + footer) to
/// JSONL text. See the module docs for the line schema.
pub fn trace_to_jsonl(
    strategy: &str,
    tenants: &[String],
    events: &[EngineEvent],
    report: &ServeReport,
) -> String {
    let mut sink = TraceSink::new(strategy, tenants);
    for ev in events {
        sink.push(ev);
    }
    sink.finish(report)
}

/// Write `text` to `path` through a sibling temp file and an atomic
/// rename, so a crash mid-write never leaves a truncated dump behind.
/// Shared by trace and timeline writers.
pub fn write_text(path: &Path, text: &str) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut tmp = path.as_os_str().to_owned();
    tmp.push(".tmp");
    let tmp = std::path::PathBuf::from(tmp);
    std::fs::write(&tmp, text)?;
    std::fs::rename(&tmp, path)
}

/// Serialize a recorded run and write it to `path` (JSONL, atomic
/// rename). Convenience over [`trace_to_jsonl`] + [`write_text`].
pub fn write_trace(
    path: &Path,
    strategy: &str,
    tenants: &[String],
    events: &[EngineEvent],
    report: &ServeReport,
) -> std::io::Result<()> {
    write_text(path, &trace_to_jsonl(strategy, tenants, events, report))
}

// ---- the loader / replayer -------------------------------------------------

/// A parsed trace: header metadata, the full event stream, and the
/// originating run's [`ServeReport`] footer.
pub struct RecordedTrace {
    /// Strategy label from the header line.
    pub strategy: String,
    /// Tenant names from the header line (index = tenant id).
    pub tenants: Vec<String>,
    /// The recorded [`EngineEvent`] stream, in emission order.
    pub events: Vec<EngineEvent>,
    /// The originating run's report, from the summary footer.
    pub report: ServeReport,
}

impl RecordedTrace {
    /// Parse a JSONL trace produced by [`trace_to_jsonl`] /
    /// [`write_trace`]. Strict: the header must come first with a
    /// supported version, every line must parse, and the summary
    /// footer must be present and last.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut strategy = None;
        let mut tenants = Vec::new();
        let mut events = Vec::new();
        let mut report = None;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let v = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 1))?;
            let kind = str_of(&v, "kind").map_err(|e| format!("line {}: {e}", i + 1))?;
            if report.is_some() {
                return Err(format!("line {}: content after the summary footer", i + 1));
            }
            match kind.as_str() {
                "header" => {
                    if strategy.is_some() {
                        return Err(format!("line {}: duplicate header", i + 1));
                    }
                    match u64_of(&v, "version")? {
                        TRACE_VERSION => {}
                        other => return Err(format!("unsupported trace version {other}")),
                    }
                    strategy = Some(str_of(&v, "strategy")?);
                    tenants = v
                        .get("tenants")
                        .and_then(Json::as_arr)
                        .ok_or("header missing tenants")?
                        .iter()
                        .map(|t| t.as_str().map(str::to_string).ok_or("non-string tenant name"))
                        .collect::<Result<Vec<_>, _>>()?;
                }
                "event" => {
                    if strategy.is_none() {
                        return Err(format!("line {}: event before the header", i + 1));
                    }
                    events.push(event_from_json(&v).map_err(|e| format!("line {}: {e}", i + 1))?);
                }
                "summary" => {
                    report =
                        Some(report_from_json(&v).map_err(|e| format!("line {}: {e}", i + 1))?);
                }
                other => return Err(format!("line {}: unknown line kind {other:?}", i + 1)),
            }
        }
        Ok(Self {
            strategy: strategy.ok_or("trace has no header line")?,
            tenants,
            events,
            report: report.ok_or("trace has no summary footer")?,
        })
    }

    /// Load and parse a trace file.
    pub fn load(path: &Path) -> std::io::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }

    /// Reconstruct a [`ServeReport`] from the event stream alone.
    ///
    /// Counters are recomputed by counting events; latency histograms
    /// are rebuilt by pairing each [`EngineEvent::BatchDone`] with the
    /// oldest un-served [`EngineEvent::Admitted`] arrivals of its
    /// tenant (the engine's own FIFO admission order), recording
    /// `(done - arrival).max(0)` exactly as the engine did. SLO
    /// counters are re-derived from those exact latencies against the
    /// footer's per-tenant deadlines (the deadlines themselves are
    /// configuration, not events, so they ride the footer like the
    /// tenant names do). `completion_s`, `epochs` and `pack_swaps` are
    /// carried from the footer (see the module docs for why they are
    /// not derivable).
    pub fn replay(&self) -> ServeReport {
        let t_n = self.tenants.len();
        let deadlines = &self.report.slo_deadline_s;
        let mut fifo: Vec<VecDeque<f64>> = vec![VecDeque::new(); t_n];
        let mut histograms = vec![LatencyHistogram::new(); t_n];
        let mut slo_met = vec![0u64; t_n];
        let mut slo_missed = vec![0u64; t_n];
        let mut served = vec![0u64; t_n];
        let mut rejected = vec![0u64; t_n];
        let mut throttled = vec![0u64; t_n];
        let (mut switches, mut preemptions, mut packs, mut unpacks) = (0u64, 0u64, 0u64, 0u64);
        let mut pack_group_sizes = Vec::new();
        for ev in &self.events {
            match ev {
                EngineEvent::Admitted { tenant, at_s, .. } => fifo[*tenant].push_back(*at_s),
                EngineEvent::BatchDone { tenant, n, at_s, .. } => {
                    for _ in 0..*n {
                        // An underflow (batch without a recorded
                        // admission) records nothing; verify() then
                        // reports the served-count mismatch.
                        if let Some(arr) = fifo[*tenant].pop_front() {
                            let lat = (*at_s - arr).max(0.0);
                            histograms[*tenant].record(lat);
                            served[*tenant] += 1;
                            if let Some(d) = deadlines.get(*tenant).copied().flatten() {
                                if lat <= d {
                                    slo_met[*tenant] += 1;
                                } else {
                                    slo_missed[*tenant] += 1;
                                }
                            }
                        }
                    }
                }
                EngineEvent::Rejected { tenant, .. } => rejected[*tenant] += 1,
                EngineEvent::Throttled { tenant, .. } => throttled[*tenant] += 1,
                EngineEvent::Resplit { .. } => switches += 1,
                EngineEvent::Preempted { .. } => preemptions += 1,
                EngineEvent::Packed { members, .. } => {
                    packs += 1;
                    pack_group_sizes.push(members.len());
                }
                EngineEvent::Unpacked { .. } => unpacks += 1,
                EngineEvent::BatchStarted { .. }
                | EngineEvent::PackHandoff { .. }
                | EngineEvent::Unified { .. }
                | EngineEvent::Migrated { .. } => {}
            }
        }
        ServeReport {
            strategy: self.strategy.clone(),
            completion_s: self.report.completion_s,
            served,
            rejected,
            throttled,
            switches,
            preemptions,
            packs,
            unpacks,
            pack_swaps: self.report.pack_swaps,
            pack_group_sizes,
            epochs: self.report.epochs,
            histograms,
            slo_deadline_s: deadlines.clone(),
            slo_met,
            slo_missed,
        }
    }

    /// Replay the event stream and hold the result to the footer
    /// bit-for-bit: counters, transition counts, and every histogram
    /// bucket, sum, min and max compared with `==` on the `f64`s.
    /// Returns the replayed report, or every mismatch found.
    pub fn verify(&self) -> Result<ServeReport, String> {
        let r = self.replay();
        let f = &self.report;
        let mut errs = Vec::new();
        let mut chk = |name: &str, ok: bool, detail: String| {
            if !ok {
                errs.push(format!("{name}: {detail}"));
            }
        };
        chk("strategy", r.strategy == f.strategy, format!("{} vs {}", r.strategy, f.strategy));
        chk("served", r.served == f.served, format!("{:?} vs {:?}", r.served, f.served));
        chk("rejected", r.rejected == f.rejected, format!("{:?} vs {:?}", r.rejected, f.rejected));
        chk(
            "throttled",
            r.throttled == f.throttled,
            format!("{:?} vs {:?}", r.throttled, f.throttled),
        );
        chk("switches", r.switches == f.switches, format!("{} vs {}", r.switches, f.switches));
        chk(
            "preemptions",
            r.preemptions == f.preemptions,
            format!("{} vs {}", r.preemptions, f.preemptions),
        );
        chk("packs", r.packs == f.packs, format!("{} vs {}", r.packs, f.packs));
        chk("unpacks", r.unpacks == f.unpacks, format!("{} vs {}", r.unpacks, f.unpacks));
        chk(
            "pack_group_sizes",
            r.pack_group_sizes == f.pack_group_sizes,
            format!("{:?} vs {:?}", r.pack_group_sizes, f.pack_group_sizes),
        );
        chk("slo_met", r.slo_met == f.slo_met, format!("{:?} vs {:?}", r.slo_met, f.slo_met));
        chk(
            "slo_missed",
            r.slo_missed == f.slo_missed,
            format!("{:?} vs {:?}", r.slo_missed, f.slo_missed),
        );
        chk(
            "histogram count",
            r.histograms.len() == f.histograms.len(),
            format!("{} vs {}", r.histograms.len(), f.histograms.len()),
        );
        for (t, (a, b)) in r.histograms.iter().zip(&f.histograms).enumerate() {
            let same = a.buckets() == b.buckets()
                && a.count() == b.count()
                && a.sum_s() == b.sum_s()
                && a.min_s() == b.min_s()
                && a.max_s() == b.max_s();
            chk(
                "histogram",
                same,
                format!(
                    "tenant {t}: n {} vs {}, sum {:.17e} vs {:.17e}",
                    a.count(),
                    b.count(),
                    a.sum_s(),
                    b.sum_s()
                ),
            );
        }
        if errs.is_empty() {
            Ok(r)
        } else {
            Err(errs.join("; "))
        }
    }

    /// Multi-line human-readable digest: header metadata, per-kind
    /// event counts, the recorded fabric-time span, and the footer's
    /// one-line summary.
    pub fn summarize(&self) -> String {
        let mut counts: BTreeMap<&str, u64> = BTreeMap::new();
        let mut span = (f64::INFINITY, f64::NEG_INFINITY);
        for ev in &self.events {
            let (name, at) = match ev {
                EngineEvent::Admitted { at_s, .. } => ("admitted", *at_s),
                EngineEvent::BatchStarted { at_s, .. } => ("batch_started", *at_s),
                EngineEvent::BatchDone { at_s, .. } => ("batch_done", *at_s),
                EngineEvent::Rejected { at_s, .. } => ("rejected", *at_s),
                EngineEvent::Throttled { at_s, .. } => ("throttled", *at_s),
                EngineEvent::Resplit { at_s, .. } => ("resplit", *at_s),
                EngineEvent::Preempted { at_s, .. } => ("preempted", *at_s),
                EngineEvent::Packed { at_s, .. } => ("packed", *at_s),
                EngineEvent::PackHandoff { at_s, .. } => ("pack_handoff", *at_s),
                EngineEvent::Unpacked { at_s, .. } => ("unpacked", *at_s),
                EngineEvent::Unified { at_s } => ("unified", *at_s),
                EngineEvent::Migrated { at_s, .. } => ("migrated", *at_s),
            };
            *counts.entry(name).or_insert(0) += 1;
            span = (span.0.min(at), span.1.max(at));
        }
        let kinds: Vec<String> =
            counts.iter().map(|(k, n)| format!("{n} {k}")).collect();
        let span_line = if self.events.is_empty() {
            "span: empty".to_string()
        } else {
            format!("span: {:.6e} .. {:.6e} s (fabric time)", span.0, span.1)
        };
        format!(
            "trace v{TRACE_VERSION}: strategy {}, tenants {:?}\nevents: {} ({})\n{}\n{}",
            self.strategy,
            self.tenants,
            self.events.len(),
            kinds.join(", "),
            span_line,
            self.report.summary(),
        )
    }
}

// ---- the metrics timeline --------------------------------------------------

/// Which policy decision a [`DecisionSample`] records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecisionKind {
    /// `should_resplit`: re-split the fabric onto proposed weights.
    Resplit,
    /// `should_preempt`: interrupt an in-flight batch at its next
    /// layer boundary during a re-split.
    Preempt,
    /// `should_pack`: merge a proposed group onto one shared slice.
    Pack,
    /// `should_unpack`: mark a packed group for dissolution.
    Unpack,
    /// Async-DSE mode: an approved re-split whose slices were not all
    /// cached yet was deferred; the background solver was asked to
    /// compute them and the resplit will be re-proposed at a later
    /// epoch once the solves land.
    Defer,
}

impl DecisionKind {
    /// Stable lowercase label used in timeline JSONL lines.
    pub fn label(self) -> &'static str {
        match self {
            DecisionKind::Resplit => "resplit",
            DecisionKind::Preempt => "preempt",
            DecisionKind::Pack => "pack",
            DecisionKind::Unpack => "unpack",
            DecisionKind::Defer => "defer",
        }
    }
}

/// One policy decision evaluated during an epoch, with the signed
/// margin the policy computed. `margin_s > 0` means the policy's
/// benefit term cleared its threshold; `approved` is the actual
/// verdict (which can differ — e.g. a re-split that merely restores
/// the equal split is approved regardless of the backlog margin, and
/// a pack needs the swap-amortization gate on top of the fit margin).
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionSample {
    /// Which decision was evaluated.
    pub kind: DecisionKind,
    /// Tenants the decision is about (group members; the preempted
    /// tenant; empty for a fabric-wide re-split).
    pub tenants: Vec<usize>,
    /// Signed margin in fabric seconds (see [`DecisionKind`] for each
    /// formula's terms).
    pub margin_s: f64,
    /// Did the transition actually get approved?
    pub approved: bool,
}

/// One tenant's admission state as sampled at a policy epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSample {
    /// Requests waiting in the pending queue.
    pub queue_depth: usize,
    /// Backlog seconds (queued plus movable in-flight work) — the
    /// signal the weight proposal ran on this epoch.
    pub backlog_s: f64,
    /// Token-bucket level in fabric seconds as of the last admission;
    /// `None` when the tenant has no rate limit.
    pub bucket_tokens: Option<f64>,
    /// Cumulative served requests that met the tenant's latency-SLO
    /// deadline (0 for throughput tiers).
    pub slo_met: u64,
    /// Cumulative served requests that missed it.
    pub slo_missed: u64,
}

/// Everything the engine observed and decided at one policy epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct EpochSample {
    /// 1-based epoch ordinal (matches `ServeReport::epochs`).
    pub epoch: u64,
    /// Fabric instant the epoch ran at.
    pub at_s: f64,
    /// Per-tenant admission state (index = tenant id).
    pub tenants: Vec<TenantSample>,
    /// Partition weights in force after this epoch's transitions.
    pub weights: Vec<u32>,
    /// Members of each live packed group after this epoch.
    pub pack_shapes: Vec<Vec<usize>>,
    /// Schedule-cache hits so far (cumulative).
    pub cache_hits: u64,
    /// Schedule-cache misses so far (cumulative).
    pub cache_misses: u64,
    /// Wall nanoseconds the engine mutex has been held so far across
    /// instrumented critical sections (cumulative; 0 when no
    /// [`LockMeter`] is attached, e.g. in the virtual-time simulator).
    pub lock_held_ns: u64,
    /// Wall nanoseconds lookups have stalled on someone else's
    /// in-flight DSE solve so far (cumulative,
    /// [`ScheduleCache::stall_ns`](super::cache::ScheduleCache::stall_ns)).
    pub dse_stall_ns: u64,
    /// Duplicate solve requests the background solver dropped before
    /// they reached the cache so far (cumulative,
    /// [`ScheduleCache::coalesced_solves`](super::cache::ScheduleCache::coalesced_solves)).
    pub coalesced_solves: u64,
    /// Schedule-cache hits whose entry was populated by a *different*
    /// board so far (cumulative,
    /// [`ScheduleCache::cross_board_hits`](super::cache::ScheduleCache::cross_board_hits);
    /// always 0 on a single-board fabric).
    pub cross_board_hits: u64,
    /// Board this sample's engine runs on (0 on a single-board fabric).
    pub board: usize,
    /// Every decision evaluated this epoch, in evaluation order.
    pub decisions: Vec<DecisionSample>,
}

/// A run's epoch-sampled metrics timeline, dumpable as JSONL next to
/// the event trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineReport {
    /// Tenant names (index = tenant id in the samples).
    pub tenants: Vec<String>,
    /// One sample per policy epoch, in epoch order.
    pub samples: Vec<EpochSample>,
}

impl TimelineReport {
    /// Serialize to JSONL: a `{"kind":"timeline_header",...}` line,
    /// then one `{"kind":"epoch",...}` line per sample.
    pub fn to_jsonl(&self) -> String {
        let mut text = String::new();
        let mut h = BTreeMap::new();
        h.insert("kind".to_string(), jstr("timeline_header"));
        h.insert("version".to_string(), junum(TRACE_VERSION));
        h.insert(
            "tenants".to_string(),
            Json::Arr(self.tenants.iter().map(|t| jstr(t)).collect()),
        );
        text.push_str(&Json::Obj(h).to_string_compact());
        text.push('\n');
        for s in &self.samples {
            let mut m = BTreeMap::new();
            m.insert("kind".to_string(), jstr("epoch"));
            m.insert("epoch".to_string(), junum(s.epoch));
            m.insert("at_s".to_string(), jnum(s.at_s));
            m.insert(
                "tenants".to_string(),
                Json::Arr(
                    s.tenants
                        .iter()
                        .map(|t| {
                            let mut tm = BTreeMap::new();
                            tm.insert("queue".to_string(), junum(t.queue_depth as u64));
                            tm.insert("backlog_s".to_string(), jnum(t.backlog_s));
                            tm.insert(
                                "bucket_tokens".to_string(),
                                t.bucket_tokens.map_or(Json::Null, jnum),
                            );
                            tm.insert("slo_met".to_string(), junum(t.slo_met));
                            tm.insert("slo_missed".to_string(), junum(t.slo_missed));
                            Json::Obj(tm)
                        })
                        .collect(),
                ),
            );
            m.insert(
                "weights".to_string(),
                Json::Arr(s.weights.iter().map(|&w| junum(w as u64)).collect()),
            );
            m.insert(
                "packs".to_string(),
                Json::Arr(
                    s.pack_shapes
                        .iter()
                        .map(|g| Json::Arr(g.iter().map(|&t| junum(t as u64)).collect()))
                        .collect(),
                ),
            );
            m.insert("cache_hits".to_string(), junum(s.cache_hits));
            m.insert("cache_misses".to_string(), junum(s.cache_misses));
            m.insert("lock_held_ns".to_string(), junum(s.lock_held_ns));
            m.insert("dse_stall_ns".to_string(), junum(s.dse_stall_ns));
            m.insert("coalesced_solves".to_string(), junum(s.coalesced_solves));
            m.insert("cross_board_hits".to_string(), junum(s.cross_board_hits));
            m.insert("board".to_string(), junum(s.board as u64));
            m.insert(
                "decisions".to_string(),
                Json::Arr(
                    s.decisions
                        .iter()
                        .map(|d| {
                            let mut dm = BTreeMap::new();
                            dm.insert("kind".to_string(), jstr(d.kind.label()));
                            dm.insert(
                                "tenants".to_string(),
                                Json::Arr(d.tenants.iter().map(|&t| junum(t as u64)).collect()),
                            );
                            dm.insert("margin_s".to_string(), jnum(d.margin_s));
                            dm.insert("approved".to_string(), Json::Bool(d.approved));
                            Json::Obj(dm)
                        })
                        .collect(),
                ),
            );
            text.push_str(&Json::Obj(m).to_string_compact());
            text.push('\n');
        }
        text
    }

    /// Write the JSONL dump to `path` (atomic rename).
    pub fn save_to(&self, path: &Path) -> std::io::Result<()> {
        write_text(path, &self.to_jsonl())
    }

    /// One-line digest: epochs sampled, decisions evaluated/approved.
    pub fn summary(&self) -> String {
        let decisions: usize = self.samples.iter().map(|s| s.decisions.len()).sum();
        let approved: usize = self
            .samples
            .iter()
            .flat_map(|s| &s.decisions)
            .filter(|d| d.approved)
            .count();
        format!(
            "timeline: {} epochs sampled, {} decisions evaluated ({} approved)",
            self.samples.len(),
            decisions,
            approved,
        )
    }
}

// ---- run instrumentation ---------------------------------------------------

/// What a driver should record during an instrumented run. The step
/// profile is always collected (it is two counters).
#[derive(Debug, Clone, Copy, Default)]
pub struct TelemetryConfig {
    /// Record the full [`EngineEvent`] trace.
    pub trace: bool,
    /// Sample the per-epoch metrics timeline.
    pub timeline: bool,
}

impl TelemetryConfig {
    /// Record everything (trace and timeline).
    pub fn full() -> Self {
        Self { trace: true, timeline: true }
    }
}

/// Wall-time profile of a driver's `step()` loop. Observability only:
/// the numbers are never fed back into any decision, so collecting
/// them cannot perturb the deterministic fabric-time trace.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepProfile {
    /// `FabricEngine::step` calls timed.
    pub steps: u64,
    /// Total wall nanoseconds across those calls.
    pub total_ns: u64,
}

impl StepProfile {
    /// Fold one timed step into the profile.
    pub fn record_ns(&mut self, ns: u64) {
        self.steps += 1;
        self.total_ns += ns;
    }

    /// Mean wall nanoseconds per engine step (0 before any step).
    pub fn ns_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.total_ns as f64 / self.steps as f64
        }
    }
}

/// Shared hold-time meter for a contended mutex — the same
/// relaxed-atomics style as the [`ScheduleCache`] wall-time counters,
/// so recording from several threads never serializes them.
/// Observability only: nothing reads the meter back into a decision.
///
/// [`ScheduleCache`]: super::cache::ScheduleCache
#[derive(Debug, Default)]
pub struct LockMeter {
    held_ns: AtomicU64,
    holds: AtomicU64,
}

impl LockMeter {
    /// Fresh meter with zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one instrumented critical section into the meter.
    pub fn record_ns(&self, ns: u64) {
        self.held_ns.fetch_add(ns, Ordering::Relaxed);
        self.holds.fetch_add(1, Ordering::Relaxed);
    }

    /// Cumulative wall nanoseconds of instrumented hold time.
    pub fn held_ns(&self) -> u64 {
        self.held_ns.load(Ordering::Relaxed)
    }

    /// Number of instrumented critical sections folded in.
    pub fn holds(&self) -> u64 {
        self.holds.load(Ordering::Relaxed)
    }
}

/// Lock-contention and DSE-stall totals an instrumented run observed —
/// the "is the mutex/solver the bottleneck?" half of [`RunTelemetry`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StallStats {
    /// Wall nanoseconds the engine mutex was held across instrumented
    /// critical sections (0 in the virtual-time simulator, which has no
    /// contended mutex).
    pub lock_held_ns: u64,
    /// Instrumented critical sections counted into
    /// [`Self::lock_held_ns`].
    pub lock_holds: u64,
    /// Wall nanoseconds schedule-cache lookups stalled on another
    /// thread's in-flight DSE solve.
    pub dse_stall_ns: u64,
    /// Lookups that stalled that way.
    pub dse_stalls: u64,
    /// Duplicate background solve requests coalesced away before they
    /// reached the cache (see
    /// [`ScheduleCache::coalesced_solves`](super::cache::ScheduleCache::coalesced_solves)).
    pub coalesced_solves: u64,
    /// Schedule-cache hits served from an entry another board had
    /// already populated (see
    /// [`ScheduleCache::cross_board_hits`](super::cache::ScheduleCache::cross_board_hits);
    /// always 0 on a single-board fabric).
    pub cross_board_hits: u64,
}

/// Everything an instrumented run recorded beyond its report.
#[derive(Debug, Clone, Default)]
pub struct RunTelemetry {
    /// The event trace, when [`TelemetryConfig::trace`] was set.
    pub trace: Option<Vec<EngineEvent>>,
    /// The epoch timeline, when [`TelemetryConfig::timeline`] was set.
    pub timeline: Option<TimelineReport>,
    /// Step-loop wall-time profile (always collected).
    pub step_profile: StepProfile,
    /// Lock-hold and DSE-stall totals (always collected; zero where
    /// the driver has no contended mutex).
    pub stalls: StallStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_json_roundtrips_every_variant() {
        let evs = vec![
            EngineEvent::Admitted { tenant: 1, id: 42, at_s: 0.125 },
            EngineEvent::BatchStarted { tenant: 0, n: 4, at_s: 1.0 / 3.0 },
            EngineEvent::BatchDone { tenant: 2, n: 1, at_s: 0.7, consumed_s: 0.1 + 0.2 },
            EngineEvent::Rejected { tenant: 0, at_s: 0.0 },
            EngineEvent::Throttled { tenant: 1, at_s: 1e-9 },
            EngineEvent::Resplit { weights: vec![8, 1, 1], at_s: 2.5 },
            EngineEvent::Preempted { tenant: 0, at_s: 2.5 },
            EngineEvent::Packed { members: vec![1, 2], at_s: 3.0 },
            EngineEvent::PackHandoff { tenant: 1, consumed_s: 0.05, at_s: 3.0 },
            EngineEvent::Unpacked { members: vec![1, 2], at_s: 4.0 },
            EngineEvent::Unified { at_s: 0.0 },
            EngineEvent::Migrated { tenant: 2, from: 0, to: 1, consumed_s: 0.015, at_s: 5.0 },
        ];
        for ev in &evs {
            let line = event_to_json(ev).to_string_compact();
            let back = event_from_json(&Json::parse(&line).expect("line parses"))
                .expect("event parses");
            assert_eq!(&back, ev, "through {line}");
        }
    }

    #[test]
    fn report_json_roundtrips_bit_for_bit() {
        let mut h0 = LatencyHistogram::new();
        for i in 1..=57u64 {
            h0.record(i as f64 * 7.3e-5);
        }
        let r = ServeReport {
            strategy: "dynamic".to_string(),
            completion_s: 1.0 / 3.0,
            served: vec![40, 17],
            rejected: vec![3, 0],
            throttled: vec![0, 1],
            switches: 5,
            preemptions: 2,
            packs: 1,
            unpacks: 1,
            pack_swaps: 9,
            pack_group_sizes: vec![2],
            epochs: 12,
            histograms: vec![h0, LatencyHistogram::new()],
            slo_deadline_s: vec![Some(0.002), None],
            slo_met: vec![27, 0],
            slo_missed: vec![13, 0],
        };
        let v = report_to_json(&r);
        let back = report_from_json(&Json::parse(&v.to_string_compact()).expect("parses"))
            .expect("report parses");
        assert_eq!(back.completion_s, r.completion_s);
        assert_eq!(back.served, r.served);
        assert_eq!(back.slo_deadline_s, r.slo_deadline_s);
        assert_eq!(back.slo_met, r.slo_met);
        assert_eq!(back.slo_missed, r.slo_missed);
        assert_eq!(back.histograms[0].buckets(), r.histograms[0].buckets());
        assert_eq!(back.histograms[0].sum_s(), r.histograms[0].sum_s());
        assert_eq!(back.histograms[0].min_s(), r.histograms[0].min_s());
        assert_eq!(back.histograms[0].max_s(), r.histograms[0].max_s());
        // The empty histogram restores its fresh sentinels.
        assert_eq!(back.histograms[1].count(), 0);
        assert_eq!(back.histograms[1].summary(), "no requests");
    }

    #[test]
    fn synthetic_trace_replays_exactly() {
        // Hand-build a tiny consistent trace and check the full
        // parse → replay → verify path.
        let events = vec![
            EngineEvent::Admitted { tenant: 0, id: 0, at_s: 0.0 },
            EngineEvent::Admitted { tenant: 0, id: 1, at_s: 0.01 },
            EngineEvent::Rejected { tenant: 1, at_s: 0.02 },
            EngineEvent::BatchStarted { tenant: 0, n: 2, at_s: 0.02 },
            EngineEvent::BatchDone { tenant: 0, n: 2, at_s: 0.3, consumed_s: 0.28 },
        ];
        let mut h = LatencyHistogram::new();
        h.record(0.3);
        h.record(0.3 - 0.01);
        let report = ServeReport {
            strategy: "static-equal".to_string(),
            completion_s: 0.3,
            served: vec![2, 0],
            rejected: vec![0, 1],
            throttled: vec![0, 0],
            switches: 0,
            preemptions: 0,
            packs: 0,
            unpacks: 0,
            pack_swaps: 0,
            pack_group_sizes: vec![],
            epochs: 0,
            histograms: vec![h, LatencyHistogram::new()],
            // Deadline between the two recorded latencies (0.29, 0.3):
            // replay must re-derive exactly one met and one missed.
            slo_deadline_s: vec![Some(0.295), None],
            slo_met: vec![1, 0],
            slo_missed: vec![1, 0],
        };
        let text = trace_to_jsonl(
            "static-equal",
            &["a".to_string(), "b".to_string()],
            &events,
            &report,
        );
        let tr = RecordedTrace::parse(&text).expect("trace parses");
        assert_eq!(tr.events, events);
        let replayed = tr.verify().expect("replay matches the footer");
        assert_eq!(replayed.served, vec![2, 0]);
        // Corrupt the footer: verify must fail loudly.
        let mut bad = tr;
        bad.report.served[0] = 3;
        assert!(bad.verify().unwrap_err().contains("served"));
    }

    #[test]
    fn timeline_jsonl_lines_all_parse() {
        let tl = TimelineReport {
            tenants: vec!["a".to_string(), "b".to_string()],
            samples: vec![EpochSample {
                epoch: 1,
                at_s: 0.05,
                tenants: vec![
                    TenantSample {
                        queue_depth: 3,
                        backlog_s: 0.2,
                        bucket_tokens: None,
                        slo_met: 5,
                        slo_missed: 1,
                    },
                    TenantSample {
                        queue_depth: 0,
                        backlog_s: 0.0,
                        bucket_tokens: Some(0.7),
                        slo_met: 0,
                        slo_missed: 0,
                    },
                ],
                weights: vec![8, 1],
                pack_shapes: vec![],
                cache_hits: 2,
                cache_misses: 2,
                lock_held_ns: 1500,
                dse_stall_ns: 0,
                coalesced_solves: 0,
                cross_board_hits: 0,
                board: 0,
                decisions: vec![DecisionSample {
                    kind: DecisionKind::Resplit,
                    tenants: vec![],
                    margin_s: 0.15,
                    approved: true,
                }],
            }],
        };
        let text = tl.to_jsonl();
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            Json::parse(line).expect("timeline line parses");
        }
        assert!(tl.summary().contains("1 epochs sampled"));
    }
}
