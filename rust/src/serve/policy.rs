//! Re-composition policy: when and how to re-split the fabric.
//!
//! The signal is per-tenant *backlog time* — queue depth × the fabric
//! seconds one request costs on the tenant's current slice. Weights
//! proportional to backlog time hand FMUs/CUs to the tenants that are
//! actually falling behind (queue depth alone would over-reward cheap
//! requests). Hysteresis keeps the fabric still when the backlog is too
//! small to be worth a switch, and proportional weight reduction keeps
//! `[2,2,2]` from being treated as different from `[1,1,1]`.

fn gcd(a: u32, b: u32) -> u32 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Reduce weights by their GCD so proportionally-equal vectors compare
/// equal (`[4, 2, 2]` → `[2, 1, 1]`).
pub fn reduce_weights(w: &[u32]) -> Vec<u32> {
    let g = w.iter().fold(0u32, |acc, &x| gcd(acc, x)).max(1);
    w.iter().map(|&x| x / g).collect()
}

/// Map per-tenant backlog times to partition weights in `1..=max_weight`
/// (every tenant keeps at least one unit — starvation-free), reduced to
/// lowest terms. All-idle backlogs yield an equal split.
pub fn backlog_weights(backlog_s: &[f64], max_weight: u32) -> Vec<u32> {
    let max_weight = max_weight.max(1);
    let mx = backlog_s.iter().cloned().fold(0.0f64, f64::max);
    if mx <= 0.0 {
        return vec![1; backlog_s.len()];
    }
    let w: Vec<u32> = backlog_s
        .iter()
        .map(|&b| ((b / mx * max_weight as f64).ceil() as u32).clamp(1, max_weight))
        .collect();
    reduce_weights(&w)
}

/// Policy knobs for the dynamic re-composer.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Seconds between policy evaluations (virtual fabric time in the
    /// simulator, wall-clock in the live scheduler).
    pub epoch_s: f64,
    /// Largest weight a single tenant can take.
    pub max_weight: u32,
    /// Re-split only when total backlog time exceeds this multiple of
    /// the switch cost (hysteresis against churn at idle).
    pub min_backlog_factor: f64,
    /// Mid-DAG preemption margin: preempt an in-flight batch at its
    /// next layer boundary only when the projected saving — remaining
    /// work on the old slice, minus remaining work re-costed on the new
    /// slice and one switch — exceeds this multiple of the switch cost.
    /// `f64::INFINITY` disables preemption entirely (re-compositions
    /// then land only at batch boundaries, the pre-cursor behavior).
    pub preempt_margin_factor: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self { epoch_s: 0.05, max_weight: 8, min_backlog_factor: 50.0, preempt_margin_factor: 1.0 }
    }
}

impl PolicyConfig {
    /// Policy tuned to a measured per-request service time: evaluate
    /// every ~10 requests' worth of fabric time, with low hysteresis.
    /// The single source of the constants behind every calibrated
    /// scenario (example, bench, CLI `--mode sim`, acceptance test).
    pub fn calibrated(per_request_s: f64) -> Self {
        Self {
            epoch_s: 10.0 * per_request_s,
            max_weight: 8,
            min_backlog_factor: 5.0,
            preempt_margin_factor: 1.0,
        }
    }

    /// Same policy with mid-DAG preemption disabled: re-compositions
    /// apply only to batches that start after them.
    pub fn without_preemption(mut self) -> Self {
        self.preempt_margin_factor = f64::INFINITY;
        self
    }

    /// Is mid-DAG preemption enabled at all?
    pub fn preemption_enabled(&self) -> bool {
        self.preempt_margin_factor.is_finite()
    }
}

/// Should the fabric be re-split from `current` to `proposed` weights?
///
/// A proposal that merely *restores the equal split* (all weights equal)
/// is exempt from the backlog hysteresis: relaxing a skewed composition
/// once load subsides costs one switch on an idle fabric and leaves it
/// in the neutral shape — which the schedule cache has always seen.
pub fn should_resplit(
    current: &[u32],
    proposed: &[u32],
    total_backlog_s: f64,
    switch_cost_s: f64,
    cfg: &PolicyConfig,
) -> bool {
    if reduce_weights(current) == reduce_weights(proposed) {
        return false;
    }
    let equalizes = proposed.windows(2).all(|w| w[0] == w[1]);
    equalizes || total_backlog_s > cfg.min_backlog_factor * switch_cost_s
}

/// The preemption-benefit term: should an *in-flight* batch be
/// interrupted at its next layer boundary when the fabric re-splits?
///
/// `remaining_old_s` is the work left if the batch drains on its
/// current slice; `remaining_new_s` the same steps re-costed on the new
/// slice. Preempting pays one mid-DAG `switch_cost_s`, so it only wins
/// when the re-costing saves more than the switch — by at least
/// `preempt_margin_factor` switches' worth of margin. A shrinking slice
/// (`remaining_new_s > remaining_old_s`) therefore always declines and
/// drains on the old composition, and inflating the switch cost above
/// the outstanding work makes every tenant decline.
pub fn should_preempt(
    remaining_old_s: f64,
    remaining_new_s: f64,
    switch_cost_s: f64,
    cfg: &PolicyConfig,
) -> bool {
    if !cfg.preemption_enabled() {
        return false;
    }
    remaining_old_s - (remaining_new_s + switch_cost_s)
        > cfg.preempt_margin_factor * switch_cost_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_backlog_equal_weights() {
        assert_eq!(backlog_weights(&[0.5, 0.5, 0.5], 8), vec![1, 1, 1]);
        assert_eq!(backlog_weights(&[0.0, 0.0], 8), vec![1, 1]);
    }

    #[test]
    fn skewed_backlog_skews_weights() {
        let w = backlog_weights(&[0.8, 0.1, 0.1], 8);
        assert_eq!(w[0], 8);
        assert_eq!(&w[1..], &[1, 1]);
        // Idle tenants still get a floor of one.
        let w = backlog_weights(&[1.0, 0.0, 0.0], 8);
        assert_eq!(w, vec![8, 1, 1]);
    }

    #[test]
    fn weights_reduced_to_lowest_terms() {
        assert_eq!(reduce_weights(&[4, 2, 2]), vec![2, 1, 1]);
        assert_eq!(reduce_weights(&[8, 8, 8]), vec![1, 1, 1]);
        assert_eq!(reduce_weights(&[0, 0]), vec![0, 0]);
    }

    #[test]
    fn hysteresis_blocks_idle_resplit() {
        let cfg = PolicyConfig::default();
        let cur = [1, 1, 1];
        let new = [8, 1, 1];
        // Large backlog: switch.
        assert!(should_resplit(&cur, &new, 1.0, 1e-6, &cfg));
        // Tiny backlog vs switch cost: hold.
        assert!(!should_resplit(&cur, &new, 1e-6, 1e-6, &cfg));
        // Proportionally identical: hold regardless.
        assert!(!should_resplit(&[2, 2, 2], &[1, 1, 1], 1.0, 1e-6, &cfg));
    }

    #[test]
    fn preemption_weighs_remaining_work_against_switch_cost() {
        let cfg = PolicyConfig { preempt_margin_factor: 1.0, ..PolicyConfig::default() };
        let sw = 1e-6;
        // Big saving: preempt.
        assert!(should_preempt(1.0, 0.3, sw, &cfg));
        // Shrinking slice: never preempt.
        assert!(!should_preempt(0.3, 1.0, sw, &cfg));
        // Switch cost inflated above the outstanding work: decline.
        assert!(!should_preempt(1.0, 0.3, 0.5, &cfg));
        // Saving must clear the margin, not just break even.
        assert!(!should_preempt(1.0, 1.0 - 1.5 * sw, sw, &cfg));
        // Disabled policy never preempts, whatever the numbers.
        let off = cfg.without_preemption();
        assert!(!off.preemption_enabled());
        assert!(!should_preempt(1e9, 0.0, sw, &off));
    }

    #[test]
    fn equal_split_restored_at_idle() {
        let cfg = PolicyConfig::default();
        // Skewed fabric, backlog gone: relax back to equal despite the
        // hysteresis…
        assert!(should_resplit(&[8, 1, 1], &[1, 1, 1], 0.0, 1e-6, &cfg));
        // …but never churn between two skewed shapes at idle.
        assert!(!should_resplit(&[8, 1, 1], &[1, 4, 1], 0.0, 1e-6, &cfg));
    }
}
