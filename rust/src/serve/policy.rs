//! Re-composition policy: when and how to re-split the fabric.
//!
//! The signal is per-tenant *backlog time* — queue depth × the fabric
//! seconds one request costs on the tenant's current slice. Weights
//! proportional to backlog time hand FMUs/CUs to the tenants that are
//! actually falling behind (queue depth alone would over-reward cheap
//! requests). Hysteresis keeps the fabric still when the backlog is too
//! small to be worth a switch, and proportional weight reduction keeps
//! `[2,2,2]` from being treated as different from `[1,1,1]`.

fn gcd(a: u32, b: u32) -> u32 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Reduce weights by their GCD so proportionally-equal vectors compare
/// equal (`[4, 2, 2]` → `[2, 1, 1]`).
pub fn reduce_weights(w: &[u32]) -> Vec<u32> {
    let g = w.iter().fold(0u32, |acc, &x| gcd(acc, x)).max(1);
    w.iter().map(|&x| x / g).collect()
}

/// Map per-tenant backlog times to partition weights in `1..=max_weight`
/// (every tenant keeps at least one unit — starvation-free), reduced to
/// lowest terms. All-idle backlogs yield an equal split.
pub fn backlog_weights(backlog_s: &[f64], max_weight: u32) -> Vec<u32> {
    let max_weight = max_weight.max(1);
    let mx = backlog_s.iter().cloned().fold(0.0f64, f64::max);
    if mx <= 0.0 {
        return vec![1; backlog_s.len()];
    }
    let w: Vec<u32> = backlog_s
        .iter()
        .map(|&b| ((b / mx * max_weight as f64).ceil() as u32).clamp(1, max_weight))
        .collect();
    reduce_weights(&w)
}

/// Policy knobs for the dynamic re-composer.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Seconds between policy evaluations (virtual fabric time in the
    /// simulator, wall-clock in the live scheduler).
    pub epoch_s: f64,
    /// Largest weight a single tenant can take.
    pub max_weight: u32,
    /// Re-split only when total backlog time exceeds this multiple of
    /// the switch cost (hysteresis against churn at idle).
    pub min_backlog_factor: f64,
    /// Mid-DAG preemption margin: preempt an in-flight batch at its
    /// next layer boundary only when the projected saving — remaining
    /// work on the old slice, minus remaining work re-costed on the new
    /// slice and one switch — exceeds this multiple of the switch cost.
    /// `f64::INFINITY` disables preemption entirely (re-compositions
    /// then land only at batch boundaries, the pre-cursor behavior).
    pub preempt_margin_factor: f64,
    /// Cross-tenant packing fit: two tenants may share one partition
    /// (time-multiplexed by the [`Interleaver`](super::Interleaver))
    /// only while their combined backlog time, scaled by this factor,
    /// still fits inside one policy epoch of that partition's fabric
    /// time. Larger is more conservative. `f64::INFINITY` disables
    /// packing entirely (the default — every tenant keeps its own
    /// partition, the pre-packing behavior).
    pub pack_headroom_factor: f64,
    /// Per-swap amortization gate: pack only while one context swap
    /// (`switch_cost_s`) costs no more than this fraction of the fabric
    /// time a packed cursor runs between swaps (its quantum).
    pub pack_swap_margin: f64,
    /// Layer steps a packed cursor runs before the interleaver rotates
    /// to the next tenant (clamped to at least 1 at use).
    pub pack_quantum_steps: usize,
    /// Unpack hysteresis: a packed pair is split back onto their own
    /// partitions once their combined backlog exceeds this multiple of
    /// the pack-fit bound (`epoch / pack_headroom_factor`). Must be
    /// > 1 to avoid pack/unpack churn at the boundary.
    pub pack_unpack_factor: f64,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            epoch_s: 0.05,
            max_weight: 8,
            min_backlog_factor: 50.0,
            preempt_margin_factor: 1.0,
            pack_headroom_factor: f64::INFINITY,
            pack_swap_margin: 0.25,
            pack_quantum_steps: 4,
            pack_unpack_factor: 2.0,
        }
    }
}

impl PolicyConfig {
    /// Policy tuned to a measured per-request service time: evaluate
    /// every ~10 requests' worth of fabric time, with low hysteresis.
    /// The single source of the constants behind every calibrated
    /// scenario (example, bench, CLI `--mode sim`, acceptance test).
    pub fn calibrated(per_request_s: f64) -> Self {
        Self {
            epoch_s: 10.0 * per_request_s,
            max_weight: 8,
            min_backlog_factor: 5.0,
            preempt_margin_factor: 1.0,
            ..Self::default()
        }
    }

    /// Same policy with mid-DAG preemption disabled: re-compositions
    /// apply only to batches that start after them.
    pub fn without_preemption(mut self) -> Self {
        self.preempt_margin_factor = f64::INFINITY;
        self
    }

    /// Is mid-DAG preemption enabled at all?
    pub fn preemption_enabled(&self) -> bool {
        self.preempt_margin_factor.is_finite()
    }

    /// Same policy with cross-tenant packing enabled at the default fit
    /// bound (combined backlog must fit half an epoch of one
    /// partition's fabric time).
    pub fn with_packing(mut self) -> Self {
        self.pack_headroom_factor = 2.0;
        self
    }

    /// Is cross-tenant packing enabled at all?
    pub fn packing_enabled(&self) -> bool {
        self.pack_headroom_factor.is_finite()
    }
}

/// Should the fabric be re-split from `current` to `proposed` weights?
///
/// A proposal that merely *restores the equal split* (all weights equal)
/// is exempt from the backlog hysteresis: relaxing a skewed composition
/// once load subsides costs one switch on an idle fabric and leaves it
/// in the neutral shape — which the schedule cache has always seen.
pub fn should_resplit(
    current: &[u32],
    proposed: &[u32],
    total_backlog_s: f64,
    switch_cost_s: f64,
    cfg: &PolicyConfig,
) -> bool {
    if reduce_weights(current) == reduce_weights(proposed) {
        return false;
    }
    let equalizes = proposed.windows(2).all(|w| w[0] == w[1]);
    equalizes || total_backlog_s > cfg.min_backlog_factor * switch_cost_s
}

/// The preemption-benefit term: should an *in-flight* batch be
/// interrupted at its next layer boundary when the fabric re-splits?
///
/// `remaining_old_s` is the work left if the batch drains on its
/// current slice; `remaining_new_s` the same steps re-costed on the new
/// slice. Preempting pays one mid-DAG `switch_cost_s`, so it only wins
/// when the re-costing saves more than the switch — by at least
/// `preempt_margin_factor` switches' worth of margin. A shrinking slice
/// (`remaining_new_s > remaining_old_s`) therefore always declines and
/// drains on the old composition, and inflating the switch cost above
/// the outstanding work makes every tenant decline.
pub fn should_preempt(
    remaining_old_s: f64,
    remaining_new_s: f64,
    switch_cost_s: f64,
    cfg: &PolicyConfig,
) -> bool {
    if !cfg.preemption_enabled() {
        return false;
    }
    remaining_old_s - (remaining_new_s + switch_cost_s)
        > cfg.preempt_margin_factor * switch_cost_s
}

/// The packing-benefit term: should two tenants share one partition,
/// time-multiplexed at layer-step granularity?
///
/// Mirrors [`should_preempt`]'s cost-vs-benefit shape with two gates:
///
/// * **fit** — `combined_backlog_s` (the candidates' queued + in-flight
///   fabric seconds) scaled by `pack_headroom_factor` must fit inside
///   one policy epoch (`epoch_s`) of the shared partition's fabric
///   time, i.e. the pair must be light enough that one slice serves
///   both without falling behind;
/// * **amortization** — one context swap (`switch_cost_s`) must cost at
///   most `pack_swap_margin` of the fabric time a packed cursor runs
///   between swaps (`quantum_s`), so the swap overhead stays a bounded
///   fraction of useful work.
///
/// All arguments are fabric seconds. With packing disabled
/// (`pack_headroom_factor == INFINITY`, the default) this always
/// returns false.
pub fn should_pack(
    combined_backlog_s: f64,
    epoch_s: f64,
    quantum_s: f64,
    switch_cost_s: f64,
    cfg: &PolicyConfig,
) -> bool {
    cfg.packing_enabled()
        && combined_backlog_s * cfg.pack_headroom_factor <= epoch_s
        && switch_cost_s <= cfg.pack_swap_margin * quantum_s
}

/// Pick the pack-candidate pair from per-tenant backlog times (fabric
/// seconds): the two lightest tenants (index tiebreak), gated on
/// *demonstrated skew* — the rest of the fabric must carry strictly
/// more backlog than the pair, so an all-idle fabric (ties) never
/// packs its heavy tenant by accident, and packing always frees
/// capacity someone else wants. Returns `None` when there are fewer
/// than two tenants or no skew. Shared by the live scheduler and the
/// simulator so their candidate selection can never diverge.
pub fn pack_candidates(backlog_s: &[f64]) -> Option<(usize, usize)> {
    if backlog_s.len() < 2 {
        return None;
    }
    let mut order: Vec<usize> = (0..backlog_s.len()).collect();
    order.sort_by(|&x, &y| backlog_s[x].partial_cmp(&backlog_s[y]).unwrap().then(x.cmp(&y)));
    let (a, b) = (order[0].min(order[1]), order[0].max(order[1]));
    let combined = backlog_s[a] + backlog_s[b];
    let total: f64 = backlog_s.iter().sum();
    (combined < total - combined).then_some((a, b))
}

/// Fabric seconds a packed cursor runs between context swaps: the
/// quantum's step count at the *slower* candidate's per-step rate.
/// Each candidate is `(per_request_s, steps_per_request)` on its
/// current schedule. Shared by the live scheduler and the simulator.
pub fn pack_quantum_s(quantum_steps: usize, candidates: [(f64, usize); 2]) -> f64 {
    let q = quantum_steps.max(1) as f64;
    candidates
        .iter()
        .map(|&(per, steps)| q * per / steps.max(1) as f64)
        .fold(f64::INFINITY, f64::min)
}

/// Should a packed pair be split back onto their own partitions?
///
/// Unpacks once the combined backlog exceeds the pack-fit bound
/// (`epoch_s / pack_headroom_factor`) by the `pack_unpack_factor`
/// hysteresis — strictly above the [`should_pack`] threshold, so a pair
/// sitting exactly at the fit bound never churns. All arguments are
/// fabric seconds.
pub fn should_unpack(combined_backlog_s: f64, epoch_s: f64, cfg: &PolicyConfig) -> bool {
    cfg.packing_enabled()
        && combined_backlog_s * cfg.pack_headroom_factor > cfg.pack_unpack_factor * epoch_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_backlog_equal_weights() {
        assert_eq!(backlog_weights(&[0.5, 0.5, 0.5], 8), vec![1, 1, 1]);
        assert_eq!(backlog_weights(&[0.0, 0.0], 8), vec![1, 1]);
    }

    #[test]
    fn skewed_backlog_skews_weights() {
        let w = backlog_weights(&[0.8, 0.1, 0.1], 8);
        assert_eq!(w[0], 8);
        assert_eq!(&w[1..], &[1, 1]);
        // Idle tenants still get a floor of one.
        let w = backlog_weights(&[1.0, 0.0, 0.0], 8);
        assert_eq!(w, vec![8, 1, 1]);
    }

    #[test]
    fn weights_reduced_to_lowest_terms() {
        assert_eq!(reduce_weights(&[4, 2, 2]), vec![2, 1, 1]);
        assert_eq!(reduce_weights(&[8, 8, 8]), vec![1, 1, 1]);
        assert_eq!(reduce_weights(&[0, 0]), vec![0, 0]);
    }

    #[test]
    fn hysteresis_blocks_idle_resplit() {
        let cfg = PolicyConfig::default();
        let cur = [1, 1, 1];
        let new = [8, 1, 1];
        // Large backlog: switch.
        assert!(should_resplit(&cur, &new, 1.0, 1e-6, &cfg));
        // Tiny backlog vs switch cost: hold.
        assert!(!should_resplit(&cur, &new, 1e-6, 1e-6, &cfg));
        // Proportionally identical: hold regardless.
        assert!(!should_resplit(&[2, 2, 2], &[1, 1, 1], 1.0, 1e-6, &cfg));
    }

    #[test]
    fn preemption_weighs_remaining_work_against_switch_cost() {
        let cfg = PolicyConfig { preempt_margin_factor: 1.0, ..PolicyConfig::default() };
        let sw = 1e-6;
        // Big saving: preempt.
        assert!(should_preempt(1.0, 0.3, sw, &cfg));
        // Shrinking slice: never preempt.
        assert!(!should_preempt(0.3, 1.0, sw, &cfg));
        // Switch cost inflated above the outstanding work: decline.
        assert!(!should_preempt(1.0, 0.3, 0.5, &cfg));
        // Saving must clear the margin, not just break even.
        assert!(!should_preempt(1.0, 1.0 - 1.5 * sw, sw, &cfg));
        // Disabled policy never preempts, whatever the numbers.
        let off = cfg.without_preemption();
        assert!(!off.preemption_enabled());
        assert!(!should_preempt(1e9, 0.0, sw, &off));
    }

    #[test]
    fn packing_disabled_by_default() {
        let cfg = PolicyConfig::default();
        assert!(!cfg.packing_enabled());
        // Whatever the numbers, a disabled policy never packs.
        assert!(!should_pack(0.0, 1.0, 1.0, 0.0, &cfg));
        assert!(!should_unpack(1e9, 1.0, &cfg));
        let on = cfg.with_packing();
        assert!(on.packing_enabled());
        assert!(on.preemption_enabled(), "packing must not disturb preemption");
    }

    #[test]
    fn packing_weighs_fit_and_swap_amortization() {
        let cfg = PolicyConfig { pack_headroom_factor: 2.0, ..PolicyConfig::default() };
        let (epoch, quantum, sw) = (1.0, 0.1, 1e-3);
        // Light pair, cheap swaps: pack.
        assert!(should_pack(0.2, epoch, quantum, sw, &cfg));
        // Combined backlog above epoch/headroom: decline.
        assert!(!should_pack(0.6, epoch, quantum, sw, &cfg));
        // Swap cost above the amortization margin of a quantum: decline.
        assert!(!should_pack(0.2, epoch, quantum, 0.5 * quantum, &cfg));
    }

    #[test]
    fn pack_candidates_need_skew() {
        // The two lightest tenants, only when the rest out-backlogs them.
        assert_eq!(pack_candidates(&[10.0, 0.5, 0.25]), Some((1, 2)));
        // Index tiebreak is deterministic.
        assert_eq!(pack_candidates(&[10.0, 0.0, 0.0, 0.0]), Some((1, 2)));
        // All idle (ties): no skew, no pack — never grab the heavy
        // tenant by accident.
        assert_eq!(pack_candidates(&[0.0, 0.0, 0.0]), None);
        // Two tenants: the pair IS the fabric; packing frees nothing.
        assert_eq!(pack_candidates(&[1.0, 2.0]), None);
        assert_eq!(pack_candidates(&[1.0]), None);
    }

    #[test]
    fn pack_quantum_uses_the_slower_candidate() {
        // 4 steps at per-step 0.25 vs per-step 1.0: the slower (finer)
        // amortization window wins.
        let q = pack_quantum_s(4, [(1.0, 4), (4.0, 4)]);
        assert!((q - 1.0).abs() < 1e-12);
        // Degenerate step counts are clamped.
        assert!(pack_quantum_s(0, [(1.0, 0), (1.0, 1)]).is_finite());
    }

    #[test]
    fn unpack_hysteresis_sits_above_the_pack_bound() {
        let cfg = PolicyConfig {
            pack_headroom_factor: 2.0,
            pack_unpack_factor: 2.0,
            ..PolicyConfig::default()
        };
        let epoch = 1.0;
        // Fit bound is epoch/headroom = 0.5; unpack bound is 1.0.
        assert!(should_pack(0.5, epoch, 1.0, 0.0, &cfg));
        assert!(!should_unpack(0.5, epoch, &cfg), "at the fit bound: no churn");
        assert!(!should_unpack(1.0, epoch, &cfg), "hysteresis band holds the pack");
        assert!(should_unpack(1.5, epoch, &cfg), "well past the band: unpack");
    }

    #[test]
    fn equal_split_restored_at_idle() {
        let cfg = PolicyConfig::default();
        // Skewed fabric, backlog gone: relax back to equal despite the
        // hysteresis…
        assert!(should_resplit(&[8, 1, 1], &[1, 1, 1], 0.0, 1e-6, &cfg));
        // …but never churn between two skewed shapes at idle.
        assert!(!should_resplit(&[8, 1, 1], &[1, 4, 1], 0.0, 1e-6, &cfg));
    }
}
