//! Re-composition policy: when and how to re-split the fabric.
//!
//! The signal is per-tenant *backlog time* — queue depth × the fabric
//! seconds one request costs on the tenant's current slice. Weights
//! proportional to backlog time hand FMUs/CUs to the tenants that are
//! actually falling behind (queue depth alone would over-reward cheap
//! requests). Hysteresis keeps the fabric still when the backlog is too
//! small to be worth a switch, and proportional weight reduction keeps
//! `[2,2,2]` from being treated as different from `[1,1,1]`.

fn gcd(a: u32, b: u32) -> u32 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Reduce weights by their GCD so proportionally-equal vectors compare
/// equal (`[4, 2, 2]` → `[2, 1, 1]`).
pub fn reduce_weights(w: &[u32]) -> Vec<u32> {
    let g = w.iter().fold(0u32, |acc, &x| gcd(acc, x)).max(1);
    w.iter().map(|&x| x / g).collect()
}

/// Map per-tenant backlog times to partition weights in `1..=max_weight`
/// (every tenant keeps at least one unit — starvation-free), reduced to
/// lowest terms. All-idle backlogs yield an equal split.
pub fn backlog_weights(backlog_s: &[f64], max_weight: u32) -> Vec<u32> {
    let max_weight = max_weight.max(1);
    let mx = backlog_s.iter().cloned().fold(0.0f64, f64::max);
    if mx <= 0.0 {
        return vec![1; backlog_s.len()];
    }
    let w: Vec<u32> = backlog_s
        .iter()
        .map(|&b| ((b / mx * max_weight as f64).ceil() as u32).clamp(1, max_weight))
        .collect();
    reduce_weights(&w)
}

/// Policy knobs for the dynamic re-composer.
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Seconds between policy evaluations (virtual fabric time in the
    /// simulator, wall-clock in the live scheduler).
    pub epoch_s: f64,
    /// Largest weight a single tenant can take.
    pub max_weight: u32,
    /// Re-split only when total backlog time exceeds this multiple of
    /// the switch cost (hysteresis against churn at idle).
    pub min_backlog_factor: f64,
    /// Mid-DAG preemption margin: preempt an in-flight batch at its
    /// next layer boundary only when the projected saving — remaining
    /// work on the old slice, minus remaining work re-costed on the new
    /// slice and one switch — exceeds this multiple of the switch cost.
    /// `f64::INFINITY` disables preemption entirely (re-compositions
    /// then land only at batch boundaries, the pre-cursor behavior).
    pub preempt_margin_factor: f64,
    /// Cross-tenant packing fit: a group of tenants may share one
    /// partition (time-multiplexed by the
    /// [`Interleaver`](super::Interleaver)) only while their combined
    /// backlog time, scaled by this factor, still fits inside one
    /// policy epoch of that partition's fabric time. Larger is more
    /// conservative. `f64::INFINITY` disables packing entirely (the
    /// default — every tenant keeps its own partition, the pre-packing
    /// behavior).
    pub pack_headroom_factor: f64,
    /// Per-swap amortization gate: pack only while one context swap
    /// (`switch_cost_s`) costs no more than this fraction of the fabric
    /// time a packed cursor runs between swaps (its quantum).
    pub pack_swap_margin: f64,
    /// Layer steps a packed cursor runs before the interleaver rotates
    /// to the next tenant (clamped to at least 1 at use).
    pub pack_quantum_steps: usize,
    /// Unpack hysteresis: a packed group is split back onto their own
    /// partitions once their combined backlog exceeds this multiple of
    /// the pack-fit bound (`epoch / pack_headroom_factor`). Must be
    /// > 1 to avoid pack/unpack churn at the boundary.
    pub pack_unpack_factor: f64,
    /// Run cold DSE solves off the hot path: when an approved re-split
    /// needs a slice whose schedule is not cached yet, defer the
    /// transition, hand the solves to the background solver, and keep
    /// the last cached split until they land (the resplit is
    /// re-proposed at a later epoch boundary). The solver drains and
    /// dedupes its whole queue each wake — a resplit re-deferred
    /// across epochs coalesces instead of re-queueing solves (counted
    /// in [`StallStats::coalesced_solves`](super::telemetry::StallStats::coalesced_solves))
    /// — and with [`LiveConfig::dse_workers`](super::LiveConfig) > 1
    /// solves distinct cold slices concurrently. Off by default — the
    /// synchronous path solves inline and the engine stays
    /// single-threaded-deterministic with no solver thread attached.
    pub async_solve: bool,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        Self {
            epoch_s: 0.05,
            max_weight: 8,
            min_backlog_factor: 50.0,
            preempt_margin_factor: 1.0,
            pack_headroom_factor: f64::INFINITY,
            pack_swap_margin: 0.25,
            pack_quantum_steps: 4,
            pack_unpack_factor: 2.0,
            async_solve: false,
        }
    }
}

impl PolicyConfig {
    /// Policy tuned to a measured per-request service time: evaluate
    /// every ~10 requests' worth of fabric time, with low hysteresis.
    /// The single source of the constants behind every calibrated
    /// scenario (example, bench, CLI `--mode sim`, acceptance test).
    pub fn calibrated(per_request_s: f64) -> Self {
        Self {
            epoch_s: 10.0 * per_request_s,
            max_weight: 8,
            min_backlog_factor: 5.0,
            preempt_margin_factor: 1.0,
            ..Self::default()
        }
    }

    /// Same policy with mid-DAG preemption disabled: re-compositions
    /// apply only to batches that start after them.
    pub fn without_preemption(mut self) -> Self {
        self.preempt_margin_factor = f64::INFINITY;
        self
    }

    /// Is mid-DAG preemption enabled at all?
    pub fn preemption_enabled(&self) -> bool {
        self.preempt_margin_factor.is_finite()
    }

    /// Same policy with cross-tenant packing enabled at the default fit
    /// bound (combined backlog must fit half an epoch of one
    /// partition's fabric time).
    pub fn with_packing(mut self) -> Self {
        self.pack_headroom_factor = 2.0;
        self
    }

    /// Is cross-tenant packing enabled at all?
    pub fn packing_enabled(&self) -> bool {
        self.pack_headroom_factor.is_finite()
    }

    /// Enable deferred (off-hot-path) DSE solves for cold re-splits.
    pub fn with_async_solve(mut self) -> Self {
        self.async_solve = true;
        self
    }
}

/// Should the fabric be re-split from `current` to `proposed` weights?
///
/// A proposal that merely *restores the equal split* (all weights equal)
/// is exempt from the backlog hysteresis: relaxing a skewed composition
/// once load subsides costs one switch on an idle fabric and leaves it
/// in the neutral shape — which the schedule cache has always seen.
pub fn should_resplit(
    current: &[u32],
    proposed: &[u32],
    total_backlog_s: f64,
    switch_cost_s: f64,
    cfg: &PolicyConfig,
) -> bool {
    if reduce_weights(current) == reduce_weights(proposed) {
        return false;
    }
    let equalizes = proposed.windows(2).all(|w| w[0] == w[1]);
    equalizes || total_backlog_s > cfg.min_backlog_factor * switch_cost_s
}

/// The preemption-benefit term: should an *in-flight* batch be
/// interrupted at its next layer boundary when the fabric re-splits?
///
/// `remaining_old_s` is the work left if the batch drains on its
/// current slice; `remaining_new_s` the same steps re-costed on the new
/// slice. Preempting pays one mid-DAG `switch_cost_s`, so it only wins
/// when the re-costing saves more than the switch — by at least
/// `preempt_margin_factor` switches' worth of margin. A shrinking slice
/// (`remaining_new_s > remaining_old_s`) therefore always declines and
/// drains on the old composition, and inflating the switch cost above
/// the outstanding work makes every tenant decline.
pub fn should_preempt(
    remaining_old_s: f64,
    remaining_new_s: f64,
    switch_cost_s: f64,
    cfg: &PolicyConfig,
) -> bool {
    if !cfg.preemption_enabled() {
        return false;
    }
    remaining_old_s - (remaining_new_s + switch_cost_s)
        > cfg.preempt_margin_factor * switch_cost_s
}

/// The packing-benefit term: should a group of tenants share one
/// partition, time-multiplexed at layer-step granularity?
///
/// Mirrors [`should_preempt`]'s cost-vs-benefit shape with two gates:
///
/// * **fit** — `combined_backlog_s` (the group's queued + in-flight
///   fabric seconds) scaled by `pack_headroom_factor` must fit inside
///   one policy epoch (`epoch_s`) of the shared partition's fabric
///   time, i.e. the group must be light enough that one slice serves
///   all of it without falling behind;
/// * **amortization** — one context swap (`switch_cost_s`) must cost at
///   most `pack_swap_margin` of the fabric time a packed cursor runs
///   between swaps (`quantum_s`), so the swap overhead stays a bounded
///   fraction of useful work.
///
/// All arguments are fabric seconds. With packing disabled
/// (`pack_headroom_factor == INFINITY`, the default) this always
/// returns false.
pub fn should_pack(
    combined_backlog_s: f64,
    epoch_s: f64,
    quantum_s: f64,
    switch_cost_s: f64,
    cfg: &PolicyConfig,
) -> bool {
    cfg.packing_enabled()
        && combined_backlog_s * cfg.pack_headroom_factor <= epoch_s
        && switch_cost_s <= cfg.pack_swap_margin * quantum_s
}

/// Propose multi-way pack groups from per-tenant backlog times (fabric
/// seconds) by first-fit-decreasing bin packing: tenants marked
/// `eligible` (not already packed) are placed, heaviest first with an
/// index tiebreak, into bins of `capacity_s` — the pack-fit bound
/// `epoch_s / pack_headroom_factor`. Bins that end up with a single
/// member are not packs and are dropped.
///
/// The whole proposal is gated on *demonstrated skew*: the rest of the
/// fabric must carry strictly more backlog than everything proposed
/// for packing, so an all-idle fabric (ties) never packs its heavy
/// tenant by accident, and packing always frees capacity someone else
/// wants. Returns member index lists, each sorted ascending (the first
/// member leads the shared partition), ordered by leader. One shared
/// site for both drivers — the engine applies the result, so candidate
/// selection can never diverge between live and sim.
pub fn pack_groups(backlog_s: &[f64], eligible: &[bool], capacity_s: f64) -> Vec<Vec<usize>> {
    let n = backlog_s.len();
    if n < 2 || !capacity_s.is_finite() {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..n).filter(|&t| eligible[t]).collect();
    if order.len() < 2 {
        return Vec::new();
    }
    order.sort_by(|&x, &y| backlog_s[y].partial_cmp(&backlog_s[x]).unwrap().then(x.cmp(&y)));
    let mut bins: Vec<(f64, Vec<usize>)> = Vec::new();
    for t in order {
        match bins.iter_mut().find(|(load, _)| *load + backlog_s[t] <= capacity_s) {
            Some((load, members)) => {
                *load += backlog_s[t];
                members.push(t);
            }
            None => bins.push((backlog_s[t], vec![t])),
        }
    }
    let mut groups: Vec<Vec<usize>> =
        bins.into_iter().map(|(_, m)| m).filter(|m| m.len() >= 2).collect();
    for g in &mut groups {
        g.sort_unstable();
    }
    groups.sort_by_key(|g| g[0]);
    let packed: f64 = groups.iter().flatten().map(|&t| backlog_s[t]).sum();
    let total: f64 = backlog_s.iter().sum();
    if packed < total - packed {
        groups
    } else {
        Vec::new()
    }
}

/// Fabric seconds a packed cursor runs between context swaps: the
/// quantum's step count at the *slowest* member's per-step rate. Each
/// member is `(per_request_s, steps_per_request)` on its current
/// schedule. Shared by the live scheduler and the simulator.
pub fn pack_quantum_s(quantum_steps: usize, members: &[(f64, usize)]) -> f64 {
    let q = quantum_steps.max(1) as f64;
    members
        .iter()
        .map(|&(per, steps)| q * per / steps.max(1) as f64)
        .fold(f64::INFINITY, f64::min)
}

/// How much of an in-flight batch's remaining work should count toward
/// the *weight proposal* backlog signal.
///
/// With preemption disabled, in-flight work is immovable and counts
/// for nothing (the pre-cursor behavior). With it enabled, the work is
/// movable but migrating it costs one mid-DAG switch — so instead of
/// the old all-or-nothing accounting, the signal is discounted by the
/// migration cost: `max(0, remaining - switch_cost)`. A batch with
/// less remaining work than a switch no longer inflates its tenant's
/// weight (preempting it could never pay off anyway, per
/// [`should_preempt`]'s margin).
pub fn inflight_backlog_s(remaining_s: f64, switch_cost_s: f64, cfg: &PolicyConfig) -> f64 {
    if !cfg.preemption_enabled() {
        return 0.0;
    }
    (remaining_s - switch_cost_s).max(0.0)
}

/// Should a packed group be split back onto their own partitions?
///
/// Unpacks once the combined backlog exceeds the pack-fit bound
/// (`epoch_s / pack_headroom_factor`) by the `pack_unpack_factor`
/// hysteresis — strictly above the [`should_pack`] threshold, so a
/// group sitting exactly at the fit bound never churns. All arguments
/// are fabric seconds.
pub fn should_unpack(combined_backlog_s: f64, epoch_s: f64, cfg: &PolicyConfig) -> bool {
    cfg.packing_enabled()
        && combined_backlog_s * cfg.pack_headroom_factor > cfg.pack_unpack_factor * epoch_s
}

/// SLO urgency multiplier for the backlog signal: a latency-tier
/// tenant whose deadline is shorter than one policy epoch cannot sit
/// out an epoch of skew, so its backlog counts `epoch_s / deadline_s`
/// times (never less than 1) toward weight proposals and pack fitting.
///
/// Throughput tiers (`deadline_s == None`) and deadlines at or above
/// one epoch multiply by exactly `1.0` — the bit-for-bit identity on
/// every finite `f64` — so a fabric with no latency tiers reproduces
/// the unweighted signal, and therefore its whole event trace,
/// unchanged. Degenerate deadlines (zero, negative, non-finite) are
/// filtered upstream by `SloClass::deadline_s`, but a defensive guard
/// here keeps the multiplier finite regardless.
pub fn slo_backlog_boost(deadline_s: Option<f64>, epoch_s: f64) -> f64 {
    match deadline_s {
        Some(d) if d > 0.0 && d.is_finite() && epoch_s.is_finite() => (epoch_s / d).max(1.0),
        _ => 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_backlog_equal_weights() {
        assert_eq!(backlog_weights(&[0.5, 0.5, 0.5], 8), vec![1, 1, 1]);
        assert_eq!(backlog_weights(&[0.0, 0.0], 8), vec![1, 1]);
    }

    #[test]
    fn skewed_backlog_skews_weights() {
        let w = backlog_weights(&[0.8, 0.1, 0.1], 8);
        assert_eq!(w[0], 8);
        assert_eq!(&w[1..], &[1, 1]);
        // Idle tenants still get a floor of one.
        let w = backlog_weights(&[1.0, 0.0, 0.0], 8);
        assert_eq!(w, vec![8, 1, 1]);
    }

    #[test]
    fn weights_reduced_to_lowest_terms() {
        assert_eq!(reduce_weights(&[4, 2, 2]), vec![2, 1, 1]);
        assert_eq!(reduce_weights(&[8, 8, 8]), vec![1, 1, 1]);
        assert_eq!(reduce_weights(&[0, 0]), vec![0, 0]);
    }

    #[test]
    fn hysteresis_blocks_idle_resplit() {
        let cfg = PolicyConfig::default();
        let cur = [1, 1, 1];
        let new = [8, 1, 1];
        // Large backlog: switch.
        assert!(should_resplit(&cur, &new, 1.0, 1e-6, &cfg));
        // Tiny backlog vs switch cost: hold.
        assert!(!should_resplit(&cur, &new, 1e-6, 1e-6, &cfg));
        // Proportionally identical: hold regardless.
        assert!(!should_resplit(&[2, 2, 2], &[1, 1, 1], 1.0, 1e-6, &cfg));
    }

    #[test]
    fn preemption_weighs_remaining_work_against_switch_cost() {
        let cfg = PolicyConfig { preempt_margin_factor: 1.0, ..PolicyConfig::default() };
        let sw = 1e-6;
        // Big saving: preempt.
        assert!(should_preempt(1.0, 0.3, sw, &cfg));
        // Shrinking slice: never preempt.
        assert!(!should_preempt(0.3, 1.0, sw, &cfg));
        // Switch cost inflated above the outstanding work: decline.
        assert!(!should_preempt(1.0, 0.3, 0.5, &cfg));
        // Saving must clear the margin, not just break even.
        assert!(!should_preempt(1.0, 1.0 - 1.5 * sw, sw, &cfg));
        // Disabled policy never preempts, whatever the numbers.
        let off = cfg.without_preemption();
        assert!(!off.preemption_enabled());
        assert!(!should_preempt(1e9, 0.0, sw, &off));
    }

    #[test]
    fn packing_disabled_by_default() {
        let cfg = PolicyConfig::default();
        assert!(!cfg.packing_enabled());
        // Whatever the numbers, a disabled policy never packs.
        assert!(!should_pack(0.0, 1.0, 1.0, 0.0, &cfg));
        assert!(!should_unpack(1e9, 1.0, &cfg));
        let on = cfg.with_packing();
        assert!(on.packing_enabled());
        assert!(on.preemption_enabled(), "packing must not disturb preemption");
    }

    #[test]
    fn packing_weighs_fit_and_swap_amortization() {
        let cfg = PolicyConfig { pack_headroom_factor: 2.0, ..PolicyConfig::default() };
        let (epoch, quantum, sw) = (1.0, 0.1, 1e-3);
        // Light pair, cheap swaps: pack.
        assert!(should_pack(0.2, epoch, quantum, sw, &cfg));
        // Combined backlog above epoch/headroom: decline.
        assert!(!should_pack(0.6, epoch, quantum, sw, &cfg));
        // Swap cost above the amortization margin of a quantum: decline.
        assert!(!should_pack(0.2, epoch, quantum, 0.5 * quantum, &cfg));
    }

    #[test]
    fn pack_groups_bin_packs_light_tenants() {
        let all = [true; 8];
        // The two light tenants group; the heavy one stays out.
        assert_eq!(pack_groups(&[10.0, 0.5, 0.25], &all[..3], 1.0), vec![vec![1, 2]]);
        // Ties break deterministically by index.
        assert_eq!(pack_groups(&[10.0, 0.0, 0.0, 0.0], &all[..4], 1.0), vec![vec![1, 2, 3]]);
        // Several packs at once: two pairs that each fit the bound but
        // together do not.
        assert_eq!(
            pack_groups(&[10.0, 0.6, 0.6, 0.3, 0.3], &all[..5], 1.0),
            vec![vec![1, 3], vec![2, 4]]
        );
        // All idle (ties): no skew, no pack — never grab the heavy
        // tenant by accident.
        assert!(pack_groups(&[0.0, 0.0, 0.0], &all[..3], 1.0).is_empty());
        // Two tenants: the pair IS the fabric; packing frees nothing.
        assert!(pack_groups(&[1.0, 2.0], &all[..2], 100.0).is_empty());
        assert!(pack_groups(&[1.0], &all[..1], 100.0).is_empty());
        // Ineligible (already-packed) tenants are never re-proposed.
        assert!(pack_groups(&[10.0, 0.1, 0.1], &[true, true, false], 1.0).is_empty());
        // A tenant too heavy for the bound on its own stays solo even
        // when lighter tenants would fit beside it.
        assert_eq!(pack_groups(&[10.0, 2.0, 0.1, 0.1], &all[..4], 1.0), vec![vec![2, 3]]);
    }

    #[test]
    fn pack_quantum_uses_the_slowest_member() {
        // 4 steps at per-step 0.25 vs per-step 1.0: the slower (finer)
        // amortization window wins.
        let q = pack_quantum_s(4, &[(1.0, 4), (4.0, 4)]);
        assert!((q - 1.0).abs() < 1e-12);
        // Degenerate step counts are clamped.
        assert!(pack_quantum_s(0, &[(1.0, 0), (1.0, 1)]).is_finite());
    }

    #[test]
    fn inflight_signal_discounts_migration_cost() {
        let cfg = PolicyConfig { preempt_margin_factor: 1.0, ..PolicyConfig::default() };
        // Movable work counts minus one switch's worth of migration.
        assert_eq!(inflight_backlog_s(1.0, 0.25, &cfg), 0.75);
        // Less remaining than a switch: contributes nothing (moving it
        // could never pay off).
        assert_eq!(inflight_backlog_s(0.1, 0.25, &cfg), 0.0);
        // Preemption off: in-flight work is immovable, signal is zero.
        assert_eq!(inflight_backlog_s(1e9, 0.25, &cfg.without_preemption()), 0.0);
    }

    #[test]
    fn unpack_hysteresis_sits_above_the_pack_bound() {
        let cfg = PolicyConfig {
            pack_headroom_factor: 2.0,
            pack_unpack_factor: 2.0,
            ..PolicyConfig::default()
        };
        let epoch = 1.0;
        // Fit bound is epoch/headroom = 0.5; unpack bound is 1.0.
        assert!(should_pack(0.5, epoch, 1.0, 0.0, &cfg));
        assert!(!should_unpack(0.5, epoch, &cfg), "at the fit bound: no churn");
        assert!(!should_unpack(1.0, epoch, &cfg), "hysteresis band holds the pack");
        assert!(should_unpack(1.5, epoch, &cfg), "well past the band: unpack");
    }

    #[test]
    fn equal_split_restored_at_idle() {
        let cfg = PolicyConfig::default();
        // Skewed fabric, backlog gone: relax back to equal despite the
        // hysteresis…
        assert!(should_resplit(&[8, 1, 1], &[1, 1, 1], 0.0, 1e-6, &cfg));
        // …but never churn between two skewed shapes at idle.
        assert!(!should_resplit(&[8, 1, 1], &[1, 4, 1], 0.0, 1e-6, &cfg));
    }

    // ---- hysteresis boundary values --------------------------------------
    // The pack/unpack gates compare with `<=` (pack admits at its
    // bound) and `>` (unpack declines at its bound); these exact-edge
    // cases pin the comparison directions down before anything new —
    // like SLO backlog weighting — feeds the operands.

    fn packing_cfg() -> PolicyConfig {
        PolicyConfig {
            pack_headroom_factor: 2.0,
            pack_unpack_factor: 2.0,
            pack_swap_margin: 0.25,
            ..PolicyConfig::default()
        }
    }

    #[test]
    fn pack_admits_exactly_at_both_thresholds() {
        let cfg = packing_cfg();
        let epoch = 1.0;
        // Fit gate at equality: backlog * headroom == epoch.
        assert!(should_pack(0.5, epoch, 1.0, 0.0, &cfg), "fit bound is inclusive");
        assert!(!should_pack(0.5 + 1e-12, epoch, 1.0, 0.0, &cfg), "just past it: declined");
        // Swap-amortization gate at equality: switch == margin * quantum.
        assert!(should_pack(0.25, epoch, 1.0, 0.25, &cfg), "swap bound is inclusive");
        assert!(!should_pack(0.25, epoch, 1.0, 0.25 + 1e-12, &cfg), "just past it: declined");
        // Both gates exactly at their bounds simultaneously.
        assert!(should_pack(0.5, epoch, 1.0, 0.25, &cfg));
    }

    #[test]
    fn unpack_declines_exactly_at_its_threshold() {
        let cfg = packing_cfg();
        let epoch = 1.0;
        // Unpack bound: combined * headroom > unpack_factor * epoch,
        // strict — equality holds the pack (no churn at the edge).
        assert!(!should_unpack(1.0, epoch, &cfg), "unpack bound is exclusive");
        assert!(should_unpack(1.0 + 1e-12, epoch, &cfg), "just past it: unpack");
    }

    #[test]
    fn infinity_disables_both_gates() {
        // The default INFINITY headroom disables packing outright…
        let off = PolicyConfig::default();
        assert!(!off.packing_enabled());
        assert!(!should_pack(0.0, f64::INFINITY, f64::INFINITY, 0.0, &off));
        assert!(!should_unpack(f64::INFINITY, 1.0, &off));
        // …and with packing on, INFINITY operands still behave: an
        // infinite epoch admits any finite backlog, an infinite
        // backlog can never pack and always unpacks.
        let on = packing_cfg();
        assert!(should_pack(1e300, f64::INFINITY, 1.0, 0.0, &on));
        assert!(!should_pack(f64::INFINITY, 1.0, 1.0, 0.0, &on), "inf backlog never fits");
        assert!(should_unpack(f64::INFINITY, 1.0, &on));
    }

    // ---- SLO backlog boost -----------------------------------------------

    #[test]
    fn slo_boost_is_the_exact_identity_without_a_deadline() {
        assert_eq!(slo_backlog_boost(None, 0.05), 1.0);
        // Deadlines at or above one epoch boost nothing.
        assert_eq!(slo_backlog_boost(Some(0.05), 0.05), 1.0);
        assert_eq!(slo_backlog_boost(Some(1.0), 0.05), 1.0);
    }

    #[test]
    fn slo_boost_scales_sub_epoch_deadlines() {
        assert_eq!(slo_backlog_boost(Some(0.01), 0.05), 5.0);
        assert_eq!(slo_backlog_boost(Some(0.025), 0.05), 2.0);
        // Degenerate deadlines and epochs never produce a non-finite
        // or sub-unit multiplier.
        assert_eq!(slo_backlog_boost(Some(0.0), 0.05), 1.0);
        assert_eq!(slo_backlog_boost(Some(-1.0), 0.05), 1.0);
        assert_eq!(slo_backlog_boost(Some(f64::INFINITY), 0.05), 1.0);
        assert_eq!(slo_backlog_boost(Some(0.01), f64::INFINITY), 1.0);
    }
}
