//! Multi-board fabrics: a cluster of M [`FabricEngine`]s with a
//! placement layer and cross-board tenant migration.
//!
//! A FILCO deployment larger than one device is M independent boards,
//! each running its own reconfigurable fabric. [`FabricCluster`] owns
//! one engine per board (built with
//! [`FabricEngine::new_on_board`](super::FabricEngine::new_on_board),
//! so shared-cache lookups are board-tagged), holds the *global*
//! arrival stream, and routes each arrival to its tenant's current
//! host board through [`FabricEngine::push`]. Time is global: the
//! cluster's [`FabricCluster::next_time`] is the min over the global
//! arrival stream and every board's own next event, and
//! [`FabricCluster::step`] steps every board to the same fabric
//! instant.
//!
//! # Placement and migration
//!
//! Tenants land on boards by declared fabric share (first-fit, see
//! [`first_fit_placement`]); every later residency change is a
//! [`ClusterTransition`] applied at exactly one site
//! ([`FabricCluster::apply`]) — mirroring the engine's own
//! `Transition` discipline. A per-epoch imbalance signal (max/min
//! board backlog ratio with hysteresis, [`ClusterPolicy`]) triggers at
//! most one cross-board migration per placement epoch: the tenant's
//! (possibly mid-DAG) batch cursor is checkpointed by
//! [`FabricEngine::remove_tenant`](super::FabricEngine::remove_tenant),
//! its queue and token bucket move wholesale, and
//! [`FabricEngine::install_tenant`](super::FabricEngine::install_tenant)
//! charges the configured migration cost to the newcomer only. The
//! move is lossless: an undisturbed batch's final consumed fabric time
//! equals its solo walk plus exactly the migration charge (asserted on
//! `f64`s in `rust/tests/serve_cluster.rs`).
//!
//! # The deterministic merged trace
//!
//! Engine events carry board-local tenant indices; the cluster
//! translates them to global indices at its per-step drain point
//! (residency is constant within a step — migrations land after the
//! drain) and buckets each board's chunk under the *step instant*.
//! [`merge_board_streams`] then stably sorts buckets by `(instant,
//! board)` using `f64::total_cmp` — no float arithmetic anywhere in
//! the merge — so the merged trace is a deterministic function of the
//! per-board streams, invariant under the order boards were stepped
//! or drained in (property-tested under stream permutation).
//!
//! # Cluster-of-1 is the single engine, bit for bit
//!
//! With one board, placement puts every tenant on board 0 in spec
//! order, the per-step push/step orchestration reproduces the single
//! engine's ingest-inside-step event order exactly (the
//! [`FabricEngine::set_external_pending`](super::FabricEngine::set_external_pending)
//! flag keeps its epoch gating identical), the merge degenerates to
//! concatenation, and the merged report is a field-by-field scatter.
//! `rust/tests/serve_cluster.rs` asserts trace, report and every
//! histogram equal (`==` on `f64`s) against the plain single-engine
//! simulator across the seed matrix.

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::platform::Platform;

use super::cache::ScheduleCache;
use super::engine::{EngineEvent, FabricEngine};
use super::sim::{report_from_engine, ServeReport, Strategy};
use super::telemetry::EpochSample;
use super::tenant::{Arrival, TenantSpec};

/// Identity of one board (one physical fabric) in a cluster. Plain
/// index: board `b` is `engines[b]`, and every [`EngineEvent`] bucket,
/// [`EpochSample::board`] tag and [`EngineEvent::Migrated`] endpoint
/// uses it directly.
pub type BoardId = usize;

/// Knobs of the cluster placement layer: when to evaluate imbalance
/// and when a migration is worth its cost.
///
/// The imbalance signal is the ratio of the most- to least-backlogged
/// board's queued work (an empty board against a non-empty one reads
/// as infinite). Hysteresis is an armed flag: a migration fires only
/// while armed and the ratio is at or above [`Self::imbalance_hi`];
/// firing disarms, and the trigger re-arms only once the ratio falls
/// to [`Self::imbalance_lo`] or below — so a single persistent skew
/// cannot thrash tenants back and forth between boards.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterPolicy {
    /// Fabric seconds between placement-epoch evaluations.
    pub epoch_s: f64,
    /// Fire threshold on the max/min board backlog ratio (while armed).
    pub imbalance_hi: f64,
    /// Re-arm threshold: the ratio must fall to this or below after a
    /// migration before another can fire.
    pub imbalance_lo: f64,
    /// Fabric seconds charged to a migrated tenant on its destination
    /// board (onto the in-flight cursor's ledger when mid-DAG, onto
    /// its availability when idle).
    pub migration_cost_s: f64,
    /// Minimum queued fabric seconds a tenant must hold to be a
    /// migration candidate (don't move tenants that carry no work).
    pub min_gain_s: f64,
}

impl Default for ClusterPolicy {
    fn default() -> Self {
        Self {
            epoch_s: 1.0,
            imbalance_hi: 4.0,
            imbalance_lo: 1.5,
            migration_cost_s: 1e-6,
            min_gain_s: 0.0,
        }
    }
}

impl ClusterPolicy {
    /// A policy calibrated to a scenario's measured per-request
    /// service time, like
    /// [`PolicyConfig::calibrated`](super::policy::PolicyConfig::calibrated):
    /// evaluate every 5 requests' worth of fabric time, charge a
    /// quarter-request migration cost.
    pub fn calibrated(per_request_s: f64) -> Self {
        Self {
            epoch_s: 5.0 * per_request_s,
            migration_cost_s: 0.25 * per_request_s,
            ..Self::default()
        }
    }
}

/// A cluster-level residency change. Every way a tenant's host board
/// can be (re)assigned is one of these, and all of them are applied at
/// exactly one site — [`FabricCluster::apply`] — mirroring the
/// engine's own `Transition` discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterTransition {
    /// Assign `tenant` to `board` at construction time, before the
    /// board engines are built. Refused once they are — later moves
    /// are [`Self::Migrate`]s.
    Place {
        /// Global tenant index.
        tenant: usize,
        /// Destination board.
        board: BoardId,
    },
    /// Move `tenant` from its current board to `to`, checkpointing a
    /// mid-DAG batch losslessly and charging the policy's migration
    /// cost on arrival.
    Migrate {
        /// Global tenant index.
        tenant: usize,
        /// Destination board.
        to: BoardId,
    },
}

/// First-fit placement of tenants onto `boards` boards by declared
/// fabric share.
///
/// A tenant's share is its [`RateLimit::fabric_share`](super::tenant::RateLimit)
/// when declared, else `1/boards` (an undeclared tenant is assumed to
/// need an equal slice of the cluster). Tenants are taken in spec
/// order: each goes to the first board whose accumulated share stays
/// within one board's capacity (1.0), overflowing to the least-loaded
/// board (lowest index on ties). A post-pass donates the
/// highest-index tenant of the most-populated board to any board left
/// empty, so every board starts with at least one resident — which is
/// why `boards` may not exceed the tenant count.
pub fn first_fit_placement(tenants: &[TenantSpec], boards: usize) -> Result<Vec<usize>, String> {
    if boards == 0 {
        return Err("a cluster needs at least one board".into());
    }
    if tenants.is_empty() {
        return Err("no tenants".into());
    }
    if boards > tenants.len() {
        return Err(format!(
            "{} boards exceed {} tenants (every board needs a resident)",
            boards,
            tenants.len()
        ));
    }
    let share = |t: &TenantSpec| {
        t.rate_limit.map(|r| r.fabric_share).unwrap_or(1.0 / boards as f64).max(0.0)
    };
    let mut load = vec![0.0f64; boards];
    let mut count = vec![0usize; boards];
    let mut assign = vec![0usize; tenants.len()];
    for (i, t) in tenants.iter().enumerate() {
        let s = share(t);
        let b = (0..boards).find(|&b| load[b] + s <= 1.0 + 1e-12).unwrap_or_else(|| {
            (0..boards).fold(0, |best, b| if load[b] < load[best] { b } else { best })
        });
        assign[i] = b;
        load[b] += s;
        count[b] += 1;
    }
    while let Some(empty) = (0..boards).find(|&b| count[b] == 0) {
        let donor = (0..boards).fold(0, |best, b| if count[b] > count[best] { b } else { best });
        let t = (0..tenants.len())
            .rev()
            .find(|&t| assign[t] == donor)
            .expect("the most-populated board has a resident");
        assign[t] = empty;
        count[donor] -= 1;
        count[empty] += 1;
        load[donor] -= share(&tenants[t]);
        load[empty] += share(&tenants[t]);
    }
    Ok(assign)
}

/// Order-stable deterministic merge of per-board event streams into
/// one global trace.
///
/// Each stream is `(board, buckets)` where a bucket is `(instant,
/// events)` — the events one board emitted at one step instant, in
/// emission order, already translated to global tenant indices.
/// Buckets are stably sorted by `(instant, board)` with
/// `f64::total_cmp` and concatenated; no float arithmetic happens
/// anywhere in the merge, so the result is bit-identical regardless
/// of the order streams are supplied in (property-tested under
/// permutation) — and a single stream passes through unchanged, which
/// is what makes the cluster-of-1 trace equal the single engine's.
pub fn merge_board_streams(
    streams: Vec<(BoardId, Vec<(f64, Vec<EngineEvent>)>)>,
) -> Vec<EngineEvent> {
    let mut flat: Vec<(f64, BoardId, Vec<EngineEvent>)> = Vec::new();
    for (board, buckets) in streams {
        for (t, chunk) in buckets {
            if !chunk.is_empty() {
                flat.push((t, board, chunk));
            }
        }
    }
    flat.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    flat.into_iter().flat_map(|(_, _, chunk)| chunk).collect()
}

/// Rewrite one board-local event onto cluster-global tenant indices
/// (`residents` maps the board's local index to the global one).
/// `Resplit` weights are per-partition-group on that board and stay
/// raw; `Unified` and `Migrated` carry no local indices.
fn globalize(ev: EngineEvent, residents: &[usize]) -> EngineEvent {
    match ev {
        EngineEvent::Admitted { tenant, id, at_s } => {
            EngineEvent::Admitted { tenant: residents[tenant], id, at_s }
        }
        EngineEvent::BatchStarted { tenant, n, at_s } => {
            EngineEvent::BatchStarted { tenant: residents[tenant], n, at_s }
        }
        EngineEvent::BatchDone { tenant, n, at_s, consumed_s } => {
            EngineEvent::BatchDone { tenant: residents[tenant], n, at_s, consumed_s }
        }
        EngineEvent::Rejected { tenant, at_s } => {
            EngineEvent::Rejected { tenant: residents[tenant], at_s }
        }
        EngineEvent::Throttled { tenant, at_s } => {
            EngineEvent::Throttled { tenant: residents[tenant], at_s }
        }
        EngineEvent::Preempted { tenant, at_s } => {
            EngineEvent::Preempted { tenant: residents[tenant], at_s }
        }
        EngineEvent::PackHandoff { tenant, consumed_s, at_s } => {
            EngineEvent::PackHandoff { tenant: residents[tenant], consumed_s, at_s }
        }
        EngineEvent::Packed { members, at_s } => EngineEvent::Packed {
            members: members.into_iter().map(|t| residents[t]).collect(),
            at_s,
        },
        EngineEvent::Unpacked { members, at_s } => EngineEvent::Unpacked {
            members: members.into_iter().map(|t| residents[t]).collect(),
            at_s,
        },
        other @ (EngineEvent::Resplit { .. }
        | EngineEvent::Unified { .. }
        | EngineEvent::Migrated { .. }) => other,
    }
}

/// Scatter per-board reports into one cluster-global [`ServeReport`].
///
/// Per-tenant state (queues, histograms, counters) travels wholesale
/// with a migrating tenant, so at the end of a run each tenant's
/// numbers live entirely on its final board: the merge is a pure
/// scatter through the residency maps plus exact integer sums and an
/// `f64::max` over completions — no float addition, so a one-board
/// merge is bit-identical to that board's own report.
pub(crate) fn merge_reports(
    label: &str,
    per_board: &[ServeReport],
    residents: &[Vec<usize>],
    n_tenants: usize,
) -> ServeReport {
    let mut served = vec![0u64; n_tenants];
    let mut rejected = vec![0u64; n_tenants];
    let mut throttled = vec![0u64; n_tenants];
    let mut slo_met = vec![0u64; n_tenants];
    let mut slo_missed = vec![0u64; n_tenants];
    let mut slo_deadline_s: Vec<Option<f64>> = vec![None; n_tenants];
    let mut histograms: Vec<Option<LatencyHistogram>> = vec![None; n_tenants];
    let mut pack_group_sizes = Vec::new();
    for (b, rep) in per_board.iter().enumerate() {
        for (l, &g) in residents[b].iter().enumerate() {
            served[g] = rep.served[l];
            rejected[g] = rep.rejected[l];
            throttled[g] = rep.throttled[l];
            slo_met[g] = rep.slo_met[l];
            slo_missed[g] = rep.slo_missed[l];
            slo_deadline_s[g] = rep.slo_deadline_s[l];
            histograms[g] = Some(rep.histograms[l].clone());
        }
        pack_group_sizes.extend(rep.pack_group_sizes.iter().copied());
    }
    ServeReport {
        strategy: label.to_string(),
        completion_s: per_board.iter().map(|r| r.completion_s).fold(f64::NEG_INFINITY, f64::max),
        served,
        rejected,
        throttled,
        switches: per_board.iter().map(|r| r.switches).sum(),
        preemptions: per_board.iter().map(|r| r.preemptions).sum(),
        packs: per_board.iter().map(|r| r.packs).sum(),
        unpacks: per_board.iter().map(|r| r.unpacks).sum(),
        pack_swaps: per_board.iter().map(|r| r.pack_swaps).sum(),
        pack_group_sizes,
        epochs: per_board.iter().map(|r| r.epochs).sum(),
        histograms: histograms
            .into_iter()
            .map(|h| h.expect("every tenant resides on exactly one board"))
            .collect(),
        slo_deadline_s,
        slo_met,
        slo_missed,
    }
}

/// Outcome of one cluster run: the merged global [`ServeReport`] plus
/// the per-board breakdown the multi-board bench and CLI read.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// The cluster-global report (global tenant indexing).
    pub report: ServeReport,
    /// Each board's own report over its final residents (board-local
    /// tenant indexing; translate through [`Self::residents`]).
    pub per_board: Vec<ServeReport>,
    /// Final residency: `residents[b][l]` is the global index of board
    /// `b`'s local tenant `l`.
    pub residents: Vec<Vec<usize>>,
    /// Cross-board migrations performed.
    pub migrations: u64,
    /// Placement epochs evaluated (0 on a single board).
    pub placement_epochs: u64,
}

impl ClusterReport {
    /// Worst per-tenant p99 across the worst board — the multi-board
    /// tail metric the bench snapshots.
    pub fn worst_board_p99_s(&self) -> f64 {
        self.per_board.iter().map(ServeReport::worst_p99_s).fold(0.0, f64::max)
    }
}

/// M boards, one [`FabricEngine`] each, behind a single global clock —
/// the serve stack's cluster abstraction (see the module docs for the
/// time model, the merge discipline and the cluster-of-1 guarantee).
pub struct FabricCluster {
    engines: Vec<FabricEngine>,
    /// Per board: local tenant index → global tenant index.
    residents: Vec<Vec<usize>>,
    /// Global tenant index → (board, local index).
    locate: Vec<(BoardId, usize)>,
    /// The global arrival stream (sorted by `t_s`) and its cursor.
    arrivals: Vec<Arrival>,
    ai: usize,
    /// `None` on a single board (no peer to migrate to, and the
    /// cluster-of-1 trace must not carry placement epochs).
    policy: Option<ClusterPolicy>,
    next_epoch: f64,
    armed: bool,
    migrations: u64,
    placement_epochs: u64,
    now: f64,
    label: String,
    tracing: bool,
    /// Per-board trace buckets keyed by step instant, plus one
    /// pseudo-stream at index `boards` for cluster-emitted
    /// [`EngineEvent::Migrated`] events (sorting after every board at
    /// the same instant).
    streams: Vec<Vec<(f64, Vec<EngineEvent>)>>,
}

impl FabricCluster {
    /// Build a cluster of `boards` boards serving `tenants` under
    /// `strategy` (each board runs the strategy over its residents;
    /// `Unified` boards compose their residents into one accelerator
    /// each and refuse migration). Tenants are placed by
    /// [`first_fit_placement`] through the [`ClusterTransition::Place`]
    /// arm of [`Self::apply`]; `arrivals` is the global trace the
    /// cluster routes itself. `cluster_policy` enables the placement
    /// epoch / migration layer and is ignored on a single board.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        platform: Platform,
        base: FilcoConfig,
        tenants: Vec<TenantSpec>,
        strategy: &Strategy,
        switch_cost_s: Option<f64>,
        arrivals: Vec<Arrival>,
        boards: usize,
        cluster_policy: Option<ClusterPolicy>,
        cache: &ScheduleCache,
    ) -> Result<Self, String> {
        let assignment = first_fit_placement(&tenants, boards)?;
        if let Some(p) = &cluster_policy {
            if p.epoch_s <= 0.0 || p.epoch_s.is_nan() {
                return Err("cluster policy epoch_s must be positive".into());
            }
        }
        let policy = if boards > 1 { cluster_policy } else { None };
        let next_epoch = policy.map(|p| p.epoch_s).unwrap_or(f64::INFINITY);
        let mut cluster = Self {
            engines: Vec::new(),
            residents: vec![Vec::new(); boards],
            locate: vec![(0, 0); tenants.len()],
            arrivals,
            ai: 0,
            policy,
            next_epoch,
            armed: true,
            migrations: 0,
            placement_epochs: 0,
            now: 0.0,
            label: strategy.label().to_string(),
            tracing: false,
            streams: Vec::new(),
        };
        for (t, &b) in assignment.iter().enumerate() {
            cluster.apply(ClusterTransition::Place { tenant: t, board: b }, 0.0, cache)?;
        }
        for b in 0..boards {
            let specs: Vec<TenantSpec> =
                cluster.residents[b].iter().map(|&g| tenants[g].clone()).collect();
            let engine = match strategy {
                Strategy::Unified => FabricEngine::new_unified(
                    platform.clone(),
                    base.clone(),
                    specs,
                    switch_cost_s,
                    Vec::new(),
                    cache,
                ),
                Strategy::StaticEqual | Strategy::Dynamic(_) => {
                    let p = match strategy {
                        Strategy::Dynamic(p) => Some(p.clone()),
                        _ => None,
                    };
                    FabricEngine::new_on_board(
                        platform.clone(),
                        base.clone(),
                        specs,
                        p,
                        switch_cost_s,
                        Vec::new(),
                        cache,
                        b,
                    )
                }
            }?;
            cluster.engines.push(engine);
        }
        Ok(cluster)
    }

    /// Number of boards in the cluster.
    pub fn num_boards(&self) -> usize {
        self.engines.len()
    }

    /// Number of tenants across the cluster.
    pub fn num_tenants(&self) -> usize {
        self.locate.len()
    }

    /// The board currently hosting global tenant `t`, and `t`'s local
    /// index on it.
    pub fn locate(&self, t: usize) -> (BoardId, usize) {
        self.locate[t]
    }

    /// Per-board residency: `residents()[b][l]` is the global index of
    /// board `b`'s local tenant `l`.
    pub fn residents(&self) -> &[Vec<usize>] {
        &self.residents
    }

    /// Cross-board migrations performed so far.
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    /// Placement epochs evaluated so far (0 on a single board).
    pub fn placement_epochs(&self) -> u64 {
        self.placement_epochs
    }

    /// Fabric seconds consumed on global tenant `t`'s behalf, read
    /// from its current host board (the ledger migrates with the
    /// tenant, so this is its cluster-lifetime total).
    pub fn fabric_s(&self, t: usize) -> f64 {
        let (b, l) = self.locate[t];
        self.engines[b].fabric_s(l)
    }

    /// Record the merged global event trace for [`Self::take_trace`]
    /// (off by default). Enable before the first step.
    pub fn record_trace(&mut self, on: bool) {
        self.tracing = on;
        self.streams = if on { vec![Vec::new(); self.engines.len() + 1] } else { Vec::new() };
        for engine in &mut self.engines {
            engine.record_trace(on);
        }
    }

    /// The merged global trace recorded so far: every board's events
    /// translated to global tenant indices plus the cluster's
    /// `Migrated` events, merged by [`merge_board_streams`]. Detaches
    /// recording.
    pub fn take_trace(&mut self) -> Vec<EngineEvent> {
        let streams = std::mem::take(&mut self.streams);
        self.tracing = false;
        for engine in &mut self.engines {
            engine.record_trace(false);
        }
        merge_board_streams(streams.into_iter().enumerate().collect())
    }

    /// Record every board's epoch-metrics timeline (off by default);
    /// samples carry their [`EpochSample::board`] tag.
    pub fn record_timeline(&mut self, on: bool) {
        for engine in &mut self.engines {
            engine.record_timeline(on);
        }
    }

    /// The boards' epoch samples, merged into one global timeline by
    /// the same `(instant, board)` stable order as the event merge.
    pub fn take_timeline(&mut self) -> Vec<EpochSample> {
        let mut flat: Vec<EpochSample> = Vec::new();
        for engine in &mut self.engines {
            flat.extend(engine.take_timeline());
        }
        flat.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.board.cmp(&b.board)));
        flat
    }

    /// Step shard workers per board (see
    /// [`FabricEngine::set_shards`](super::FabricEngine::set_shards)).
    pub fn set_shards(&mut self, n: usize) {
        for engine in &mut self.engines {
            engine.set_shards(n);
        }
    }

    /// Earliest fabric instant at which anything can happen on any
    /// board: the next unrouted global arrival, every board's own next
    /// event, and (multi-board, with a policy) the next placement
    /// epoch while the cluster still holds or expects work.
    pub fn next_time(&self) -> Option<f64> {
        let mut next = f64::INFINITY;
        if self.ai < self.arrivals.len() {
            next = next.min(self.arrivals[self.ai].t_s);
        }
        for engine in &self.engines {
            if let Some(t) = engine.next_time() {
                next = next.min(t);
            }
        }
        if self.policy.is_some() && self.next_epoch.is_finite() && self.cluster_relevant() {
            next = next.min(self.next_epoch);
        }
        next.is_finite().then_some(next)
    }

    /// Is there anything left for a placement epoch to look at?
    fn cluster_relevant(&self) -> bool {
        self.ai < self.arrivals.len() || self.engines.iter().any(FabricEngine::has_work)
    }

    /// Advance the whole cluster to fabric instant `now`: route due
    /// global arrivals to their tenants' host boards, step every board
    /// (ascending), drain the per-board traces into the merge buckets,
    /// then run the placement epoch if one is due. Returns this step's
    /// events across all boards (board-ascending, global indices) plus
    /// any migration — admission events go to the trace only, exactly
    /// like [`FabricEngine::step`].
    pub fn step(&mut self, now: f64, cache: &ScheduleCache) -> Vec<EngineEvent> {
        let now = now.max(self.now);
        self.now = now;
        // External-pending is computed *before* this step's pushes, so
        // each board's epoch gating sees exactly what a single engine
        // ingesting the same trace inside its own step would see.
        let pre = self.ai < self.arrivals.len();
        for engine in &mut self.engines {
            engine.set_external_pending(pre);
        }
        while self.ai < self.arrivals.len() && self.arrivals[self.ai].t_s <= now {
            let a = self.arrivals[self.ai];
            self.ai += 1;
            let (b, l) = self.locate[a.tenant];
            let _ = self.engines[b].push(l, a.id, a.t_s);
        }
        let mut per_board: Vec<Vec<EngineEvent>> = Vec::with_capacity(self.engines.len());
        for engine in &mut self.engines {
            per_board.push(engine.step(now, cache));
        }
        // Post-push truth, so each board's `next_time` epoch gating
        // matches a single engine's post-ingest `trace_pending`.
        let post = self.ai < self.arrivals.len();
        for engine in &mut self.engines {
            engine.set_external_pending(post);
        }
        if self.tracing {
            for b in 0..self.engines.len() {
                let chunk = self.engines[b].drain_trace();
                if !chunk.is_empty() {
                    let translated =
                        chunk.into_iter().map(|e| globalize(e, &self.residents[b])).collect();
                    self.streams[b].push((now, translated));
                }
            }
        }
        let mut out = Vec::new();
        for (b, events) in per_board.into_iter().enumerate() {
            out.extend(events.into_iter().map(|e| globalize(e, &self.residents[b])));
        }
        if self.policy.is_some() && now >= self.next_epoch {
            if let Some(ev) = self.placement_epoch(now, cache) {
                out.push(ev);
            }
            self.placement_epochs += 1;
            let epoch = self.policy.as_ref().map(|p| p.epoch_s).unwrap_or(f64::INFINITY);
            while self.next_epoch <= now {
                self.next_epoch += epoch;
            }
        }
        out
    }

    /// Retire everything still in flight on every board (ascending)
    /// after [`Self::next_time`] returns `None` — the cluster's
    /// [`FabricEngine::finish`](super::FabricEngine::finish). Final
    /// trace buckets are keyed at `f64::INFINITY`, after every step
    /// instant.
    pub fn finish(&mut self) -> Vec<EngineEvent> {
        let mut out = Vec::new();
        for b in 0..self.engines.len() {
            let events = self.engines[b].finish();
            if self.tracing {
                let chunk = self.engines[b].drain_trace();
                if !chunk.is_empty() {
                    let translated =
                        chunk.into_iter().map(|e| globalize(e, &self.residents[b])).collect();
                    self.streams[b].push((f64::INFINITY, translated));
                }
            }
            out.extend(events.into_iter().map(|e| globalize(e, &self.residents[b])));
        }
        out
    }

    /// The cluster-global [`ServeReport`]: per-board reports scattered
    /// through the residency maps (see [`merge_reports`]'s exactness
    /// note — one board merges bit-for-bit).
    pub fn report(&self) -> ServeReport {
        let per_board: Vec<ServeReport> =
            self.engines.iter().map(|e| report_from_engine(e, &self.label)).collect();
        merge_reports(&self.label, &per_board, &self.residents, self.locate.len())
    }

    /// Each board's own [`ServeReport`] over its residents (local
    /// tenant indexing; pair with [`Self::residents`]) — what the
    /// bench's per-board scaling and worst-board tails read.
    pub fn board_reports(&self) -> Vec<ServeReport> {
        self.engines.iter().map(|e| report_from_engine(e, &self.label)).collect()
    }

    /// The full [`ClusterReport`]: the merged global report, the
    /// per-board breakdown, final residency and migration counters.
    pub fn cluster_report(&self) -> ClusterReport {
        ClusterReport {
            report: self.report(),
            per_board: self.board_reports(),
            residents: self.residents.clone(),
            migrations: self.migrations,
            placement_epochs: self.placement_epochs,
        }
    }

    /// Apply one cluster transition — the single site every residency
    /// change goes through. `Place` is construction-only; `Migrate`
    /// checkpoints the tenant off its current board, installs it on
    /// `to` (charging the policy's migration cost there), updates the
    /// residency maps, and returns the [`EngineEvent::Migrated`]
    /// recorded into the merged trace.
    pub fn apply(
        &mut self,
        tr: ClusterTransition,
        now: f64,
        cache: &ScheduleCache,
    ) -> Result<Option<EngineEvent>, String> {
        match tr {
            ClusterTransition::Place { tenant, board } => {
                if !self.engines.is_empty() {
                    return Err("placement is fixed once boards are built (use Migrate)".into());
                }
                if board >= self.residents.len() {
                    return Err(format!("no board {board}"));
                }
                if tenant >= self.locate.len() {
                    return Err(format!("no tenant {tenant}"));
                }
                let local = self.residents[board].len();
                self.residents[board].push(tenant);
                self.locate[tenant] = (board, local);
                Ok(None)
            }
            ClusterTransition::Migrate { tenant, to } => {
                if tenant >= self.locate.len() {
                    return Err(format!("no tenant {tenant}"));
                }
                if to >= self.engines.len() {
                    return Err(format!("no board {to}"));
                }
                let (from, local) = self.locate[tenant];
                if from == to {
                    return Err(format!("tenant {tenant} already resides on board {to}"));
                }
                if !self.engines[to].can_host_migrant() {
                    return Err(format!("board {to} cannot host a migrant right now"));
                }
                let cost = self.policy.map(|p| p.migration_cost_s).unwrap_or(0.0);
                let ex = self.engines[from].remove_tenant(local, now, cache)?;
                let consumed_s = ex.inflight_consumed_s();
                let new_local = self.engines[to].install_tenant(ex, now, cost, cache)?;
                self.residents[from].remove(local);
                for l in local..self.residents[from].len() {
                    let g = self.residents[from][l];
                    self.locate[g] = (from, l);
                }
                self.residents[to].push(tenant);
                self.locate[tenant] = (to, new_local);
                debug_assert_eq!(new_local + 1, self.residents[to].len());
                self.migrations += 1;
                let ev = EngineEvent::Migrated { tenant, from, to, consumed_s, at_s: now };
                if self.tracing {
                    let pseudo = self.engines.len();
                    self.streams[pseudo].push((now, vec![ev.clone()]));
                }
                Ok(Some(ev))
            }
        }
    }

    /// One placement-epoch evaluation: compute per-board queued
    /// backlog, check the hysteresis-gated imbalance trigger, and
    /// perform at most one migration (the candidate from the
    /// most-backlogged board that minimizes the post-move worse side,
    /// provided it strictly improves on the current max).
    fn placement_epoch(&mut self, now: f64, cache: &ScheduleCache) -> Option<EngineEvent> {
        let p = self.policy?;
        let nb = self.engines.len();
        let mut backlog = vec![0.0f64; nb];
        for (b, engine) in self.engines.iter().enumerate() {
            for l in 0..engine.num_tenants() {
                backlog[b] += engine.pending_len(l) as f64 * engine.per_request_s(l);
            }
        }
        let max = backlog.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let min = backlog.iter().copied().fold(f64::INFINITY, f64::min);
        let ratio = if min <= 0.0 {
            if max > 0.0 {
                f64::INFINITY
            } else {
                0.0
            }
        } else {
            max / min
        };
        if ratio <= p.imbalance_lo {
            self.armed = true;
        }
        if !self.armed || ratio < p.imbalance_hi {
            return None;
        }
        let src = (0..nb).fold(0, |best, b| if backlog[b] > backlog[best] { b } else { best });
        let dst = (0..nb).fold(0, |best, b| if backlog[b] < backlog[best] { b } else { best });
        if src == dst || !self.engines[src].migratable() || !self.engines[dst].can_host_migrant()
        {
            return None;
        }
        let mut best: Option<(f64, usize)> = None;
        for (l, &g) in self.residents[src].iter().enumerate() {
            let bt = self.engines[src].pending_len(l) as f64 * self.engines[src].per_request_s(l);
            if bt < p.min_gain_s {
                continue;
            }
            let post = (backlog[src] - bt).max(backlog[dst] + bt);
            if post >= backlog[src] {
                continue;
            }
            let better = match best {
                None => true,
                Some((bp, bg)) => post < bp || (post == bp && g < bg),
            };
            if better {
                best = Some((post, g));
            }
        }
        let (_, tenant) = best?;
        match self.apply(ClusterTransition::Migrate { tenant, to: dst }, now, cache) {
            Ok(ev) => {
                self.armed = false;
                ev
            }
            Err(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;
    use crate::workload::zoo;

    fn spec(name: &str) -> TenantSpec {
        TenantSpec::new(name, zoo::mlp_s())
    }

    #[test]
    fn one_board_places_everyone_on_it_in_order() {
        let tenants = vec![spec("a"), spec("b"), spec("c")];
        assert_eq!(first_fit_placement(&tenants, 1).unwrap(), vec![0, 0, 0]);
    }

    #[test]
    fn declared_shares_drive_first_fit() {
        // 0.5 + 0.5 fill board 0; the third share opens board 1.
        let tenants = vec![
            spec("a").with_fabric_share(0.5, 1.0),
            spec("b").with_fabric_share(0.5, 1.0),
            spec("c").with_fabric_share(0.5, 1.0),
        ];
        assert_eq!(first_fit_placement(&tenants, 2).unwrap(), vec![0, 0, 1]);
    }

    #[test]
    fn overflow_goes_to_the_least_loaded_board() {
        let tenants = vec![
            spec("a").with_fabric_share(0.9, 1.0),
            spec("b").with_fabric_share(0.6, 1.0),
            spec("c").with_fabric_share(0.9, 1.0),
        ];
        // a → board 0 (0.9); b → board 1 (0.6); c fits nowhere and
        // overflows to the least-loaded board (1).
        assert_eq!(first_fit_placement(&tenants, 2).unwrap(), vec![0, 1, 1]);
    }

    #[test]
    fn post_pass_fills_empty_boards() {
        // Tiny shares all land on board 0; the post-pass donates the
        // highest-index tenant to the empty board.
        let tenants = vec![
            spec("a").with_fabric_share(0.1, 1.0),
            spec("b").with_fabric_share(0.1, 1.0),
            spec("c").with_fabric_share(0.1, 1.0),
        ];
        assert_eq!(first_fit_placement(&tenants, 2).unwrap(), vec![0, 0, 1]);
    }

    #[test]
    fn more_boards_than_tenants_is_refused() {
        let tenants = vec![spec("a"), spec("b")];
        assert!(first_fit_placement(&tenants, 3).is_err());
        assert!(first_fit_placement(&tenants, 0).is_err());
        assert!(first_fit_placement(&[], 1).is_err());
    }

    fn ev(tenant: usize, id: u64, at_s: f64) -> EngineEvent {
        EngineEvent::Admitted { tenant, id, at_s }
    }

    #[test]
    fn merge_is_identity_for_one_stream() {
        let buckets = vec![
            (0.0, vec![ev(0, 0, 0.0), ev(1, 1, 0.0)]),
            (1.5, vec![ev(0, 2, 1.25)]),
            (f64::INFINITY, vec![ev(1, 3, 2.0)]),
        ];
        let merged = merge_board_streams(vec![(0, buckets.clone())]);
        let flat: Vec<EngineEvent> = buckets.into_iter().flat_map(|(_, c)| c).collect();
        assert_eq!(merged, flat);
    }

    #[test]
    fn merge_orders_ties_by_board() {
        let b0 = vec![(1.0, vec![ev(0, 0, 1.0)])];
        let b1 = vec![(1.0, vec![ev(1, 1, 1.0)]), (2.0, vec![ev(1, 2, 2.0)])];
        let merged = merge_board_streams(vec![(1, b1), (0, b0)]);
        assert_eq!(merged, vec![ev(0, 0, 1.0), ev(1, 1, 1.0), ev(1, 2, 2.0)]);
    }

    #[test]
    fn merge_is_invariant_under_stream_permutation() {
        // Random per-board streams on a shared instant grid (so
        // cross-board ties are common), merged after shuffling the
        // stream order: the output must be bit-identical.
        Cases::new(64).run(|rng| {
            let boards = rng.range(2, 5);
            let mut id = 0u64;
            let mut streams: Vec<(usize, Vec<(f64, Vec<EngineEvent>)>)> = Vec::new();
            for b in 0..boards {
                let n_buckets = rng.range(0, 5);
                let mut buckets = Vec::new();
                let mut t = 0.0f64;
                for _ in 0..n_buckets {
                    t += 0.25 * rng.range(0, 3) as f64;
                    let n_ev = rng.range(1, 4);
                    let chunk: Vec<EngineEvent> = (0..n_ev)
                        .map(|_| {
                            id += 1;
                            ev(b, id, t)
                        })
                        .collect();
                    buckets.push((t, chunk));
                }
                streams.push((b, buckets));
            }
            let baseline = merge_board_streams(streams.clone());
            let mut shuffled = streams;
            rng.shuffle(&mut shuffled);
            assert_eq!(merge_board_streams(shuffled), baseline);
        });
    }

    #[test]
    fn globalize_translates_tenant_fields_and_members() {
        let residents = [4usize, 7, 2];
        assert_eq!(
            globalize(ev(1, 9, 3.0), &residents),
            EngineEvent::Admitted { tenant: 7, id: 9, at_s: 3.0 }
        );
        assert_eq!(
            globalize(EngineEvent::Packed { members: vec![0, 2], at_s: 1.0 }, &residents),
            EngineEvent::Packed { members: vec![4, 2], at_s: 1.0 }
        );
        let resplit = EngineEvent::Resplit { weights: vec![2, 1], at_s: 1.0 };
        assert_eq!(globalize(resplit.clone(), &residents), resplit);
    }
}
