//! Live multi-tenant fabric scheduler: thread shells around the shared
//! [`FabricEngine`], paced by a [`WallClock`].
//!
//! The execution semantics — admission control, batching, layer-step
//! interleaving, mid-DAG preemption, cross-tenant packing with
//! mid-flight handoff, and every composition transition — live in the
//! engine, the same deterministic core the virtual-time simulator
//! drains. This module supplies only what a live deployment adds on
//! top:
//!
//! * **producer ingress** — [`FabricScheduler::push`] stamps requests
//!   with the wall-derived fabric instant and feeds the engine's
//!   per-tenant queues under the one engine lock (the modern form of
//!   the old per-tenant plan-lock/preempt-generation discipline: every
//!   plan read and transition now happens under a single lock, so a
//!   phantom preemption is structurally impossible). The lock's cost
//!   is metered ([`LockMeter`] on `push` and [`Self::policy_step`],
//!   surfaced per epoch in the timeline and by
//!   [`Self::stall_stats`]). Historically a schedule-cache *miss*
//!   inside a policy epoch ran the DSE solve while holding the lock,
//!   stalling pushes for the solve's duration; with
//!   [`PolicyConfig::async_solve`] the epoch instead hands the missing
//!   `(config, DAG)` keys to a [`BackgroundSolver`] thread, keeps the
//!   last cached split, and re-proposes at a later epoch — a cold
//!   composition then costs `push` a cache *lookup*, never a solve.
//!   Without async mode, warm the cache (`--cache-file`, or the
//!   equal-split calibration every entry point performs) so the
//!   serving path only ever hits;
//! * **worker shells** — one thread per tenant, all running the same
//!   drive loop: ask the engine for its next fabric instant, let the
//!   [`WallClock`] sleep toward the deadline (`timescale` wall seconds
//!   per fabric second; 0 drains at host speed), then step the engine.
//!   Which thread wins the lock never matters: the engine's decisions
//!   depend only on fabric instants, so a live run replays the
//!   simulator's event trace bit-for-bit (see
//!   `rust/tests/serve_engine.rs`);
//! * **a policy shell** — policy *epochs* fire on the engine's fabric
//!   timeline (wall epochs are converted through the timescale); the
//!   shell thread only relaxes an idle, skewed fabric back to the
//!   equal split between bursts. Only [`LiveMode::Dynamic`] runs a
//!   policy at all: `--strategy static` fixes the equal split and
//!   `--strategy unified` composes the whole fabric into one
//!   round-robin accelerator ([`LiveMode`]), both with the policy
//!   machinery statically disabled;
//! * **wall-clock latency accounting** — fabric-time histograms live in
//!   the engine; the shells record each request's wall latency when its
//!   batch's [`EngineEvent::BatchDone`] fires.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::platform::Platform;

use super::cache::{BackgroundSolver, ScheduleCache};
use super::clock::{Clock, WallClock};
use super::engine::{EngineEvent, FabricEngine};
use super::policy::PolicyConfig;
use super::queue::PushError;
use super::telemetry::{LockMeter, StallStats};
use super::tenant::{Arrival, TenantSpec};

/// Which composition the live scheduler runs — the same three
/// strategies the simulator compares ([`Strategy`](super::Strategy)),
/// selected by `filco serve --strategy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LiveMode {
    /// The whole fabric as one unified accelerator: tenants time-share
    /// it round-robin at batch granularity
    /// ([`FabricEngine::new_unified`]); no policy runs and no
    /// transition is accepted.
    Unified,
    /// Fixed equal split, one partition per tenant, no policy epochs.
    StaticEqual,
    /// Backlog-driven live re-composition via [`LiveConfig::policy`]
    /// (the default).
    #[default]
    Dynamic,
}

/// Live-mode knobs.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Re-composition / preemption / packing policy. `epoch_s` is in
    /// wall seconds; the scheduler converts it onto the engine's
    /// fabric timeline through `timescale` (an unpaced run uses it as
    /// fabric seconds directly). Ignored outside [`LiveMode::Dynamic`].
    pub policy: PolicyConfig,
    /// Composition strategy ([`LiveMode::Dynamic`] by default).
    pub mode: LiveMode,
    /// Wall seconds slept per fabric second to emulate device pacing;
    /// 0.0 drains at host speed (tests).
    pub timescale: f64,
    /// Cap on any single pacing sleep, so demos stay responsive.
    pub max_sleep: Duration,
    /// Shard workers stepping partition units in parallel inside the
    /// engine (1 = step inline). A throughput knob only: traces and
    /// reports are bit-for-bit identical for any value
    /// ([`FabricEngine::set_shards`]).
    pub shards: usize,
    /// Worker threads for the background DSE solver when
    /// [`PolicyConfig::async_solve`] is on (1 = one solver thread, the
    /// legacy behaviour): distinct cold-slice requests drained in one
    /// wake solve concurrently
    /// ([`BackgroundSolver::spawn_pool`](super::BackgroundSolver::spawn_pool)).
    pub dse_workers: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self {
            policy: PolicyConfig::default(),
            mode: LiveMode::Dynamic,
            timescale: 0.0,
            max_sleep: Duration::from_millis(100),
            shards: 1,
            dse_workers: 1,
        }
    }
}

/// One request in the live path.
#[derive(Debug)]
pub struct LiveRequest {
    /// Caller-assigned request id (reporting only).
    pub id: u64,
    /// Wall-clock admission instant; latency is measured from here.
    pub enqueued: Instant,
}

impl LiveRequest {
    /// A request enqueued now.
    pub fn new(id: u64) -> Self {
        Self { id, enqueued: Instant::now() }
    }
}

/// Per-tenant outcome of a live run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Tenant name (from its [`TenantSpec`]).
    pub name: String,
    /// Requests fully served.
    pub served: u64,
    /// Requests refused by the tenant's fabric-time token bucket.
    pub throttled: u64,
    /// Fabric seconds consumed on this tenant's behalf (layer steps,
    /// swap charges while packed, and switch charges while leading a
    /// partition).
    pub fabric_s: f64,
    /// Wall-clock latency distribution of served requests (seconds).
    pub wall_latency: LatencyHistogram,
    /// The tenant's effective latency-SLO deadline in fabric seconds
    /// (`None` for throughput tiers).
    pub slo_deadline_s: Option<f64>,
    /// Served requests that met the deadline on the fabric timeline
    /// (always 0 for throughput tiers).
    pub slo_met: u64,
    /// Served requests that missed it.
    pub slo_missed: u64,
}

impl TenantReport {
    /// Tail wall-clock latency (p99) of this tenant's served requests.
    pub fn p99_s(&self) -> f64 {
        self.wall_latency.p99()
    }

    /// Fraction of served requests that met the latency-SLO deadline
    /// (`1.0` for throughput tiers and when nothing was served).
    pub fn slo_attainment(&self) -> f64 {
        if self.slo_met + self.slo_missed == 0 {
            1.0
        } else {
            self.slo_met as f64 / (self.slo_met + self.slo_missed) as f64
        }
    }
}

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    /// One entry per tenant, in spec order.
    pub tenants: Vec<TenantReport>,
    /// Re-compositions performed (setup split excluded).
    pub switches: u64,
    /// In-flight batches preempted at a layer boundary.
    pub preemptions: u64,
    /// Pack transitions (tenants merged onto a shared partition).
    pub packs: u64,
    /// Unpack transitions (a packed group dissolved after draining).
    pub unpacks: u64,
    /// Cursor context swaps charged by partition interleavers.
    pub pack_swaps: u64,
    /// Batches that executed inside a packed group's interleaver
    /// (admissions and mid-flight handoffs).
    pub packed_batches: u64,
    /// Size of every pack group formed, in transition order.
    pub pack_group_sizes: Vec<usize>,
    /// Schedule-cache activity during this run only (the cache may be
    /// shared with calibration or simulation phases).
    pub cache_hits: u64,
    /// Schedule-cache misses during this run only.
    pub cache_misses: u64,
    /// Wall-clock seconds from [`FabricScheduler::run`] entry to exit.
    pub wall_s: f64,
}

impl LiveReport {
    /// Requests served across every tenant.
    pub fn total_served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    /// Worst per-tenant p99 wall latency (seconds).
    pub fn worst_p99_s(&self) -> f64 {
        self.tenants.iter().map(|t| t.p99_s()).fold(0.0, f64::max)
    }

    /// Worst per-tenant SLO attainment across latency-tier tenants
    /// (`1.0` when no tenant carries a deadline).
    pub fn worst_slo_attainment(&self) -> f64 {
        self.tenants
            .iter()
            .filter(|t| t.slo_deadline_s.is_some())
            .map(TenantReport::slo_attainment)
            .fold(1.0, f64::min)
    }

    /// Multi-line human-readable summary.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for t in &self.tenants {
            let slo = if t.slo_deadline_s.is_some() {
                format!("  slo {:.3}", t.slo_attainment())
            } else {
                String::new()
            };
            s.push_str(&format!(
                "  {:<10} served {:>6}  throttled {:>4}  fabric {:.4e} s  wall {}{}\n",
                t.name,
                t.served,
                t.throttled,
                t.fabric_s,
                t.wall_latency.summary(),
                slo,
            ));
        }
        s.push_str(&format!(
            "  {} re-compositions ({} preemptive) | {} packs {:?}, {} unpacks, {} swaps, \
             {} packed batches | worst p99 {:.3e} s | \
             schedule cache: {} hits, {} misses | {:.2} s wall",
            self.switches,
            self.preemptions,
            self.packs,
            self.pack_group_sizes,
            self.unpacks,
            self.pack_swaps,
            self.packed_batches,
            self.worst_p99_s(),
            self.cache_hits,
            self.cache_misses,
            self.wall_s
        ));
        s
    }
}

/// A point-in-time view of the scheduler's composition, captured under
/// a single engine-lock acquisition by [`FabricScheduler::snapshot`].
/// Per-field accessors would each take the lock separately, so a
/// transition landing between two reads could pair tenant names with
/// another composition's dimensions; the snapshot cannot tear.
#[derive(Debug, Clone)]
pub struct SchedulerSnapshot {
    /// Number of tenants the scheduler serves.
    pub num_tenants: usize,
    /// For each tenant, the tenant whose partition currently hosts it
    /// (itself unless the policy packed it onto another's slice).
    pub hosts: Vec<usize>,
    /// Current composition as `(name, fmus, cus)` triples, in tenant
    /// order. Packed tenants report their shared partition's dimensions.
    pub composition: Vec<(String, u32, u32)>,
    /// The engine's fabric clock at capture time (seconds).
    pub now_s: f64,
}

/// State behind the one engine lock: the deterministic core plus the
/// shell-side bookkeeping that pairs live requests with engine events.
struct Shared {
    engine: FabricEngine,
    /// The wall↔fabric mapping all shells share. Re-anchored
    /// ([`WallClock::resync`]) when a push lands on an idle engine, so
    /// idle wall time is never banked as pacing lead — without that, a
    /// burst after a producer gap would drain unpaced at host speed.
    clock: WallClock,
    /// Admitted-but-unfinished requests per tenant, in engine order
    /// (the engine serves each tenant strictly FIFO, so `BatchDone`
    /// events pop from the front).
    reqs: Vec<VecDeque<LiveRequest>>,
    /// Wall-clock latency histograms, recorded at `BatchDone`.
    hist: Vec<LatencyHistogram>,
    closed: bool,
    finished: bool,
}

/// Live multi-tenant scheduler over a dynamically re-partitioned
/// fabric: producer threads [`Self::push`] into the shared
/// [`FabricEngine`]; worker shells drive it under wall pacing.
pub struct FabricScheduler {
    cache: Arc<ScheduleCache>,
    cfg: LiveConfig,
    shared: Mutex<Shared>,
    cv: Condvar,
    stop_policy: AtomicBool,
    /// Deterministic-ingest mode ([`Self::with_arrivals`]): the engine
    /// consumes its own virtual-time trace and the idle-relaxation
    /// shell stays out of the way, so the run replays the simulator.
    deterministic: bool,
    /// Engine-mutex hold-time meter, fed by [`Self::push`] and
    /// [`Self::policy_step`] and shared with the engine's timeline
    /// sampling.
    lock_meter: Arc<LockMeter>,
    /// The async-DSE solver thread, spawned when the policy opts in
    /// ([`PolicyConfig::async_solve`], [`LiveMode::Dynamic`] only).
    /// Declared after `shared`: the engine's requester channel clone
    /// drops with `shared` first, so the solver's shutdown join can
    /// observe a disconnected queue and terminate.
    background: Option<BackgroundSolver>,
}

impl FabricScheduler {
    /// Build the scheduler: equal initial split (every tenant leads its
    /// own partition), schedules resolved through `cache` (pre-warming
    /// it counts as misses here, hits on every later re-composition
    /// into a seen shape).
    pub fn new(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
    ) -> Result<Self, String> {
        Self::build(platform, base, specs, cache, cfg, Vec::new(), false)
    }

    /// Build a scheduler that ingests `arrivals` (a virtual-time trace,
    /// as the simulator would) instead of external pushes, with engine
    /// event tracing enabled — the deterministic mode the live-vs-sim
    /// differential test runs in. Close it immediately and [`Self::run`];
    /// the trace is retrieved with [`Self::take_trace`] afterwards.
    pub fn with_arrivals(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
        arrivals: Vec<Arrival>,
    ) -> Result<Self, String> {
        Self::build(platform, base, specs, cache, cfg, arrivals, true)
    }

    fn build(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
        arrivals: Vec<Arrival>,
        deterministic: bool,
    ) -> Result<Self, String> {
        let t_n = specs.len();
        // The async-DSE solver works against the same shared cache and
        // platform; spawn it before the engine so the engine can hold
        // a requester channel from construction.
        let background = (cfg.mode == LiveMode::Dynamic && cfg.policy.async_solve).then(|| {
            BackgroundSolver::spawn_pool(platform.clone(), cache.clone(), cfg.dse_workers.max(1))
        });
        let mut engine = match cfg.mode {
            // The unified and static compositions run no policy: the
            // fabric's shape is fixed for the whole run.
            LiveMode::Unified => {
                FabricEngine::new_unified(platform, base, specs, None, arrivals, &cache)?
            }
            LiveMode::StaticEqual => {
                FabricEngine::new(platform, base, specs, None, None, arrivals, &cache)?
            }
            LiveMode::Dynamic => {
                // Policy epochs live on the engine's fabric timeline; a
                // paced run converts the wall-clock epoch through the
                // timescale (an unpaced run drains at host speed, where
                // the configured value is the only meaningful fabric
                // budget).
                let mut policy = cfg.policy.clone();
                if cfg.timescale > 0.0 {
                    policy.epoch_s = cfg.policy.epoch_s / cfg.timescale;
                }
                FabricEngine::new(platform, base, specs, Some(policy), None, arrivals, &cache)?
            }
        };
        engine.eager_completions(true);
        engine.set_shards(cfg.shards);
        let lock_meter = Arc::new(LockMeter::new());
        engine.set_lock_meter(lock_meter.clone());
        if let Some(solver) = &background {
            engine.set_solve_channel(solver.requester());
        }
        if deterministic {
            engine.record_trace(true);
        }
        Ok(Self {
            cache,
            shared: Mutex::new(Shared {
                engine,
                clock: WallClock::new(cfg.timescale, cfg.max_sleep),
                reqs: (0..t_n).map(|_| VecDeque::new()).collect(),
                hist: vec![LatencyHistogram::new(); t_n],
                closed: false,
                finished: false,
            }),
            cv: Condvar::new(),
            stop_policy: AtomicBool::new(false),
            deterministic,
            lock_meter,
            background,
            cfg,
        })
    }

    /// Number of tenants this scheduler serves.
    pub fn num_tenants(&self) -> usize {
        self.shared.lock().unwrap().engine.num_tenants()
    }

    /// A consistent point-in-time view of the composition, read under
    /// one lock acquisition — the accessor callers use instead of
    /// stitching together per-field reads (each of which would take
    /// and release the engine mutex, interleaving with transitions).
    pub fn snapshot(&self) -> SchedulerSnapshot {
        let s = self.shared.lock().unwrap();
        let n = s.engine.num_tenants();
        SchedulerSnapshot {
            num_tenants: n,
            hosts: (0..n).map(|t| s.engine.host(t)).collect(),
            composition: (0..n)
                .map(|t| {
                    let (fmus, cus) = s.engine.dims(t);
                    (s.engine.tenant_name(t).to_string(), fmus, cus)
                })
                .collect(),
            now_s: s.engine.now_s(),
        }
    }

    /// Admission-controlled enqueue for tenant `t`: closed check, then
    /// queue depth, then the tenant's fabric-time token bucket (charged
    /// the request's estimated cost on the current slice) — the same
    /// classification order as the simulator's trace ingest, because it
    /// *is* the engine's one admission path. The engine-lock hold time
    /// is metered into [`Self::stall_stats`] and the epoch timeline.
    pub fn push(&self, t: usize, req: LiveRequest) -> Result<(), PushError> {
        let mut s = self.shared.lock().unwrap();
        let t0 = Instant::now();
        let res = self.push_locked(&mut s, t, req);
        self.lock_meter.record_ns(t0.elapsed().as_nanos() as u64);
        drop(s);
        if res.is_ok() {
            self.cv.notify_all();
        }
        res
    }

    /// The body of [`Self::push`], under the caller-held engine lock.
    fn push_locked(&self, s: &mut Shared, t: usize, req: LiveRequest) -> Result<(), PushError> {
        if s.closed {
            return Err(PushError::Closed);
        }
        // A push onto an idle engine re-anchors the pacing map: the
        // fabric clock stood still while the wall clock ran, and that
        // gap must not be banked as pacing lead.
        if s.clock.timescale() > 0.0 && !s.engine.has_work() && !s.engine.trace_pending() {
            let fabric_now = s.engine.now_s();
            s.clock.resync(fabric_now);
        }
        let arr_s = s.clock.now_s();
        // Catch the engine's fabric clock up to the wall before
        // admitting: with no event instants between (say, one long
        // preempt-off batch in flight), the engine lags wall-fabric
        // time, and a batch started against the lagging clock would
        // execute in the fabric past — unpaced, with a corrupt
        // latency stamp. Never steps past a scheduled instant.
        if s.clock.timescale() > 0.0
            && arr_s > s.engine.now_s()
            && s.engine.next_time().is_none_or(|next| next > arr_s)
        {
            let events = s.engine.step(arr_s, &self.cache);
            Self::record(s, &events);
        }
        s.engine.push(t, req.id, arr_s)?;
        s.reqs[t].push_back(req);
        Ok(())
    }

    /// Close ingress; the run ends once the engine drains.
    pub fn close(&self) {
        self.shared.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    /// Force one policy evaluation at the engine's current fabric
    /// instant (the epoch schedule is untouched). Returns true when
    /// the composition changed. Public so step-driven callers (and
    /// tests) can exercise the policy without the wall-clock loop. The
    /// engine-lock hold time is metered into [`Self::stall_stats`].
    pub fn policy_step(&self) -> bool {
        let mut s = self.shared.lock().unwrap();
        let t0 = Instant::now();
        let changed = s.engine.epoch_now(&self.cache);
        self.lock_meter.record_ns(t0.elapsed().as_nanos() as u64);
        changed
    }

    /// Cumulative contention counters: engine-mutex hold time from
    /// [`Self::push`] and [`Self::policy_step`], and DSE stalls from
    /// the shared schedule cache (which may include other users of the
    /// same cache — share a cache per serving stack to keep this
    /// attribution clean).
    pub fn stall_stats(&self) -> StallStats {
        StallStats {
            lock_held_ns: self.lock_meter.held_ns(),
            lock_holds: self.lock_meter.holds(),
            dse_stall_ns: self.cache.stall_ns(),
            dse_stalls: self.cache.stalls(),
            coalesced_solves: self.cache.coalesced_solves(),
        }
    }

    /// Drop every request still pending for tenant `t` (not yet in a
    /// batch), returning how many were discarded — an operational
    /// shed-load aid, also used by tests to empty a backlog.
    pub fn drain_pending(&self, t: usize) -> usize {
        let mut s = self.shared.lock().unwrap();
        let n = s.engine.drain_pending(t);
        for _ in 0..n {
            s.reqs[t].pop_back();
        }
        n
    }

    /// The engine event trace recorded so far (empty unless built with
    /// [`Self::with_arrivals`]). Call after [`Self::run`] returns.
    pub fn take_trace(&self) -> Vec<EngineEvent> {
        self.shared.lock().unwrap().engine.take_trace()
    }

    /// Enable or disable engine event tracing for this run (on by
    /// construction in [`Self::with_arrivals`]; call before
    /// [`Self::run`] to capture a trace from an externally-pushed live
    /// run, e.g. `filco serve --mode live --trace-out`).
    pub fn record_trace(&self, on: bool) {
        self.shared.lock().unwrap().engine.record_trace(on);
    }

    /// Enable or disable per-epoch timeline sampling
    /// ([`super::telemetry::EpochSample`]). Only meaningful in
    /// [`LiveMode::Dynamic`] — fixed compositions run no policy epochs,
    /// so their timelines stay empty.
    pub fn record_timeline(&self, on: bool) {
        self.shared.lock().unwrap().engine.record_timeline(on);
    }

    /// The epoch samples recorded so far (empty unless
    /// [`Self::record_timeline`] was enabled). Call after [`Self::run`]
    /// returns.
    pub fn take_timeline(&self) -> Vec<super::telemetry::EpochSample> {
        self.shared.lock().unwrap().engine.take_timeline()
    }

    /// The engine-side fabric-time report for this run, in the same
    /// shape the simulator emits ([`super::ServeReport`]) — the footer a
    /// recorded live trace is verified against. Call after
    /// [`Self::run`] returns.
    pub fn serve_report(&self) -> super::ServeReport {
        let label = match self.cfg.mode {
            LiveMode::Unified => "unified",
            LiveMode::StaticEqual => "static-equal",
            LiveMode::Dynamic => "dynamic",
        };
        super::sim::report_from_engine(&self.shared.lock().unwrap().engine, label)
    }

    /// Record wall latencies for the batches an engine step completed.
    fn record(s: &mut Shared, events: &[EngineEvent]) {
        for ev in events {
            if let EngineEvent::BatchDone { tenant, n, .. } = ev {
                for _ in 0..*n {
                    if let Some(req) = s.reqs[*tenant].pop_front() {
                        s.hist[*tenant].record(req.enqueued.elapsed().as_secs_f64());
                    }
                }
            }
        }
    }

    /// The worker shell: one bounded drive pass per iteration — ask
    /// the engine for its next fabric instant; if it is due on the
    /// wall clock, step the engine under the same lock hold, otherwise
    /// wait toward the deadline on the condvar (so an earlier-event
    /// push wakes the shell). Exits once ingress is closed and the
    /// engine has drained.
    fn worker_loop(&self) {
        let max_sleep_s = self.cfg.max_sleep.as_secs_f64().max(1e-3);
        loop {
            let lead_s = {
                let mut s = self.shared.lock().unwrap();
                if s.finished {
                    return;
                }
                let idle = !s.engine.has_work() && !s.engine.trace_pending();
                if idle {
                    if s.closed {
                        let events = s.engine.finish();
                        Self::record(&mut s, &events);
                        s.finished = true;
                        drop(s);
                        self.cv.notify_all();
                        return;
                    }
                    let _ = self.cv.wait_timeout(s, Duration::from_millis(20)).unwrap();
                    continue;
                }
                let Some(t) = s.engine.next_time() else {
                    // In-flight work whose completion needs no event
                    // can only appear with eager completions off; park
                    // briefly and re-check.
                    let _ = self.cv.wait_timeout(s, Duration::from_millis(20)).unwrap();
                    continue;
                };
                let lead_s = s.clock.lead_s(t);
                if lead_s <= 0.0 {
                    let events = s.engine.step(t, &self.cache);
                    Self::record(&mut s, &events);
                    continue;
                }
                lead_s
            };
            // Not due yet: wait toward the deadline with the lock
            // released, capped so shutdown and re-planning stay
            // responsive; any push re-wakes us through the condvar.
            let wait = Duration::from_secs_f64(lead_s.min(max_sleep_s));
            let s = self.shared.lock().unwrap();
            let _ = self.cv.wait_timeout(s, wait).unwrap();
        }
    }

    /// The policy shell: epochs fire on the engine's fabric timeline
    /// while work flows; this thread only relaxes an idle, skewed
    /// fabric back to the equal split between bursts (a shape the
    /// schedule cache has always seen).
    fn policy_loop(&self) {
        let epoch = Duration::from_secs_f64(self.cfg.policy.epoch_s.max(1e-3));
        // Sleep in short slices so shutdown never waits a whole epoch.
        let slice = epoch.min(Duration::from_millis(20));
        let mut slept = Duration::ZERO;
        while !self.stop_policy.load(Ordering::Relaxed) {
            std::thread::sleep(slice);
            slept += slice;
            if slept < epoch {
                continue;
            }
            slept = Duration::ZERO;
            if self.stop_policy.load(Ordering::Relaxed) || self.deterministic {
                continue;
            }
            let mut s = self.shared.lock().unwrap();
            if !s.finished
                && !s.engine.has_work()
                && !s.engine.trace_pending()
                && !s.engine.weights_equal()
            {
                s.engine.epoch_now(&self.cache);
            }
        }
    }

    /// Run the worker and policy shells until ingress is closed and
    /// the engine has drained. Producers push concurrently from other
    /// threads via [`Self::push`].
    ///
    /// One worker shell is spawned per tenant. The shells serialize on
    /// the engine lock, so the extra threads buy liveness (a shell
    /// stuck in a long pacing wait never stalls the run; any other
    /// shell picks up the next due instant), not parallelism — engine
    /// stepping is deliberately single-site.
    pub fn run(&self) -> LiveReport {
        let t0 = Instant::now();
        // The cache may be shared with calibration / sim phases; report
        // only this run's activity.
        let (hits0, misses0) = (self.cache.hits(), self.cache.misses());
        let n = self.num_tenants();
        std::thread::scope(|s| {
            let workers: Vec<_> = (0..n).map(|_| s.spawn(|| self.worker_loop())).collect();
            // Fixed compositions (unified / static) run no policy, so
            // no relaxation shell is spawned for them.
            let policy =
                (self.cfg.mode == LiveMode::Dynamic).then(|| s.spawn(|| self.policy_loop()));
            // Stop the policy thread before propagating any worker
            // panic: panicking while it still runs would leave the
            // scope blocked on a loop that never observes the flag.
            let worker_panicked =
                workers.into_iter().map(|w| usize::from(w.join().is_err())).sum::<usize>();
            self.stop_policy.store(true, Ordering::Relaxed);
            let policy_result = policy.map_or(Ok(()), |p| p.join());
            assert_eq!(worker_panicked, 0, "{worker_panicked} worker thread(s) panicked");
            policy_result.expect("policy thread panicked");
        });
        let shared = self.shared.lock().unwrap();
        let engine = &shared.engine;
        let served = engine.served();
        let (slo_met, slo_missed, slo_deadlines) =
            (engine.slo_met(), engine.slo_missed(), engine.slo_deadlines());
        LiveReport {
            tenants: (0..n)
                .map(|t| TenantReport {
                    name: engine.tenant_name(t).to_string(),
                    served: served[t],
                    throttled: engine.throttled()[t],
                    fabric_s: engine.fabric_s(t),
                    wall_latency: shared.hist[t].clone(),
                    slo_deadline_s: slo_deadlines[t],
                    slo_met: slo_met[t],
                    slo_missed: slo_missed[t],
                })
                .collect(),
            switches: engine.switches(),
            preemptions: engine.preemptions(),
            packs: engine.packs(),
            unpacks: engine.unpacks(),
            pack_swaps: engine.pack_swaps(),
            packed_batches: engine.packed_batches(),
            pack_group_sizes: engine.pack_group_sizes().to_vec(),
            cache_hits: self.cache.hits() - hits0,
            cache_misses: self.cache.misses() - misses0,
            wall_s: t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 5 }
    }

    fn scheduler(caps: usize) -> FabricScheduler {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap()
    }

    #[test]
    fn serves_all_pushed_requests() {
        let sched = scheduler(10_000);
        for i in 0..200 {
            sched.push(i as usize % 2, LiveRequest::new(i)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 200);
        assert_eq!(report.tenants[0].served, 100);
        assert!(report.tenants[0].fabric_s > 0.0);
        assert_eq!(report.tenants[0].wall_latency.count(), 100);
        assert!(report.worst_p99_s() >= report.tenants[0].p99_s());
        // Packing never engaged: it is off by default.
        assert_eq!((report.packs, report.unpacks, report.packed_batches), (0, 0, 0));
        assert!(report.pack_group_sizes.is_empty());
    }

    #[test]
    fn admission_control_is_per_tenant() {
        let sched = scheduler(4);
        // The shells aren't running: the 4-deep engine queue must
        // overflow.
        let mut rejected = 0;
        for i in 0..10 {
            if sched.push(0, LiveRequest::new(i)).is_err() {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 6);
        assert_eq!(sched.shared.lock().unwrap().engine.pending_len(1), 0);
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 4);
    }

    #[test]
    fn token_bucket_throttles_pushes() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        // Measure the equal-split per-request cost, then allow tenant a
        // a burst of exactly 3 requests and essentially no refill.
        let probe = vec![
            TenantSpec::new("a", zoo::mlp_s()),
            TenantSpec::new("b", zoo::mlp_s()),
        ];
        let per =
            crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        // 3.5x: mid-bucket headroom keeps the pass/throttle boundary
        // away from f64 rounding of repeated same-cost takes.
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_fabric_share(1e-12, 3.5 * per),
            TenantSpec::new("b", zoo::mlp_s()),
        ];
        let sched =
            FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap();
        let mut throttled = 0;
        for i in 0..6 {
            match sched.push(0, LiveRequest::new(i)) {
                Ok(()) => {}
                Err(PushError::Throttled) => throttled += 1,
                Err(e) => panic!("unexpected push error {e}"),
            }
        }
        assert_eq!(throttled, 3, "burst of 3 requests' fabric time, then throttle");
        // The unlimited tenant is unaffected.
        sched.push(1, LiveRequest::new(99)).unwrap();
        sched.close();
        let report = sched.run();
        assert_eq!(report.tenants[0].throttled, 3);
        assert_eq!(report.tenants[0].served, 3);
        assert_eq!(report.tenants[1].served, 1);
    }

    #[test]
    fn policy_step_resplits_under_skew() {
        let sched = scheduler(10_000);
        // Flood tenant a while the shells are not yet running.
        for i in 0..500 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        let before = sched.snapshot().composition;
        assert!(sched.policy_step(), "skewed backlog must trigger a re-split");
        let after = sched.snapshot().composition;
        assert!(after[0].2 > before[0].2, "tenant a must gain CUs: {before:?} -> {after:?}");
        // No batch in flight: nothing to preempt.
        {
            let s = sched.shared.lock().unwrap();
            assert_eq!(s.engine.switches(), 1);
            assert_eq!(s.engine.preemptions(), 0);
        }
        // An idle fabric proposes the equal split again — a shape the
        // cache has already seen, so re-splitting back is pure hits.
        assert_eq!(sched.drain_pending(0), 500);
        let h0 = sched.cache.hits();
        assert!(sched.policy_step(), "drained backlog must restore the equal split");
        assert!(sched.cache.hits() > h0, "returning to a seen composition must hit the cache");
        sched.close();
        let report = sched.run();
        assert_eq!(report.switches, 2);
        assert_eq!(report.total_served(), 0, "drained requests are gone");
    }

    #[test]
    fn preemption_lands_at_a_layer_boundary_mid_batch() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("hot", zoo::mlp_s()).with_queue_capacity(10_000).with_max_batch(4096),
            TenantSpec::new("cold", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        // Pace the fabric so one big batch takes ~1 s of wall time:
        // plenty of layer boundaries for the policy epochs (50 ms of
        // wall, ~5% of the batch each) to land a preemption on.
        let probe = vec![
            TenantSpec::new("hot", zoo::mlp_s()),
            TenantSpec::new("cold", zoo::mlp_s()),
        ];
        let per = crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        let n = 400usize;
        let batch_s = crate::serve::tenant::batch_fabric_s(per, n);
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 0.05,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                ..PolicyConfig::default()
            },
            timescale: 1.0 / batch_s,
            ..LiveConfig::default()
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        for i in 0..n {
            sched.push(0, LiveRequest::new(i as u64)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), n as u64);
        assert!(report.switches >= 1, "in-flight remaining work must trigger a re-split");
        assert!(
            report.preemptions >= 1,
            "the engine must land at least one mid-batch preemption ({} switches)",
            report.switches
        );
    }

    #[test]
    fn policy_packs_and_unpacks_light_tenants() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let probe = vec![
            TenantSpec::new("heavy", zoo::mlp_s()),
            TenantSpec::new("s1", zoo::mlp_s()),
            TenantSpec::new("s2", zoo::mlp_s()),
        ];
        let per = crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        let specs = vec![
            TenantSpec::new("heavy", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s2", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 5.0 * per,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                pack_headroom_factor: 2.0,
                // Decouple the amortization gate from the model's
                // absolute time scale: this test is about transitions.
                pack_swap_margin: 1e9,
                ..PolicyConfig::default()
            },
            timescale: 0.0,
            ..LiveConfig::default()
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        // Flood the heavy tenant while the shells are not yet running;
        // the light tenants are idle, so the pack fit is trivially met.
        for i in 0..300 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        assert!(sched.policy_step(), "skew must trigger a re-split");
        {
            let s = sched.shared.lock().unwrap();
            assert_eq!(s.engine.packs(), 1, "light pair must pack");
            assert_eq!(s.engine.pack_group_sizes(), &[2]);
        }
        let snap = sched.snapshot();
        assert_eq!(snap.hosts[2], 1, "s2 is hosted on s1's partition");
        assert_eq!(snap.hosts[1], 1);
        let comp = snap.composition;
        assert_eq!(
            (comp[1].1, comp[1].2),
            (comp[2].1, comp[2].2),
            "a packed pair shares one partition's dimensions: {comp:?}"
        );
        assert!(comp[0].2 > comp[1].2, "the heavy tenant gains the freed capacity: {comp:?}");
        // Flood a packed member past the unpack hysteresis: backlog of
        // 200 requests dwarfs the 5-request-epoch fit bound.
        for i in 0..200 {
            sched.push(2, LiveRequest::new(1000 + i)).unwrap();
        }
        assert!(sched.policy_step(), "unpack is a forced re-composition");
        {
            let s = sched.shared.lock().unwrap();
            assert_eq!(s.engine.unpacks(), 1, "flooded member must unpack");
        }
        assert_eq!(sched.snapshot().hosts[2], 2);
        // Everything still gets served after the transitions. (Policy
        // epochs fire on the fabric timeline during the drain, so a
        // late re-pack of the emptied light pair is legitimate — the
        // floor, not an exact count, is the contract.)
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 500);
        assert!(report.packs >= 1);
        assert!(report.unpacks >= 1);
        assert!(report.pack_group_sizes.iter().all(|&s| s == 2));
    }

    #[test]
    fn packed_group_serves_its_members_queues() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("heavy", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s2", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 0.05,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                pack_headroom_factor: 2.0,
                pack_swap_margin: 1e9,
                ..PolicyConfig::default()
            },
            timescale: 0.0,
            ..LiveConfig::default()
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        for i in 0..100 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        // Pack the idle pair before the shells start.
        assert!(sched.policy_step());
        assert_eq!(sched.snapshot().hosts[2], 1);
        // Traffic for both packed members lands after the transition.
        for i in 0..40 {
            sched.push(1, LiveRequest::new(500 + i)).unwrap();
            sched.push(2, LiveRequest::new(600 + i)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 180, "no request may strand across packing");
        assert_eq!(report.tenants[1].served, 40);
        assert_eq!(report.tenants[2].served, 40);
        assert!(report.packed_batches >= 2, "member batches ran interleaved");
    }

    #[test]
    fn push_after_close_rejected() {
        let sched = scheduler(16);
        sched.close();
        assert_eq!(sched.push(0, LiveRequest::new(1)).unwrap_err(), PushError::Closed);
        let report = sched.run();
        assert_eq!(report.total_served(), 0);
    }

    /// Cold-start contract of the async-DSE path: an epoch whose
    /// proposed split is not cached defers to the background solver,
    /// and neither that epoch nor any `push` during the pending solve
    /// blocks longer than one policy epoch — the serving path's cost
    /// is a cache lookup, never a solve.
    #[test]
    fn async_solve_keeps_cold_epochs_off_the_push_path() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        let cfg = LiveConfig {
            policy: PolicyConfig { epoch_s: 0.25, ..PolicyConfig::default() }.with_async_solve(),
            timescale: 0.0,
            ..LiveConfig::default()
        };
        let epoch = Duration::from_secs_f64(cfg.policy.epoch_s);
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        // Flood tenant a while the shells are not running: the skewed
        // proposal's unequal slices are shapes calibration never saw.
        for i in 0..500 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        let t0 = Instant::now();
        let committed = sched.policy_step();
        let epoch_wall = t0.elapsed();
        assert!(!committed, "cold epoch must defer, not solve under the engine lock");
        assert!(epoch_wall < epoch, "deferring epoch blocked {epoch_wall:?} (> one epoch)");
        assert!(
            sched.shared.lock().unwrap().engine.deferred_resplits() >= 1,
            "the deferral must be counted"
        );
        // Ingress stays bounded by a cache lookup while the solve is
        // in flight on the background thread.
        let t1 = Instant::now();
        sched.push(1, LiveRequest::new(9_000)).unwrap();
        let push_wall = t1.elapsed();
        assert!(push_wall < epoch, "push blocked {push_wall:?} during a pending solve");
        // Once the background solve lands, a later epoch re-proposes
        // the same split and commits it straight from the cache.
        let deadline = Instant::now() + Duration::from_secs(60);
        let mut committed = sched.policy_step();
        while !committed && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
            committed = sched.policy_step();
        }
        assert!(committed, "deferred resplit must commit once the solve lands");
        let stats = sched.stall_stats();
        assert!(stats.lock_holds >= 502, "every push and epoch meters its hold: {stats:?}");
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 501, "the full backlog drains after the transition");
    }
}
