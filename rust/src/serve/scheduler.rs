//! Live multi-tenant fabric scheduler: real threads, real queues,
//! layer-granular preemption.
//!
//! One worker thread per tenant, each owning that tenant's current
//! fabric [`Partition`](crate::coordinator::reconfig::Partition) and
//! draining its bounded queue in batches. Batches execute through a
//! [`BatchCursor`]: the worker retires one layer step at a time,
//! charging each step's fabric seconds as it goes, and checks the
//! tenant's preemption generation between steps — so when the policy
//! thread re-splits the fabric through the
//! [`Reconfigurator`], the switch lands at the *next layer boundary* of
//! an in-flight batch (the remaining layers resume on the new slice's
//! cached schedule) instead of waiting for the whole DAG to drain.
//! Schedules resolve through the [`ScheduleCache`] so the DSE never
//! runs on the hot path after a composition has been seen once.
//!
//! Fabric time is *accounted* (the modelled VCK190 is not attached);
//! `timescale` optionally paces workers by sleeping a scaled-down
//! multiple of each step's fabric time so queue depths — and therefore
//! the policy — behave like they would on hardware.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::reconfig::Reconfigurator;
use crate::platform::Platform;

use super::cache::{CachedSchedule, ScheduleCache};
use super::policy::{backlog_weights, should_preempt, should_resplit, PolicyConfig};
use super::queue::{BoundedQueue, PushError};
use super::tenant::{BatchCursor, TenantSpec, TokenBucket};

/// Live-mode knobs.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub policy: PolicyConfig,
    /// Wall seconds slept per fabric second to emulate device pacing;
    /// 0.0 drains at host speed (tests).
    pub timescale: f64,
    /// Cap on any single pacing sleep, so demos stay responsive.
    pub max_sleep: Duration,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self {
            policy: PolicyConfig::default(),
            timescale: 0.0,
            max_sleep: Duration::from_millis(100),
        }
    }
}

/// One request in the live path.
#[derive(Debug)]
pub struct LiveRequest {
    pub id: u64,
    pub enqueued: Instant,
}

impl LiveRequest {
    pub fn new(id: u64) -> Self {
        Self { id, enqueued: Instant::now() }
    }
}

/// The slice a tenant's worker currently runs on.
#[derive(Clone)]
struct Plan {
    fmus: u32,
    cus: u32,
    sched: Arc<CachedSchedule>,
}

impl Plan {
    fn per_request_s(&self) -> f64 {
        self.sched.per_request_s
    }
}

struct TenantRuntime {
    spec: TenantSpec,
    queue: BoundedQueue<LiveRequest>,
    plan: Mutex<Plan>,
    hist: Mutex<LatencyHistogram>,
    /// Fabric seconds this tenant's slice has consumed (layer steps +
    /// switch charges).
    fabric_s: Mutex<f64>,
    served: AtomicU64,
    /// Admission token bucket (fabric-time share), if configured.
    bucket: Option<Mutex<TokenBucket>>,
    /// Bumped by the policy thread when an approved preemption should
    /// land at the worker's next layer boundary.
    preempt_gen: AtomicU64,
    /// Worker-published estimate of the in-flight batch's remaining
    /// fabric seconds (f64 bits; 0 when idle) — the policy's
    /// preemption-benefit signal.
    inflight_remaining: AtomicU64,
}

impl TenantRuntime {
    fn inflight_remaining_s(&self) -> f64 {
        f64::from_bits(self.inflight_remaining.load(Ordering::Relaxed))
    }

    fn publish_remaining(&self, remaining_s: f64) {
        self.inflight_remaining.store(remaining_s.to_bits(), Ordering::Relaxed);
    }
}

/// Per-tenant outcome of a live run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub served: u64,
    pub throttled: u64,
    pub fabric_s: f64,
    pub wall_latency: LatencyHistogram,
}

impl TenantReport {
    /// Tail wall-clock latency (p99) of this tenant's served requests.
    pub fn p99_s(&self) -> f64 {
        self.wall_latency.p99()
    }
}

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    pub tenants: Vec<TenantReport>,
    /// Re-compositions performed (setup split excluded).
    pub switches: u64,
    /// In-flight batches preempted at a layer boundary.
    pub preemptions: u64,
    /// Schedule-cache activity during this run only (the cache may be
    /// shared with calibration or simulation phases).
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub wall_s: f64,
}

impl LiveReport {
    pub fn total_served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    /// Worst per-tenant p99 wall latency.
    pub fn worst_p99_s(&self) -> f64 {
        self.tenants.iter().map(|t| t.p99_s()).fold(0.0, f64::max)
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        for t in &self.tenants {
            s.push_str(&format!(
                "  {:<10} served {:>6}  throttled {:>4}  fabric {:.4e} s  wall {}\n",
                t.name,
                t.served,
                t.throttled,
                t.fabric_s,
                t.wall_latency.summary()
            ));
        }
        s.push_str(&format!(
            "  {} re-compositions ({} preemptive) | worst p99 {:.3e} s | \
             schedule cache: {} hits, {} misses | {:.2} s wall",
            self.switches,
            self.preemptions,
            self.worst_p99_s(),
            self.cache_hits,
            self.cache_misses,
            self.wall_s
        ));
        s
    }
}

/// Live multi-tenant scheduler over a dynamically re-partitioned fabric.
pub struct FabricScheduler {
    platform: Platform,
    base: FilcoConfig,
    cfg: LiveConfig,
    cache: Arc<ScheduleCache>,
    recon: Mutex<Reconfigurator>,
    weights: Mutex<Vec<u32>>,
    tenants: Vec<TenantRuntime>,
    /// Token-bucket clock origin.
    t0: Instant,
    /// Re-compositions after setup.
    switches: AtomicU64,
    /// Approved mid-DAG preemptions landed by workers.
    preemptions: AtomicU64,
    /// Bucket refusals per tenant index.
    throttled: Vec<AtomicU64>,
    stop_policy: AtomicBool,
}

impl FabricScheduler {
    /// Build the scheduler: equal initial split, schedules resolved
    /// through `cache` (pre-warming it counts as misses here, hits on
    /// every later re-composition into a seen shape).
    pub fn new(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
    ) -> Result<Self, String> {
        if specs.is_empty() {
            return Err("no tenants".into());
        }
        let mut recon = Reconfigurator::new(base.clone());
        let weights = vec![1u32; specs.len()];
        let named: Vec<(&str, u32)> =
            specs.iter().zip(&weights).map(|(s, &w)| (s.name.as_str(), w)).collect();
        let parts = recon.split(&named)?;
        recon.validate()?;
        let throttled = specs.iter().map(|_| AtomicU64::new(0)).collect();
        let tenants = specs
            .into_iter()
            .zip(&parts)
            .map(|(spec, part)| {
                let slice = part.config(&base);
                let cached = cache.get_or_compute(&platform, &slice, &spec.dag);
                let queue = BoundedQueue::new(spec.queue_capacity);
                TenantRuntime {
                    queue,
                    plan: Mutex::new(Plan {
                        fmus: part.n_fmus(),
                        cus: part.m_cus(),
                        sched: cached,
                    }),
                    hist: Mutex::new(LatencyHistogram::new()),
                    fabric_s: Mutex::new(0.0),
                    served: AtomicU64::new(0),
                    bucket: spec.rate_limit.map(|rl| Mutex::new(TokenBucket::from_limit(rl))),
                    preempt_gen: AtomicU64::new(0),
                    inflight_remaining: AtomicU64::new(0.0f64.to_bits()),
                    spec,
                }
            })
            .collect();
        Ok(Self {
            platform,
            base,
            cfg,
            cache,
            recon: Mutex::new(recon),
            weights: Mutex::new(weights),
            tenants,
            t0: Instant::now(),
            switches: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            throttled,
            stop_policy: AtomicBool::new(false),
        })
    }

    pub fn num_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Admission-controlled enqueue for tenant `t`: closed check, then
    /// queue depth, then the tenant's fabric-time token bucket (charged
    /// the request's estimated cost on the current slice) — the same
    /// classification order as the simulator's ingest, so a
    /// full-queue-and-empty-bucket request counts as `Full` in both
    /// paths. Tokens taken for a request the queue then refuses in a
    /// concurrent-drain race are refunded.
    pub fn push(&self, t: usize, req: LiveRequest) -> Result<(), PushError> {
        let tr = &self.tenants[t];
        if tr.queue.is_closed() {
            return Err(PushError::Closed);
        }
        if tr.queue.len() >= tr.queue.capacity() {
            return Err(PushError::Full);
        }
        let cost = match &tr.bucket {
            None => 0.0,
            Some(b) => {
                let cost = tr.plan.lock().unwrap().per_request_s();
                let now_s = self.t0.elapsed().as_secs_f64();
                if !b.lock().unwrap().try_take(cost, now_s) {
                    self.throttled[t].fetch_add(1, Ordering::Relaxed);
                    return Err(PushError::Throttled);
                }
                cost
            }
        };
        let pushed = tr.queue.try_push(req);
        if pushed.is_err() && cost > 0.0 {
            if let Some(b) = &tr.bucket {
                b.lock().unwrap().refund(cost);
            }
        }
        pushed
    }

    /// Close every tenant queue; workers exit once drained.
    pub fn close(&self) {
        for t in &self.tenants {
            t.queue.close();
        }
    }

    /// Current composition as `(name, fmus, cus)` triples.
    pub fn composition(&self) -> Vec<(String, u32, u32)> {
        self.tenants
            .iter()
            .map(|t| {
                let p = t.plan.lock().unwrap();
                (t.spec.name.clone(), p.fmus, p.cus)
            })
            .collect()
    }

    fn pace(&self, fabric_dur_s: f64) {
        if self.cfg.timescale > 0.0 {
            // Clamp before Duration conversion: an extreme timescale
            // (inf/NaN overflow) must not panic the worker.
            let secs = (fabric_dur_s * self.cfg.timescale)
                .min(self.cfg.max_sleep.as_secs_f64())
                .max(0.0);
            std::thread::sleep(Duration::from_secs_f64(secs));
        }
    }

    fn worker(&self, i: usize) {
        let t = &self.tenants[i];
        loop {
            let Some(batch) = t.queue.pop_batch_timeout(t.spec.max_batch, Duration::from_millis(20))
            else {
                break; // closed and drained
            };
            if batch.is_empty() {
                continue; // timeout — check for close, re-observe plan
            }
            let (mut cursor, mut seen_gen) = {
                let p = t.plan.lock().unwrap();
                let g = t.preempt_gen.load(Ordering::Acquire);
                (BatchCursor::new(p.sched.clone(), batch.len()), g)
            };
            t.publish_remaining(cursor.remaining_s());
            // Retire the batch one layer step at a time; between steps,
            // an approved preemption re-bases the remaining steps onto
            // the slice the policy just assigned us.
            while let Some(ev) = cursor.advance() {
                *t.fabric_s.lock().unwrap() += ev.dur_s;
                self.pace(ev.dur_s);
                t.publish_remaining(cursor.remaining_s());
                let cur_gen = t.preempt_gen.load(Ordering::Acquire);
                if cur_gen != seen_gen {
                    seen_gen = cur_gen;
                    if !cursor.is_done() {
                        let sched = t.plan.lock().unwrap().sched.clone();
                        // The mid-DAG switch cost is charged by
                        // policy_step into fabric_s (exactly once per
                        // tenant per re-split); the cursor only
                        // re-bases the remaining layers.
                        cursor.retarget(sched, 0.0);
                        self.preemptions.fetch_add(1, Ordering::Relaxed);
                        t.publish_remaining(cursor.remaining_s());
                    }
                }
            }
            t.publish_remaining(0.0);
            let mut hist = t.hist.lock().unwrap();
            for req in &batch {
                hist.record(req.enqueued.elapsed().as_secs_f64());
            }
            drop(hist);
            t.served.fetch_add(batch.len() as u64, Ordering::Relaxed);
        }
    }

    /// One policy evaluation: observe backlog (queued work, plus
    /// in-flight remaining work when preemption is enabled), re-split
    /// if warranted, and approve per-tenant mid-DAG preemptions whose
    /// projected saving clears the switch-cost margin.
    /// Public so step-driven callers (and tests) can run it without the
    /// wall-clock loop.
    pub fn policy_step(&self) -> bool {
        let preempt_on = self.cfg.policy.preemption_enabled();
        let per_req: Vec<f64> =
            self.tenants.iter().map(|t| t.plan.lock().unwrap().per_request_s()).collect();
        let backlog: Vec<f64> = self
            .tenants
            .iter()
            .zip(&per_req)
            .map(|(t, &per)| {
                let queued = t.queue.len() as f64 * per;
                let inflight = if preempt_on { t.inflight_remaining_s() } else { 0.0 };
                queued + inflight
            })
            .collect();
        let total: f64 = backlog.iter().sum();
        let proposed = backlog_weights(&backlog, self.cfg.policy.max_weight);
        let mut recon = self.recon.lock().unwrap();
        let mut weights = self.weights.lock().unwrap();
        if !should_resplit(&weights[..], &proposed, total, recon.switch_cost_s(), &self.cfg.policy)
        {
            return false;
        }
        let named: Vec<(&str, u32)> = self
            .tenants
            .iter()
            .zip(&proposed)
            .map(|(t, &w)| (t.spec.name.as_str(), w))
            .collect();
        let parts = match recon.split(&named) {
            Ok(p) => p,
            Err(e) => {
                log::warn!("re-split rejected: {e}");
                return false;
            }
        };
        debug_assert!(recon.validate().is_ok());
        let switch_cost = recon.switch_cost_s();
        for ((t, part), &old_per) in self.tenants.iter().zip(&parts).zip(&per_req) {
            let slice = part.config(&self.base);
            let cached = self.cache.get_or_compute(&self.platform, &slice, &t.spec.dag);
            let new_per = cached.per_request_s;
            {
                // Plan write and preemption-generation bump happen under
                // one lock hold: a worker snapshots (plan, gen) under the
                // same lock, so it can never pair the new schedule with a
                // stale generation and count a phantom preemption.
                let mut plan = t.plan.lock().unwrap();
                *plan = Plan { fmus: part.n_fmus(), cus: part.m_cus(), sched: cached };
                // Preemption-benefit term: interrupt the in-flight batch
                // at its next layer boundary only when re-costing the
                // rest on the new slice beats draining on the old one.
                let rem_old = t.inflight_remaining_s();
                if preempt_on && rem_old > 0.0 {
                    let rem_new =
                        if old_per > 0.0 { rem_old * (new_per / old_per) } else { rem_old };
                    if should_preempt(rem_old, rem_new, switch_cost, &self.cfg.policy) {
                        t.preempt_gen.fetch_add(1, Ordering::Release);
                    }
                }
            }
            *t.fabric_s.lock().unwrap() += switch_cost;
        }
        *weights = proposed;
        self.switches.fetch_add(1, Ordering::Relaxed);
        true
    }

    fn policy_loop(&self) {
        let epoch = Duration::from_secs_f64(self.cfg.policy.epoch_s.max(1e-3));
        // Sleep in short slices so shutdown never waits a whole epoch.
        let slice = epoch.min(Duration::from_millis(20));
        let mut slept = Duration::ZERO;
        while !self.stop_policy.load(Ordering::Relaxed) {
            std::thread::sleep(slice);
            slept += slice;
            if slept < epoch {
                continue;
            }
            slept = Duration::ZERO;
            if self.stop_policy.load(Ordering::Relaxed) {
                break;
            }
            self.policy_step();
        }
    }

    /// Run workers + policy until every queue is closed and drained.
    /// Producers push concurrently from other threads via [`Self::push`].
    pub fn run(&self) -> LiveReport {
        let t0 = Instant::now();
        // The cache may be shared with calibration / sim phases; report
        // only this run's activity.
        let (hits0, misses0) = (self.cache.hits(), self.cache.misses());
        std::thread::scope(|s| {
            let workers: Vec<_> =
                (0..self.tenants.len()).map(|i| s.spawn(move || self.worker(i))).collect();
            let policy = s.spawn(|| self.policy_loop());
            // Stop the policy thread before propagating any worker
            // panic: panicking while it still runs would leave the
            // scope blocked on a loop that never observes the flag.
            let worker_panicked =
                workers.into_iter().map(|w| usize::from(w.join().is_err())).sum::<usize>();
            self.stop_policy.store(true, Ordering::Relaxed);
            let policy_result = policy.join();
            assert_eq!(worker_panicked, 0, "{worker_panicked} worker thread(s) panicked");
            policy_result.expect("policy thread panicked");
        });
        LiveReport {
            tenants: self
                .tenants
                .iter()
                .enumerate()
                .map(|(i, t)| TenantReport {
                    name: t.spec.name.clone(),
                    served: t.served.load(Ordering::Relaxed),
                    throttled: self.throttled[i].load(Ordering::Relaxed),
                    fabric_s: *t.fabric_s.lock().unwrap(),
                    wall_latency: t.hist.lock().unwrap().clone(),
                })
                .collect(),
            switches: self.switches.load(Ordering::Relaxed),
            preemptions: self.preemptions.load(Ordering::Relaxed),
            cache_hits: self.cache.hits() - hits0,
            cache_misses: self.cache.misses() - misses0,
            wall_s: t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 5 }
    }

    fn scheduler(caps: usize) -> FabricScheduler {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap()
    }

    #[test]
    fn serves_all_pushed_requests() {
        let sched = scheduler(10_000);
        for i in 0..200 {
            sched.push(i as usize % 2, LiveRequest::new(i)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 200);
        assert_eq!(report.tenants[0].served, 100);
        assert!(report.tenants[0].fabric_s > 0.0);
        assert_eq!(report.tenants[0].wall_latency.count(), 100);
        assert!(report.worst_p99_s() >= report.tenants[0].p99_s());
    }

    #[test]
    fn admission_control_is_per_tenant() {
        let sched = scheduler(4);
        // Workers aren't running: the 4-deep queue must overflow.
        let mut rejected = 0;
        for i in 0..10 {
            if sched.push(0, LiveRequest::new(i)).is_err() {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 6);
        assert_eq!(sched.tenants[1].queue.len(), 0);
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 4);
    }

    #[test]
    fn token_bucket_throttles_pushes() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        // Measure the equal-split per-request cost, then allow tenant a
        // a burst of exactly 3 requests and essentially no refill.
        let probe = vec![
            TenantSpec::new("a", zoo::mlp_s()),
            TenantSpec::new("b", zoo::mlp_s()),
        ];
        let per =
            crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        // 3.5x: mid-bucket headroom keeps the pass/throttle boundary
        // away from f64 rounding of repeated same-cost takes.
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_fabric_share(1e-12, 3.5 * per),
            TenantSpec::new("b", zoo::mlp_s()),
        ];
        let sched =
            FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap();
        let mut throttled = 0;
        for i in 0..6 {
            match sched.push(0, LiveRequest::new(i)) {
                Ok(()) => {}
                Err(PushError::Throttled) => throttled += 1,
                Err(e) => panic!("unexpected push error {e}"),
            }
        }
        assert_eq!(throttled, 3, "burst of 3 requests' fabric time, then throttle");
        // The unlimited tenant is unaffected.
        sched.push(1, LiveRequest::new(99)).unwrap();
        sched.close();
        let report = sched.run();
        assert_eq!(report.tenants[0].throttled, 3);
        assert_eq!(report.tenants[0].served, 3);
        assert_eq!(report.tenants[1].served, 1);
    }

    #[test]
    fn policy_step_resplits_under_skew() {
        let sched = scheduler(10_000);
        // Flood tenant a while workers are not yet running.
        for i in 0..500 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        let before = sched.composition();
        assert!(sched.policy_step(), "skewed backlog must trigger a re-split");
        let after = sched.composition();
        assert!(after[0].2 > before[0].2, "tenant a must gain CUs: {before:?} -> {after:?}");
        assert_eq!(sched.switches.load(Ordering::Relaxed), 1);
        // No batch in flight: nothing to preempt.
        assert_eq!(sched.preemptions.load(Ordering::Relaxed), 0);
        // An idle fabric proposes the equal split again — a shape the
        // cache has already seen, so re-splitting back is pure hits.
        loop {
            match sched.tenants[0].queue.pop_batch_timeout(64, Duration::from_millis(1)) {
                Some(b) if !b.is_empty() => continue,
                _ => break,
            }
        }
        let h0 = sched.cache.hits();
        assert!(sched.policy_step(), "drained backlog must restore the equal split");
        assert!(sched.cache.hits() > h0, "returning to a seen composition must hit the cache");
        sched.close();
        let report = sched.run();
        assert_eq!(report.switches, 2);
    }

    #[test]
    fn preemption_lands_at_a_layer_boundary_mid_batch() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("hot", zoo::mlp_s()).with_queue_capacity(10_000).with_max_batch(4096),
            TenantSpec::new("cold", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        // Pace the fabric so one big batch takes ~1 s of wall time:
        // plenty of layer boundaries for the policy thread (50 ms
        // epochs) to land a preemption on.
        let probe = vec![
            TenantSpec::new("hot", zoo::mlp_s()),
            TenantSpec::new("cold", zoo::mlp_s()),
        ];
        let per = crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        let n = 400usize;
        let batch_s = crate::serve::tenant::batch_fabric_s(per, n);
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 0.05,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
            },
            timescale: 1.0 / batch_s,
            max_sleep: Duration::from_millis(100),
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        for i in 0..n {
            sched.push(0, LiveRequest::new(i as u64)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), n as u64);
        assert!(report.switches >= 1, "in-flight remaining work must trigger a re-split");
        assert!(
            report.preemptions >= 1,
            "the worker must land at least one mid-batch preemption ({} switches)",
            report.switches
        );
    }

    #[test]
    fn push_after_close_rejected() {
        let sched = scheduler(16);
        sched.close();
        assert_eq!(sched.push(0, LiveRequest::new(1)).unwrap_err(), PushError::Closed);
        let report = sched.run();
        assert_eq!(report.total_served(), 0);
    }
}
