//! Live multi-tenant fabric scheduler: real threads, real queues.
//!
//! One worker thread per tenant, each owning that tenant's current
//! fabric [`Partition`](crate::coordinator::reconfig::Partition) and
//! draining its bounded queue in batches; a policy thread that
//! periodically observes queue depths and re-splits the fabric through
//! the [`Reconfigurator`], resolving the new slices' schedules through
//! the [`ScheduleCache`] so the DSE never runs on the hot path after a
//! composition has been seen once.
//!
//! Fabric time is *accounted* (the modelled VCK190 is not attached);
//! `timescale` optionally paces workers by sleeping a scaled-down
//! multiple of the fabric time so queue depths — and therefore the
//! policy — behave like they would on hardware.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::reconfig::Reconfigurator;
use crate::platform::Platform;

use super::cache::ScheduleCache;
use super::policy::{backlog_weights, should_resplit, PolicyConfig};
use super::queue::{BoundedQueue, PushError};
use super::tenant::{batch_fabric_s, TenantSpec};

/// Live-mode knobs.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    pub policy: PolicyConfig,
    /// Wall seconds slept per fabric second to emulate device pacing;
    /// 0.0 drains at host speed (tests).
    pub timescale: f64,
    /// Cap on any single pacing sleep, so demos stay responsive.
    pub max_sleep: Duration,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self {
            policy: PolicyConfig::default(),
            timescale: 0.0,
            max_sleep: Duration::from_millis(100),
        }
    }
}

/// One request in the live path.
#[derive(Debug)]
pub struct LiveRequest {
    pub id: u64,
    pub enqueued: Instant,
}

impl LiveRequest {
    pub fn new(id: u64) -> Self {
        Self { id, enqueued: Instant::now() }
    }
}

/// The slice a tenant's worker currently runs on.
#[derive(Debug, Clone)]
struct Plan {
    fmus: u32,
    cus: u32,
    per_request_s: f64,
}

struct TenantRuntime {
    spec: TenantSpec,
    queue: BoundedQueue<LiveRequest>,
    plan: Mutex<Plan>,
    hist: Mutex<LatencyHistogram>,
    /// Fabric seconds this tenant's slice has consumed (batches +
    /// switch charges).
    fabric_s: Mutex<f64>,
    served: AtomicU64,
}

/// Per-tenant outcome of a live run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    pub served: u64,
    pub fabric_s: f64,
    pub wall_latency: LatencyHistogram,
}

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    pub tenants: Vec<TenantReport>,
    /// Re-compositions performed (setup split excluded).
    pub switches: u64,
    /// Schedule-cache activity during this run only (the cache may be
    /// shared with calibration or simulation phases).
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub wall_s: f64,
}

impl LiveReport {
    pub fn total_served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        for t in &self.tenants {
            s.push_str(&format!(
                "  {:<10} served {:>6}  fabric {:.4e} s  wall {}\n",
                t.name,
                t.served,
                t.fabric_s,
                t.wall_latency.summary()
            ));
        }
        s.push_str(&format!(
            "  {} re-compositions | schedule cache: {} hits, {} misses | {:.2} s wall",
            self.switches, self.cache_hits, self.cache_misses, self.wall_s
        ));
        s
    }
}

/// Live multi-tenant scheduler over a dynamically re-partitioned fabric.
pub struct FabricScheduler {
    platform: Platform,
    base: FilcoConfig,
    cfg: LiveConfig,
    cache: Arc<ScheduleCache>,
    recon: Mutex<Reconfigurator>,
    weights: Mutex<Vec<u32>>,
    tenants: Vec<TenantRuntime>,
    /// Re-compositions after setup.
    switches: AtomicU64,
    stop_policy: AtomicBool,
}

impl FabricScheduler {
    /// Build the scheduler: equal initial split, schedules resolved
    /// through `cache` (pre-warming it counts as misses here, hits on
    /// every later re-composition into a seen shape).
    pub fn new(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
    ) -> Result<Self, String> {
        if specs.is_empty() {
            return Err("no tenants".into());
        }
        let mut recon = Reconfigurator::new(base.clone());
        let weights = vec![1u32; specs.len()];
        let named: Vec<(&str, u32)> =
            specs.iter().zip(&weights).map(|(s, &w)| (s.name.as_str(), w)).collect();
        let parts = recon.split(&named)?;
        recon.validate()?;
        let tenants = specs
            .into_iter()
            .zip(&parts)
            .map(|(spec, part)| {
                let slice = part.config(&base);
                let cached = cache.get_or_compute(&platform, &slice, &spec.dag);
                let queue = BoundedQueue::new(spec.queue_capacity);
                TenantRuntime {
                    queue,
                    plan: Mutex::new(Plan {
                        fmus: part.n_fmus(),
                        cus: part.m_cus(),
                        per_request_s: cached.per_request_s,
                    }),
                    hist: Mutex::new(LatencyHistogram::new()),
                    fabric_s: Mutex::new(0.0),
                    served: AtomicU64::new(0),
                    spec,
                }
            })
            .collect();
        Ok(Self {
            platform,
            base,
            cfg,
            cache,
            recon: Mutex::new(recon),
            weights: Mutex::new(weights),
            tenants,
            switches: AtomicU64::new(0),
            stop_policy: AtomicBool::new(false),
        })
    }

    pub fn num_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Admission-controlled enqueue for tenant `t`.
    pub fn push(&self, t: usize, req: LiveRequest) -> Result<(), PushError> {
        self.tenants[t].queue.try_push(req)
    }

    /// Close every tenant queue; workers exit once drained.
    pub fn close(&self) {
        for t in &self.tenants {
            t.queue.close();
        }
    }

    /// Current composition as `(name, fmus, cus)` triples.
    pub fn composition(&self) -> Vec<(String, u32, u32)> {
        self.tenants
            .iter()
            .map(|t| {
                let p = t.plan.lock().unwrap();
                (t.spec.name.clone(), p.fmus, p.cus)
            })
            .collect()
    }

    fn worker(&self, i: usize) {
        let t = &self.tenants[i];
        loop {
            let Some(batch) = t.queue.pop_batch_timeout(t.spec.max_batch, Duration::from_millis(20))
            else {
                break; // closed and drained
            };
            if batch.is_empty() {
                continue; // timeout — re-read plan, check for close
            }
            let plan = t.plan.lock().unwrap().clone();
            let dur = batch_fabric_s(plan.per_request_s, batch.len());
            *t.fabric_s.lock().unwrap() += dur;
            if self.cfg.timescale > 0.0 {
                // Clamp before Duration conversion: an extreme timescale
                // (inf/NaN overflow) must not panic the worker.
                let secs = (dur * self.cfg.timescale)
                    .min(self.cfg.max_sleep.as_secs_f64())
                    .max(0.0);
                std::thread::sleep(Duration::from_secs_f64(secs));
            }
            let mut hist = t.hist.lock().unwrap();
            for req in &batch {
                hist.record(req.enqueued.elapsed().as_secs_f64());
            }
            drop(hist);
            t.served.fetch_add(batch.len() as u64, Ordering::Relaxed);
        }
    }

    /// One policy evaluation: observe backlog, re-split if warranted.
    /// Public so step-driven callers (and tests) can run it without the
    /// wall-clock loop.
    pub fn policy_step(&self) -> bool {
        let backlog: Vec<f64> = self
            .tenants
            .iter()
            .map(|t| {
                let depth = t.queue.len() as f64;
                depth * t.plan.lock().unwrap().per_request_s
            })
            .collect();
        let total: f64 = backlog.iter().sum();
        let proposed = backlog_weights(&backlog, self.cfg.policy.max_weight);
        let mut recon = self.recon.lock().unwrap();
        let mut weights = self.weights.lock().unwrap();
        if !should_resplit(&weights[..], &proposed, total, recon.switch_cost_s(), &self.cfg.policy)
        {
            return false;
        }
        let named: Vec<(&str, u32)> = self
            .tenants
            .iter()
            .zip(&proposed)
            .map(|(t, &w)| (t.spec.name.as_str(), w))
            .collect();
        let parts = match recon.split(&named) {
            Ok(p) => p,
            Err(e) => {
                log::warn!("re-split rejected: {e}");
                return false;
            }
        };
        debug_assert!(recon.validate().is_ok());
        let switch_cost = recon.switch_cost_s();
        for (t, part) in self.tenants.iter().zip(&parts) {
            let slice = part.config(&self.base);
            let cached = self.cache.get_or_compute(&self.platform, &slice, &t.spec.dag);
            *t.plan.lock().unwrap() = Plan {
                fmus: part.n_fmus(),
                cus: part.m_cus(),
                per_request_s: cached.per_request_s,
            };
            *t.fabric_s.lock().unwrap() += switch_cost;
        }
        *weights = proposed;
        self.switches.fetch_add(1, Ordering::Relaxed);
        true
    }

    fn policy_loop(&self) {
        let epoch = Duration::from_secs_f64(self.cfg.policy.epoch_s.max(1e-3));
        // Sleep in short slices so shutdown never waits a whole epoch.
        let slice = epoch.min(Duration::from_millis(20));
        let mut slept = Duration::ZERO;
        while !self.stop_policy.load(Ordering::Relaxed) {
            std::thread::sleep(slice);
            slept += slice;
            if slept < epoch {
                continue;
            }
            slept = Duration::ZERO;
            if self.stop_policy.load(Ordering::Relaxed) {
                break;
            }
            self.policy_step();
        }
    }

    /// Run workers + policy until every queue is closed and drained.
    /// Producers push concurrently from other threads via [`Self::push`].
    pub fn run(&self) -> LiveReport {
        let t0 = Instant::now();
        // The cache may be shared with calibration / sim phases; report
        // only this run's activity.
        let (hits0, misses0) = (self.cache.hits(), self.cache.misses());
        std::thread::scope(|s| {
            let workers: Vec<_> =
                (0..self.tenants.len()).map(|i| s.spawn(move || self.worker(i))).collect();
            let policy = s.spawn(|| self.policy_loop());
            // Stop the policy thread before propagating any worker
            // panic: panicking while it still runs would leave the
            // scope blocked on a loop that never observes the flag.
            let worker_panicked =
                workers.into_iter().map(|w| usize::from(w.join().is_err())).sum::<usize>();
            self.stop_policy.store(true, Ordering::Relaxed);
            let policy_result = policy.join();
            assert_eq!(worker_panicked, 0, "{worker_panicked} worker thread(s) panicked");
            policy_result.expect("policy thread panicked");
        });
        LiveReport {
            tenants: self
                .tenants
                .iter()
                .map(|t| TenantReport {
                    name: t.spec.name.clone(),
                    served: t.served.load(Ordering::Relaxed),
                    fabric_s: *t.fabric_s.lock().unwrap(),
                    wall_latency: t.hist.lock().unwrap().clone(),
                })
                .collect(),
            switches: self.switches.load(Ordering::Relaxed),
            cache_hits: self.cache.hits() - hits0,
            cache_misses: self.cache.misses() - misses0,
            wall_s: t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 5 }
    }

    fn scheduler(caps: usize) -> FabricScheduler {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap()
    }

    #[test]
    fn serves_all_pushed_requests() {
        let sched = scheduler(10_000);
        for i in 0..200 {
            sched.push(i as usize % 2, LiveRequest::new(i)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 200);
        assert_eq!(report.tenants[0].served, 100);
        assert!(report.tenants[0].fabric_s > 0.0);
        assert_eq!(report.tenants[0].wall_latency.count(), 100);
    }

    #[test]
    fn admission_control_is_per_tenant() {
        let sched = scheduler(4);
        // Workers aren't running: the 4-deep queue must overflow.
        let mut rejected = 0;
        for i in 0..10 {
            if sched.push(0, LiveRequest::new(i)).is_err() {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 6);
        assert_eq!(sched.tenants[1].queue.len(), 0);
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 4);
    }

    #[test]
    fn policy_step_resplits_under_skew() {
        let sched = scheduler(10_000);
        // Flood tenant a while workers are not yet running.
        for i in 0..500 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        let before = sched.composition();
        assert!(sched.policy_step(), "skewed backlog must trigger a re-split");
        let after = sched.composition();
        assert!(after[0].2 > before[0].2, "tenant a must gain CUs: {before:?} -> {after:?}");
        assert_eq!(sched.switches.load(Ordering::Relaxed), 1);
        // An idle fabric proposes the equal split again — a shape the
        // cache has already seen, so re-splitting back is pure hits.
        loop {
            match sched.tenants[0].queue.pop_batch_timeout(64, Duration::from_millis(1)) {
                Some(b) if !b.is_empty() => continue,
                _ => break,
            }
        }
        let h0 = sched.cache.hits();
        assert!(sched.policy_step(), "drained backlog must restore the equal split");
        assert!(sched.cache.hits() > h0, "returning to a seen composition must hit the cache");
        sched.close();
        let report = sched.run();
        assert_eq!(report.switches, 2);
    }

    #[test]
    fn push_after_close_rejected() {
        let sched = scheduler(16);
        sched.close();
        assert_eq!(sched.push(0, LiveRequest::new(1)).unwrap_err(), PushError::Closed);
        let report = sched.run();
        assert_eq!(report.total_served(), 0);
    }
}
