//! Live multi-tenant fabric scheduler: thread shells around the shared
//! [`FabricEngine`], paced by a [`WallClock`].
//!
//! The execution semantics — admission control, batching, layer-step
//! interleaving, mid-DAG preemption, cross-tenant packing with
//! mid-flight handoff, and every composition transition — live in the
//! engine, the same deterministic core the virtual-time simulator
//! drains. This module supplies only what a live deployment adds on
//! top:
//!
//! * **producer ingress** — [`FabricScheduler::push`] stamps requests
//!   with the wall-derived fabric instant and feeds the engine's
//!   per-tenant queues under the one engine lock (the modern form of
//!   the old per-tenant plan-lock/preempt-generation discipline: every
//!   plan read and transition now happens under a single lock, so a
//!   phantom preemption is structurally impossible). The lock's cost
//!   is metered ([`LockMeter`] on `push` and [`Self::policy_step`],
//!   surfaced per epoch in the timeline and by
//!   [`Self::stall_stats`]). Historically a schedule-cache *miss*
//!   inside a policy epoch ran the DSE solve while holding the lock,
//!   stalling pushes for the solve's duration; with
//!   [`PolicyConfig::async_solve`] the epoch instead hands the missing
//!   `(config, DAG)` keys to a [`BackgroundSolver`] thread, keeps the
//!   last cached split, and re-proposes at a later epoch — a cold
//!   composition then costs `push` a cache *lookup*, never a solve.
//!   Without async mode, warm the cache (`--cache-file`, or the
//!   equal-split calibration every entry point performs) so the
//!   serving path only ever hits;
//! * **worker shells** — one thread per tenant, all running the same
//!   drive loop: ask the engine for its next fabric instant, let the
//!   [`WallClock`] sleep toward the deadline (`timescale` wall seconds
//!   per fabric second; 0 drains at host speed), then step the engine.
//!   Which thread wins the lock never matters: the engine's decisions
//!   depend only on fabric instants, so a live run replays the
//!   simulator's event trace bit-for-bit (see
//!   `rust/tests/serve_engine.rs`);
//! * **a policy shell** — policy *epochs* fire on the engine's fabric
//!   timeline (wall epochs are converted through the timescale); the
//!   shell thread only relaxes an idle, skewed fabric back to the
//!   equal split between bursts. Only [`LiveMode::Dynamic`] runs a
//!   policy at all: `--strategy static` fixes the equal split and
//!   `--strategy unified` composes the whole fabric into one
//!   round-robin accelerator ([`LiveMode`]), both with the policy
//!   machinery statically disabled;
//! * **wall-clock latency accounting** — fabric-time histograms live in
//!   the engine; the shells record each request's wall latency when its
//!   batch's [`EngineEvent::BatchDone`] fires;
//! * **multi-board hosting** — with [`LiveConfig::boards`] `> 1` the
//!   scheduler owns M engines behind per-board mutexes (tenants
//!   first-fit-placed by declared fabric share, exactly like the
//!   virtual-time [`FabricCluster`](super::FabricCluster)), and a
//!   single placement thread migrates tenants across boards when the
//!   queued-backlog imbalance crosses the [`ClusterPolicy`]
//!   hysteresis — checkpointing a (possibly mid-DAG) batch cursor
//!   losslessly and charging the configured migration cost on the
//!   destination board. Lock order is placement map first, then board
//!   mutexes ascending; a cluster of one board runs the classic
//!   single-fabric paths bit-for-bit.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::platform::Platform;

use super::cache::{BackgroundSolver, ScheduleCache};
use super::clock::{Clock, WallClock};
use super::cluster::{first_fit_placement, ClusterPolicy};
use super::engine::{EngineEvent, FabricEngine};
use super::policy::PolicyConfig;
use super::queue::PushError;
use super::telemetry::{LockMeter, StallStats};
use super::tenant::{Arrival, TenantSpec};

/// Which composition the live scheduler runs — the same three
/// strategies the simulator compares ([`Strategy`](super::Strategy)),
/// selected by `filco serve --strategy`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LiveMode {
    /// The whole fabric as one unified accelerator: tenants time-share
    /// it round-robin at batch granularity
    /// ([`FabricEngine::new_unified`]); no policy runs and no
    /// transition is accepted.
    Unified,
    /// Fixed equal split, one partition per tenant, no policy epochs.
    StaticEqual,
    /// Backlog-driven live re-composition via [`LiveConfig::policy`]
    /// (the default).
    #[default]
    Dynamic,
}

/// Live-mode knobs.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Re-composition / preemption / packing policy. `epoch_s` is in
    /// wall seconds; the scheduler converts it onto the engine's
    /// fabric timeline through `timescale` (an unpaced run uses it as
    /// fabric seconds directly). Ignored outside [`LiveMode::Dynamic`].
    pub policy: PolicyConfig,
    /// Composition strategy ([`LiveMode::Dynamic`] by default).
    pub mode: LiveMode,
    /// Wall seconds slept per fabric second to emulate device pacing;
    /// 0.0 drains at host speed (tests).
    pub timescale: f64,
    /// Cap on any single pacing sleep, so demos stay responsive.
    pub max_sleep: Duration,
    /// Shard workers stepping partition units in parallel inside the
    /// engine (1 = step inline). A throughput knob only: traces and
    /// reports are bit-for-bit identical for any value
    /// ([`FabricEngine::set_shards`]).
    pub shards: usize,
    /// Worker threads for the background DSE solver when
    /// [`PolicyConfig::async_solve`] is on (1 = one solver thread, the
    /// legacy behaviour): distinct cold-slice requests drained in one
    /// wake solve concurrently
    /// ([`BackgroundSolver::spawn_pool`](super::BackgroundSolver::spawn_pool)).
    pub dse_workers: usize,
    /// Independent fabric boards hosted by this scheduler (1 = the
    /// classic single-fabric scheduler, bit-for-bit). Tenants are
    /// placed by declared fabric share
    /// ([`first_fit_placement`](super::cluster::first_fit_placement)).
    pub boards: usize,
    /// Cross-board placement/migration policy: its `epoch_s` paces the
    /// placement thread in wall seconds. Active only when `boards > 1`
    /// and the mode is [`LiveMode::Dynamic`].
    pub cluster: ClusterPolicy,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self {
            policy: PolicyConfig::default(),
            mode: LiveMode::Dynamic,
            timescale: 0.0,
            max_sleep: Duration::from_millis(100),
            shards: 1,
            dse_workers: 1,
            boards: 1,
            cluster: ClusterPolicy::default(),
        }
    }
}

/// One request in the live path.
#[derive(Debug)]
pub struct LiveRequest {
    /// Caller-assigned request id (reporting only).
    pub id: u64,
    /// Wall-clock admission instant; latency is measured from here.
    pub enqueued: Instant,
}

impl LiveRequest {
    /// A request enqueued now.
    pub fn new(id: u64) -> Self {
        Self { id, enqueued: Instant::now() }
    }
}

/// Per-tenant outcome of a live run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Tenant name (from its [`TenantSpec`]).
    pub name: String,
    /// Requests fully served.
    pub served: u64,
    /// Requests refused by the tenant's fabric-time token bucket.
    pub throttled: u64,
    /// Fabric seconds consumed on this tenant's behalf (layer steps,
    /// swap charges while packed, and switch charges while leading a
    /// partition).
    pub fabric_s: f64,
    /// Wall-clock latency distribution of served requests (seconds).
    pub wall_latency: LatencyHistogram,
    /// The tenant's effective latency-SLO deadline in fabric seconds
    /// (`None` for throughput tiers).
    pub slo_deadline_s: Option<f64>,
    /// Served requests that met the deadline on the fabric timeline
    /// (always 0 for throughput tiers).
    pub slo_met: u64,
    /// Served requests that missed it.
    pub slo_missed: u64,
}

impl TenantReport {
    /// Tail wall-clock latency (p99) of this tenant's served requests.
    pub fn p99_s(&self) -> f64 {
        self.wall_latency.p99()
    }

    /// Fraction of served requests that met the latency-SLO deadline
    /// (`1.0` for throughput tiers and when nothing was served).
    pub fn slo_attainment(&self) -> f64 {
        if self.slo_met + self.slo_missed == 0 {
            1.0
        } else {
            self.slo_met as f64 / (self.slo_met + self.slo_missed) as f64
        }
    }
}

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    /// One entry per tenant, in spec order.
    pub tenants: Vec<TenantReport>,
    /// Re-compositions performed (setup split excluded).
    pub switches: u64,
    /// In-flight batches preempted at a layer boundary.
    pub preemptions: u64,
    /// Pack transitions (tenants merged onto a shared partition).
    pub packs: u64,
    /// Unpack transitions (a packed group dissolved after draining).
    pub unpacks: u64,
    /// Cursor context swaps charged by partition interleavers.
    pub pack_swaps: u64,
    /// Batches that executed inside a packed group's interleaver
    /// (admissions and mid-flight handoffs).
    pub packed_batches: u64,
    /// Size of every pack group formed, in transition order.
    pub pack_group_sizes: Vec<usize>,
    /// Cross-board tenant migrations performed by the placement thread
    /// (always 0 on a single board).
    pub migrations: u64,
    /// Schedule-cache activity during this run only (the cache may be
    /// shared with calibration or simulation phases).
    pub cache_hits: u64,
    /// Schedule-cache misses during this run only.
    pub cache_misses: u64,
    /// Wall-clock seconds from [`FabricScheduler::run`] entry to exit.
    pub wall_s: f64,
}

impl LiveReport {
    /// Requests served across every tenant.
    pub fn total_served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    /// Worst per-tenant p99 wall latency (seconds).
    pub fn worst_p99_s(&self) -> f64 {
        self.tenants.iter().map(|t| t.p99_s()).fold(0.0, f64::max)
    }

    /// Worst per-tenant SLO attainment across latency-tier tenants
    /// (`1.0` when no tenant carries a deadline).
    pub fn worst_slo_attainment(&self) -> f64 {
        self.tenants
            .iter()
            .filter(|t| t.slo_deadline_s.is_some())
            .map(TenantReport::slo_attainment)
            .fold(1.0, f64::min)
    }

    /// Multi-line human-readable summary.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for t in &self.tenants {
            let slo = if t.slo_deadline_s.is_some() {
                format!("  slo {:.3}", t.slo_attainment())
            } else {
                String::new()
            };
            s.push_str(&format!(
                "  {:<10} served {:>6}  throttled {:>4}  fabric {:.4e} s  wall {}{}\n",
                t.name,
                t.served,
                t.throttled,
                t.fabric_s,
                t.wall_latency.summary(),
                slo,
            ));
        }
        s.push_str(&format!(
            "  {} re-compositions ({} preemptive) | {} packs {:?}, {} unpacks, {} swaps, \
             {} packed batches | {} migrations | worst p99 {:.3e} s | \
             schedule cache: {} hits, {} misses | {:.2} s wall",
            self.switches,
            self.preemptions,
            self.packs,
            self.pack_group_sizes,
            self.unpacks,
            self.pack_swaps,
            self.packed_batches,
            self.migrations,
            self.worst_p99_s(),
            self.cache_hits,
            self.cache_misses,
            self.wall_s
        ));
        s
    }
}

/// A point-in-time view of the scheduler's composition, captured under
/// a single engine-lock acquisition by [`FabricScheduler::snapshot`].
/// Per-field accessors would each take the lock separately, so a
/// transition landing between two reads could pair tenant names with
/// another composition's dimensions; the snapshot cannot tear.
#[derive(Debug, Clone)]
pub struct SchedulerSnapshot {
    /// Number of tenants the scheduler serves.
    pub num_tenants: usize,
    /// For each tenant, the tenant whose partition currently hosts it
    /// (itself unless the policy packed it onto another's slice).
    pub hosts: Vec<usize>,
    /// Current composition as `(name, fmus, cus)` triples, in tenant
    /// order. Packed tenants report their shared partition's dimensions.
    pub composition: Vec<(String, u32, u32)>,
    /// The engine's fabric clock at capture time (seconds).
    pub now_s: f64,
}

/// State behind one board's engine lock: the deterministic core plus
/// the shell-side bookkeeping that pairs live requests with engine
/// events. All indexing in here is *board-local*; `residents`
/// translates back to the scheduler's global tenant space.
struct Shared {
    engine: FabricEngine,
    /// The wall↔fabric mapping this board's shells share. Re-anchored
    /// ([`WallClock::resync`]) when a push lands on an idle engine, so
    /// idle wall time is never banked as pacing lead — without that, a
    /// burst after a producer gap would drain unpaced at host speed.
    clock: WallClock,
    /// Admitted-but-unfinished requests per local tenant, in engine
    /// order (the engine serves each tenant strictly FIFO, so
    /// `BatchDone` events pop from the front).
    reqs: Vec<VecDeque<LiveRequest>>,
    /// Wall-clock latency histograms, recorded at `BatchDone`.
    hist: Vec<LatencyHistogram>,
    /// `residents[l]` is the global index of this board's local tenant
    /// `l` — kept in lock-step with the engine's lane order (and with
    /// the scheduler's placement map) across migrations.
    residents: Vec<usize>,
    closed: bool,
    finished: bool,
}

/// One board of the live cluster: an engine (plus its shell-side
/// bookkeeping) behind its own mutex, with its own condvar so pushes
/// and migrations wake only the shells driving this board.
struct BoardCell {
    shared: Mutex<Shared>,
    cv: Condvar,
}

/// Live multi-tenant scheduler over one or more dynamically
/// re-partitioned fabric boards: producer threads [`Self::push`] into
/// the owning board's [`FabricEngine`]; per-board worker shells drive
/// the engines under wall pacing; on a multi-board cluster a single
/// placement thread migrates tenants across boards when the backlog
/// imbalance crosses the [`ClusterPolicy`] hysteresis. With
/// `boards == 1` (the default) every code path reduces to the classic
/// single-fabric scheduler, bit-for-bit.
///
/// Lock order everywhere: `placement` first, then board mutexes in
/// ascending board order — so a push's placement lookup and the
/// placement thread's migration can never deadlock.
pub struct FabricScheduler {
    cache: Arc<ScheduleCache>,
    cfg: LiveConfig,
    /// The boards, each behind its own mutex (ascending lock order).
    boards: Vec<BoardCell>,
    /// Global tenant → (board, board-local index). Held while a push
    /// resolves its target board (released only after the board lock
    /// is taken, so a migration cannot move the tenant in between).
    placement: Mutex<Vec<(usize, usize)>>,
    /// Cross-board migrations performed so far.
    migrations: AtomicU64,
    stop_policy: AtomicBool,
    /// Deterministic-ingest mode ([`Self::with_arrivals`]): the engine
    /// consumes its own virtual-time trace and the idle-relaxation
    /// shell stays out of the way, so the run replays the simulator.
    /// Requires a single board.
    deterministic: bool,
    /// Engine-mutex hold-time meter (shared by every board), fed by
    /// [`Self::push`] and [`Self::policy_step`] and shared with the
    /// engines' timeline sampling.
    lock_meter: Arc<LockMeter>,
    /// The async-DSE solver thread, spawned when the policy opts in
    /// ([`PolicyConfig::async_solve`], [`LiveMode::Dynamic`] only).
    /// Declared after `boards`: the engines' requester channel clones
    /// drop with `boards` first, so the solver's shutdown join can
    /// observe a disconnected queue and terminate.
    background: Option<BackgroundSolver>,
}

impl FabricScheduler {
    /// Build the scheduler: equal initial split (every tenant leads its
    /// own partition), schedules resolved through `cache` (pre-warming
    /// it counts as misses here, hits on every later re-composition
    /// into a seen shape).
    pub fn new(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
    ) -> Result<Self, String> {
        Self::build(platform, base, specs, cache, cfg, Vec::new(), false)
    }

    /// Build a scheduler that ingests `arrivals` (a virtual-time trace,
    /// as the simulator would) instead of external pushes, with engine
    /// event tracing enabled — the deterministic mode the live-vs-sim
    /// differential test runs in. Close it immediately and [`Self::run`];
    /// the trace is retrieved with [`Self::take_trace`] afterwards.
    pub fn with_arrivals(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
        arrivals: Vec<Arrival>,
    ) -> Result<Self, String> {
        Self::build(platform, base, specs, cache, cfg, arrivals, true)
    }

    fn build(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
        arrivals: Vec<Arrival>,
        deterministic: bool,
    ) -> Result<Self, String> {
        if deterministic && cfg.boards != 1 {
            return Err("deterministic arrival ingest requires a single board".into());
        }
        // Share-driven first-fit placement — the same initial placement
        // the virtual-time cluster computes.
        let assignment = first_fit_placement(&specs, cfg.boards)?;
        let mut residents: Vec<Vec<usize>> = vec![Vec::new(); cfg.boards];
        let mut placement = vec![(0usize, 0usize); specs.len()];
        for (g, &b) in assignment.iter().enumerate() {
            placement[g] = (b, residents[b].len());
            residents[b].push(g);
        }
        // The async-DSE solver works against the same shared cache and
        // platform; spawn it before the engines so each engine can hold
        // a requester channel from construction.
        let background = (cfg.mode == LiveMode::Dynamic && cfg.policy.async_solve).then(|| {
            BackgroundSolver::spawn_pool(platform.clone(), cache.clone(), cfg.dse_workers.max(1))
        });
        let lock_meter = Arc::new(LockMeter::new());
        let mut boards = Vec::with_capacity(cfg.boards);
        for (b, locals) in residents.into_iter().enumerate() {
            let board_specs: Vec<TenantSpec> =
                locals.iter().map(|&g| specs[g].clone()).collect();
            let n_local = board_specs.len();
            // Deterministic ingest is single-board, so the whole trace
            // belongs to board 0 (the only board).
            let board_arrivals = if b == 0 { arrivals.clone() } else { Vec::new() };
            let mut engine = match cfg.mode {
                // The unified and static compositions run no policy:
                // each board's shape is fixed for the whole run.
                LiveMode::Unified => FabricEngine::new_unified(
                    platform.clone(),
                    base.clone(),
                    board_specs,
                    None,
                    board_arrivals,
                    &cache,
                )?,
                LiveMode::StaticEqual => FabricEngine::new_on_board(
                    platform.clone(),
                    base.clone(),
                    board_specs,
                    None,
                    None,
                    board_arrivals,
                    &cache,
                    b,
                )?,
                LiveMode::Dynamic => {
                    // Policy epochs live on the engine's fabric
                    // timeline; a paced run converts the wall-clock
                    // epoch through the timescale (an unpaced run
                    // drains at host speed, where the configured value
                    // is the only meaningful fabric budget).
                    let mut policy = cfg.policy.clone();
                    if cfg.timescale > 0.0 {
                        policy.epoch_s = cfg.policy.epoch_s / cfg.timescale;
                    }
                    FabricEngine::new_on_board(
                        platform.clone(),
                        base.clone(),
                        board_specs,
                        Some(policy),
                        None,
                        board_arrivals,
                        &cache,
                        b,
                    )?
                }
            };
            engine.eager_completions(true);
            engine.set_shards(cfg.shards);
            engine.set_lock_meter(lock_meter.clone());
            if let Some(solver) = &background {
                engine.set_solve_channel(solver.requester());
            }
            if deterministic {
                engine.record_trace(true);
            }
            boards.push(BoardCell {
                shared: Mutex::new(Shared {
                    engine,
                    clock: WallClock::new(cfg.timescale, cfg.max_sleep),
                    reqs: (0..n_local).map(|_| VecDeque::new()).collect(),
                    hist: vec![LatencyHistogram::new(); n_local],
                    residents: locals,
                    closed: false,
                    finished: false,
                }),
                cv: Condvar::new(),
            });
        }
        Ok(Self {
            cache,
            boards,
            placement: Mutex::new(placement),
            migrations: AtomicU64::new(0),
            stop_policy: AtomicBool::new(false),
            deterministic,
            lock_meter,
            background,
            cfg,
        })
    }

    /// Number of tenants this scheduler serves (across every board).
    pub fn num_tenants(&self) -> usize {
        self.placement.lock().unwrap().len()
    }

    /// Number of fabric boards this scheduler hosts.
    pub fn num_boards(&self) -> usize {
        self.boards.len()
    }

    /// Cross-board migrations performed so far.
    pub fn migrations(&self) -> u64 {
        self.migrations.load(Ordering::Relaxed)
    }

    /// A consistent point-in-time view of the composition (global
    /// tenant indexing), read under the placement lock plus one lock
    /// acquisition per board — the accessor callers use instead of
    /// stitching together per-field reads (each of which would take
    /// and release locks, interleaving with transitions). `now_s` is
    /// the furthest board's fabric clock.
    pub fn snapshot(&self) -> SchedulerSnapshot {
        let placement = self.placement.lock().unwrap();
        let n = placement.len();
        let mut hosts = vec![0usize; n];
        let mut composition = vec![(String::new(), 0u32, 0u32); n];
        let mut now_s = 0.0f64;
        for cell in &self.boards {
            let s = cell.shared.lock().unwrap();
            now_s = now_s.max(s.engine.now_s());
            for (l, &g) in s.residents.iter().enumerate() {
                hosts[g] = s.residents[s.engine.host(l)];
                let (fmus, cus) = s.engine.dims(l);
                composition[g] = (s.engine.tenant_name(l).to_string(), fmus, cus);
            }
        }
        SchedulerSnapshot { num_tenants: n, hosts, composition, now_s }
    }

    /// Admission-controlled enqueue for tenant `t`: closed check, then
    /// queue depth, then the tenant's fabric-time token bucket (charged
    /// the request's estimated cost on the current slice) — the same
    /// classification order as the simulator's trace ingest, because it
    /// *is* the engine's one admission path. The engine-lock hold time
    /// is metered into [`Self::stall_stats`] and the epoch timeline.
    pub fn push(&self, t: usize, req: LiveRequest) -> Result<(), PushError> {
        // Resolve the tenant's board under the placement lock and keep
        // holding it until the board lock is taken: a migration (which
        // acquires the same locks in the same order) can then never
        // move the tenant between the lookup and the enqueue.
        let placement = self.placement.lock().unwrap();
        let (b, local) = placement[t];
        let cell = &self.boards[b];
        let mut s = cell.shared.lock().unwrap();
        drop(placement);
        let t0 = Instant::now();
        let res = self.push_locked(&mut s, local, req);
        self.lock_meter.record_ns(t0.elapsed().as_nanos() as u64);
        drop(s);
        if res.is_ok() {
            cell.cv.notify_all();
        }
        res
    }

    /// The body of [`Self::push`], under the caller-held board lock;
    /// `t` is the tenant's board-local index.
    fn push_locked(&self, s: &mut Shared, t: usize, req: LiveRequest) -> Result<(), PushError> {
        if s.closed {
            return Err(PushError::Closed);
        }
        // A push onto an idle engine re-anchors the pacing map: the
        // fabric clock stood still while the wall clock ran, and that
        // gap must not be banked as pacing lead.
        if s.clock.timescale() > 0.0 && !s.engine.has_work() && !s.engine.trace_pending() {
            let fabric_now = s.engine.now_s();
            s.clock.resync(fabric_now);
        }
        let arr_s = s.clock.now_s();
        // Catch the engine's fabric clock up to the wall before
        // admitting: with no event instants between (say, one long
        // preempt-off batch in flight), the engine lags wall-fabric
        // time, and a batch started against the lagging clock would
        // execute in the fabric past — unpaced, with a corrupt
        // latency stamp. Never steps past a scheduled instant.
        if s.clock.timescale() > 0.0
            && arr_s > s.engine.now_s()
            && s.engine.next_time().is_none_or(|next| next > arr_s)
        {
            let events = s.engine.step(arr_s, &self.cache);
            Self::record(s, &events);
        }
        s.engine.push(t, req.id, arr_s)?;
        s.reqs[t].push_back(req);
        Ok(())
    }

    /// Close ingress; the run ends once every board's engine drains.
    pub fn close(&self) {
        for cell in &self.boards {
            cell.shared.lock().unwrap().closed = true;
            cell.cv.notify_all();
        }
    }

    /// Force one policy evaluation on every board at its current
    /// fabric instant (the epoch schedules are untouched). Returns
    /// true when any board's composition changed. Public so
    /// step-driven callers (and tests) can exercise the policy without
    /// the wall-clock loop. The engine-lock hold times are metered
    /// into [`Self::stall_stats`].
    pub fn policy_step(&self) -> bool {
        let mut changed = false;
        for cell in &self.boards {
            let mut s = cell.shared.lock().unwrap();
            let t0 = Instant::now();
            changed |= s.engine.epoch_now(&self.cache);
            self.lock_meter.record_ns(t0.elapsed().as_nanos() as u64);
        }
        changed
    }

    /// Cumulative contention counters: engine-mutex hold time from
    /// [`Self::push`] and [`Self::policy_step`], and DSE stalls from
    /// the shared schedule cache (which may include other users of the
    /// same cache — share a cache per serving stack to keep this
    /// attribution clean).
    pub fn stall_stats(&self) -> StallStats {
        StallStats {
            lock_held_ns: self.lock_meter.held_ns(),
            lock_holds: self.lock_meter.holds(),
            dse_stall_ns: self.cache.stall_ns(),
            dse_stalls: self.cache.stalls(),
            coalesced_solves: self.cache.coalesced_solves(),
            cross_board_hits: self.cache.cross_board_hits(),
        }
    }

    /// Drop every request still pending for global tenant `t` (not yet
    /// in a batch), returning how many were discarded — an operational
    /// shed-load aid, also used by tests to empty a backlog.
    pub fn drain_pending(&self, t: usize) -> usize {
        let placement = self.placement.lock().unwrap();
        let (b, local) = placement[t];
        let mut s = self.boards[b].shared.lock().unwrap();
        drop(placement);
        let n = s.engine.drain_pending(local);
        for _ in 0..n {
            s.reqs[local].pop_back();
        }
        n
    }

    /// The engine event trace recorded so far (empty unless built with
    /// [`Self::with_arrivals`]). Call after [`Self::run`] returns.
    /// Deterministic tracing is single-board, so this reads board 0.
    pub fn take_trace(&self) -> Vec<EngineEvent> {
        self.boards[0].shared.lock().unwrap().engine.take_trace()
    }

    /// Enable or disable engine event tracing for this run (on by
    /// construction in [`Self::with_arrivals`]; call before
    /// [`Self::run`] to capture a trace from an externally-pushed live
    /// run, e.g. `filco serve --mode live --trace-out`). Live tracing
    /// captures board 0's engine — on a multi-board cluster the CLI
    /// refuses `--trace-out` rather than emit a partial trace.
    pub fn record_trace(&self, on: bool) {
        self.boards[0].shared.lock().unwrap().engine.record_trace(on);
    }

    /// Enable or disable per-epoch timeline sampling
    /// ([`super::telemetry::EpochSample`]) on every board. Only
    /// meaningful in [`LiveMode::Dynamic`] — fixed compositions run no
    /// policy epochs, so their timelines stay empty.
    pub fn record_timeline(&self, on: bool) {
        for cell in &self.boards {
            cell.shared.lock().unwrap().engine.record_timeline(on);
        }
    }

    /// The epoch samples recorded so far across every board (empty
    /// unless [`Self::record_timeline`] was enabled), merged in
    /// `(at_s, board)` order. Call after [`Self::run`] returns.
    pub fn take_timeline(&self) -> Vec<super::telemetry::EpochSample> {
        let mut flat: Vec<super::telemetry::EpochSample> = self
            .boards
            .iter()
            .flat_map(|cell| cell.shared.lock().unwrap().engine.take_timeline())
            .collect();
        flat.sort_by(|a, b| a.at_s.total_cmp(&b.at_s).then(a.board.cmp(&b.board)));
        flat
    }

    /// The engine-side fabric-time report for this run, in the same
    /// shape the simulator emits ([`super::ServeReport`]) — the footer
    /// a recorded live trace is verified against. On a multi-board
    /// cluster the per-board reports are merged back into global
    /// tenant indexing ([`super::cluster`]'s scatter merge). Call
    /// after [`Self::run`] returns.
    pub fn serve_report(&self) -> super::ServeReport {
        let label = match self.cfg.mode {
            LiveMode::Unified => "unified",
            LiveMode::StaticEqual => "static-equal",
            LiveMode::Dynamic => "dynamic",
        };
        if self.boards.len() == 1 {
            return super::sim::report_from_engine(
                &self.boards[0].shared.lock().unwrap().engine,
                label,
            );
        }
        let placement = self.placement.lock().unwrap();
        let n = placement.len();
        let mut per_board = Vec::with_capacity(self.boards.len());
        let mut residents = Vec::with_capacity(self.boards.len());
        for cell in &self.boards {
            let s = cell.shared.lock().unwrap();
            per_board.push(super::sim::report_from_engine(&s.engine, label));
            residents.push(s.residents.clone());
        }
        super::cluster::merge_reports(label, &per_board, &residents, n)
    }

    /// Record wall latencies for the batches an engine step completed.
    fn record(s: &mut Shared, events: &[EngineEvent]) {
        for ev in events {
            if let EngineEvent::BatchDone { tenant, n, .. } = ev {
                for _ in 0..*n {
                    if let Some(req) = s.reqs[*tenant].pop_front() {
                        s.hist[*tenant].record(req.enqueued.elapsed().as_secs_f64());
                    }
                }
            }
        }
    }

    /// The worker shell for board `b`: one bounded drive pass per
    /// iteration — ask the board's engine for its next fabric instant;
    /// if it is due on the wall clock, step the engine under the same
    /// lock hold, otherwise wait toward the deadline on the board's
    /// condvar (so an earlier-event push wakes the shell). Exits once
    /// ingress is closed and the engine has drained.
    fn worker_loop(&self, b: usize) {
        let cell = &self.boards[b];
        let max_sleep_s = self.cfg.max_sleep.as_secs_f64().max(1e-3);
        loop {
            let lead_s = {
                let mut s = cell.shared.lock().unwrap();
                if s.finished {
                    return;
                }
                let idle = !s.engine.has_work() && !s.engine.trace_pending();
                if idle {
                    if s.closed {
                        let events = s.engine.finish();
                        Self::record(&mut s, &events);
                        s.finished = true;
                        drop(s);
                        cell.cv.notify_all();
                        return;
                    }
                    let _ = cell.cv.wait_timeout(s, Duration::from_millis(20)).unwrap();
                    continue;
                }
                let Some(t) = s.engine.next_time() else {
                    // In-flight work whose completion needs no event
                    // can only appear with eager completions off; park
                    // briefly and re-check.
                    let _ = cell.cv.wait_timeout(s, Duration::from_millis(20)).unwrap();
                    continue;
                };
                let lead_s = s.clock.lead_s(t);
                if lead_s <= 0.0 {
                    let events = s.engine.step(t, &self.cache);
                    Self::record(&mut s, &events);
                    continue;
                }
                lead_s
            };
            // Not due yet: wait toward the deadline with the lock
            // released, capped so shutdown and re-planning stay
            // responsive; any push re-wakes us through the condvar.
            let wait = Duration::from_secs_f64(lead_s.min(max_sleep_s));
            let s = cell.shared.lock().unwrap();
            let _ = cell.cv.wait_timeout(s, wait).unwrap();
        }
    }

    /// The policy shell: epochs fire on each engine's fabric timeline
    /// while work flows; this thread only relaxes an idle, skewed
    /// board back to its equal split between bursts (a shape the
    /// schedule cache has always seen).
    fn policy_loop(&self) {
        let epoch = Duration::from_secs_f64(self.cfg.policy.epoch_s.max(1e-3));
        // Sleep in short slices so shutdown never waits a whole epoch.
        let slice = epoch.min(Duration::from_millis(20));
        let mut slept = Duration::ZERO;
        while !self.stop_policy.load(Ordering::Relaxed) {
            std::thread::sleep(slice);
            slept += slice;
            if slept < epoch {
                continue;
            }
            slept = Duration::ZERO;
            if self.stop_policy.load(Ordering::Relaxed) || self.deterministic {
                continue;
            }
            for cell in &self.boards {
                let mut s = cell.shared.lock().unwrap();
                if !s.finished
                    && !s.engine.has_work()
                    && !s.engine.trace_pending()
                    && !s.engine.weights_equal()
                {
                    s.engine.epoch_now(&self.cache);
                }
            }
        }
    }

    /// Step board state `s` through every event instant up to `target`
    /// and land its fabric clock there — the pre-migration
    /// synchronization that retires due completions before a cursor is
    /// checkpointed (mirroring the virtual-time cluster, where both
    /// boards always sit at the same global instant).
    fn drive_to(s: &mut Shared, target: f64, cache: &ScheduleCache) {
        while let Some(t) = s.engine.next_time() {
            if t > target {
                break;
            }
            let events = s.engine.step(t, cache);
            Self::record(s, &events);
        }
        if s.engine.now_s() < target {
            let events = s.engine.step(target, cache);
            Self::record(s, &events);
        }
    }

    /// The placement shell (multi-board [`LiveMode::Dynamic`] only):
    /// every [`ClusterPolicy::epoch_s`] wall seconds, compare per-board
    /// queued-backlog times and — when the max/min ratio crosses the
    /// re-armed `imbalance_hi` threshold — migrate the one tenant that
    /// most reduces the worst board's backlog, checkpointing its
    /// (possibly mid-DAG) batch losslessly and charging
    /// [`ClusterPolicy::migration_cost_s`] on the destination.
    fn placement_loop(&self) {
        let epoch = Duration::from_secs_f64(self.cfg.cluster.epoch_s.max(1e-3));
        let slice = epoch.min(Duration::from_millis(20));
        let mut slept = Duration::ZERO;
        // Hysteresis: a migration disarms the trigger until the ratio
        // decays below `imbalance_lo`, so one sustained skew cannot
        // thrash tenants back and forth.
        let mut armed = true;
        while !self.stop_policy.load(Ordering::Relaxed) {
            std::thread::sleep(slice);
            slept += slice;
            if slept < epoch {
                continue;
            }
            slept = Duration::ZERO;
            if self.stop_policy.load(Ordering::Relaxed) {
                continue;
            }
            self.placement_epoch(&mut armed);
        }
    }

    /// One placement evaluation under the full lock set (placement,
    /// then every board ascending — the global lock order). Returns
    /// true when a migration was performed.
    fn placement_epoch(&self, armed: &mut bool) -> bool {
        let p = self.cfg.cluster;
        let mut placement = self.placement.lock().unwrap();
        let mut shareds: Vec<_> =
            self.boards.iter().map(|cell| cell.shared.lock().unwrap()).collect();
        if shareds.iter().any(|s| s.finished) {
            return false;
        }
        // Queued-only backlog time per board: in-flight work finishes
        // where it runs either way, so it is no reason to migrate.
        let backlog: Vec<f64> = shareds
            .iter()
            .map(|s| {
                (0..s.engine.num_tenants())
                    .map(|l| s.engine.pending_len(l) as f64 * s.engine.per_request_s(l))
                    .sum()
            })
            .collect();
        let min = backlog.iter().copied().fold(f64::INFINITY, f64::min);
        let max = backlog.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        let ratio = if min <= 0.0 && max > 0.0 {
            f64::INFINITY
        } else if max <= 0.0 {
            0.0
        } else {
            max / min
        };
        if ratio <= p.imbalance_lo {
            *armed = true;
        }
        if !*armed || ratio < p.imbalance_hi {
            return false;
        }
        let src = (0..backlog.len())
            .fold(0, |best, b| if backlog[b] > backlog[best] { b } else { best });
        let dst = (0..backlog.len())
            .fold(0, |best, b| if backlog[b] < backlog[best] { b } else { best });
        if src == dst
            || !shareds[src].engine.migratable()
            || !shareds[dst].engine.can_host_migrant()
        {
            return false;
        }
        // Candidate: the source tenant whose departure minimizes the
        // post-migration worst of the two boards — and strictly
        // improves on the source's backlog, so a migration is never a
        // lateral move.
        let mut best: Option<(usize, usize, f64)> = None; // (local, global, post)
        for l in 0..shareds[src].engine.num_tenants() {
            let se = &shareds[src].engine;
            let bt = se.pending_len(l) as f64 * se.per_request_s(l);
            if bt < p.min_gain_s {
                continue;
            }
            let post = (backlog[src] - bt).max(backlog[dst] + bt);
            if post >= backlog[src] {
                continue;
            }
            let g = shareds[src].residents[l];
            if best.is_none_or(|(_, bg, bp)| post < bp || (post == bp && g < bg)) {
                best = Some((l, g, post));
            }
        }
        let Some((local, g, _)) = best else { return false };
        // Synchronize both engines on one fabric instant before the
        // checkpoint, so due completions retire on their home board.
        let target = shareds[src].engine.now_s().max(shareds[dst].engine.now_s());
        Self::drive_to(&mut shareds[src], target, &self.cache);
        Self::drive_to(&mut shareds[dst], target, &self.cache);
        // Stepping runs policy epochs, which may pack — re-check the
        // preconditions the checkpoint relies on.
        if !shareds[src].engine.migratable() || !shareds[dst].engine.can_host_migrant() {
            return false;
        }
        let Ok(ex) = shareds[src].engine.remove_tenant(local, target, &self.cache) else {
            return false;
        };
        let new_local = shareds[dst]
            .engine
            .install_tenant(ex, target, p.migration_cost_s, &self.cache)
            .expect("install after can_host_migrant");
        // Move the shell-side bookkeeping with the tenant and repair
        // both index spaces (engine lanes shifted down on the source).
        let reqs = shareds[src].reqs.remove(local);
        let hist = shareds[src].hist.remove(local);
        shareds[src].residents.remove(local);
        shareds[dst].reqs.push(reqs);
        shareds[dst].hist.push(hist);
        shareds[dst].residents.push(g);
        debug_assert_eq!(new_local + 1, shareds[dst].residents.len());
        placement[g] = (dst, new_local);
        for (l2, &g2) in shareds[src].residents.iter().enumerate() {
            placement[g2] = (src, l2);
        }
        self.migrations.fetch_add(1, Ordering::Relaxed);
        *armed = false;
        drop(shareds);
        self.boards[src].cv.notify_all();
        self.boards[dst].cv.notify_all();
        true
    }

    /// Run the worker, policy and placement shells until ingress is
    /// closed and every board's engine has drained. Producers push
    /// concurrently from other threads via [`Self::push`].
    ///
    /// One worker shell is spawned per tenant, bound to the tenant's
    /// initial board. A board's shells serialize on its lock, so the
    /// extra threads buy liveness (a shell stuck in a long pacing wait
    /// never stalls the board; any sibling picks up the next due
    /// instant), not parallelism — each engine's stepping is
    /// deliberately single-site. Boards, however, genuinely step in
    /// parallel: they share nothing but the schedule cache.
    pub fn run(&self) -> LiveReport {
        let t0 = Instant::now();
        // The cache may be shared with calibration / sim phases; report
        // only this run's activity.
        let (hits0, misses0) = (self.cache.hits(), self.cache.misses());
        let board_workers: Vec<usize> = self
            .boards
            .iter()
            .map(|cell| cell.shared.lock().unwrap().residents.len().max(1))
            .collect();
        std::thread::scope(|s| {
            let workers: Vec<_> = board_workers
                .iter()
                .enumerate()
                .flat_map(|(b, &n)| (0..n).map(move |_| b))
                .map(|b| s.spawn(move || self.worker_loop(b)))
                .collect();
            // Fixed compositions (unified / static) run no policy, so
            // no relaxation or placement shell is spawned for them.
            let policy =
                (self.cfg.mode == LiveMode::Dynamic).then(|| s.spawn(|| self.policy_loop()));
            let placement = (self.cfg.mode == LiveMode::Dynamic && self.boards.len() > 1)
                .then(|| s.spawn(|| self.placement_loop()));
            // Stop the policy threads before propagating any worker
            // panic: panicking while they still run would leave the
            // scope blocked on loops that never observe the flag.
            let worker_panicked =
                workers.into_iter().map(|w| usize::from(w.join().is_err())).sum::<usize>();
            self.stop_policy.store(true, Ordering::Relaxed);
            let policy_result = policy.map_or(Ok(()), |p| p.join());
            let placement_result = placement.map_or(Ok(()), |p| p.join());
            assert_eq!(worker_panicked, 0, "{worker_panicked} worker thread(s) panicked");
            policy_result.expect("policy thread panicked");
            placement_result.expect("placement thread panicked");
        });
        // Assemble the global report: every counter lives wholesale on
        // its tenant's final board, so this is a pure scatter.
        let placement = self.placement.lock().unwrap();
        let n = placement.len();
        let mut tenants: Vec<Option<TenantReport>> = vec![None; n];
        let (mut switches, mut preemptions, mut packs, mut unpacks) = (0, 0, 0, 0);
        let (mut pack_swaps, mut packed_batches) = (0, 0);
        let mut pack_group_sizes = Vec::new();
        for cell in &self.boards {
            let shared = cell.shared.lock().unwrap();
            let engine = &shared.engine;
            let served = engine.served();
            let (slo_met, slo_missed, slo_deadlines) =
                (engine.slo_met(), engine.slo_missed(), engine.slo_deadlines());
            for (l, &g) in shared.residents.iter().enumerate() {
                tenants[g] = Some(TenantReport {
                    name: engine.tenant_name(l).to_string(),
                    served: served[l],
                    throttled: engine.throttled()[l],
                    fabric_s: engine.fabric_s(l),
                    wall_latency: shared.hist[l].clone(),
                    slo_deadline_s: slo_deadlines[l],
                    slo_met: slo_met[l],
                    slo_missed: slo_missed[l],
                });
            }
            switches += engine.switches();
            preemptions += engine.preemptions();
            packs += engine.packs();
            unpacks += engine.unpacks();
            pack_swaps += engine.pack_swaps();
            packed_batches += engine.packed_batches();
            pack_group_sizes.extend_from_slice(engine.pack_group_sizes());
        }
        LiveReport {
            tenants: tenants
                .into_iter()
                .map(|t| t.expect("every tenant resides on exactly one board"))
                .collect(),
            switches,
            preemptions,
            packs,
            unpacks,
            pack_swaps,
            packed_batches,
            pack_group_sizes,
            migrations: self.migrations.load(Ordering::Relaxed),
            cache_hits: self.cache.hits() - hits0,
            cache_misses: self.cache.misses() - misses0,
            wall_s: t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 5 }
    }

    fn scheduler(caps: usize) -> FabricScheduler {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap()
    }

    #[test]
    fn serves_all_pushed_requests() {
        let sched = scheduler(10_000);
        for i in 0..200 {
            sched.push(i as usize % 2, LiveRequest::new(i)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 200);
        assert_eq!(report.tenants[0].served, 100);
        assert!(report.tenants[0].fabric_s > 0.0);
        assert_eq!(report.tenants[0].wall_latency.count(), 100);
        assert!(report.worst_p99_s() >= report.tenants[0].p99_s());
        // Packing never engaged: it is off by default.
        assert_eq!((report.packs, report.unpacks, report.packed_batches), (0, 0, 0));
        assert!(report.pack_group_sizes.is_empty());
    }

    #[test]
    fn admission_control_is_per_tenant() {
        let sched = scheduler(4);
        // The shells aren't running: the 4-deep engine queue must
        // overflow.
        let mut rejected = 0;
        for i in 0..10 {
            if sched.push(0, LiveRequest::new(i)).is_err() {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 6);
        assert_eq!(sched.boards[0].shared.lock().unwrap().engine.pending_len(1), 0);
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 4);
    }

    #[test]
    fn token_bucket_throttles_pushes() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        // Measure the equal-split per-request cost, then allow tenant a
        // a burst of exactly 3 requests and essentially no refill.
        let probe = vec![
            TenantSpec::new("a", zoo::mlp_s()),
            TenantSpec::new("b", zoo::mlp_s()),
        ];
        let per =
            crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        // 3.5x: mid-bucket headroom keeps the pass/throttle boundary
        // away from f64 rounding of repeated same-cost takes.
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_fabric_share(1e-12, 3.5 * per),
            TenantSpec::new("b", zoo::mlp_s()),
        ];
        let sched =
            FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap();
        let mut throttled = 0;
        for i in 0..6 {
            match sched.push(0, LiveRequest::new(i)) {
                Ok(()) => {}
                Err(PushError::Throttled) => throttled += 1,
                Err(e) => panic!("unexpected push error {e}"),
            }
        }
        assert_eq!(throttled, 3, "burst of 3 requests' fabric time, then throttle");
        // The unlimited tenant is unaffected.
        sched.push(1, LiveRequest::new(99)).unwrap();
        sched.close();
        let report = sched.run();
        assert_eq!(report.tenants[0].throttled, 3);
        assert_eq!(report.tenants[0].served, 3);
        assert_eq!(report.tenants[1].served, 1);
    }

    #[test]
    fn policy_step_resplits_under_skew() {
        let sched = scheduler(10_000);
        // Flood tenant a while the shells are not yet running.
        for i in 0..500 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        let before = sched.snapshot().composition;
        assert!(sched.policy_step(), "skewed backlog must trigger a re-split");
        let after = sched.snapshot().composition;
        assert!(after[0].2 > before[0].2, "tenant a must gain CUs: {before:?} -> {after:?}");
        // No batch in flight: nothing to preempt.
        {
            let s = sched.boards[0].shared.lock().unwrap();
            assert_eq!(s.engine.switches(), 1);
            assert_eq!(s.engine.preemptions(), 0);
        }
        // An idle fabric proposes the equal split again — a shape the
        // cache has already seen, so re-splitting back is pure hits.
        assert_eq!(sched.drain_pending(0), 500);
        let h0 = sched.cache.hits();
        assert!(sched.policy_step(), "drained backlog must restore the equal split");
        assert!(sched.cache.hits() > h0, "returning to a seen composition must hit the cache");
        sched.close();
        let report = sched.run();
        assert_eq!(report.switches, 2);
        assert_eq!(report.total_served(), 0, "drained requests are gone");
    }

    #[test]
    fn preemption_lands_at_a_layer_boundary_mid_batch() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("hot", zoo::mlp_s()).with_queue_capacity(10_000).with_max_batch(4096),
            TenantSpec::new("cold", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        // Pace the fabric so one big batch takes ~1 s of wall time:
        // plenty of layer boundaries for the policy epochs (50 ms of
        // wall, ~5% of the batch each) to land a preemption on.
        let probe = vec![
            TenantSpec::new("hot", zoo::mlp_s()),
            TenantSpec::new("cold", zoo::mlp_s()),
        ];
        let per = crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        let n = 400usize;
        let batch_s = crate::serve::tenant::batch_fabric_s(per, n);
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 0.05,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                ..PolicyConfig::default()
            },
            timescale: 1.0 / batch_s,
            ..LiveConfig::default()
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        for i in 0..n {
            sched.push(0, LiveRequest::new(i as u64)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), n as u64);
        assert!(report.switches >= 1, "in-flight remaining work must trigger a re-split");
        assert!(
            report.preemptions >= 1,
            "the engine must land at least one mid-batch preemption ({} switches)",
            report.switches
        );
    }

    #[test]
    fn policy_packs_and_unpacks_light_tenants() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let probe = vec![
            TenantSpec::new("heavy", zoo::mlp_s()),
            TenantSpec::new("s1", zoo::mlp_s()),
            TenantSpec::new("s2", zoo::mlp_s()),
        ];
        let per = crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        let specs = vec![
            TenantSpec::new("heavy", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s2", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 5.0 * per,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                pack_headroom_factor: 2.0,
                // Decouple the amortization gate from the model's
                // absolute time scale: this test is about transitions.
                pack_swap_margin: 1e9,
                ..PolicyConfig::default()
            },
            timescale: 0.0,
            ..LiveConfig::default()
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        // Flood the heavy tenant while the shells are not yet running;
        // the light tenants are idle, so the pack fit is trivially met.
        for i in 0..300 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        assert!(sched.policy_step(), "skew must trigger a re-split");
        {
            let s = sched.boards[0].shared.lock().unwrap();
            assert_eq!(s.engine.packs(), 1, "light pair must pack");
            assert_eq!(s.engine.pack_group_sizes(), &[2]);
        }
        let snap = sched.snapshot();
        assert_eq!(snap.hosts[2], 1, "s2 is hosted on s1's partition");
        assert_eq!(snap.hosts[1], 1);
        let comp = snap.composition;
        assert_eq!(
            (comp[1].1, comp[1].2),
            (comp[2].1, comp[2].2),
            "a packed pair shares one partition's dimensions: {comp:?}"
        );
        assert!(comp[0].2 > comp[1].2, "the heavy tenant gains the freed capacity: {comp:?}");
        // Flood a packed member past the unpack hysteresis: backlog of
        // 200 requests dwarfs the 5-request-epoch fit bound.
        for i in 0..200 {
            sched.push(2, LiveRequest::new(1000 + i)).unwrap();
        }
        assert!(sched.policy_step(), "unpack is a forced re-composition");
        {
            let s = sched.boards[0].shared.lock().unwrap();
            assert_eq!(s.engine.unpacks(), 1, "flooded member must unpack");
        }
        assert_eq!(sched.snapshot().hosts[2], 2);
        // Everything still gets served after the transitions. (Policy
        // epochs fire on the fabric timeline during the drain, so a
        // late re-pack of the emptied light pair is legitimate — the
        // floor, not an exact count, is the contract.)
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 500);
        assert!(report.packs >= 1);
        assert!(report.unpacks >= 1);
        assert!(report.pack_group_sizes.iter().all(|&s| s == 2));
    }

    #[test]
    fn packed_group_serves_its_members_queues() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("heavy", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s2", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 0.05,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                pack_headroom_factor: 2.0,
                pack_swap_margin: 1e9,
                ..PolicyConfig::default()
            },
            timescale: 0.0,
            ..LiveConfig::default()
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        for i in 0..100 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        // Pack the idle pair before the shells start.
        assert!(sched.policy_step());
        assert_eq!(sched.snapshot().hosts[2], 1);
        // Traffic for both packed members lands after the transition.
        for i in 0..40 {
            sched.push(1, LiveRequest::new(500 + i)).unwrap();
            sched.push(2, LiveRequest::new(600 + i)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 180, "no request may strand across packing");
        assert_eq!(report.tenants[1].served, 40);
        assert_eq!(report.tenants[2].served, 40);
        assert!(report.packed_batches >= 2, "member batches ran interleaved");
    }

    #[test]
    fn push_after_close_rejected() {
        let sched = scheduler(16);
        sched.close();
        assert_eq!(sched.push(0, LiveRequest::new(1)).unwrap_err(), PushError::Closed);
        let report = sched.run();
        assert_eq!(report.total_served(), 0);
    }

    /// Cold-start contract of the async-DSE path: an epoch whose
    /// proposed split is not cached defers to the background solver,
    /// and neither that epoch nor any `push` during the pending solve
    /// blocks longer than one policy epoch — the serving path's cost
    /// is a cache lookup, never a solve.
    #[test]
    fn async_solve_keeps_cold_epochs_off_the_push_path() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        let cfg = LiveConfig {
            policy: PolicyConfig { epoch_s: 0.25, ..PolicyConfig::default() }.with_async_solve(),
            timescale: 0.0,
            ..LiveConfig::default()
        };
        let epoch = Duration::from_secs_f64(cfg.policy.epoch_s);
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        // Flood tenant a while the shells are not running: the skewed
        // proposal's unequal slices are shapes calibration never saw.
        for i in 0..500 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        let t0 = Instant::now();
        let committed = sched.policy_step();
        let epoch_wall = t0.elapsed();
        assert!(!committed, "cold epoch must defer, not solve under the engine lock");
        assert!(epoch_wall < epoch, "deferring epoch blocked {epoch_wall:?} (> one epoch)");
        assert!(
            sched.boards[0].shared.lock().unwrap().engine.deferred_resplits() >= 1,
            "the deferral must be counted"
        );
        // Ingress stays bounded by a cache lookup while the solve is
        // in flight on the background thread.
        let t1 = Instant::now();
        sched.push(1, LiveRequest::new(9_000)).unwrap();
        let push_wall = t1.elapsed();
        assert!(push_wall < epoch, "push blocked {push_wall:?} during a pending solve");
        // Once the background solve lands, a later epoch re-proposes
        // the same split and commits it straight from the cache.
        let deadline = Instant::now() + Duration::from_secs(60);
        let mut committed = sched.policy_step();
        while !committed && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
            committed = sched.policy_step();
        }
        assert!(committed, "deferred resplit must commit once the solve lands");
        let stats = sched.stall_stats();
        assert!(stats.lock_holds >= 502, "every push and epoch meters its hold: {stats:?}");
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 501, "the full backlog drains after the transition");
    }
}
