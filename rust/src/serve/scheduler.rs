//! Live multi-tenant fabric scheduler: real threads, real queues,
//! layer-granular preemption, cross-tenant packing.
//!
//! One worker thread per tenant. A worker that *leads* a partition
//! drains its tenant's bounded queue in batches and executes them
//! through an [`Interleaver`] — a solo tenant's interleaver holds one
//! [`BatchCursor`]; a packed partition's holds one per co-located
//! tenant, time-multiplexed a quantum of layer steps at a time with
//! the composition-switch cost charged per context swap. The worker
//! retires one layer step at a time, charging each step's fabric
//! seconds as it goes, and checks each slot tenant's preemption
//! generation between steps — so when the policy thread re-splits the
//! fabric through the [`Reconfigurator`], the switch lands at the
//! *next layer boundary* of an in-flight batch (the remaining layers
//! resume on the new slice's cached schedule) instead of waiting for
//! the whole DAG to drain.
//!
//! Cross-tenant packing ([`should_pack`]) assigns a light tenant to
//! another tenant's partition: the hosted tenant's worker parks and the
//! host worker drains both queues into its interleaver. Pack and
//! unpack transitions are published by the policy thread under the
//! same lock discipline as preemptions (plan lock + generation bump)
//! and observed by workers at batch boundaries — which are layer-step
//! boundaries of the interleaved walk. Schedules resolve through the
//! [`ScheduleCache`] so the DSE never runs on the hot path after a
//! composition has been seen once.
//!
//! Fabric time is *accounted* (the modelled VCK190 is not attached);
//! `timescale` optionally paces workers so queue depths — and
//! therefore the policy — behave like they would on hardware. Pacing
//! is deadline-based (an internal pacer sleeps until `start +
//! consumed × timescale`) rather than per-step, so the
//! scheduler-jitter of thousands of sub-millisecond sleeps does not
//! accumulate into drift on long runs.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::arch::FilcoConfig;
use crate::coordinator::metrics::LatencyHistogram;
use crate::coordinator::reconfig::Reconfigurator;
use crate::platform::Platform;

use super::cache::{CachedSchedule, ScheduleCache};
use super::interleave::Interleaver;
use super::policy::{
    backlog_weights, pack_candidates, pack_quantum_s, should_pack, should_preempt,
    should_resplit, should_unpack, PolicyConfig,
};
use super::queue::{BoundedQueue, PushError};
use super::tenant::{BatchCursor, TenantSpec, TokenBucket};

/// Live-mode knobs.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Re-composition / preemption / packing policy (epochs in wall
    /// seconds for the live scheduler).
    pub policy: PolicyConfig,
    /// Wall seconds slept per fabric second to emulate device pacing;
    /// 0.0 drains at host speed (tests).
    pub timescale: f64,
    /// Cap on any single pacing sleep, so demos stay responsive.
    pub max_sleep: Duration,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self {
            policy: PolicyConfig::default(),
            timescale: 0.0,
            max_sleep: Duration::from_millis(100),
        }
    }
}

/// One request in the live path.
#[derive(Debug)]
pub struct LiveRequest {
    /// Caller-assigned request id (reporting only).
    pub id: u64,
    /// Wall-clock admission instant; latency is measured from here.
    pub enqueued: Instant,
}

impl LiveRequest {
    /// A request enqueued now.
    pub fn new(id: u64) -> Self {
        Self { id, enqueued: Instant::now() }
    }
}

/// Deadline-based pacer: tracks fabric seconds consumed since an
/// anchor instant and sleeps until `anchor + consumed × timescale`,
/// so per-sleep overshoot (OS scheduler granularity) is absorbed by
/// later steps instead of accumulating — a run of thousands of
/// sub-millisecond steps drifts by at most one sleep's overshoot, not
/// the sum of all of them. Workers anchor one pacer per batch.
struct Pacer {
    anchor: Instant,
    consumed_s: f64,
}

impl Pacer {
    fn new() -> Self {
        Self { anchor: Instant::now(), consumed_s: 0.0 }
    }

    /// Account `fabric_dur_s` and sleep off any lead over the
    /// deadline, capped at `max_sleep` per call (an extreme or
    /// non-finite timescale must throttle, not panic or hang).
    fn pace(&mut self, fabric_dur_s: f64, timescale: f64, max_sleep: Duration) {
        if timescale <= 0.0 {
            return;
        }
        self.consumed_s += fabric_dur_s.max(0.0);
        let lead = self.consumed_s * timescale - self.anchor.elapsed().as_secs_f64();
        if lead > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(lead.min(max_sleep.as_secs_f64())));
        }
    }
}

/// The slice a tenant's worker currently runs on.
#[derive(Clone)]
struct Plan {
    fmus: u32,
    cus: u32,
    sched: Arc<CachedSchedule>,
}

impl Plan {
    fn per_request_s(&self) -> f64 {
        self.sched.per_request_s
    }
}

struct TenantRuntime {
    spec: TenantSpec,
    queue: BoundedQueue<LiveRequest>,
    plan: Mutex<Plan>,
    hist: Mutex<LatencyHistogram>,
    /// Fabric seconds this tenant's slice has consumed (layer steps +
    /// switch charges).
    fabric_s: Mutex<f64>,
    served: AtomicU64,
    /// Admission token bucket (fabric-time share), if configured.
    bucket: Option<Mutex<TokenBucket>>,
    /// Bumped by the policy thread when an approved preemption should
    /// land at the worker's next layer boundary.
    preempt_gen: AtomicU64,
    /// Worker-published estimate of the in-flight batch's remaining
    /// fabric seconds (f64 bits; 0 when idle) — the policy's
    /// preemption-benefit signal.
    inflight_remaining: AtomicU64,
}

impl TenantRuntime {
    fn inflight_remaining_s(&self) -> f64 {
        f64::from_bits(self.inflight_remaining.load(Ordering::Relaxed))
    }

    fn publish_remaining(&self, remaining_s: f64) {
        self.inflight_remaining.store(remaining_s.to_bits(), Ordering::Relaxed);
    }
}

/// Per-tenant outcome of a live run.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Tenant name (from its [`TenantSpec`]).
    pub name: String,
    /// Requests fully served.
    pub served: u64,
    /// Requests refused by the tenant's fabric-time token bucket.
    pub throttled: u64,
    /// Fabric seconds consumed on this tenant's behalf (layer steps,
    /// swap charges while packed, and switch charges while leading a
    /// partition).
    pub fabric_s: f64,
    /// Wall-clock latency distribution of served requests (seconds).
    pub wall_latency: LatencyHistogram,
}

impl TenantReport {
    /// Tail wall-clock latency (p99) of this tenant's served requests.
    pub fn p99_s(&self) -> f64 {
        self.wall_latency.p99()
    }
}

/// Outcome of a live run.
#[derive(Debug, Clone)]
pub struct LiveReport {
    /// One entry per tenant, in spec order.
    pub tenants: Vec<TenantReport>,
    /// Re-compositions performed (setup split excluded).
    pub switches: u64,
    /// In-flight batches preempted at a layer boundary.
    pub preemptions: u64,
    /// Pack transitions (a tenant moved onto another's partition).
    pub packs: u64,
    /// Unpack transitions (a packed tenant given back its own slice).
    pub unpacks: u64,
    /// Cursor context swaps charged by partition interleavers.
    pub pack_swaps: u64,
    /// Interleaved walks that multiplexed two or more tenants.
    pub packed_batches: u64,
    /// Schedule-cache activity during this run only (the cache may be
    /// shared with calibration or simulation phases).
    pub cache_hits: u64,
    /// Schedule-cache misses during this run only.
    pub cache_misses: u64,
    /// Wall-clock seconds from [`FabricScheduler::run`] entry to exit.
    pub wall_s: f64,
}

impl LiveReport {
    /// Requests served across every tenant.
    pub fn total_served(&self) -> u64 {
        self.tenants.iter().map(|t| t.served).sum()
    }

    /// Worst per-tenant p99 wall latency (seconds).
    pub fn worst_p99_s(&self) -> f64 {
        self.tenants.iter().map(|t| t.p99_s()).fold(0.0, f64::max)
    }

    /// Multi-line human-readable summary.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for t in &self.tenants {
            s.push_str(&format!(
                "  {:<10} served {:>6}  throttled {:>4}  fabric {:.4e} s  wall {}\n",
                t.name,
                t.served,
                t.throttled,
                t.fabric_s,
                t.wall_latency.summary()
            ));
        }
        s.push_str(&format!(
            "  {} re-compositions ({} preemptive) | {} packs, {} unpacks, {} swaps, \
             {} packed batches | worst p99 {:.3e} s | \
             schedule cache: {} hits, {} misses | {:.2} s wall",
            self.switches,
            self.preemptions,
            self.packs,
            self.unpacks,
            self.pack_swaps,
            self.packed_batches,
            self.worst_p99_s(),
            self.cache_hits,
            self.cache_misses,
            self.wall_s
        ));
        s
    }
}

/// Live multi-tenant scheduler over a dynamically re-partitioned fabric.
///
/// Locking: per-tenant `plan` mutexes guard the (slice, schedule,
/// preemption-generation) snapshot; `recon` + `weights` are held only
/// by [`Self::policy_step`]; pack assignments (`host`) are written only
/// by the policy thread while holding `recon` and read by workers with
/// atomics at batch boundaries. No lock is held across a DSE run
/// except a cache-miss's own computation.
pub struct FabricScheduler {
    platform: Platform,
    base: FilcoConfig,
    cfg: LiveConfig,
    cache: Arc<ScheduleCache>,
    recon: Mutex<Reconfigurator>,
    /// Per-*group* partition weights (one entry per partition leader).
    weights: Mutex<Vec<u32>>,
    tenants: Vec<TenantRuntime>,
    /// `host[t]` is the tenant whose worker leads `t`'s partition;
    /// `host[t] == t` means `t` leads its own. Written only by the
    /// policy thread (under the `recon` lock), read by workers.
    host: Vec<AtomicUsize>,
    /// Token-bucket clock origin.
    t0: Instant,
    /// Re-compositions after setup.
    switches: AtomicU64,
    /// Approved mid-DAG preemptions landed by workers.
    preemptions: AtomicU64,
    /// Pack / unpack transitions decided by the policy.
    packs: AtomicU64,
    unpacks: AtomicU64,
    /// Context swaps charged by worker interleavers.
    pack_swaps: AtomicU64,
    /// Interleaved walks holding two or more tenants' cursors.
    packed_batches: AtomicU64,
    /// Bucket refusals per tenant index.
    throttled: Vec<AtomicU64>,
    stop_policy: AtomicBool,
    /// Copy of the reconfigurator's switch cost (fabric seconds), so
    /// workers never touch the `recon` lock on the hot path — the
    /// policy thread may hold it across a schedule-cache miss.
    switch_cost_s: f64,
}

impl FabricScheduler {
    /// Build the scheduler: equal initial split (every tenant leads its
    /// own partition), schedules resolved through `cache` (pre-warming
    /// it counts as misses here, hits on every later re-composition
    /// into a seen shape).
    pub fn new(
        platform: Platform,
        base: FilcoConfig,
        specs: Vec<TenantSpec>,
        cache: Arc<ScheduleCache>,
        cfg: LiveConfig,
    ) -> Result<Self, String> {
        if specs.is_empty() {
            return Err("no tenants".into());
        }
        let mut recon = Reconfigurator::new(base.clone());
        let weights = vec![1u32; specs.len()];
        let named: Vec<(&str, u32)> =
            specs.iter().zip(&weights).map(|(s, &w)| (s.name.as_str(), w)).collect();
        let parts = recon.split(&named)?;
        recon.validate()?;
        let throttled = specs.iter().map(|_| AtomicU64::new(0)).collect();
        let host = (0..specs.len()).map(AtomicUsize::new).collect();
        let switch_cost_s = recon.switch_cost_s();
        let tenants = specs
            .into_iter()
            .zip(&parts)
            .map(|(spec, part)| {
                let slice = part.config(&base);
                let cached = cache.get_or_compute(&platform, &slice, &spec.dag);
                let queue = BoundedQueue::new(spec.queue_capacity);
                TenantRuntime {
                    queue,
                    plan: Mutex::new(Plan {
                        fmus: part.n_fmus(),
                        cus: part.m_cus(),
                        sched: cached,
                    }),
                    hist: Mutex::new(LatencyHistogram::new()),
                    fabric_s: Mutex::new(0.0),
                    served: AtomicU64::new(0),
                    bucket: spec.rate_limit.map(|rl| Mutex::new(TokenBucket::from_limit(rl))),
                    preempt_gen: AtomicU64::new(0),
                    inflight_remaining: AtomicU64::new(0.0f64.to_bits()),
                    spec,
                }
            })
            .collect();
        Ok(Self {
            platform,
            base,
            cfg,
            cache,
            recon: Mutex::new(recon),
            weights: Mutex::new(weights),
            tenants,
            host,
            t0: Instant::now(),
            switches: AtomicU64::new(0),
            preemptions: AtomicU64::new(0),
            packs: AtomicU64::new(0),
            unpacks: AtomicU64::new(0),
            pack_swaps: AtomicU64::new(0),
            packed_batches: AtomicU64::new(0),
            throttled,
            stop_policy: AtomicBool::new(false),
            switch_cost_s,
        })
    }

    /// Number of tenants this scheduler serves.
    pub fn num_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// The tenant whose worker currently leads `t`'s partition (`t`
    /// itself unless the policy packed `t` onto another's slice).
    pub fn host_of(&self, t: usize) -> usize {
        let h = self.host[t].load(Ordering::Acquire);
        if h < self.tenants.len() {
            h
        } else {
            t
        }
    }

    /// Admission-controlled enqueue for tenant `t`: closed check, then
    /// queue depth, then the tenant's fabric-time token bucket (charged
    /// the request's estimated cost on the current slice) — the same
    /// classification order as the simulator's ingest, so a
    /// full-queue-and-empty-bucket request counts as `Full` in both
    /// paths. Tokens taken for a request the queue then refuses in a
    /// concurrent-drain race are refunded.
    pub fn push(&self, t: usize, req: LiveRequest) -> Result<(), PushError> {
        let tr = &self.tenants[t];
        if tr.queue.is_closed() {
            return Err(PushError::Closed);
        }
        if tr.queue.len() >= tr.queue.capacity() {
            return Err(PushError::Full);
        }
        let cost = match &tr.bucket {
            None => 0.0,
            Some(b) => {
                let cost = tr.plan.lock().unwrap().per_request_s();
                let now_s = self.t0.elapsed().as_secs_f64();
                if !b.lock().unwrap().try_take(cost, now_s) {
                    self.throttled[t].fetch_add(1, Ordering::Relaxed);
                    return Err(PushError::Throttled);
                }
                cost
            }
        };
        let pushed = tr.queue.try_push(req);
        if pushed.is_err() && cost > 0.0 {
            if let Some(b) = &tr.bucket {
                b.lock().unwrap().refund(cost);
            }
        }
        pushed
    }

    /// Close every tenant queue; workers exit once drained.
    pub fn close(&self) {
        for t in &self.tenants {
            t.queue.close();
        }
    }

    /// Current composition as `(name, fmus, cus)` triples. Packed
    /// tenants report their shared partition's dimensions.
    pub fn composition(&self) -> Vec<(String, u32, u32)> {
        self.tenants
            .iter()
            .map(|t| {
                let p = t.plan.lock().unwrap();
                (t.spec.name.clone(), p.fmus, p.cus)
            })
            .collect()
    }

    /// Execute one interleaved walk over `batches` (one entry per
    /// tenant with work; a solo walk is the one-slot case). Charges
    /// step durations and swap costs into per-tenant fabric time,
    /// paces by the deadline pacer, lands approved preemptions at step
    /// boundaries, and records latencies as each slot's batch retires.
    fn serve_interleaved(&self, batches: Vec<(usize, Vec<LiveRequest>)>) {
        let mut il = Interleaver::new(self.switch_cost_s, self.cfg.policy.pack_quantum_steps);
        // Snapshot (plan, preemption generation) under each tenant's
        // plan lock: the policy writes both under the same lock, so a
        // worker can never pair a new schedule with a stale generation
        // and count a phantom preemption.
        let mut gens: Vec<(usize, u64)> = Vec::with_capacity(batches.len());
        for (tenant, reqs) in &batches {
            let tr = &self.tenants[*tenant];
            {
                let p = tr.plan.lock().unwrap();
                let g = tr.preempt_gen.load(Ordering::Acquire);
                il.add(*tenant, BatchCursor::new(p.sched.clone(), reqs.len()));
                gens.push((*tenant, g));
            }
            tr.publish_remaining(il.slot_remaining_s(*tenant));
        }
        if batches.len() > 1 {
            self.packed_batches.fetch_add(1, Ordering::Relaxed);
        }
        let mut pacer = Pacer::new();
        while let Some(ev) = il.advance() {
            let dur = ev.step.dur_s + ev.swap_charge_s;
            let tr = &self.tenants[ev.tenant];
            *tr.fabric_s.lock().unwrap() += dur;
            pacer.pace(dur, self.cfg.timescale, self.cfg.max_sleep);
            tr.publish_remaining(il.slot_remaining_s(ev.tenant));
            if ev.done {
                let (_, reqs) = batches.iter().find(|(t, _)| *t == ev.tenant).unwrap();
                let mut hist = tr.hist.lock().unwrap();
                for req in reqs {
                    hist.record(req.enqueued.elapsed().as_secs_f64());
                }
                drop(hist);
                tr.served.fetch_add(reqs.len() as u64, Ordering::Relaxed);
            }
            // Approved preemptions land here, at the step boundary: the
            // affected slot re-bases its remaining layers onto the
            // slice the policy just assigned its tenant.
            for g in gens.iter_mut() {
                let (tenant, seen) = *g;
                if !il.contains(tenant) {
                    continue;
                }
                let tt = &self.tenants[tenant];
                let cur = tt.preempt_gen.load(Ordering::Acquire);
                if cur != seen {
                    g.1 = cur;
                    let sched = tt.plan.lock().unwrap().sched.clone();
                    // The mid-DAG switch cost is charged by policy_step
                    // into fabric_s (exactly once per slice per
                    // re-split); the cursor only re-bases.
                    il.retarget(tenant, sched, 0.0);
                    self.preemptions.fetch_add(1, Ordering::Relaxed);
                    tt.publish_remaining(il.slot_remaining_s(tenant));
                }
            }
        }
        for (tenant, _) in &batches {
            self.tenants[*tenant].publish_remaining(0.0);
        }
        self.pack_swaps.fetch_add(il.swaps(), Ordering::Relaxed);
    }

    fn worker(&self, i: usize) {
        let t = &self.tenants[i];
        loop {
            // Parked: the policy packed this tenant onto another's
            // partition, whose worker drains our queue. Once the queue
            // closes, fall through and serve any remainder ourselves —
            // the host may exit before us and requests must not strand.
            // Poll at the idle pop's cadence: transitions land at
            // policy epochs (default 200 ms), so faster wakeups would
            // buy nothing.
            if self.host_of(i) != i && !t.queue.is_closed() {
                std::thread::sleep(Duration::from_millis(20));
                continue;
            }
            let Some(own) = t.queue.pop_batch_timeout(t.spec.max_batch, Duration::from_millis(20))
            else {
                break; // closed and drained
            };
            let mut batches: Vec<(usize, Vec<LiveRequest>)> = Vec::new();
            if !own.is_empty() {
                batches.push((i, own));
            }
            // Drain packed partners' queues into extra interleaver
            // slots (non-blocking; partnership is re-observed every
            // batch, so pack/unpack transitions land at batch
            // boundaries — themselves layer-step boundaries).
            for (j, tj) in self.tenants.iter().enumerate() {
                if j != i && self.host_of(j) == i {
                    if let Some(b) = tj.queue.pop_batch_timeout(tj.spec.max_batch, Duration::ZERO)
                    {
                        if !b.is_empty() {
                            batches.push((j, b));
                        }
                    }
                }
            }
            if batches.is_empty() {
                continue; // timeout — re-observe pack state and plan
            }
            self.serve_interleaved(batches);
        }
    }

    /// One policy evaluation: observe backlog (queued work, plus
    /// in-flight remaining work when preemption is enabled), decide
    /// pack/unpack transitions, re-split if warranted, and approve
    /// per-tenant mid-DAG preemptions whose projected saving clears
    /// the switch-cost margin. Public so step-driven callers (and
    /// tests) can run it without the wall-clock loop.
    pub fn policy_step(&self) -> bool {
        let preempt_on = self.cfg.policy.preemption_enabled();
        let pack_on = self.cfg.policy.packing_enabled();
        let n = self.tenants.len();
        let per_req: Vec<f64> =
            self.tenants.iter().map(|t| t.plan.lock().unwrap().per_request_s()).collect();
        let backlog: Vec<f64> = self
            .tenants
            .iter()
            .zip(&per_req)
            .map(|(t, &per)| {
                let queued = t.queue.len() as f64 * per;
                let inflight = if preempt_on { t.inflight_remaining_s() } else { 0.0 };
                queued + inflight
            })
            .collect();
        let total: f64 = backlog.iter().sum();
        let mut recon = self.recon.lock().unwrap();
        let mut weights = self.weights.lock().unwrap();
        // ---- pack / unpack transitions (this thread is the only
        // host[] writer; at most one packed pair at a time) ----
        //
        // Live epochs are wall-clock, but the pack fit bound is about
        // the shared slice's *fabric* throughput per epoch: with pacing
        // on, one wall epoch executes epoch_s/timescale fabric seconds.
        // Unpaced runs drain at host speed, where the wall epoch itself
        // is the only meaningful budget.
        let epoch_fabric_s = if self.cfg.timescale > 0.0 {
            self.cfg.policy.epoch_s / self.cfg.timescale
        } else {
            self.cfg.policy.epoch_s
        };
        let mut grouping_changed = false;
        if pack_on && n >= 2 {
            let pair = (0..n).find_map(|j| {
                let h = self.host_of(j);
                (h != j).then_some((h, j))
            });
            match pair {
                Some((a, b)) => {
                    let combined = backlog[a] + backlog[b];
                    if should_unpack(combined, epoch_fabric_s, &self.cfg.policy) {
                        self.host[b].store(b, Ordering::Release);
                        self.unpacks.fetch_add(1, Ordering::Relaxed);
                        grouping_changed = true;
                    }
                }
                None => {
                    // Candidate selection and the swap-amortization
                    // window are shared with the simulator (policy.rs)
                    // so the two paths cannot drift apart.
                    if let Some((a, b)) = pack_candidates(&backlog) {
                        let cand = |t: usize| {
                            let steps = self.tenants[t].plan.lock().unwrap().sched.steps.len();
                            (per_req[t], steps)
                        };
                        let quantum_s = pack_quantum_s(
                            self.cfg.policy.pack_quantum_steps,
                            [cand(a), cand(b)],
                        );
                        if should_pack(
                            backlog[a] + backlog[b],
                            epoch_fabric_s,
                            quantum_s,
                            recon.switch_cost_s(),
                            &self.cfg.policy,
                        ) {
                            self.host[b].store(a, Ordering::Release);
                            self.packs.fetch_add(1, Ordering::Relaxed);
                            grouping_changed = true;
                        }
                    }
                }
            }
        }
        // ---- group weights (one partition per leader) ----
        let groups: Vec<Vec<usize>> = (0..n)
            .filter(|&t| self.host_of(t) == t)
            .map(|t| {
                let mut g = vec![t];
                g.extend((0..n).filter(|&j| j != t && self.host_of(j) == t));
                g
            })
            .collect();
        let group_backlog: Vec<f64> =
            groups.iter().map(|g| g.iter().map(|&t| backlog[t]).sum()).collect();
        let proposed = backlog_weights(&group_backlog, self.cfg.policy.max_weight);
        let switch_cost = recon.switch_cost_s();
        let resplit =
            should_resplit(&weights[..], &proposed, total, switch_cost, &self.cfg.policy);
        if !grouping_changed && !resplit {
            return false;
        }
        let named: Vec<(&str, u32)> = groups
            .iter()
            .zip(&proposed)
            .map(|(g, &w)| (self.tenants[g[0]].spec.name.as_str(), w))
            .collect();
        let parts = match recon.split(&named) {
            Ok(p) => p,
            Err(e) => {
                log::warn!("re-split rejected: {e}");
                return false;
            }
        };
        debug_assert!(recon.validate().is_ok());
        for (g, part) in groups.iter().zip(&parts) {
            for &t in g {
                let tr = &self.tenants[t];
                let slice = part.config(&self.base);
                let cached = self.cache.get_or_compute(&self.platform, &slice, &tr.spec.dag);
                let new_per = cached.per_request_s;
                let old_per = per_req[t];
                // Plan write and preemption-generation bump happen under
                // one lock hold: a worker snapshots (plan, gen) under the
                // same lock, so it can never pair the new schedule with a
                // stale generation and count a phantom preemption.
                let mut plan = tr.plan.lock().unwrap();
                *plan = Plan { fmus: part.n_fmus(), cus: part.m_cus(), sched: cached };
                // Preemption-benefit term: interrupt the in-flight batch
                // at its next layer boundary only when re-costing the
                // rest on the new slice beats draining on the old one.
                let rem_old = tr.inflight_remaining_s();
                if preempt_on && rem_old > 0.0 {
                    let rem_new =
                        if old_per > 0.0 { rem_old * (new_per / old_per) } else { rem_old };
                    if should_preempt(rem_old, rem_new, switch_cost, &self.cfg.policy) {
                        tr.preempt_gen.fetch_add(1, Ordering::Release);
                    }
                }
            }
            // One reprogram per slice: charged to the partition leader
            // (identical to per-tenant charging when nothing is packed).
            *self.tenants[g[0]].fabric_s.lock().unwrap() += switch_cost;
        }
        *weights = proposed;
        self.switches.fetch_add(1, Ordering::Relaxed);
        true
    }

    fn policy_loop(&self) {
        let epoch = Duration::from_secs_f64(self.cfg.policy.epoch_s.max(1e-3));
        // Sleep in short slices so shutdown never waits a whole epoch.
        let slice = epoch.min(Duration::from_millis(20));
        let mut slept = Duration::ZERO;
        while !self.stop_policy.load(Ordering::Relaxed) {
            std::thread::sleep(slice);
            slept += slice;
            if slept < epoch {
                continue;
            }
            slept = Duration::ZERO;
            if self.stop_policy.load(Ordering::Relaxed) {
                break;
            }
            self.policy_step();
        }
    }

    /// Run workers + policy until every queue is closed and drained.
    /// Producers push concurrently from other threads via [`Self::push`].
    pub fn run(&self) -> LiveReport {
        let t0 = Instant::now();
        // The cache may be shared with calibration / sim phases; report
        // only this run's activity.
        let (hits0, misses0) = (self.cache.hits(), self.cache.misses());
        std::thread::scope(|s| {
            let workers: Vec<_> =
                (0..self.tenants.len()).map(|i| s.spawn(move || self.worker(i))).collect();
            let policy = s.spawn(|| self.policy_loop());
            // Stop the policy thread before propagating any worker
            // panic: panicking while it still runs would leave the
            // scope blocked on a loop that never observes the flag.
            let worker_panicked =
                workers.into_iter().map(|w| usize::from(w.join().is_err())).sum::<usize>();
            self.stop_policy.store(true, Ordering::Relaxed);
            let policy_result = policy.join();
            assert_eq!(worker_panicked, 0, "{worker_panicked} worker thread(s) panicked");
            policy_result.expect("policy thread panicked");
        });
        LiveReport {
            tenants: self
                .tenants
                .iter()
                .enumerate()
                .map(|(i, t)| TenantReport {
                    name: t.spec.name.clone(),
                    served: t.served.load(Ordering::Relaxed),
                    throttled: self.throttled[i].load(Ordering::Relaxed),
                    fabric_s: *t.fabric_s.lock().unwrap(),
                    wall_latency: t.hist.lock().unwrap().clone(),
                })
                .collect(),
            switches: self.switches.load(Ordering::Relaxed),
            preemptions: self.preemptions.load(Ordering::Relaxed),
            packs: self.packs.load(Ordering::Relaxed),
            unpacks: self.unpacks.load(Ordering::Relaxed),
            pack_swaps: self.pack_swaps.load(Ordering::Relaxed),
            packed_batches: self.packed_batches.load(Ordering::Relaxed),
            cache_hits: self.cache.hits() - hits0,
            cache_misses: self.cache.misses() - misses0,
            wall_s: t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Solver;
    use crate::workload::zoo;

    fn tiny_solver() -> Solver {
        Solver::Ga { population: 12, generations: 12, seed: 5 }
    }

    fn scheduler(caps: usize) -> FabricScheduler {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_queue_capacity(caps),
            TenantSpec::new("b", zoo::mlp_s()).with_queue_capacity(caps),
        ];
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap()
    }

    #[test]
    fn serves_all_pushed_requests() {
        let sched = scheduler(10_000);
        for i in 0..200 {
            sched.push(i as usize % 2, LiveRequest::new(i)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 200);
        assert_eq!(report.tenants[0].served, 100);
        assert!(report.tenants[0].fabric_s > 0.0);
        assert_eq!(report.tenants[0].wall_latency.count(), 100);
        assert!(report.worst_p99_s() >= report.tenants[0].p99_s());
        // Packing never engaged: it is off by default.
        assert_eq!((report.packs, report.unpacks, report.packed_batches), (0, 0, 0));
    }

    #[test]
    fn admission_control_is_per_tenant() {
        let sched = scheduler(4);
        // Workers aren't running: the 4-deep queue must overflow.
        let mut rejected = 0;
        for i in 0..10 {
            if sched.push(0, LiveRequest::new(i)).is_err() {
                rejected += 1;
            }
        }
        assert_eq!(rejected, 6);
        assert_eq!(sched.tenants[1].queue.len(), 0);
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 4);
    }

    #[test]
    fn token_bucket_throttles_pushes() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        // Measure the equal-split per-request cost, then allow tenant a
        // a burst of exactly 3 requests and essentially no refill.
        let probe = vec![
            TenantSpec::new("a", zoo::mlp_s()),
            TenantSpec::new("b", zoo::mlp_s()),
        ];
        let per =
            crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        // 3.5x: mid-bucket headroom keeps the pass/throttle boundary
        // away from f64 rounding of repeated same-cost takes.
        let specs = vec![
            TenantSpec::new("a", zoo::mlp_s()).with_fabric_share(1e-12, 3.5 * per),
            TenantSpec::new("b", zoo::mlp_s()),
        ];
        let sched =
            FabricScheduler::new(platform, base, specs, cache, LiveConfig::default()).unwrap();
        let mut throttled = 0;
        for i in 0..6 {
            match sched.push(0, LiveRequest::new(i)) {
                Ok(()) => {}
                Err(PushError::Throttled) => throttled += 1,
                Err(e) => panic!("unexpected push error {e}"),
            }
        }
        assert_eq!(throttled, 3, "burst of 3 requests' fabric time, then throttle");
        // The unlimited tenant is unaffected.
        sched.push(1, LiveRequest::new(99)).unwrap();
        sched.close();
        let report = sched.run();
        assert_eq!(report.tenants[0].throttled, 3);
        assert_eq!(report.tenants[0].served, 3);
        assert_eq!(report.tenants[1].served, 1);
    }

    #[test]
    fn policy_step_resplits_under_skew() {
        let sched = scheduler(10_000);
        // Flood tenant a while workers are not yet running.
        for i in 0..500 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        let before = sched.composition();
        assert!(sched.policy_step(), "skewed backlog must trigger a re-split");
        let after = sched.composition();
        assert!(after[0].2 > before[0].2, "tenant a must gain CUs: {before:?} -> {after:?}");
        assert_eq!(sched.switches.load(Ordering::Relaxed), 1);
        // No batch in flight: nothing to preempt.
        assert_eq!(sched.preemptions.load(Ordering::Relaxed), 0);
        // An idle fabric proposes the equal split again — a shape the
        // cache has already seen, so re-splitting back is pure hits.
        loop {
            match sched.tenants[0].queue.pop_batch_timeout(64, Duration::from_millis(1)) {
                Some(b) if !b.is_empty() => continue,
                _ => break,
            }
        }
        let h0 = sched.cache.hits();
        assert!(sched.policy_step(), "drained backlog must restore the equal split");
        assert!(sched.cache.hits() > h0, "returning to a seen composition must hit the cache");
        sched.close();
        let report = sched.run();
        assert_eq!(report.switches, 2);
    }

    #[test]
    fn preemption_lands_at_a_layer_boundary_mid_batch() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("hot", zoo::mlp_s()).with_queue_capacity(10_000).with_max_batch(4096),
            TenantSpec::new("cold", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        // Pace the fabric so one big batch takes ~1 s of wall time:
        // plenty of layer boundaries for the policy thread (50 ms
        // epochs) to land a preemption on.
        let probe = vec![
            TenantSpec::new("hot", zoo::mlp_s()),
            TenantSpec::new("cold", zoo::mlp_s()),
        ];
        let per = crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        let n = 400usize;
        let batch_s = crate::serve::tenant::batch_fabric_s(per, n);
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 0.05,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                ..PolicyConfig::default()
            },
            timescale: 1.0 / batch_s,
            max_sleep: Duration::from_millis(100),
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        for i in 0..n {
            sched.push(0, LiveRequest::new(i as u64)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), n as u64);
        assert!(report.switches >= 1, "in-flight remaining work must trigger a re-split");
        assert!(
            report.preemptions >= 1,
            "the worker must land at least one mid-batch preemption ({} switches)",
            report.switches
        );
    }

    #[test]
    fn policy_packs_and_unpacks_light_tenants() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let probe = vec![
            TenantSpec::new("heavy", zoo::mlp_s()),
            TenantSpec::new("s1", zoo::mlp_s()),
            TenantSpec::new("s2", zoo::mlp_s()),
        ];
        let per = crate::serve::equal_split_per_request(&platform, &base, &probe, &cache)[0];
        let specs = vec![
            TenantSpec::new("heavy", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s2", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 5.0 * per,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                pack_headroom_factor: 2.0,
                // Decouple the amortization gate from the model's
                // absolute time scale: this test is about transitions.
                pack_swap_margin: 1e9,
                ..PolicyConfig::default()
            },
            timescale: 0.0,
            max_sleep: Duration::from_millis(100),
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        // Flood the heavy tenant while workers are not yet running; the
        // light tenants are idle, so the pack fit is trivially met.
        for i in 0..300 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        assert!(sched.policy_step(), "skew must trigger a re-split");
        assert_eq!(sched.packs.load(Ordering::Relaxed), 1, "light pair must pack");
        assert_eq!(sched.host_of(2), 1, "s2 is hosted on s1's partition");
        assert_eq!(sched.host_of(1), 1);
        let comp = sched.composition();
        assert_eq!(
            (comp[1].1, comp[1].2),
            (comp[2].1, comp[2].2),
            "a packed pair shares one partition's dimensions: {comp:?}"
        );
        assert!(comp[0].2 > comp[1].2, "the heavy tenant gains the freed capacity: {comp:?}");
        // Flood a packed member past the unpack hysteresis: backlog of
        // 200 requests dwarfs the 5-request-epoch fit bound.
        for i in 0..200 {
            sched.push(2, LiveRequest::new(1000 + i)).unwrap();
        }
        assert!(sched.policy_step(), "unpack is a forced re-composition");
        assert_eq!(sched.unpacks.load(Ordering::Relaxed), 1, "flooded member must unpack");
        assert_eq!(sched.host_of(2), 2);
        // Everything still gets served after the transitions.
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 500);
        assert_eq!(report.packs, 1);
        assert_eq!(report.unpacks, 1);
    }

    #[test]
    fn packed_host_serves_its_partner_queue() {
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let cache = Arc::new(ScheduleCache::new(tiny_solver()));
        let specs = vec![
            TenantSpec::new("heavy", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s1", zoo::mlp_s()).with_queue_capacity(10_000),
            TenantSpec::new("s2", zoo::mlp_s()).with_queue_capacity(10_000),
        ];
        let cfg = LiveConfig {
            policy: PolicyConfig {
                epoch_s: 0.05,
                max_weight: 8,
                min_backlog_factor: 0.0,
                preempt_margin_factor: 1.0,
                pack_headroom_factor: 2.0,
                pack_swap_margin: 1e9,
                ..PolicyConfig::default()
            },
            timescale: 0.0,
            max_sleep: Duration::from_millis(100),
        };
        let sched = FabricScheduler::new(platform, base, specs, cache, cfg).unwrap();
        for i in 0..100 {
            sched.push(0, LiveRequest::new(i)).unwrap();
        }
        // Pack the idle pair before the workers start.
        assert!(sched.policy_step());
        assert_eq!(sched.host_of(2), 1);
        // Traffic for both packed members lands after the transition.
        for i in 0..40 {
            sched.push(1, LiveRequest::new(500 + i)).unwrap();
            sched.push(2, LiveRequest::new(600 + i)).unwrap();
        }
        sched.close();
        let report = sched.run();
        assert_eq!(report.total_served(), 180, "no request may strand across packing");
        assert_eq!(report.tenants[1].served, 40);
        assert_eq!(report.tenants[2].served, 40);
    }

    #[test]
    fn deadline_pacer_bounds_cumulative_drift() {
        // 5000 sub-millisecond steps, 0.1 s of paced fabric time in
        // total. A per-step sleeper accumulates one OS-granularity
        // overshoot per step (hundreds of ms in aggregate); the
        // deadline pacer absorbs overshoot into later steps, so the
        // total drift stays bounded by roughly one sleep's overshoot.
        let mut p = Pacer::new();
        let steps = 5000usize;
        let dur = 2e-5f64;
        let t0 = Instant::now();
        for _ in 0..steps {
            p.pace(dur, 1.0, Duration::from_millis(100));
        }
        let elapsed = t0.elapsed().as_secs_f64();
        let target = steps as f64 * dur;
        assert!(elapsed >= 0.9 * target, "pacer must actually pace: {elapsed:.3} s");
        assert!(
            elapsed < target + 0.35,
            "deadline pacing must not accumulate per-step jitter: {elapsed:.3} s vs {target:.3} s"
        );
    }

    #[test]
    fn push_after_close_rejected() {
        let sched = scheduler(16);
        sched.close();
        assert_eq!(sched.push(0, LiveRequest::new(1)).unwrap_err(), PushError::Closed);
        let report = sched.run();
        assert_eq!(report.total_served(), 0);
    }
}
