//! The scenario zoo: deterministic workload generation for the serve
//! layer.
//!
//! Every acceptance claim about dynamic re-composition is only as
//! strong as the traffic it was demonstrated on. This module turns
//! workload diversity into a first-class subsystem: a [`ScenarioSpec`]
//! names a set of tenants, gives each a traffic [`Shape`] and an
//! optional latency-SLO deadline, and materializes — against a
//! [`ScheduleCache`], so rates calibrate to the *measured* equal-split
//! service times — into a ready-to-run [`Scenario`] plus a calibrated
//! [`PolicyConfig`]. The same spec always produces the same arrival
//! stream: generation is seeded ([`SplitMix64`]), single-threaded, and
//! independent of the strategy that later consumes it.
//!
//! # Scale-free rates
//!
//! Shapes express intensity as **multiples of the tenant's equal-split
//! capacity** (`x = 1.0` means "exactly what a 1-of-N fabric slice can
//! serve"), and durations/periods in **units of the first tenant's
//! per-request time**. A scenario therefore stresses the *policy*, not
//! an absolute latency scale: the same spec is meaningful on any
//! platform or model mix the cache can schedule.
//!
//! # Shape catalog
//!
//! * [`Shape::Steady`] — homogeneous Poisson at a fixed multiple.
//! * [`Shape::Diurnal`] — sinusoidal mean with a phase offset, so two
//!   tenants can trade load back and forth (day/night skew).
//! * [`Shape::FlashCrowd`] — a step to `peak_x` at a chosen fraction
//!   of the run, decaying exponentially back toward `base_x`.
//! * [`Shape::Ramp`] — linear drift between two multiples across the
//!   run (grow-out / drain-down).
//! * [`Shape::EpochBurst`] — adversarial square-wave bursts
//!   phase-locked to the policy epoch (`period_epochs` multiples of
//!   the calibrated epoch), the worst case for an epoch-sampled
//!   policy: every burst starts just after a decision point.
//!
//! Non-homogeneous shapes are sampled by Lewis–Shedler thinning: a
//! homogeneous Poisson process at the shape's peak rate, keeping each
//! point with probability `x(t) / x_max`. One RNG fork per tenant (in
//! tenant order) keeps streams independent and the whole trace
//! reproducible.
//!
//! The sixth generator is **trace replay** ([`replay_arrivals`]): the
//! `Admitted` events of a recorded [`RecordedTrace`] become the
//! arrival stream of a new run, closing the loop with the telemetry
//! layer. Replaying only the *admitted* arrivals through the same
//! tenant specs reproduces the original run's admissions exactly:
//! rejected arrivals never entered a queue, and a throttled arrival's
//! failed bucket probe consumes no tokens, so dropping them from the
//! input changes no queue or bucket state the surviving arrivals
//! observe (`rust/tests/serve_scenarios.rs` holds this bit-for-bit).

use std::collections::BTreeMap;

use crate::arch::FilcoConfig;
use crate::platform::Platform;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;
use crate::workload::{zoo, Dag};

use super::cache::ScheduleCache;
use super::engine::EngineEvent;
use super::policy::PolicyConfig;
use super::sim::{equal_split_per_request, Scenario};
use super::telemetry::RecordedTrace;
use super::tenant::{finalize_trace, Arrival, SloClass, TenantSpec};

/// One tenant's traffic intensity over the run, in multiples of the
/// tenant's equal-split capacity (see the module docs). Negative
/// intensities are clamped to zero at sampling time.
#[derive(Debug, Clone, PartialEq)]
pub enum Shape {
    /// Homogeneous Poisson at `rate_x` times the equal-split capacity.
    Steady {
        /// Arrival intensity, in capacity multiples.
        rate_x: f64,
    },
    /// Sinusoidal intensity `mean_x + amplitude_x * sin(2π (t/period +
    /// phase))` — a diurnal cycle. Two tenants with phases half a
    /// period apart trade load back and forth.
    Diurnal {
        /// Mean intensity, in capacity multiples.
        mean_x: f64,
        /// Swing around the mean, in capacity multiples.
        amplitude_x: f64,
        /// Cycle length, in units of the first tenant's per-request
        /// time (like `duration_reqs`).
        period_reqs: f64,
        /// Phase offset as a fraction of the period in `[0, 1)`.
        phase: f64,
    },
    /// A flash crowd: `base_x` until `at_frac` of the run, then a step
    /// to `peak_x` decaying exponentially back toward `base_x` with
    /// time constant `decay_reqs`.
    FlashCrowd {
        /// Quiescent intensity before (and asymptotically after) the
        /// crowd, in capacity multiples.
        base_x: f64,
        /// Intensity at the step, in capacity multiples.
        peak_x: f64,
        /// When the crowd hits, as a fraction of the run in `[0, 1]`.
        at_frac: f64,
        /// Exponential decay time constant, in per-request units.
        decay_reqs: f64,
    },
    /// Linear drift from `from_x` to `to_x` across the run.
    Ramp {
        /// Intensity at the start of the run, in capacity multiples.
        from_x: f64,
        /// Intensity at the end of the run, in capacity multiples.
        to_x: f64,
    },
    /// Adversarial square-wave bursts phase-locked to the policy
    /// epoch: `burst_x` for the first `duty` fraction of every period,
    /// `idle_x` for the rest. With an integer `period_epochs`, every
    /// burst front lands exactly on an epoch boundary — right after
    /// the policy sampled a calm queue.
    EpochBurst {
        /// Intensity between bursts, in capacity multiples.
        idle_x: f64,
        /// Intensity during a burst, in capacity multiples.
        burst_x: f64,
        /// Burst period, in multiples of the calibrated policy epoch.
        period_epochs: f64,
        /// Fraction of each period spent bursting, clamped to `[0, 1]`.
        duty: f64,
    },
}

impl Shape {
    /// Stable kind tag used by the JSON codec and `describe`.
    pub fn kind(&self) -> &'static str {
        match self {
            Shape::Steady { .. } => "steady",
            Shape::Diurnal { .. } => "diurnal",
            Shape::FlashCrowd { .. } => "flash-crowd",
            Shape::Ramp { .. } => "ramp",
            Shape::EpochBurst { .. } => "epoch-burst",
        }
    }

    /// Upper bound on the intensity multiple over the whole run — the
    /// homogeneous rate the thinning sampler proposes at.
    fn max_x(&self) -> f64 {
        let m = match *self {
            Shape::Steady { rate_x } => rate_x,
            Shape::Diurnal { mean_x, amplitude_x, .. } => mean_x + amplitude_x.abs(),
            Shape::FlashCrowd { base_x, peak_x, .. } => base_x.max(peak_x),
            Shape::Ramp { from_x, to_x } => from_x.max(to_x),
            Shape::EpochBurst { idle_x, burst_x, .. } => idle_x.max(burst_x),
        };
        m.max(0.0)
    }

    /// The intensity multiple at instant `t_s` of a run `duration_s`
    /// long with policy epoch `epoch_s` (both fabric seconds; the
    /// caller converts the spec's request-unit knobs). Never negative.
    fn x_at(&self, t_s: f64, duration_s: f64, epoch_s: f64, unit_s: f64) -> f64 {
        let x = match *self {
            Shape::Steady { rate_x } => rate_x,
            Shape::Diurnal { mean_x, amplitude_x, period_reqs, phase } => {
                let period = period_reqs * unit_s;
                if period <= 0.0 {
                    mean_x
                } else {
                    mean_x + amplitude_x * (std::f64::consts::TAU * (t_s / period + phase)).sin()
                }
            }
            Shape::FlashCrowd { base_x, peak_x, at_frac, decay_reqs } => {
                let t0 = at_frac.clamp(0.0, 1.0) * duration_s;
                let tau = decay_reqs * unit_s;
                if t_s < t0 || tau <= 0.0 {
                    base_x
                } else {
                    base_x + (peak_x - base_x) * (-(t_s - t0) / tau).exp()
                }
            }
            Shape::Ramp { from_x, to_x } => {
                let frac = if duration_s > 0.0 { (t_s / duration_s).clamp(0.0, 1.0) } else { 0.0 };
                from_x + (to_x - from_x) * frac
            }
            Shape::EpochBurst { idle_x, burst_x, period_epochs, duty } => {
                let period = period_epochs * epoch_s;
                if period <= 0.0 {
                    burst_x
                } else {
                    let frac = (t_s / period).fract();
                    if frac < duty.clamp(0.0, 1.0) {
                        burst_x
                    } else {
                        idle_x
                    }
                }
            }
        };
        x.max(0.0)
    }
}

/// One tenant of a scenario: which model it serves, how its traffic
/// arrives, and its SLO class.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioTenant {
    /// Display name (unique within the scenario).
    pub name: String,
    /// Model-zoo key resolved by [`model_dag`] (e.g. `"mlp-l"`).
    pub model: String,
    /// Traffic shape, in equal-split capacity multiples.
    pub shape: Shape,
    /// Latency-SLO deadline in multiples of *this tenant's* measured
    /// per-request time (`None` = throughput tier). Converted to
    /// fabric seconds at materialization.
    pub deadline_reqs: Option<f64>,
}

/// A named, seeded, scale-free workload scenario — everything needed
/// to reproduce one arrival stream and its SLO context.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Registry / CLI name.
    pub name: String,
    /// One-line description for `filco scenario list`.
    pub description: String,
    /// The tenants sharing the fabric.
    pub tenants: Vec<ScenarioTenant>,
    /// Run length in units of the first tenant's per-request time.
    pub duration_reqs: f64,
    /// RNG seed for the arrival streams.
    pub seed: u64,
    /// Queue depth for every tenant (deep by default so scenario
    /// comparisons measure latency, not rejection policy).
    pub queue_capacity: usize,
}

/// A spec resolved against real schedules: the runnable [`Scenario`],
/// the policy calibrated to its service times, and the measured
/// per-request seconds the rates were scaled by.
#[derive(Debug, Clone)]
pub struct MaterializedScenario {
    /// The runnable scenario (tenants with SLO classes, generated
    /// arrivals, shards 1).
    pub scenario: Scenario,
    /// `PolicyConfig::calibrated` to the first tenant's per-request
    /// time — the epoch the `EpochBurst` shapes are locked to.
    pub policy: PolicyConfig,
    /// Measured equal-split per-request fabric seconds, per tenant.
    pub per_request_s: Vec<f64>,
}

/// Resolve a model-zoo key to its layer DAG (`None` for unknown keys).
pub fn model_dag(key: &str) -> Option<Dag> {
    match key {
        "mlp-s" => Some(zoo::mlp_s()),
        "mlp-l" => Some(zoo::mlp_l()),
        "deit-s" => Some(zoo::deit_s()),
        "deit-l" => Some(zoo::deit_l()),
        "pointnet" => Some(zoo::pointnet()),
        "mlp-mixer" => Some(zoo::mlp_mixer()),
        _ => None,
    }
}

/// Generate the merged arrival stream for `(shape, per_request_s)`
/// tenants over `duration_s` fabric seconds with policy epoch
/// `epoch_s` and request unit `unit_s` (the first tenant's per-request
/// time). Deterministic in `seed`: one [`SplitMix64`] fork per tenant,
/// in tenant order, then the shared `(t, tenant)` sort + id renumber
/// every trace generator uses.
pub fn generate_arrivals(
    tenants: &[(Shape, f64)],
    duration_s: f64,
    epoch_s: f64,
    unit_s: f64,
    seed: u64,
) -> Vec<Arrival> {
    let mut rng = SplitMix64::new(seed);
    let mut all: Vec<Arrival> = Vec::new();
    for (tenant, (shape, per_s)) in tenants.iter().enumerate() {
        // Fork unconditionally so adding/removing load on one tenant
        // never perturbs another tenant's stream.
        let mut fork = rng.fork();
        let max_x = shape.max_x();
        if max_x <= 0.0 || *per_s <= 0.0 || duration_s <= 0.0 {
            continue;
        }
        let max_rate = max_x / per_s;
        let mut t = 0.0f64;
        loop {
            let u = fork.next_f64();
            t += -(1.0 - u).ln() / max_rate;
            if t >= duration_s {
                break;
            }
            // Thinning: keep with probability x(t) / x_max.
            if fork.next_f64() * max_x < shape.x_at(t, duration_s, epoch_s, unit_s) {
                all.push(Arrival { t_s: t, tenant, id: 0 });
            }
        }
    }
    finalize_trace(&mut all);
    all
}

/// Re-derive an arrival stream from a recorded trace's `Admitted`
/// events, preserving the original request ids and admission instants.
/// See the module docs for why running these through the same tenant
/// specs reproduces the recording's admissions exactly.
pub fn replay_arrivals(trace: &RecordedTrace) -> Vec<Arrival> {
    trace
        .events
        .iter()
        .filter_map(|ev| match ev {
            EngineEvent::Admitted { tenant, id, at_s } => {
                Some(Arrival { t_s: *at_s, tenant: *tenant, id: *id })
            }
            _ => None,
        })
        .collect()
}

impl ScenarioSpec {
    /// Resolve the spec against real schedules: compute the measured
    /// equal-split per-request times through `cache` (on
    /// [`Platform::vck190`] with its default FILCO config), convert
    /// the scale-free knobs to fabric seconds, generate the arrival
    /// streams, and attach each tenant's SLO class. Fails on an
    /// unknown model key or an empty tenant list.
    pub fn materialize(&self, cache: &ScheduleCache) -> Result<MaterializedScenario, String> {
        if self.tenants.is_empty() {
            return Err(format!("scenario '{}' has no tenants", self.name));
        }
        let platform = Platform::vck190();
        let base = FilcoConfig::default_for(&platform);
        let mut specs = Vec::with_capacity(self.tenants.len());
        for t in &self.tenants {
            let dag = model_dag(&t.model)
                .ok_or_else(|| format!("unknown model '{}' for tenant '{}'", t.model, t.name))?;
            specs.push(
                TenantSpec::new(t.name.clone(), dag).with_queue_capacity(self.queue_capacity),
            );
        }
        let per = equal_split_per_request(&platform, &base, &specs, cache);
        for (spec, (t, &per_s)) in specs.iter_mut().zip(self.tenants.iter().zip(&per)) {
            if let Some(reqs) = t.deadline_reqs {
                spec.slo = SloClass::LatencyTier { deadline_s: reqs * per_s };
            }
        }
        let unit_s = per[0];
        let duration_s = self.duration_reqs * unit_s;
        let policy = PolicyConfig::calibrated(unit_s);
        let shaped: Vec<(Shape, f64)> = self
            .tenants
            .iter()
            .zip(&per)
            .map(|(t, &p)| (t.shape.clone(), p))
            .collect();
        let arrivals = generate_arrivals(&shaped, duration_s, policy.epoch_s, unit_s, self.seed);
        Ok(MaterializedScenario {
            scenario: Scenario {
                platform,
                base,
                tenants: specs,
                arrivals,
                switch_cost_s: None,
                shards: 1,
            },
            policy,
            per_request_s: per,
        })
    }

    /// Multi-line human-readable description (for `filco scenario
    /// describe`).
    pub fn describe(&self) -> String {
        let mut s = format!(
            "{}: {}\n  duration {} req-units, seed {:#x}, queue capacity {}\n",
            self.name, self.description, self.duration_reqs, self.seed, self.queue_capacity
        );
        for t in &self.tenants {
            let slo = match t.deadline_reqs {
                Some(d) => format!("latency tier, deadline {d} req-units"),
                None => "throughput tier".to_string(),
            };
            s.push_str(&format!(
                "  {:<10} {:<9} {:<12} {:?}  [{}]\n",
                t.name,
                t.model,
                t.shape.kind(),
                t.shape,
                slo,
            ));
        }
        s
    }

    /// Serialize to the JSON object `--scenario-file` accepts.
    /// Inverse of [`Self::from_json`].
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("description".to_string(), Json::Str(self.description.clone()));
        m.insert("duration_reqs".to_string(), Json::Num(self.duration_reqs));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        m.insert("queue_capacity".to_string(), Json::Num(self.queue_capacity as f64));
        m.insert(
            "tenants".to_string(),
            Json::Arr(
                self.tenants
                    .iter()
                    .map(|t| {
                        let mut tm = BTreeMap::new();
                        tm.insert("name".to_string(), Json::Str(t.name.clone()));
                        tm.insert("model".to_string(), Json::Str(t.model.clone()));
                        tm.insert(
                            "deadline_reqs".to_string(),
                            t.deadline_reqs.map_or(Json::Null, Json::Num),
                        );
                        tm.insert("shape".to_string(), shape_to_json(&t.shape));
                        Json::Obj(tm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    /// Parse a scenario from its JSON object form. Inverse of
    /// [`Self::to_json`]; every error names the offending field.
    pub fn from_json(v: &Json) -> Result<Self, String> {
        let name = req_str(v, "name")?;
        let tenants = v
            .get("tenants")
            .and_then(Json::as_arr)
            .ok_or("scenario missing tenants array")?
            .iter()
            .map(|tv| {
                Ok(ScenarioTenant {
                    name: req_str(tv, "name")?,
                    model: req_str(tv, "model")?,
                    deadline_reqs: tv.get("deadline_reqs").and_then(Json::as_f64),
                    shape: shape_from_json(
                        tv.get("shape").ok_or("tenant missing shape")?,
                    )?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(Self {
            name,
            description: v
                .get("description")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            tenants,
            duration_reqs: req_f64(v, "duration_reqs")?,
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(0),
            queue_capacity: v
                .get("queue_capacity")
                .and_then(Json::as_u64)
                .map(|c| (c as usize).max(1))
                .unwrap_or(DEFAULT_QUEUE_CAPACITY),
        })
    }
}

fn req_str(v: &Json, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string field '{key}'"))
}

fn req_f64(v: &Json, key: &str) -> Result<f64, String> {
    v.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing number field '{key}'"))
}

fn shape_to_json(s: &Shape) -> Json {
    let mut m = BTreeMap::new();
    m.insert("kind".to_string(), Json::Str(s.kind().to_string()));
    match *s {
        Shape::Steady { rate_x } => {
            m.insert("rate_x".to_string(), Json::Num(rate_x));
        }
        Shape::Diurnal { mean_x, amplitude_x, period_reqs, phase } => {
            m.insert("mean_x".to_string(), Json::Num(mean_x));
            m.insert("amplitude_x".to_string(), Json::Num(amplitude_x));
            m.insert("period_reqs".to_string(), Json::Num(period_reqs));
            m.insert("phase".to_string(), Json::Num(phase));
        }
        Shape::FlashCrowd { base_x, peak_x, at_frac, decay_reqs } => {
            m.insert("base_x".to_string(), Json::Num(base_x));
            m.insert("peak_x".to_string(), Json::Num(peak_x));
            m.insert("at_frac".to_string(), Json::Num(at_frac));
            m.insert("decay_reqs".to_string(), Json::Num(decay_reqs));
        }
        Shape::Ramp { from_x, to_x } => {
            m.insert("from_x".to_string(), Json::Num(from_x));
            m.insert("to_x".to_string(), Json::Num(to_x));
        }
        Shape::EpochBurst { idle_x, burst_x, period_epochs, duty } => {
            m.insert("idle_x".to_string(), Json::Num(idle_x));
            m.insert("burst_x".to_string(), Json::Num(burst_x));
            m.insert("period_epochs".to_string(), Json::Num(period_epochs));
            m.insert("duty".to_string(), Json::Num(duty));
        }
    }
    Json::Obj(m)
}

fn shape_from_json(v: &Json) -> Result<Shape, String> {
    let kind = req_str(v, "kind")?;
    match kind.as_str() {
        "steady" => Ok(Shape::Steady { rate_x: req_f64(v, "rate_x")? }),
        "diurnal" => Ok(Shape::Diurnal {
            mean_x: req_f64(v, "mean_x")?,
            amplitude_x: req_f64(v, "amplitude_x")?,
            period_reqs: req_f64(v, "period_reqs")?,
            phase: v.get("phase").and_then(Json::as_f64).unwrap_or(0.0),
        }),
        "flash-crowd" => Ok(Shape::FlashCrowd {
            base_x: req_f64(v, "base_x")?,
            peak_x: req_f64(v, "peak_x")?,
            at_frac: req_f64(v, "at_frac")?,
            decay_reqs: req_f64(v, "decay_reqs")?,
        }),
        "ramp" => Ok(Shape::Ramp { from_x: req_f64(v, "from_x")?, to_x: req_f64(v, "to_x")? }),
        "epoch-burst" => Ok(Shape::EpochBurst {
            idle_x: req_f64(v, "idle_x")?,
            burst_x: req_f64(v, "burst_x")?,
            period_epochs: req_f64(v, "period_epochs")?,
            duty: req_f64(v, "duty")?,
        }),
        other => Err(format!("unknown shape kind '{other}'")),
    }
}

/// Default queue depth for zoo scenarios: deep enough that the
/// comparison measures latency under load, not rejection policy.
pub const DEFAULT_QUEUE_CAPACITY: usize = 1 << 20;

/// Names of the built-in scenarios, in registry order.
pub fn builtin_names() -> &'static [&'static str] {
    &["steady", "skewed", "diurnal", "flash-crowd", "ramp", "epoch-burst"]
}

/// Look up a built-in scenario by name (`None` for unknown names).
pub fn builtin(name: &str) -> Option<ScenarioSpec> {
    let spec = |description: &str, tenants: Vec<ScenarioTenant>, seed: u64| ScenarioSpec {
        name: name.to_string(),
        description: description.to_string(),
        tenants,
        duration_reqs: 80.0,
        seed,
        queue_capacity: DEFAULT_QUEUE_CAPACITY,
    };
    let tenant = |name: &str, model: &str, shape: Shape, deadline: Option<f64>| ScenarioTenant {
        name: name.to_string(),
        model: model.to_string(),
        shape,
        deadline_reqs: deadline,
    };
    match name {
        "steady" => Some(spec(
            "balanced steady Poisson on every tenant — the tie case a \
             well-damped policy must not churn on",
            vec![
                tenant("a", "mlp-s", Shape::Steady { rate_x: 0.5 }, Some(40.0)),
                tenant("b", "mlp-s", Shape::Steady { rate_x: 0.5 }, None),
                tenant("c", "mlp-s", Shape::Steady { rate_x: 0.5 }, None),
            ],
            0x51EAD1,
        )),
        "skewed" => Some(spec(
            "one latency-tier tenant pushed to 2.5x its equal-split \
             capacity over two light tenants — the classic re-composition win",
            vec![
                tenant("heavy", "mlp-l", Shape::Steady { rate_x: 2.5 }, Some(25.0)),
                tenant("light1", "mlp-s", Shape::Steady { rate_x: 0.1 }, None),
                tenant("light2", "mlp-s", Shape::Steady { rate_x: 0.1 }, None),
            ],
            0xBEEF1,
        )),
        "diurnal" => Some(spec(
            "two anti-phase sinusoidal tenants trading load each half-period \
             over a light background — skew that keeps moving",
            vec![
                tenant(
                    "day",
                    "mlp-s",
                    Shape::Diurnal {
                        mean_x: 1.2,
                        amplitude_x: 1.0,
                        period_reqs: 40.0,
                        phase: 0.0,
                    },
                    Some(20.0),
                ),
                tenant(
                    "night",
                    "mlp-s",
                    Shape::Diurnal {
                        mean_x: 1.2,
                        amplitude_x: 1.0,
                        period_reqs: 40.0,
                        phase: 0.5,
                    },
                    None,
                ),
                tenant("base", "mlp-s", Shape::Steady { rate_x: 0.1 }, None),
            ],
            0xD1E1,
        )),
        "flash-crowd" => Some(spec(
            "a quiet latency-tier tenant hit by a flash crowd (4x its slice \
             capacity at 30% of the run, exponential decay)",
            vec![
                tenant(
                    "flash",
                    "mlp-l",
                    Shape::FlashCrowd {
                        base_x: 0.3,
                        peak_x: 4.0,
                        at_frac: 0.3,
                        decay_reqs: 20.0,
                    },
                    Some(25.0),
                ),
                tenant("bg1", "mlp-s", Shape::Steady { rate_x: 0.4 }, None),
                tenant("bg2", "mlp-s", Shape::Steady { rate_x: 0.4 }, None),
            ],
            0xF1A54,
        )),
        "ramp" => Some(spec(
            "one tenant ramping up to 2.5x while another drains from 2x — \
             crossing skew with no steady state",
            vec![
                tenant("ramp-up", "mlp-s", Shape::Ramp { from_x: 0.2, to_x: 2.5 }, Some(25.0)),
                tenant("ramp-down", "mlp-s", Shape::Ramp { from_x: 2.0, to_x: 0.2 }, None),
                tenant("base", "mlp-s", Shape::Steady { rate_x: 0.2 }, None),
            ],
            0x4A3B,
        )),
        "epoch-burst" => Some(spec(
            "adversarial square-wave bursts phase-locked to the policy epoch: \
             4x load starting right after every other decision point",
            vec![
                tenant(
                    "burst",
                    "mlp-l",
                    Shape::EpochBurst {
                        idle_x: 0.0,
                        burst_x: 4.0,
                        period_epochs: 2.0,
                        duty: 0.5,
                    },
                    Some(25.0),
                ),
                tenant("bg1", "mlp-s", Shape::Steady { rate_x: 0.3 }, None),
                tenant("bg2", "mlp-s", Shape::Steady { rate_x: 0.3 }, None),
            ],
            0xEB0B,
        )),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zoo_shapes() -> Vec<(Shape, f64)> {
        vec![
            (Shape::Steady { rate_x: 1.5 }, 0.01),
            (
                Shape::Diurnal { mean_x: 1.0, amplitude_x: 0.8, period_reqs: 20.0, phase: 0.25 },
                0.02,
            ),
            (
                Shape::FlashCrowd { base_x: 0.2, peak_x: 3.0, at_frac: 0.4, decay_reqs: 10.0 },
                0.01,
            ),
            (Shape::Ramp { from_x: 0.1, to_x: 2.0 }, 0.015),
            (
                Shape::EpochBurst { idle_x: 0.0, burst_x: 4.0, period_epochs: 2.0, duty: 0.5 },
                0.01,
            ),
        ]
    }

    #[test]
    fn generation_is_deterministic_in_the_seed() {
        let shapes = zoo_shapes();
        let a = generate_arrivals(&shapes, 1.0, 0.1, 0.01, 42);
        let b = generate_arrivals(&shapes, 1.0, 0.1, 0.01, 42);
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed must reproduce the stream bit-for-bit");
        let c = generate_arrivals(&shapes, 1.0, 0.1, 0.01, 43);
        assert_ne!(a, c, "a different seed must move arrivals");
        // Ids are the global arrival order.
        for (i, arr) in a.iter().enumerate() {
            assert_eq!(arr.id, i as u64);
        }
        for w in a.windows(2) {
            assert!(w[0].t_s <= w[1].t_s, "arrivals must be time-sorted");
        }
    }

    #[test]
    fn epoch_bursts_respect_their_windows() {
        let shape = Shape::EpochBurst { idle_x: 0.0, burst_x: 2.0, period_epochs: 1.0, duty: 0.5 };
        let arrivals = generate_arrivals(&[(shape, 0.001)], 1.0, 0.1, 0.001, 7);
        assert!(!arrivals.is_empty());
        for a in &arrivals {
            let frac = (a.t_s / 0.1).fract();
            assert!(frac < 0.5, "idle_x = 0: every arrival sits in a burst window ({frac})");
        }
    }

    #[test]
    fn flash_crowd_is_denser_after_the_step() {
        let shape = Shape::FlashCrowd { base_x: 0.2, peak_x: 4.0, at_frac: 0.5, decay_reqs: 300.0 };
        let arrivals = generate_arrivals(&[(shape, 0.001)], 1.0, 0.1, 0.001, 11);
        let before = arrivals.iter().filter(|a| a.t_s < 0.5).count();
        let after = arrivals.len() - before;
        assert!(
            after > 3 * before,
            "the crowd must dominate: {before} before vs {after} after"
        );
    }

    #[test]
    fn builtins_roundtrip_through_json() {
        for name in builtin_names() {
            let spec = builtin(name).expect("builtin exists");
            assert_eq!(&spec.name, name);
            let text = spec.to_json().to_string_compact();
            let back = ScenarioSpec::from_json(&Json::parse(&text).expect("parses"))
                .expect("scenario parses");
            assert_eq!(back, spec, "{name} must round-trip");
        }
        assert!(builtin("no-such-scenario").is_none());
    }

    fn fuzz_word(rng: &mut SplitMix64, n: usize) -> String {
        // Hostile string palette: quotes, backslashes, control chars,
        // JSON structure chars, non-BMP scalars, DEL.
        const PALETTE: &[&str] =
            &["a", "β", "\"", "\\", "\n", "\t", "\u{1}", "\u{1F600}", "]}", "{\"", ",", "\u{7f}"];
        let mut s = String::new();
        for _ in 0..n {
            s.push_str(PALETTE[(rng.next_u64() % PALETTE.len() as u64) as usize]);
        }
        s
    }

    /// Finite numbers exactly representable in f64 (multiples of 1/16),
    /// so `==` after a serialize/parse round-trip is legitimate.
    fn fuzz_num(rng: &mut SplitMix64) -> f64 {
        (rng.next_u64() % 4096) as f64 / 16.0
    }

    fn fuzz_shape(rng: &mut SplitMix64) -> Shape {
        match rng.next_u64() % 5 {
            0 => Shape::Steady { rate_x: fuzz_num(rng) },
            1 => Shape::Diurnal {
                mean_x: fuzz_num(rng),
                amplitude_x: fuzz_num(rng),
                period_reqs: fuzz_num(rng),
                phase: fuzz_num(rng),
            },
            2 => Shape::FlashCrowd {
                base_x: fuzz_num(rng),
                peak_x: fuzz_num(rng),
                at_frac: fuzz_num(rng),
                decay_reqs: fuzz_num(rng),
            },
            3 => Shape::Ramp { from_x: fuzz_num(rng), to_x: fuzz_num(rng) },
            _ => Shape::EpochBurst {
                idle_x: fuzz_num(rng),
                burst_x: fuzz_num(rng),
                period_epochs: fuzz_num(rng),
                duty: fuzz_num(rng),
            },
        }
    }

    #[test]
    fn fuzz_lite_specs_roundtrip_through_json() {
        let mut rng = SplitMix64::new(0xF422);
        for round in 0..64u64 {
            let n_tenants = 1 + (rng.next_u64() % 6) as usize;
            let tenants = (0..n_tenants)
                .map(|_| ScenarioTenant {
                    name: fuzz_word(&mut rng, 1 + (rng.next_u64() % 8) as usize),
                    model: fuzz_word(&mut rng, 4),
                    shape: fuzz_shape(&mut rng),
                    deadline_reqs: if rng.next_u64() % 2 == 0 {
                        Some(fuzz_num(&mut rng))
                    } else {
                        None
                    },
                })
                .collect();
            let spec = ScenarioSpec {
                name: fuzz_word(&mut rng, 6),
                description: fuzz_word(&mut rng, 12),
                tenants,
                duration_reqs: fuzz_num(&mut rng),
                // Seeds stay under 2^53 so the f64 JSON carrier is exact.
                seed: rng.next_u64() >> 12,
                queue_capacity: 1 + (rng.next_u64() % 100_000) as usize,
            };
            let text = spec.to_json().to_string_compact();
            let v = Json::parse(&text)
                .unwrap_or_else(|e| panic!("round {round}: unparseable output: {e}\n{text}"));
            let back = ScenarioSpec::from_json(&v)
                .unwrap_or_else(|e| panic!("round {round}: spec rejected: {e}\n{text}"));
            assert_eq!(back, spec, "round {round} must round-trip\n{text}");
        }
    }

    #[test]
    fn non_finite_spec_fields_degrade_gracefully() {
        // An infinite deadline serializes as null (RFC 8259 has no inf
        // token), which reads back as "no deadline" — a throughput
        // tier, not a corrupt document.
        let mut spec = builtin("steady").expect("builtin");
        spec.tenants[0].deadline_reqs = Some(f64::INFINITY);
        let v = Json::parse(&spec.to_json().to_string_compact())
            .expect("non-finite fields must not corrupt the document");
        let back = ScenarioSpec::from_json(&v).expect("spec still parses");
        assert_eq!(back.tenants[0].deadline_reqs, None);

        // A NaN rate is a loud, named error — never silent garbage.
        let mut spec = builtin("steady").expect("builtin");
        spec.tenants[1].shape = Shape::Steady { rate_x: f64::NAN };
        let v = Json::parse(&spec.to_json().to_string_compact()).expect("document stays valid");
        let err = ScenarioSpec::from_json(&v).expect_err("NaN rate must be rejected");
        assert!(err.contains("rate_x"), "the error must name the field: {err}");
    }

    #[test]
    fn model_keys_resolve() {
        for key in ["mlp-s", "mlp-l", "deit-s", "deit-l", "pointnet", "mlp-mixer"] {
            assert!(model_dag(key).is_some(), "{key} must resolve");
        }
        assert!(model_dag("resnet-9000").is_none());
    }

    #[test]
    fn replay_arrivals_extracts_admissions_in_order() {
        use crate::serve::sim::ServeReport;
        let events = vec![
            EngineEvent::Admitted { tenant: 0, id: 0, at_s: 0.0 },
            EngineEvent::Rejected { tenant: 1, at_s: 0.005 },
            EngineEvent::Admitted { tenant: 1, id: 2, at_s: 0.01 },
            EngineEvent::BatchDone { tenant: 0, n: 1, at_s: 0.02, consumed_s: 0.02 },
        ];
        let trace = RecordedTrace {
            strategy: "dynamic".to_string(),
            tenants: vec!["a".to_string(), "b".to_string()],
            events,
            report: ServeReport {
                strategy: "dynamic".to_string(),
                completion_s: 0.02,
                served: vec![1, 0],
                rejected: vec![0, 1],
                throttled: vec![0, 0],
                switches: 0,
                preemptions: 0,
                packs: 0,
                unpacks: 0,
                pack_swaps: 0,
                pack_group_sizes: vec![],
                epochs: 0,
                histograms: vec![],
                slo_deadline_s: vec![None, None],
                slo_met: vec![0, 0],
                slo_missed: vec![0, 0],
            },
        };
        let arrivals = replay_arrivals(&trace);
        assert_eq!(
            arrivals,
            vec![
                Arrival { t_s: 0.0, tenant: 0, id: 0 },
                Arrival { t_s: 0.01, tenant: 1, id: 2 },
            ],
            "only admissions replay, ids preserved"
        );
    }
}
