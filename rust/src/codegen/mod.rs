//! Code Generator (paper Fig 6): materialises the DSE output as the
//! "ready-to-run binary files" — encoded per-unit instruction streams,
//! a schedule manifest, and a human-readable dataflow header (the analog
//! of the HLS configuration the real framework feeds Vitis).

use std::io::Write;
use std::path::Path;

use crate::dse::{CandidateTable, Schedule};
use crate::isa::{encode, Program, UnitId};
use crate::util::json::Json;
use crate::workload::Dag;

/// Everything the backend/board (here: the simulator) needs to run.
pub struct GeneratedArtifacts {
    /// (unit, encoded instruction stream).
    pub streams: Vec<(UnitId, Vec<u8>)>,
    /// schedule.json text.
    pub schedule_json: String,
    /// dataflow header text.
    pub header: String,
}

/// Generate binary streams + metadata from a scheduled workload.
pub fn generate(
    dag: &Dag,
    table: &CandidateTable,
    schedule: &Schedule,
    program: &Program,
) -> GeneratedArtifacts {
    let mut streams = Vec::new();
    let mut units: Vec<UnitId> = program.units().collect();
    units.sort();
    for u in units {
        streams.push((u, encode::encode_stream(program.stream(u))));
    }

    // schedule.json
    let mut entries = Vec::new();
    for e in &schedule.entries {
        let mode = &table.modes[e.layer][e.mode];
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("layer".into(), Json::Str(dag.layers[e.layer].name.clone()));
        obj.insert("index".into(), Json::Num(e.layer as f64));
        obj.insert("start_s".into(), Json::Num(e.start));
        obj.insert("end_s".into(), Json::Num(e.end));
        obj.insert("fmus".into(), Json::Arr(e.fmus.iter().map(|&f| Json::Num(f as f64)).collect()));
        obj.insert("cus".into(), Json::Arr(e.cus.iter().map(|&c| Json::Num(c as f64)).collect()));
        obj.insert(
            "tile".into(),
            Json::Arr(vec![
                Json::Num(mode.tile.0 as f64),
                Json::Num(mode.tile.1 as f64),
                Json::Num(mode.tile.2 as f64),
            ]),
        );
        entries.push(Json::Obj(obj));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("workload".into(), Json::Str(dag.name.clone()));
    root.insert("makespan_s".into(), Json::Num(schedule.makespan));
    root.insert("entries".into(), Json::Arr(entries));
    let schedule_json = Json::Obj(root).to_string_compact();

    // Dataflow header (per-layer runtime parameters).
    let mut header = String::new();
    header.push_str(&format!("// FILCO generated dataflow for {}\n", dag.name));
    header.push_str(&format!("// makespan: {:.6e} s\n", schedule.makespan));
    for e in &schedule.entries {
        let mode = &table.modes[e.layer][e.mode];
        header.push_str(&format!(
            "layer {:<24} mode={} fmus={} cus={} tile={}x{}x{} latency={:.3e}\n",
            dag.layers[e.layer].name,
            e.mode,
            mode.fmus,
            mode.cus,
            mode.tile.0,
            mode.tile.1,
            mode.tile.2,
            mode.latency_s
        ));
    }

    GeneratedArtifacts { streams, schedule_json, header }
}

impl GeneratedArtifacts {
    /// Write everything under `dir`.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        for (u, bytes) in &self.streams {
            let name = format!("{}.bin", u.to_string().replace('.', "_").to_lowercase());
            std::fs::File::create(dir.join(name))?.write_all(bytes)?;
        }
        std::fs::write(dir.join("schedule.json"), &self.schedule_json)?;
        std::fs::write(dir.join("dataflow.h"), &self.header)?;
        Ok(())
    }

    pub fn total_bytes(&self) -> usize {
        self.streams.iter().map(|(_, b)| b.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::FilcoConfig;
    use crate::coordinator::instrgen;
    use crate::dse::{ga::GaConfig, stage1};
    use crate::platform::Platform;
    use crate::workload::zoo;

    fn generated() -> (Dag, GeneratedArtifacts) {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let dag = zoo::bert_layers(64, 1);
        let table = stage1::optimize(&p, &cfg, &dag);
        let sched = GaConfig { population: 8, generations: 4, seed: 2, ..Default::default() }
            .solve(&dag, &table, &cfg)
            .schedule;
        let prog = instrgen::generate(&dag, &table, &sched, 32);
        let arts = generate(&dag, &table, &sched, &prog);
        (dag, arts)
    }

    #[test]
    fn binary_streams_decode_back() {
        let (_, arts) = generated();
        assert!(!arts.streams.is_empty());
        for (u, bytes) in &arts.streams {
            let decoded = encode::decode_stream(bytes)
                .unwrap_or_else(|e| panic!("{u}: decode failed: {e}"));
            assert!(!decoded.is_empty());
            assert!(decoded.last().unwrap().is_last());
        }
    }

    #[test]
    fn schedule_json_parses() {
        let (dag, arts) = generated();
        let v = Json::parse(&arts.schedule_json).unwrap();
        assert_eq!(
            v.get("entries").unwrap().as_arr().unwrap().len(),
            dag.len()
        );
        assert!(v.get("makespan_s").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn header_mentions_every_layer() {
        let (dag, arts) = generated();
        for l in &dag.layers {
            assert!(arts.header.contains(&l.name), "missing {}", l.name);
        }
    }

    #[test]
    fn writes_files() {
        let (_, arts) = generated();
        let dir = std::env::temp_dir().join(format!("filco_codegen_{}", std::process::id()));
        arts.write_to(&dir).unwrap();
        assert!(dir.join("schedule.json").exists());
        assert!(dir.join("dataflow.h").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
