//! Serving metrics: per-request latency tracking and throughput summary.

use std::time::Instant;

use crate::util::stats::{percentile, Running};

/// Accumulates request latencies + byte/flop counters for a serving run.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latencies_s: Vec<f64>,
    running: Running,
    pub total_flops: u64,
    pub errors: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            latencies_s: Vec::new(),
            running: Running::new(),
            total_flops: 0,
            errors: 0,
        }
    }

    pub fn record(&mut self, latency_s: f64, flops: u64) {
        self.latencies_s.push(latency_s);
        self.running.push(latency_s);
        self.total_flops += flops;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn count(&self) -> usize {
        self.latencies_s.len()
    }

    pub fn mean_latency_s(&self) -> f64 {
        self.running.mean()
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.latencies_s, 0.50)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.latencies_s, 0.99)
    }

    /// Requests per second over the wall-clock window so far.
    pub fn throughput_rps(&self) -> f64 {
        self.count() as f64 / self.started.elapsed().as_secs_f64().max(1e-12)
    }

    /// Achieved GFLOP/s of useful work.
    pub fn gflops(&self) -> f64 {
        self.total_flops as f64 / self.started.elapsed().as_secs_f64().max(1e-12) / 1e9
    }

    pub fn summary(&self) -> String {
        if self.latencies_s.is_empty() {
            return "no requests".to_string();
        }
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms max={:.3}ms rps={:.1} errors={}",
            self.count(),
            self.mean_latency_s() * 1e3,
            self.p50() * 1e3,
            self.p99() * 1e3,
            self.running.max() * 1e3,
            self.throughput_rps(),
            self.errors,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(i as f64 / 1000.0, 1000);
        }
        assert_eq!(m.count(), 100);
        assert!((m.p50() - 0.0505).abs() < 1e-3);
        assert!(m.p99() > 0.098);
        assert_eq!(m.total_flops, 100_000);
        assert!(m.summary().contains("n=100"));
    }

    #[test]
    fn empty_summary_safe() {
        assert_eq!(Metrics::new().summary(), "no requests");
    }
}
