//! Serving metrics: per-request latency tracking, log-bucketed latency
//! histograms (per-tenant p50/p95/p99), and throughput summaries.

use std::time::Instant;

use crate::util::stats::Running;

/// Accumulates request latencies + byte/flop counters for a serving run.
///
/// Latencies land in a fixed-memory [`LatencyHistogram`] (the same
/// log-bucketed structure the serve layer uses per tenant), so the
/// quantiles are O(buckets) and memory never grows with request count —
/// the old unbounded `Vec<f64>` re-sorted on every percentile call is
/// gone. The mean stays exact through the streaming [`Running`].
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    hist: LatencyHistogram,
    running: Running,
    pub total_flops: u64,
    pub errors: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            hist: LatencyHistogram::new(),
            running: Running::new(),
            total_flops: 0,
            errors: 0,
        }
    }

    pub fn record(&mut self, latency_s: f64, flops: u64) {
        self.hist.record(latency_s);
        self.running.push(latency_s);
        self.total_flops += flops;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }

    /// Exact mean latency (streaming, not bucketed).
    pub fn mean_latency_s(&self) -> f64 {
        self.running.mean()
    }

    /// Median latency, accurate to one histogram bucket (~33%).
    pub fn p50(&self) -> f64 {
        self.hist.p50()
    }

    /// 95th-percentile latency, accurate to one histogram bucket.
    pub fn p95(&self) -> f64 {
        self.hist.p95()
    }

    /// 99th-percentile latency, accurate to one histogram bucket.
    pub fn p99(&self) -> f64 {
        self.hist.p99()
    }

    /// Requests per second over the wall-clock window so far.
    pub fn throughput_rps(&self) -> f64 {
        self.count() as f64 / self.started.elapsed().as_secs_f64().max(1e-12)
    }

    /// Achieved GFLOP/s of useful work.
    pub fn gflops(&self) -> f64 {
        self.total_flops as f64 / self.started.elapsed().as_secs_f64().max(1e-12) / 1e9
    }

    pub fn summary(&self) -> String {
        if self.count() == 0 {
            return "no requests".to_string();
        }
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms rps={:.1} errors={}",
            self.count(),
            self.mean_latency_s() * 1e3,
            self.p50() * 1e3,
            self.p95() * 1e3,
            self.p99() * 1e3,
            self.running.max() * 1e3,
            self.throughput_rps(),
            self.errors,
        )
    }
}

/// Smallest latency the histogram resolves (100 ns).
const HIST_FLOOR_S: f64 = 1e-7;
/// Log-spaced buckets per decade.
const HIST_PER_DECADE: usize = 8;
/// Decades covered: 1e-7 s .. 1e+3 s.
const HIST_DECADES: usize = 10;
const HIST_BUCKETS: usize = HIST_PER_DECADE * HIST_DECADES;

/// Fixed-memory log-bucketed latency histogram: O(1) record, O(buckets)
/// quantiles, mergeable across workers. Resolution is one bucket,
/// `10^(1/8)` ≈ 33% — plenty for p50/p95/p99 serving dashboards, and
/// unlike [`Metrics`] it never grows with request count.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; HIST_BUCKETS],
    total: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            counts: [0; HIST_BUCKETS],
            total: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(latency_s: f64) -> usize {
        let x = latency_s.max(HIST_FLOOR_S);
        let idx = ((x / HIST_FLOOR_S).log10() * HIST_PER_DECADE as f64).floor();
        (idx as usize).min(HIST_BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i`'s bounds.
    fn bucket_mid(i: usize) -> f64 {
        let lo = HIST_FLOOR_S * 10f64.powf(i as f64 / HIST_PER_DECADE as f64);
        let hi = HIST_FLOOR_S * 10f64.powf((i + 1) as f64 / HIST_PER_DECADE as f64);
        (lo * hi).sqrt()
    }

    pub fn record(&mut self, latency_s: f64) {
        self.counts[Self::bucket_of(latency_s)] += 1;
        self.total += 1;
        self.sum_s += latency_s;
        self.min_s = self.min_s.min(latency_s);
        self.max_s = self.max_s.max(latency_s);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    /// Raw log-spaced bucket counts (see the constants above for the
    /// layout). Lets oracle tests assert *full-distribution* equality
    /// between two runs, not just the summary quantiles.
    pub fn buckets(&self) -> &[u64] {
        &self.counts
    }

    pub fn mean_s(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_s / self.total as f64
        }
    }

    pub fn max_s(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.max_s
        }
    }

    /// Smallest recorded latency; 0 when empty (mirrors [`Self::max_s`]).
    pub fn min_s(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.min_s
        }
    }

    /// Exact sum of all recorded latencies (seconds).
    pub fn sum_s(&self) -> f64 {
        self.sum_s
    }

    /// Rebuild a histogram from its serialized parts (trace footers).
    ///
    /// `buckets` longer than the fixed layout is rejected with `None`;
    /// shorter is zero-padded (forward-compatible with narrower dumps).
    /// An empty histogram (`total == 0`) restores the `±inf` min/max
    /// sentinels regardless of the passed extremes, so a round-tripped
    /// empty histogram behaves identically to a fresh one.
    pub fn from_parts(buckets: &[u64], sum_s: f64, min_s: f64, max_s: f64) -> Option<Self> {
        if buckets.len() > HIST_BUCKETS {
            return None;
        }
        let mut counts = [0u64; HIST_BUCKETS];
        counts[..buckets.len()].copy_from_slice(buckets);
        let total: u64 = counts.iter().sum();
        Some(if total == 0 {
            Self::new()
        } else {
            Self {
                counts,
                total,
                sum_s,
                min_s,
                max_s,
            }
        })
    }

    /// Quantile estimate, `q` in [0, 1]; 0 when empty. Accurate to one
    /// bucket (~33%), then clamped into the observed [min, max] range.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q * self.total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_mid(i).clamp(self.min_s, self.max_s);
            }
        }
        self.max_s
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold another histogram into this one (worker merge).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_s += other.sum_s;
        self.min_s = self.min_s.min(other.min_s);
        self.max_s = self.max_s.max(other.max_s);
    }

    pub fn summary(&self) -> String {
        if self.total == 0 {
            return "no requests".to_string();
        }
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms p99={:.3}ms max={:.3}ms",
            self.total,
            self.mean_s() * 1e3,
            self.p50() * 1e3,
            self.p95() * 1e3,
            self.p99() * 1e3,
            self.max_s * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarises() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.record(i as f64 / 1000.0, 1000);
        }
        assert_eq!(m.count(), 100);
        // Exact mean via Running; quantiles accurate to one log bucket
        // (~33%), same tolerance discipline as histogram_orders_quantiles.
        assert!((m.mean_latency_s() - 0.0505).abs() < 1e-9);
        assert!(m.p50() > 0.0505 / 1.4 && m.p50() < 0.0505 * 1.4, "p50 {}", m.p50());
        assert!(m.p95() > 0.095 / 1.4, "p95 {}", m.p95());
        assert!(m.p99() > 0.099 / 1.4, "p99 {}", m.p99());
        assert!(m.p50() <= m.p95() && m.p95() <= m.p99());
        assert_eq!(m.total_flops, 100_000);
        assert!(m.summary().contains("n=100"));
    }

    #[test]
    fn empty_summary_safe() {
        assert_eq!(Metrics::new().summary(), "no requests");
    }

    #[test]
    fn histogram_quantiles_within_bucket() {
        let mut h = LatencyHistogram::new();
        for _ in 0..99 {
            h.record(1e-3);
        }
        h.record(1.0);
        assert_eq!(h.count(), 100);
        // p50 lands in the 1 ms bucket (exactly 1 ms after clamping).
        assert!((h.p50() - 1e-3).abs() < 1e-3 * 0.5, "p50 {}", h.p50());
        // p99 must not see the 1 s outlier below its rank... the outlier
        // IS the 100th value, so p99 < 1 s but p100-ish max is 1 s.
        assert!(h.max_s() == 1.0);
        assert!(h.p95() < 0.1, "p95 {}", h.p95());
    }

    #[test]
    fn histogram_orders_quantiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000u64 {
            h.record(i as f64 * 1e-5); // 10 µs .. 10 ms
        }
        assert!(h.p50() <= h.p95());
        assert!(h.p95() <= h.p99());
        assert!(h.p99() <= h.max_s());
        // p50 around 5 ms, one bucket (~33%) tolerance.
        assert!(h.p50() > 5e-3 / 1.4 && h.p50() < 5e-3 * 1.4, "p50 {}", h.p50());
    }

    #[test]
    fn histogram_merge_matches_combined() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut c = LatencyHistogram::new();
        for i in 0..50 {
            let x = 1e-4 * (1.0 + i as f64);
            a.record(x);
            c.record(x);
        }
        for i in 0..50 {
            let x = 2e-3 * (1.0 + i as f64);
            b.record(x);
            c.record(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert!((a.p95() - c.p95()).abs() < 1e-12);
        assert!((a.mean_s() - c.mean_s()).abs() < 1e-12);
    }

    #[test]
    fn histogram_empty_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.max_s(), 0.0);
        assert_eq!(h.summary(), "no requests");
    }

    #[test]
    fn histogram_from_parts_roundtrips() {
        let mut h = LatencyHistogram::new();
        for i in 1..=200u64 {
            h.record(i as f64 * 3e-5);
        }
        let r = LatencyHistogram::from_parts(h.buckets(), h.sum_s(), h.min_s(), h.max_s())
            .expect("matching layout");
        assert_eq!(r.buckets(), h.buckets());
        assert_eq!(r.count(), h.count());
        assert_eq!(r.sum_s(), h.sum_s());
        assert_eq!(r.min_s(), h.min_s());
        assert_eq!(r.max_s(), h.max_s());
        assert_eq!(r.p99(), h.p99());

        // Empty parts restore the fresh-histogram sentinels.
        let e = LatencyHistogram::from_parts(&[], 0.0, 0.0, 0.0).unwrap();
        assert_eq!(e.count(), 0);
        assert_eq!(e.summary(), "no requests");

        // Oversized layouts are rejected, not truncated.
        assert!(LatencyHistogram::from_parts(&[0; 81], 0.0, 0.0, 0.0).is_none());
    }

    #[test]
    fn histogram_extremes_clamped() {
        let mut h = LatencyHistogram::new();
        h.record(0.0); // below floor
        h.record(1e6); // above ceiling
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) >= 0.0);
        assert_eq!(h.max_s(), 1e6);
    }
}
