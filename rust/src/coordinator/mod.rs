//! L3 coordinator — the FILCO control plane plus the serving runtime.
//!
//! * [`instrgen`] — the Instruction Generator (paper Fig 6): lowers a
//!   DSE [`crate::dse::Schedule`] into per-unit [`crate::isa::Program`]
//!   streams (tiled loads, FMU view/functionality switches, CU kernel
//!   launches with runtime loop bounds).
//! * [`serving`] — leader loop: request queue, per-model batching,
//!   dispatch to the PJRT runtime for numerics with fabric timing from
//!   the analytical model/simulator.
//! * [`reconfig`] — real-time reconfiguration manager: composes the
//!   fabric into one unified accelerator or several independent ones
//!   (the paper's headline capability) by repartitioning FMUs/CUs
//!   between tenants at runtime. Driven online by
//!   [`crate::serve::FabricScheduler`].
//! * [`metrics`] — latency/throughput accounting, including the
//!   log-bucketed per-tenant latency histograms the serve layer uses.

pub mod instrgen;
pub mod metrics;
pub mod reconfig;
pub mod serving;
