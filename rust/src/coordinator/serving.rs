//! Serving loop: the leader that makes FILCO a *system*, not a kernel.
//!
//! Requests (DNN inferences) arrive on a queue; the leader batches them
//! per model, dispatches numerics to the PJRT runtime (AOT artifacts —
//! python is long gone), and accounts both wall-clock latency and the
//! *fabric time* the FILCO schedule would take on the modelled VCK190
//! (the quantity the paper reports).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::runtime::{Engine, HostTensor};
use crate::serve::queue::{BoundedQueue, PushError};

use super::metrics::Metrics;

/// A servable model: owns its weights, knows how to run one input
/// through the engine.
pub trait Servable: Send + Sync {
    fn name(&self) -> &str;
    /// Expected input shape.
    fn input_shape(&self) -> Vec<usize>;
    /// Useful FLOPs per request (for throughput accounting).
    fn flops(&self) -> u64;
    /// Run one request.
    fn run(&self, engine: &Engine, input: &HostTensor) -> Result<HostTensor>;
    /// Fabric seconds one request takes on the modelled accelerator
    /// (from the DSE schedule makespan).
    fn fabric_latency_s(&self) -> f64;
}

/// A BERT encoder stack served through the `bert_layer_*` artifact.
pub struct BertModel {
    pub artifact: String,
    pub seq: usize,
    pub hidden: usize,
    pub layers: usize,
    /// Per-layer parameter tensors, in aot.py's BERT_PARAM_ORDER.
    pub params: Vec<Vec<HostTensor>>,
    pub fabric_s: f64,
}

impl BertModel {
    /// Synthesise a model with random (deterministic) weights.
    pub fn synthetic(
        seq: usize,
        hidden: usize,
        heads: usize,
        ffn: usize,
        layers: usize,
        seed: u64,
    ) -> Self {
        let artifact = format!("bert_layer_s{seq}_h{hidden}_a{heads}_f{ffn}");
        let shapes: Vec<Vec<usize>> = vec![
            vec![hidden, hidden], vec![hidden], // wq bq
            vec![hidden, hidden], vec![hidden], // wk bk
            vec![hidden, hidden], vec![hidden], // wv bv
            vec![hidden, hidden], vec![hidden], // wo bo
            vec![hidden, ffn], vec![ffn],       // w1 b1
            vec![ffn, hidden], vec![hidden],    // w2 b2
            vec![hidden], vec![hidden],         // ln1 g/b
            vec![hidden], vec![hidden],         // ln2 g/b
        ];
        let scale = 1.0 / (hidden as f32).sqrt();
        let params = (0..layers)
            .map(|l| {
                shapes
                    .iter()
                    .enumerate()
                    .map(|(i, sh)| {
                        let mut t = if sh.len() == 2 {
                            let mut t = HostTensor::randn(sh, seed ^ ((l * 31 + i) as u64));
                            for v in &mut t.data {
                                *v *= scale;
                            }
                            t
                        } else {
                            HostTensor::zeros(sh)
                        };
                        // LayerNorm gains start at 1.
                        if i == 12 || i == 14 {
                            for v in &mut t.data {
                                *v = 1.0;
                            }
                        }
                        t
                    })
                    .collect()
            })
            .collect();
        Self { artifact, seq, hidden, layers, params, fabric_s: 0.0 }
    }
}

impl Servable for BertModel {
    fn name(&self) -> &str {
        &self.artifact
    }

    fn input_shape(&self) -> Vec<usize> {
        vec![self.seq, self.hidden]
    }

    fn flops(&self) -> u64 {
        // 4 projections + 2 FFN MMs per layer (scores/ctx ignored for
        // the counter; dominated by these six).
        let h = self.hidden as u64;
        let s = self.seq as u64;
        let ffn = self.params[0][8].shape[1] as u64;
        self.layers as u64 * (4 * 2 * s * h * h + 2 * 2 * s * h * ffn)
    }

    fn run(&self, engine: &Engine, input: &HostTensor) -> Result<HostTensor> {
        let mut x = input.clone();
        for layer in &self.params {
            let mut args = Vec::with_capacity(1 + layer.len());
            args.push(x);
            args.extend(layer.iter().cloned());
            let out = engine.execute(&self.artifact, &args)?;
            x = out.into_iter().next().unwrap();
        }
        Ok(x)
    }

    fn fabric_latency_s(&self) -> f64 {
        self.fabric_s
    }
}

/// Raw bucketed-MM model (the quickstart workload).
pub struct MmModel {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub weights: HostTensor,
    pub fabric_s: f64,
    name: String,
}

impl MmModel {
    pub fn new(m: usize, k: usize, n: usize, seed: u64) -> Self {
        Self {
            m,
            k,
            n,
            weights: HostTensor::randn(&[k, n], seed),
            fabric_s: 0.0,
            name: format!("mm:{m}x{k}x{n}"),
        }
    }
}

impl Servable for MmModel {
    fn name(&self) -> &str {
        &self.name
    }

    fn input_shape(&self) -> Vec<usize> {
        vec![self.m, self.k]
    }

    fn flops(&self) -> u64 {
        2 * self.m as u64 * self.k as u64 * self.n as u64
    }

    fn run(&self, engine: &Engine, input: &HostTensor) -> Result<HostTensor> {
        engine.mm(input, &self.weights)
    }

    fn fabric_latency_s(&self) -> f64 {
        self.fabric_s
    }
}

/// An inference request.
pub struct Request {
    pub id: u64,
    pub input: HostTensor,
    pub enqueued: Instant,
}

/// A completed response.
pub struct Response {
    pub id: u64,
    pub output: HostTensor,
    pub wall_latency_s: f64,
    pub fabric_latency_s: f64,
}

/// FIFO with blocking batched pop — the leader's request queue. A thin
/// wrapper over [`BoundedQueue`], which keeps the deque and the closed
/// flag under one lock (the old two-mutex `closed` check could observe
/// the flag without the queue state it guards).
pub struct RequestQueue {
    inner: BoundedQueue<Request>,
}

impl Default for RequestQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestQueue {
    /// Unbounded queue (the single-model leader's historical behavior).
    pub fn new() -> Self {
        Self { inner: BoundedQueue::unbounded() }
    }

    /// Bounded queue: [`Self::try_push`] rejects beyond `capacity`.
    pub fn bounded(capacity: usize) -> Self {
        Self { inner: BoundedQueue::new(capacity) }
    }

    /// Infallible push; a request offered to a full or closed queue is
    /// dropped with a warning. Use [`Self::try_push`] for backpressure.
    pub fn push(&self, r: Request) {
        let id = r.id;
        if let Err(e) = self.inner.try_push(r) {
            log::warn!("request {id} dropped: {e}");
        }
    }

    /// Admission-controlled push.
    pub fn try_push(&self, r: Request) -> Result<(), PushError> {
        self.inner.try_push(r)
    }

    pub fn close(&self) {
        self.inner.close();
    }

    /// Pop up to `max_batch` requests; blocks until at least one is
    /// available or the queue is closed (then returns None when empty).
    pub fn pop_batch(&self, max_batch: usize) -> Option<Vec<Request>> {
        self.inner.pop_batch(max_batch)
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }
}

/// The serving leader: owns the engine, a model, and the queue.
pub struct Server {
    pub engine: Arc<Engine>,
    pub model: Arc<dyn Servable>,
    pub queue: Arc<RequestQueue>,
    pub max_batch: usize,
}

impl Server {
    pub fn new(engine: Arc<Engine>, model: Arc<dyn Servable>, max_batch: usize) -> Self {
        Self { engine, model, queue: Arc::new(RequestQueue::new()), max_batch }
    }

    /// Drain the queue until closed; returns responses + metrics.
    /// (Call from a worker thread; producers push into `self.queue`.)
    pub fn run_to_completion(&self) -> (Vec<Response>, Metrics) {
        let mut metrics = Metrics::new();
        let mut responses = Vec::new();
        while let Some(batch) = self.queue.pop_batch(self.max_batch) {
            for req in batch {
                let t0 = Instant::now();
                match self.model.run(&self.engine, &req.input) {
                    Ok(output) => {
                        let wall = t0.elapsed().as_secs_f64();
                        let queued = req.enqueued.elapsed().as_secs_f64();
                        metrics.record(queued.max(wall), self.model.flops());
                        responses.push(Response {
                            id: req.id,
                            output,
                            wall_latency_s: wall,
                            fabric_latency_s: self.model.fabric_latency_s(),
                        });
                    }
                    Err(e) => {
                        log::warn!("request {} failed: {e:#}", req.id);
                        metrics.record_error();
                    }
                }
            }
        }
        (responses, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_batches_fifo() {
        let q = RequestQueue::new();
        for i in 0..5 {
            q.push(Request { id: i, input: HostTensor::zeros(&[1]), enqueued: Instant::now() });
        }
        let b = q.pop_batch(3).unwrap();
        assert_eq!(b.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        let b = q.pop_batch(3).unwrap();
        assert_eq!(b.len(), 2);
        q.close();
        assert!(q.pop_batch(3).is_none());
    }

    #[test]
    fn bounded_queue_admission_control() {
        let q = RequestQueue::bounded(2);
        let req = |i| Request { id: i, input: HostTensor::zeros(&[1]), enqueued: Instant::now() };
        q.try_push(req(0)).unwrap();
        q.try_push(req(1)).unwrap();
        assert_eq!(q.try_push(req(2)).unwrap_err(), PushError::Full);
        q.close();
        assert_eq!(q.try_push(req(3)).unwrap_err(), PushError::Closed);
        // Infallible push drops (with a warning) instead of panicking.
        q.push(req(4));
        assert_eq!(q.pop_batch(8).unwrap().len(), 2);
    }

    #[test]
    fn queue_close_unblocks() {
        let q = Arc::new(RequestQueue::new());
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.pop_batch(4));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn bert_model_shapes() {
        let m = BertModel::synthetic(32, 128, 4, 512, 2, 1);
        assert_eq!(m.input_shape(), vec![32, 128]);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].len(), 16);
        assert_eq!(m.params[0][8].shape, vec![128, 512]);
        assert!(m.flops() > 0);
        // LayerNorm gains initialised to one.
        assert!(m.params[0][12].data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn serving_end_to_end_mm() {
        // Full serving path through real PJRT artifacts (skipped if not
        // built).
        let dir = crate::runtime::default_artifact_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let engine = Arc::new(Engine::open(dir).unwrap());
        let model = Arc::new(MmModel::new(30, 20, 10, 7));
        let server = Server::new(engine, model.clone(), 4);
        for i in 0..8 {
            server.queue.push(Request {
                id: i,
                input: HostTensor::randn(&[30, 20], i),
                enqueued: Instant::now(),
            });
        }
        server.queue.close();
        let (responses, metrics) = server.run_to_completion();
        assert_eq!(responses.len(), 8);
        assert_eq!(metrics.count(), 8);
        // Verify numerics of one response against the host oracle.
        let r0 = responses.iter().find(|r| r.id == 0).unwrap();
        let exp = crate::runtime::tensor::matmul_ref(
            &HostTensor::randn(&[30, 20], 0),
            &model.weights,
        );
        assert!(r0.output.allclose(&exp, 1e-3, 1e-3));
    }
}
