//! Real-time reconfiguration manager — the "flexibly composed into a
//! unified or multiple independent accelerators" capability (abstract,
//! §1).
//!
//! Because FILCO's runtime parameters are delivered by instruction
//! decode (no bitstream reload), the coordinator can re-partition the
//! fabric between tenants *between layers*: each partition is a
//! contiguous slice of FMUs and CUs that behaves as an independent
//! FILCO accelerator with its own schedule. The cost of a switch is a
//! few instruction words per unit (~µs), modelled by
//! [`Reconfigurator::switch_cost_s`].

use crate::arch::FilcoConfig;

/// One fabric partition: a tenant's accelerator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub name: String,
    /// FMU id range [start, end).
    pub fmus: (u32, u32),
    /// CU id range [start, end).
    pub cus: (u32, u32),
}

impl Partition {
    pub fn n_fmus(&self) -> u32 {
        self.fmus.1 - self.fmus.0
    }

    pub fn m_cus(&self) -> u32 {
        self.cus.1 - self.cus.0
    }

    /// FILCO config for this slice (same per-unit capacities).
    pub fn config(&self, base: &FilcoConfig) -> FilcoConfig {
        let mut c = base.clone();
        c.n_fmus = self.n_fmus();
        c.m_cus = self.m_cus();
        c
    }
}

/// Default composition-switch cost: ~150 PL cycles at 150 MHz — every
/// unit decodes one ~32 B instruction word in parallel, plus
/// control-plane dispatch.
pub const DEFAULT_SWITCH_COST_S: f64 = 1e-6;

/// Tracks the current fabric composition.
#[derive(Debug)]
pub struct Reconfigurator {
    base: FilcoConfig,
    partitions: Vec<Partition>,
    switch_cost_s: f64,
    /// Number of reconfigurations performed.
    pub switches: u64,
}

impl Reconfigurator {
    pub fn new(base: FilcoConfig) -> Self {
        let unified = Partition {
            name: "unified".into(),
            fmus: (0, base.n_fmus),
            cus: (0, base.m_cus),
        };
        Self {
            base,
            partitions: vec![unified],
            switch_cost_s: DEFAULT_SWITCH_COST_S,
            switches: 0,
        }
    }

    pub fn partitions(&self) -> &[Partition] {
        &self.partitions
    }

    pub fn base(&self) -> &FilcoConfig {
        &self.base
    }

    /// Cost of one composition switch (defaults to
    /// [`DEFAULT_SWITCH_COST_S`]).
    pub fn switch_cost_s(&self) -> f64 {
        self.switch_cost_s
    }

    /// Override the modelled switch cost (what-if studies: slower
    /// control planes, bitstream-reload baselines). Negative values are
    /// clamped to zero.
    pub fn set_switch_cost_s(&mut self, cost_s: f64) {
        self.switch_cost_s = cost_s.max(0.0);
    }

    /// Compose the whole fabric into one accelerator.
    pub fn compose_unified(&mut self) -> Partition {
        self.switches += 1;
        let unified = Partition {
            name: "unified".into(),
            fmus: (0, self.base.n_fmus),
            cus: (0, self.base.m_cus),
        };
        self.partitions = vec![unified.clone()];
        unified
    }

    /// Compute the partition layout [`Reconfigurator::split`] would
    /// commit for the given proportional weights, without mutating the
    /// composition: no switch is counted and the current partitions
    /// are untouched. The async-DSE policy path uses this to probe the
    /// schedule cache for the would-be slices before deciding whether
    /// the resplit can land this epoch.
    pub fn plan(&self, tenants: &[(&str, u32)]) -> Result<Vec<Partition>, String> {
        if tenants.is_empty() {
            return Err("no tenants".into());
        }
        let total_w: u32 = tenants.iter().map(|(_, w)| *w).sum();
        if total_w == 0 {
            return Err("zero total weight".into());
        }
        if tenants.len() as u32 > self.base.m_cus || tenants.len() as u32 > self.base.n_fmus {
            return Err("more tenants than units".into());
        }
        let alloc = |total: u32| -> Vec<u32> {
            // Largest-remainder allocation with a floor of 1.
            let mut counts: Vec<u32> =
                tenants.iter().map(|(_, w)| (total * w / total_w).max(1)).collect();
            let mut sum: u32 = counts.iter().sum();
            // Repair: shrink the largest / grow the smallest until exact.
            while sum > total {
                let i = (0..counts.len()).max_by_key(|&i| counts[i]).unwrap();
                if counts[i] > 1 {
                    counts[i] -= 1;
                    sum -= 1;
                } else {
                    break;
                }
            }
            while sum < total {
                let i = (0..counts.len()).min_by_key(|&i| counts[i]).unwrap();
                counts[i] += 1;
                sum += 1;
            }
            counts
        };
        let f_counts = alloc(self.base.n_fmus);
        let c_counts = alloc(self.base.m_cus);
        let mut parts = Vec::new();
        let (mut f0, mut c0) = (0u32, 0u32);
        for (i, (name, _)) in tenants.iter().enumerate() {
            let p = Partition {
                name: name.to_string(),
                fmus: (f0, f0 + f_counts[i]),
                cus: (c0, c0 + c_counts[i]),
            };
            f0 += f_counts[i];
            c0 += c_counts[i];
            parts.push(p);
        }
        Ok(parts)
    }

    /// Split the fabric into independent accelerators with the given
    /// proportional weights (e.g. `[("bert", 2), ("mlp", 1), ("pnet", 1)]`).
    /// Every partition receives at least one FMU and one CU.
    pub fn split(&mut self, tenants: &[(&str, u32)]) -> Result<Vec<Partition>, String> {
        let parts = self.plan(tenants)?;
        self.switches += 1;
        self.partitions = parts.clone();
        Ok(parts)
    }

    /// Invariant check: partitions tile the fabric without overlap.
    pub fn validate(&self) -> Result<(), String> {
        let mut fmus = vec![false; self.base.n_fmus as usize];
        let mut cus = vec![false; self.base.m_cus as usize];
        for p in &self.partitions {
            if p.fmus.1 > self.base.n_fmus || p.cus.1 > self.base.m_cus {
                return Err(format!("{}: out of range", p.name));
            }
            if p.n_fmus() == 0 || p.m_cus() == 0 {
                return Err(format!("{}: empty partition", p.name));
            }
            for f in p.fmus.0..p.fmus.1 {
                if std::mem::replace(&mut fmus[f as usize], true) {
                    return Err(format!("FMU {f} double-assigned"));
                }
            }
            for c in p.cus.0..p.cus.1 {
                if std::mem::replace(&mut cus[c as usize], true) {
                    return Err(format!("CU {c} double-assigned"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;

    fn base() -> FilcoConfig {
        FilcoConfig::default_for(&Platform::vck190())
    }

    #[test]
    fn starts_unified() {
        let r = Reconfigurator::new(base());
        assert_eq!(r.partitions().len(), 1);
        r.validate().unwrap();
        assert_eq!(r.partitions()[0].m_cus(), base().m_cus);
    }

    #[test]
    fn switch_cost_is_overridable() {
        let mut r = Reconfigurator::new(base());
        assert_eq!(r.switch_cost_s(), DEFAULT_SWITCH_COST_S);
        r.set_switch_cost_s(0.5);
        assert_eq!(r.switch_cost_s(), 0.5);
        r.set_switch_cost_s(-1.0);
        assert_eq!(r.switch_cost_s(), 0.0);
    }

    #[test]
    fn split_tiles_fabric() {
        let mut r = Reconfigurator::new(base());
        let parts = r.split(&[("bert", 2), ("mlp", 1), ("pnet", 1)]).unwrap();
        assert_eq!(parts.len(), 3);
        r.validate().unwrap();
        let fmus: u32 = parts.iter().map(|p| p.n_fmus()).sum();
        let cus: u32 = parts.iter().map(|p| p.m_cus()).sum();
        assert_eq!(fmus, base().n_fmus);
        assert_eq!(cus, base().m_cus);
        // Weighted: bert gets the most CUs.
        assert!(parts[0].m_cus() >= parts[1].m_cus());
    }

    #[test]
    fn every_partition_nonempty() {
        let mut r = Reconfigurator::new(base());
        // 8 tenants on 8 CUs: 1 CU each.
        let names: Vec<String> = (0..8).map(|i| format!("t{i}")).collect();
        let tenants: Vec<(&str, u32)> = names.iter().map(|n| (n.as_str(), 1)).collect();
        let parts = r.split(&tenants).unwrap();
        assert!(parts.iter().all(|p| p.m_cus() >= 1 && p.n_fmus() >= 1));
        r.validate().unwrap();
    }

    #[test]
    fn plan_is_pure_and_matches_split() {
        let mut r = Reconfigurator::new(base());
        let planned = r.plan(&[("bert", 2), ("mlp", 1)]).unwrap();
        // Planning commits nothing: still unified, no switch counted.
        assert_eq!(r.partitions().len(), 1);
        assert_eq!(r.switches, 0);
        let committed = r.split(&[("bert", 2), ("mlp", 1)]).unwrap();
        assert_eq!(planned, committed);
        assert_eq!(r.switches, 1);
    }

    #[test]
    fn too_many_tenants_rejected() {
        let mut r = Reconfigurator::new(base());
        let names: Vec<String> = (0..9).map(|i| format!("t{i}")).collect();
        let tenants: Vec<(&str, u32)> = names.iter().map(|n| (n.as_str(), 1)).collect();
        assert!(r.split(&tenants).is_err());
    }

    #[test]
    fn recompose_unified_after_split() {
        let mut r = Reconfigurator::new(base());
        r.split(&[("a", 1), ("b", 1)]).unwrap();
        let u = r.compose_unified();
        assert_eq!(u.m_cus(), base().m_cus);
        assert_eq!(r.switches, 2);
        r.validate().unwrap();
    }

    #[test]
    fn zero_weight_tenant_still_gets_a_floor() {
        let mut r = Reconfigurator::new(base());
        // A zero-weight tenant is admitted but floored to one unit each.
        let parts = r.split(&[("hot", 4), ("idle", 0)]).unwrap();
        r.validate().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(parts[1].n_fmus() >= 1 && parts[1].m_cus() >= 1);
        assert!(parts[0].m_cus() > parts[1].m_cus());
    }

    #[test]
    fn all_zero_weights_rejected() {
        let mut r = Reconfigurator::new(base());
        assert!(r.split(&[("a", 0), ("b", 0)]).is_err());
        assert!(r.split(&[]).is_err());
    }

    #[test]
    fn more_tenants_than_fmus_rejected() {
        // CU-rich, FMU-poor fabric: the FMU side must also bound tenancy.
        let mut cfg = base();
        cfg.n_fmus = 2;
        let mut r = Reconfigurator::new(cfg);
        assert!(r.split(&[("a", 1), ("b", 1)]).is_ok());
        let mut r = Reconfigurator::new({
            let mut c = base();
            c.n_fmus = 2;
            c
        });
        assert!(r.split(&[("a", 1), ("b", 1), ("c", 1)]).is_err());
    }

    #[test]
    fn single_tenant_split_round_trips_to_unified() {
        let mut r = Reconfigurator::new(base());
        r.split(&[("a", 1), ("b", 3)]).unwrap();
        let solo = r.split(&[("everything", 7)]).unwrap();
        assert_eq!(solo.len(), 1);
        // One tenant owns the whole fabric — identical to the unified
        // composition apart from the name.
        let unified = r.compose_unified();
        assert_eq!(solo[0].fmus, unified.fmus);
        assert_eq!(solo[0].cus, unified.cus);
        r.validate().unwrap();
    }

    #[test]
    fn validate_catches_overlap() {
        let mut r = Reconfigurator::new(base());
        r.split(&[("a", 1), ("b", 1)]).unwrap();
        // Corrupt: b's FMU range now overlaps a's.
        r.partitions[1].fmus.0 = 0;
        let err = r.validate().unwrap_err();
        assert!(err.contains("double-assigned"), "got {err}");
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut r = Reconfigurator::new(base());
        r.split(&[("a", 1), ("b", 1)]).unwrap();
        r.partitions[1].cus.1 = base().m_cus + 5;
        assert!(r.validate().is_err());
    }

    #[test]
    fn validate_catches_empty_partition() {
        let mut r = Reconfigurator::new(base());
        r.split(&[("a", 1), ("b", 1)]).unwrap();
        r.partitions[0].cus = (3, 3);
        assert!(r.validate().is_err());
    }

    #[test]
    fn partition_config_slices() {
        let mut r = Reconfigurator::new(base());
        let parts = r.split(&[("a", 1), ("b", 3)]).unwrap();
        let ca = parts[0].config(r.base());
        assert_eq!(ca.n_fmus, parts[0].n_fmus());
        assert_eq!(ca.m_cus, parts[0].m_cus());
        ca.validate(&Platform::vck190()).unwrap();
    }
}
