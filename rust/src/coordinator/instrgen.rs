//! Instruction Generator: schedule -> per-unit instruction streams.
//!
//! For every scheduled layer the generator walks the on-chip tile grid
//! chosen by Stage 1 and emits, per output tile:
//!
//! ```text
//! IOM:  LOAD A(i,kk) -> FMU_a     LOAD B(kk,j) -> FMU_b
//! FMU:  recv(ping) + sendToCU(pong)           (view = the tile)
//! CU:   computeMM(m=tm,k=tk,n=tn)[count=2]    (runtime loop bounds!)
//!       ... accumulate over kk ...
//! CU:   last kk: pong = writeBack -> FMU_c
//! FMU_c: recvFromCU(ping) + sendToIOM(pong)
//! IOM:  STORE C(i,j)
//! ```
//!
//! Loads round-robin over the layer's assigned FMUs and output tiles
//! round-robin over its assigned CUs — the composable-fabric behaviour
//! §2.1's fully-connected stream topology buys.

use crate::dse::{CandidateTable, Schedule};
use crate::isa::{
    CuInstr, CuOp, FmuInstr, FmuOp, Instr, IomLoadInstr, IomStoreInstr, Program, TileView, UnitId,
};
use crate::util::ceil_div;
use crate::workload::Dag;

/// DDR layout assigned to a layer's operands (synthetic base addresses;
/// the simulator only uses sizes, but real codegen needs addresses).
fn ddr_base(layer: usize) -> (u64, u64, u64) {
    let stride = 64 << 20; // 64 MB per operand region
    let base = 3 * layer as u64 * stride;
    (base, base + stride, base + 2 * stride)
}

/// Generate the full program for a schedule.
///
/// Instruction volume is bounded by `max_tiles_per_layer`: oversized
/// grids are coarsened (the generator merges K-chunks) so simulator runs
/// stay tractable; timing fidelity is preserved because the kernel cycle
/// model is linear in the merged work.
pub fn generate(
    dag: &Dag,
    table: &CandidateTable,
    schedule: &Schedule,
    max_tiles_per_layer: usize,
) -> Program {
    let mut prog = Program::new();
    // Emit layers in start-time order so per-unit streams are causally
    // ordered.
    let mut order: Vec<&crate::dse::ScheduleEntry> = schedule.entries.iter().collect();
    order.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());

    for e in order {
        let shape = dag.layers[e.layer].shape;
        let mode = &table.modes[e.layer][e.mode];
        let (mut tm, mut tk, mut tn) = mode.tile;
        tm = tm.max(1);
        tk = tk.max(1);
        tn = tn.max(1);
        // Coarsen the grid if it would blow the instruction budget.
        loop {
            let tiles = (ceil_div(shape.m as u64, tm as u64)
                * ceil_div(shape.k as u64, tk as u64)
                * ceil_div(shape.n as u64, tn as u64)) as usize
                * shape.batch as usize;
            if tiles <= max_tiles_per_layer.max(1) {
                break;
            }
            // Double the smallest tile dim (fewer, bigger tiles).
            if tm <= tk && tm <= tn && tm < shape.m {
                tm = (tm * 2).min(shape.m);
            } else if tk <= tn && tk < shape.k {
                tk = (tk * 2).min(shape.k);
            } else if tn < shape.n {
                tn = (tn * 2).min(shape.n);
            } else {
                break;
            }
        }

        let (addr_a, addr_b, addr_c) = ddr_base(e.layer);
        let gm = ceil_div(shape.m as u64, tm as u64) as u32;
        let gk = ceil_div(shape.k as u64, tk as u64) as u32;
        let gn = ceil_div(shape.n as u64, tn as u64) as u32;
        let fmus = &e.fmus;
        let cus = &e.cus;
        let mut rr_f = 0usize;

        for b in 0..shape.batch {
            for i in 0..gm {
                for j in 0..gn {
                    let cu = cus[((b as usize * gm as usize * gn as usize)
                        + (i as usize * gn as usize + j as usize))
                        % cus.len()];
                    let rm = (shape.m - i * tm).min(tm);
                    let rn = (shape.n - j * tn).min(tn);
                    for kk in 0..gk {
                        let rk = (shape.k - kk * tk).min(tk);
                        // A tile load + forward.
                        let fa = fmus[rr_f % fmus.len()];
                        rr_f += 1;
                        let va = TileView {
                            start_row: i * tm,
                            end_row: i * tm + rm,
                            start_col: kk * tk,
                            end_col: kk * tk + rk,
                        };
                        prog.push(
                            UnitId::IomLoader,
                            Instr::IomLoad(IomLoadInstr {
                                is_last: false,
                                ddr_addr: addr_a,
                                des_fmu: fa as u16,
                                m: shape.m,
                                n: shape.k,
                                view: va,
                            }),
                        );
                        prog.push(
                            UnitId::Fmu(fa as u16),
                            Instr::Fmu(FmuInstr {
                                is_last: false,
                                ping_op: FmuOp::RecvFromIom,
                                pong_op: FmuOp::SendToCu,
                                src_cu: cu as u16,
                                des_cu: cu as u16,
                                count: va.elements() as u32,
                                view: va,
                            }),
                        );
                        // B tile load + forward.
                        let fb = fmus[rr_f % fmus.len()];
                        rr_f += 1;
                        let vb = TileView {
                            start_row: kk * tk,
                            end_row: kk * tk + rk,
                            start_col: j * tn,
                            end_col: j * tn + rn,
                        };
                        prog.push(
                            UnitId::IomLoader,
                            Instr::IomLoad(IomLoadInstr {
                                is_last: false,
                                ddr_addr: addr_b,
                                des_fmu: fb as u16,
                                m: shape.k,
                                n: shape.n,
                                view: vb,
                            }),
                        );
                        prog.push(
                            UnitId::Fmu(fb as u16),
                            Instr::Fmu(FmuInstr {
                                is_last: false,
                                ping_op: FmuOp::RecvFromIom,
                                pong_op: FmuOp::SendToCu,
                                src_cu: cu as u16,
                                des_cu: cu as u16,
                                count: vb.elements() as u32,
                                view: vb,
                            }),
                        );
                        // CU: compute; write back on the final K chunk.
                        let last = kk == gk - 1;
                        let fc = fmus[rr_f % fmus.len()];
                        prog.push(
                            UnitId::Cu(cu as u16),
                            Instr::Cu(CuInstr {
                                is_last: false,
                                ping_op: CuOp::ComputeMm,
                                pong_op: if last { CuOp::WriteBack } else { CuOp::Idle },
                                src_fmu: fa as u16,
                                des_fmu: fc as u16,
                                count: 2,
                                m: rm,
                                k: rk,
                                n: rn,
                            }),
                        );
                        if last {
                            rr_f += 1;
                            let vc = TileView {
                                start_row: i * tm,
                                end_row: i * tm + rm,
                                start_col: j * tn,
                                end_col: j * tn + rn,
                            };
                            prog.push(
                                UnitId::Fmu(fc as u16),
                                Instr::Fmu(FmuInstr {
                                    is_last: false,
                                    ping_op: FmuOp::RecvFromCu,
                                    pong_op: FmuOp::SendToIom,
                                    src_cu: cu as u16,
                                    des_cu: cu as u16,
                                    count: vc.elements() as u32,
                                    view: vc,
                                }),
                            );
                            prog.push(
                                UnitId::IomStorer,
                                Instr::IomStore(IomStoreInstr {
                                    is_last: false,
                                    ddr_addr: addr_c,
                                    src_fmu: fc as u16,
                                    m: shape.m,
                                    n: shape.n,
                                    view: vc,
                                }),
                            );
                        }
                    }
                }
            }
        }
    }
    prog.seal();
    prog
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::FilcoConfig;
    use crate::dse::{ga::GaConfig, stage1};
    use crate::platform::Platform;
    use crate::sim::{self, Fabric};
    use crate::workload::zoo;

    fn pipeline(dag: &Dag) -> (Platform, FilcoConfig, Program) {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let table = stage1::optimize(&p, &cfg, dag);
        let sched = GaConfig { population: 12, generations: 8, seed: 5, ..Default::default() }
            .solve(dag, &table, &cfg)
            .schedule;
        let prog = generate(dag, &table, &sched, 64);
        (p, cfg, prog)
    }

    #[test]
    fn generated_program_is_valid_and_runs() {
        let dag = zoo::bert_layers(64, 1);
        let (p, cfg, prog) = pipeline(&dag);
        prog.validate().unwrap();
        let fabric = Fabric::from_config(&cfg);
        let report = sim::simulate(&p, &fabric, &prog).expect("no deadlock");
        assert!(report.makespan_s > 0.0);
        assert!(report.instructions as usize == prog.total_len());
    }

    #[test]
    fn traffic_covers_operands() {
        // The program must load at least one copy of A and B and store
        // one full C for every layer.
        let dag = zoo::mlp_s();
        let (p, cfg, prog) = pipeline(&dag);
        let fabric = Fabric::from_config(&cfg);
        let report = sim::simulate(&p, &fabric, &prog).unwrap();
        let min_in: u64 = dag
            .layers
            .iter()
            .map(|l| {
                4 * l.shape.batch as u64
                    * (l.shape.m as u64 * l.shape.k as u64 + l.shape.k as u64 * l.shape.n as u64)
            })
            .sum();
        let out: u64 = dag
            .layers
            .iter()
            .map(|l| 4 * l.shape.batch as u64 * l.shape.m as u64 * l.shape.n as u64)
            .sum();
        assert!(report.ddr_in_bytes >= min_in, "in {} < {min_in}", report.ddr_in_bytes);
        assert_eq!(report.ddr_out_bytes, out);
    }

    #[test]
    fn tile_budget_respected() {
        let dag = zoo::bert_layers(512, 1);
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let table = stage1::optimize(&p, &cfg, &dag);
        let sched = GaConfig { population: 8, generations: 4, seed: 1, ..Default::default() }
            .solve(&dag, &table, &cfg)
            .schedule;
        let prog = generate(&dag, &table, &sched, 16);
        // <= 16 output tiles * (up to ~6 instrs) per layer + slack.
        assert!(
            prog.total_len() <= dag.len() * 16 * 8,
            "program too large: {}",
            prog.total_len()
        );
    }

    #[test]
    fn every_assigned_cu_gets_work() {
        let dag = zoo::bert_layers(128, 1);
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let table = stage1::optimize(&p, &cfg, &dag);
        let sched = GaConfig { population: 12, generations: 8, seed: 5, ..Default::default() }
            .solve(&dag, &table, &cfg)
            .schedule;
        let prog = generate(&dag, &table, &sched, 64);
        for e in &sched.entries {
            // Layers with >= #cus output tiles must spread across all
            // assigned CUs; just assert assigned CUs have streams when
            // they got any tile at all.
            for &cu in &e.cus {
                let has = !prog.stream(UnitId::Cu(cu as u16)).is_empty();
                assert!(has || e.cus.len() > 1, "CU{cu} has no instructions");
            }
        }
    }
}
