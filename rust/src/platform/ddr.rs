//! DDR profiling results — one of the three framework inputs (Fig 6).
//!
//! The paper's IO Managers "achieve high DDR bandwidth by issuing AXI
//! transactions with large burst length" (§2.5); what the framework needs
//! from profiling is exactly the *effective bandwidth as a function of
//! burst length* curve. The board is unavailable, so we ship a synthetic
//! profile with the canonical DDR4/AXI shape: efficiency saturating with
//! burst size (row activation + protocol overhead amortised away).

/// Effective-bandwidth profile: piecewise-linear interpolation over
/// (burst_bytes, efficiency) points, times a peak bandwidth.
#[derive(Debug, Clone)]
pub struct DdrProfile {
    /// Peak (theoretical) bandwidth, bytes/s.
    pub peak_bytes_per_sec: f64,
    /// (burst length in bytes, fraction of peak achieved), sorted by
    /// burst length ascending.
    pub efficiency_points: Vec<(u64, f64)>,
    /// Fixed per-transaction latency, seconds (address + controller).
    pub txn_latency_s: f64,
}

impl DdrProfile {
    /// Synthetic VCK190 LPDDR4 profile (25.6 GB/s peak). Shape follows
    /// measured AXI behaviour: ~25% of peak at 64 B bursts, ~90% at 4 KB.
    pub fn vck190_lpddr4() -> Self {
        Self {
            peak_bytes_per_sec: 25.6e9,
            efficiency_points: vec![
                (64, 0.25),
                (128, 0.40),
                (256, 0.55),
                (512, 0.68),
                (1024, 0.78),
                (2048, 0.85),
                (4096, 0.90),
                (8192, 0.93),
                (16384, 0.94),
            ],
            txn_latency_s: 150e-9,
        }
    }

    /// Efficiency (0..1] for a given burst length, linear interpolation,
    /// clamped at the table ends.
    pub fn efficiency(&self, burst_bytes: u64) -> f64 {
        let pts = &self.efficiency_points;
        assert!(!pts.is_empty());
        if burst_bytes <= pts[0].0 {
            return pts[0].1;
        }
        if burst_bytes >= pts[pts.len() - 1].0 {
            return pts[pts.len() - 1].1;
        }
        for w in pts.windows(2) {
            let (b0, e0) = w[0];
            let (b1, e1) = w[1];
            if burst_bytes >= b0 && burst_bytes <= b1 {
                let t = (burst_bytes - b0) as f64 / (b1 - b0) as f64;
                return e0 + t * (e1 - e0);
            }
        }
        unreachable!()
    }

    /// Effective bandwidth at a burst length, bytes/s.
    pub fn effective_bw(&self, burst_bytes: u64) -> f64 {
        self.peak_bytes_per_sec * self.efficiency(burst_bytes)
    }

    /// AXI outstanding-transaction depth: per-transaction latency is
    /// pipelined across this many requests in flight.
    pub const QUEUE_DEPTH: f64 = 8.0;

    /// Time to move `total_bytes` using transactions of `burst_bytes`.
    /// Transaction latency is amortised over [`Self::QUEUE_DEPTH`]
    /// outstanding requests (AXI pipelining), plus one exposed latency.
    pub fn transfer_time_s(&self, total_bytes: u64, burst_bytes: u64) -> f64 {
        if total_bytes == 0 {
            return 0.0;
        }
        let burst = burst_bytes.max(1);
        let txns = total_bytes.div_ceil(burst) as f64;
        let bw_time = total_bytes as f64 / self.effective_bw(burst);
        let latency_time = txns * self.txn_latency_s / Self::QUEUE_DEPTH;
        bw_time.max(latency_time) + self.txn_latency_s
    }

    /// Contiguous-row transfer: a 2-D `rows x row_bytes` region whose
    /// rows are NOT contiguous in DDR bursts at most one row at a time —
    /// this is where padded operands hurt (the paper's communication
    /// overhead): the burst length is capped by the *useful* row bytes.
    pub fn transfer_time_2d_s(&self, rows: u64, row_bytes: u64) -> f64 {
        if rows == 0 || row_bytes == 0 {
            return 0.0;
        }
        self.transfer_time_s(rows * row_bytes, row_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_monotone_in_burst() {
        let p = DdrProfile::vck190_lpddr4();
        let mut prev = 0.0;
        for b in [32u64, 64, 100, 256, 300, 1024, 4096, 1 << 20] {
            let e = p.efficiency(b);
            assert!(e >= prev, "efficiency dropped at burst {b}");
            assert!(e <= 1.0);
            prev = e;
        }
    }

    #[test]
    fn interpolation_between_points() {
        let p = DdrProfile::vck190_lpddr4();
        let e = p.efficiency(192); // halfway 128 -> 256
        assert!((e - (0.40 + 0.55) / 2.0).abs() < 1e-9);
    }

    #[test]
    fn clamped_at_ends() {
        let p = DdrProfile::vck190_lpddr4();
        assert_eq!(p.efficiency(1), p.efficiency(64));
        assert_eq!(p.efficiency(1 << 30), p.efficiency(16384));
    }

    #[test]
    fn bigger_bursts_faster() {
        let p = DdrProfile::vck190_lpddr4();
        let total = 1 << 20;
        assert!(p.transfer_time_s(total, 4096) < p.transfer_time_s(total, 64));
    }

    #[test]
    fn zero_bytes_zero_time() {
        let p = DdrProfile::vck190_lpddr4();
        assert_eq!(p.transfer_time_s(0, 64), 0.0);
        assert_eq!(p.transfer_time_2d_s(0, 128), 0.0);
    }

    #[test]
    fn short_rows_pay_overhead() {
        // Same total bytes, shorter rows => more transactions + lower
        // efficiency => slower. This is the padded-operand penalty.
        let p = DdrProfile::vck190_lpddr4();
        let t_wide = p.transfer_time_2d_s(64, 4096);
        let t_narrow = p.transfer_time_2d_s(4096, 64);
        assert!(t_narrow > 2.0 * t_wide, "narrow {t_narrow} vs wide {t_wide}");
    }
}
