//! Platform resource model — the stand-in for the AMD Versal VCK190
//! board the paper evaluates on (§4: 150 MHz PL, 1 GHz AIE, Vitis 2023.1).
//!
//! The FILCO framework takes "DNN models, platform information, and DDR
//! profiling results as input" (paper Fig 6); this module is the
//! *platform information* + *DDR profiling* part. Numbers follow public
//! VCK190 specs and the CHARM paper's characterisation:
//!
//! * 400 AIE tiles @ 1 GHz, 8 fp32 MACs/cycle each → 6.4 TFLOPS fp32 peak
//! * 32 KB local memory per AIE tile, 16 KB program memory
//! * PL on-chip SRAM: 967 BRAM36 (4.35 MB) + 463 URAM288 (16.6 MB)
//! * one DDR4-3200 channel, 25.6 GB/s peak, efficiency profiled vs
//!   AXI burst length ([`ddr::DdrProfile`])

pub mod ddr;

pub use ddr::DdrProfile;

/// Static description of the target device + clocks.
#[derive(Debug, Clone)]
pub struct Platform {
    pub name: String,
    /// Total AIE tiles on the device.
    pub aie_tiles: u32,
    /// AIE clock in GHz.
    pub aie_ghz: f64,
    /// fp32 MACs per AIE tile per cycle (VCK190 AIE1: 8).
    pub aie_macs_per_cycle: u32,
    /// AIE local data memory per tile, bytes.
    pub aie_local_bytes: u64,
    /// AIE program memory per tile, bytes (16 KB — the constraint that
    /// rules out "finite instruction blocks" in §2.2).
    pub aie_pm_bytes: u64,
    /// PL fabric clock in MHz.
    pub pl_mhz: f64,
    /// Total usable PL SRAM (BRAM + URAM), bytes.
    pub pl_sram_bytes: u64,
    /// Stream width between PL and AIE per port, bits at PL clock.
    pub plio_bits: u32,
    /// Number of PLIO ports usable per direction.
    pub plio_ports: u32,
    /// DDR profile (peak + efficiency curve).
    pub ddr: DdrProfile,
}

impl Platform {
    /// The VCK190 configuration used throughout the paper's evaluation.
    pub fn vck190() -> Self {
        Self {
            name: "VCK190".to_string(),
            aie_tiles: 400,
            aie_ghz: 1.0,
            aie_macs_per_cycle: 8,
            aie_local_bytes: 32 * 1024,
            aie_pm_bytes: 16 * 1024,
            pl_mhz: 150.0,
            // 967 * 36 Kb + 463 * 288 Kb ≈ 4.35 MB + 16.67 MB; keep 90%
            // usable after controller/interconnect overhead.
            pl_sram_bytes: ((967u64 * 36 + 463u64 * 288) * 1024 / 8) * 9 / 10,
            plio_bits: 128,
            plio_ports: 78,
            ddr: DdrProfile::vck190_lpddr4(),
        }
    }

    /// Peak fp32 throughput of `tiles` AIE tiles, FLOP/s (2 FLOPs/MAC).
    pub fn aie_peak_flops(&self, tiles: u32) -> f64 {
        tiles as f64 * self.aie_macs_per_cycle as f64 * 2.0 * self.aie_ghz * 1e9
    }

    /// PL cycles per second.
    pub fn pl_hz(&self) -> f64 {
        self.pl_mhz * 1e6
    }

    /// AIE cycles per PL cycle (the two clock domains the simulator
    /// converts between).
    pub fn aie_cycles_per_pl_cycle(&self) -> f64 {
        self.aie_ghz * 1e9 / self.pl_hz()
    }

    /// On-chip stream bandwidth of one PLIO port, bytes/s.
    pub fn plio_bytes_per_sec(&self) -> f64 {
        self.plio_bits as f64 / 8.0 * self.pl_hz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vck190_peak_matches_charm() {
        let p = Platform::vck190();
        // 400 tiles * 8 MACs * 2 * 1 GHz = 6.4 TFLOPS
        assert!((p.aie_peak_flops(p.aie_tiles) - 6.4e12).abs() < 1e6);
    }

    #[test]
    fn sram_budget_about_19mb(){
        let p = Platform::vck190();
        let mb = p.pl_sram_bytes as f64 / (1024.0 * 1024.0);
        assert!((17.0..20.0).contains(&mb), "sram = {mb} MB");
    }

    #[test]
    fn clock_ratio() {
        let p = Platform::vck190();
        assert!((p.aie_cycles_per_pl_cycle() - 1e9 / 150e6).abs() < 1e-9);
    }

    #[test]
    fn plio_bandwidth() {
        let p = Platform::vck190();
        // 128 bit @ 150 MHz = 2.4 GB/s per port
        assert!((p.plio_bytes_per_sec() - 2.4e9).abs() < 1.0);
    }
}
