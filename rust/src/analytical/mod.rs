//! Analytical performance model (paper §3, Fig 6 "Runtime Parameter
//! Optimizer").
//!
//! One parameterised accelerator model covers FILCO (with any feature
//! subset) *and* the baselines: CHARM's monolithic/diverse designs and
//! the RSN overlay are specific parameter points of the same equations
//! (see [`crate::baseline`]). This keeps Fig 1/9/10 comparisons
//! apples-to-apples, exactly like the paper's in-house analytical models.
//!
//! The model splits a layer's latency into *compute* and *communication*
//! and overlaps them (the fabric double-buffers everything):
//!
//! ```text
//! latency = max(T_compute, T_ddr, T_stream) + T_reconfig
//! ```
//!
//! * `T_compute` — [`aie::AieKernelModel`] cycle model scaled to the
//!   allocated AIEs, with padding at the design's compute granularity
//!   (atomic 2x8x8 when FP is on; the full static tile otherwise).
//! * `T_ddr` — classic tiled-MM traffic: `A` is re-read `ceil(n/Tn)`
//!   times, `B` `ceil(m/Tm)` times, `C` written once, with the on-chip
//!   tile `(Tm,Tk,Tn)` bounded by the FMU capacity the design grants
//!   each operand (shared pool when FMF is on, fixed split otherwise)
//!   and inflated to the buffer-view page when FMV is off.
//! * `T_stream` — on-chip FMU->CU traffic over the fully-connected
//!   stream topology.

pub mod aie;

use crate::arch::{ATOM_K, ATOM_M, ATOM_N};
use crate::platform::Platform;
use crate::util::round_up;
use crate::workload::MmShape;

/// How a design stores operands in on-chip memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemoryView {
    /// FMV on: 1-D addressing, any shape stored exactly (padded only to
    /// the atomic op granularity).
    Flexible,
    /// FMV off: operands occupy fixed `page x page` buffer views; both
    /// storage *and DDR traffic* are padded to the page grid (the padded
    /// rows/cols are physically transferred — §2.3's red blocks).
    Paged { page: u32 },
}

/// How FMU capacity is assigned to operands.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MemoryFunc {
    /// FMF on: one shared pool; any operand may use any FMU (§2.4).
    Shared,
    /// FMF off: the pool is split at compile time in fixed fractions
    /// A : B : C.
    FixedSplit { a: f64, b: f64, c: f64 },
}

/// On-chip tile selection policy (ablated in `benches/ablations.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TilePolicy {
    /// Minimise estimated DDR *time* (bytes / burst-efficiency) — the
    /// policy FILCO's Stage-1 uses.
    #[default]
    MinTime,
    /// Minimise raw DDR bytes — the naive objective; favours skinny
    /// tiles whose short bursts destroy effective bandwidth.
    MinTraffic,
}

/// A fully-specified accelerator design point for the analytical model.
#[derive(Debug, Clone)]
pub struct AccModel {
    pub name: String,
    /// Compute units allocated and AIEs per CU.
    pub cus: u32,
    pub aies_per_cu: u32,
    /// Total on-chip operand memory, fp32 elements (sum over the FMUs /
    /// buffers granted to this accelerator; one ping half — the pong
    /// half is what buys compute/transfer overlap).
    pub onchip_elems: u64,
    /// Compute padding granularity: atomic (FP on) or the static tile.
    pub compute_gran: (u32, u32, u32),
    pub view: MemoryView,
    pub func: MemoryFunc,
    /// AIE kernel cycle model (flexible or static instruction schedule).
    pub kernel: aie::AieKernelModel,
    /// Per-layer reconfiguration cost, seconds (instruction decode —
    /// "a few bytes"; ~µs for FILCO, 0 for designs with nothing to
    /// reconfigure).
    pub reconfig_s: f64,
    /// Tile selection objective (MinTime unless ablating).
    pub tile_policy: TilePolicy,
}

/// Per-layer performance breakdown.
#[derive(Debug, Clone, Copy)]
pub struct LayerPerf {
    pub compute_s: f64,
    pub ddr_s: f64,
    pub stream_s: f64,
    pub latency_s: f64,
    /// Useful FLOPs / issued FLOPs (compute padding efficiency).
    pub compute_eff: f64,
    /// Useful DDR bytes / transferred bytes.
    pub comm_eff: f64,
    /// On-chip tile used for the traffic model.
    pub tile: (u32, u32, u32),
}

impl AccModel {
    /// Total AIEs.
    pub fn aies(&self) -> u32 {
        self.cus * self.aies_per_cu
    }

    /// Storage footprint of a `rows x cols` operand under the view rule.
    fn stored_elems(&self, rows: u32, cols: u32) -> u64 {
        match self.view {
            MemoryView::Flexible => {
                round_up(rows as u64, ATOM_M as u64) * round_up(cols as u64, ATOM_N as u64)
            }
            MemoryView::Paged { page } => {
                round_up(rows as u64, page as u64) * round_up(cols as u64, page as u64)
            }
        }
    }

    /// Padded dims transferred over DDR for a `rows x cols` region.
    fn xfer_dims(&self, rows: u32, cols: u32) -> (u64, u64) {
        match self.view {
            MemoryView::Flexible => (rows as u64, cols as u64),
            MemoryView::Paged { page } => {
                (round_up(rows as u64, page as u64), round_up(cols as u64, page as u64))
            }
        }
    }

    /// Can a tile `(tm, tk, tn)` be resident on-chip?
    ///
    /// * FMF on (Shared): FMUs are fungible — "FILCO can maximize data
    ///   reuse as long as the total data size of operands and results
    ///   does not exceed resource constraints" (paper Fig 5b): the SUM
    ///   of stored footprints must fit the pool.
    /// * FMF off (FixedSplit): each operand is confined to its
    ///   compile-time share.
    fn tile_fits(&self, tm: u32, tk: u32, tn: u32) -> bool {
        let a = self.stored_elems(tm, tk);
        let b = self.stored_elems(tk, tn);
        let c = self.stored_elems(tm, tn);
        match self.func {
            MemoryFunc::Shared => a + b + c <= self.onchip_elems,
            MemoryFunc::FixedSplit { a: fa, b: fb, c: fc } => {
                let pool = self.onchip_elems as f64;
                a as f64 <= pool * fa && b as f64 <= pool * fb && c as f64 <= pool * fc
            }
        }
    }

    /// Per-operand DDR traffic for a given on-chip tile: classic
    /// tiled-MM — A re-read per N-stripe, B per M-stripe, C written
    /// once; regions padded at the view granularity. Returns
    /// (bytes_a, bytes_b, bytes_c).
    fn tile_traffic(&self, shape: &MmShape, tm: u32, tk: u32, tn: u32) -> (u64, u64, u64) {
        let b_ = shape.batch as u64;
        let (am, ak) = self.xfer_dims(shape.m, shape.k);
        let (bk, bn) = self.xfer_dims(shape.k, shape.n);
        let (cm, cn) = self.xfer_dims(shape.m, shape.n);
        let reload_a = shape.n.div_ceil(tn.max(1)) as u64;
        let reload_b = shape.m.div_ceil(tm.max(1)) as u64;
        let _ = tk;
        (4 * b_ * am * ak * reload_a, 4 * b_ * bk * bn * reload_b, 4 * b_ * cm * cn)
    }

    /// Burst lengths for the three operand streams under a tile: rows of
    /// the transferred tile are the contiguous units (wide cyclic ports
    /// issue one burst per tile row).
    fn tile_bursts(&self, tm: u32, tk: u32, tn: u32) -> (u64, u64, u64) {
        (
            (4 * self.xfer_dims(tm, tk).1).max(64),
            (4 * self.xfer_dims(tk, tn).1).max(64),
            (4 * self.xfer_dims(tm, tn).1).max(64),
        )
    }

    /// Estimated DDR time for a tile choice — the quantity the Runtime
    /// Parameter Optimizer actually minimises (bytes alone would favour
    /// skinny tiles whose short bursts destroy effective bandwidth).
    fn tile_ddr_time(&self, p: &Platform, shape: &MmShape, tm: u32, tk: u32, tn: u32) -> f64 {
        let (ba, bb, bc) = self.tile_traffic(shape, tm, tk, tn);
        let (ua, ub, uc) = self.tile_bursts(tm, tk, tn);
        p.ddr.transfer_time_s(ba, ua)
            + p.ddr.transfer_time_s(bb, ub)
            + p.ddr.transfer_time_s(bc, uc)
    }

    /// Candidate tile extents for one dimension: the full extent plus
    /// successive halvings down to the atomic granularity.
    fn dim_candidates(full: u32, atom: u32) -> Vec<u32> {
        let mut v = Vec::new();
        let mut d = full.max(atom);
        loop {
            v.push(d);
            if d <= atom {
                break;
            }
            d = (d / 2).max(atom);
        }
        v
    }

    /// Choose the on-chip tile minimising estimated DDR time subject to
    /// the residency constraint (this is what the Runtime Parameter
    /// Optimizer's brute-force search does per layer, §3.1 Stage 1).
    fn pick_tile(&self, p: &Platform, shape: &MmShape) -> (u32, u32, u32) {
        let ms = Self::dim_candidates(shape.m, ATOM_M);
        let ks = Self::dim_candidates(shape.k, ATOM_K);
        let ns = Self::dim_candidates(shape.n, ATOM_N);
        let mut best: Option<((u32, u32, u32), f64)> = None;
        for &tm in &ms {
            for &tk in &ks {
                for &tn in &ns {
                    if !self.tile_fits(tm, tk, tn) {
                        continue;
                    }
                    let t = match self.tile_policy {
                        TilePolicy::MinTime => self.tile_ddr_time(p, shape, tm, tk, tn),
                        TilePolicy::MinTraffic => {
                            let (a, b, c) = self.tile_traffic(shape, tm, tk, tn);
                            (a + b + c) as f64
                        }
                    };
                    if best.is_none_or(|(_, bt)| t < bt) {
                        best = Some(((tm, tk, tn), t));
                    }
                }
            }
        }
        match best {
            Some((tile, _)) => tile,
            // Nothing fits (pool smaller than the minimal tile): run
            // with the minimal tile anyway; the hardware would stream.
            None => (
                ATOM_M.min(shape.m.max(1)),
                ATOM_K.min(shape.k.max(1)),
                ATOM_N.min(shape.n.max(1)),
            ),
        }
    }

    /// Evaluate one layer on this design under `platform`.
    pub fn layer_perf(&self, p: &Platform, shape: &MmShape) -> LayerPerf {
        let (gm, gk, gn) = self.compute_gran;
        let b = shape.batch as u64;

        // ---- compute ------------------------------------------------
        let pm = round_up(shape.m as u64, gm as u64);
        let pk = round_up(shape.k as u64, gk as u64);
        let pn = round_up(shape.n as u64, gn as u64);
        let cycles_one = self.kernel.mm_cycles(pm as u32, pk as u32, pn as u32);
        // Macro-tile parallelism across AIEs: when the padded matrix has
        // fewer 32^3 macro tiles than allocated AIEs, the surplus AIEs
        // idle (edge quantisation).
        let tiles = (pm.div_ceil(32) * pk.div_ceil(32) * pn.div_ceil(32)).max(1) * b;
        let aies = self.aies().max(1) as u64;
        // Total work spread over AIEs with macro-tile quantisation: in
        // each "round" every AIE runs one macro tile; partial last round.
        let rounds = tiles.div_ceil(aies) as f64;
        let per_tile_cycles = cycles_one * b as f64 / tiles as f64;
        let compute_cycles = rounds * per_tile_cycles;
        let compute_s = compute_cycles / (p.aie_ghz * 1e9);
        let useful = shape.macs() as f64;
        let issued = (pm * pk * pn * b) as f64;

        // ---- DDR traffic ---------------------------------------------
        let (tm, tk, tn) = self.pick_tile(p, shape);
        let (bytes_a, bytes_b, bytes_c) = self.tile_traffic(shape, tm, tk, tn);
        let ddr_s = self.tile_ddr_time(p, shape, tm, tk, tn);
        // Padding waste in a single pass (reload traffic is counted in
        // ddr_s but is a tiling effect, not a padding inefficiency).
        let (am, ak) = self.xfer_dims(shape.m, shape.k);
        let (bk, bn) = self.xfer_dims(shape.k, shape.n);
        let (cm, cn) = self.xfer_dims(shape.m, shape.n);
        let once = 4 * b * (am * ak + bk * bn + cm * cn);
        let comm_eff = shape.bytes() as f64 / once as f64;

        // ---- on-chip streams ------------------------------------------
        // Operand + result tiles stream between FMUs and CUs over the
        // fully-connected topology; each CU has one in + one out port.
        let stream_bytes = (bytes_a + bytes_b + bytes_c) as f64;
        let stream_bw = self.cus as f64 * p.plio_bytes_per_sec() * 2.0;
        let stream_s = stream_bytes / stream_bw;

        let latency_s = compute_s.max(ddr_s).max(stream_s) + self.reconfig_s;
        LayerPerf {
            compute_s,
            ddr_s,
            stream_s,
            latency_s,
            compute_eff: useful / issued.max(1.0),
            comm_eff,
            tile: (tm, tk, tn),
        }
    }

    /// Sequential makespan of a DAG on this single accelerator
    /// (layer-at-a-time execution — how CHARM-1 and RSN run a model).
    pub fn dag_latency(&self, p: &Platform, dag: &crate::workload::Dag) -> f64 {
        dag.layers.iter().map(|l| self.layer_perf(p, &l.shape).latency_s).sum()
    }

    /// Throughput in GFLOP/s for a DAG run sequentially.
    pub fn dag_gflops(&self, p: &Platform, dag: &crate::workload::Dag) -> f64 {
        dag.total_flops() as f64 / self.dag_latency(p, dag) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::FilcoConfig;

    fn filco_model() -> AccModel {
        let p = Platform::vck190();
        let c = FilcoConfig::default_for(&p);
        crate::baseline::filco_acc(&c, crate::arch::Features::ALL)
    }

    #[test]
    fn large_square_is_efficient_and_balanced() {
        // fp32 square MM with ~1 MFMU-elements of reuse buffer on a
        // 25.6 GB/s DDR channel is mildly bandwidth-limited at every
        // size (reuse ~ sqrt(buffer)); what must hold: near-perfect
        // padding efficiency and a bounded comm/compute ratio, with the
        // compute fraction growing from small to large MMs.
        let p = Platform::vck190();
        let m = filco_model();
        let big = m.layer_perf(&p, &MmShape::new(2048, 2048, 2048));
        let small = m.layer_perf(&p, &MmShape::new(128, 128, 128));
        assert!(big.compute_eff > 0.99, "{big:?}");
        assert!(big.comm_eff > 0.99, "{big:?}");
        assert!(big.ddr_s / big.compute_s < 4.0, "{big:?}");
        assert!(
            big.compute_s / big.ddr_s > small.compute_s / small.ddr_s,
            "compute fraction must grow with size"
        );
    }

    #[test]
    fn comm_bound_for_small_bert_layer() {
        // §4.3: "for the small BERT applications, limited by a low CTC
        // ratio, the communication time dominates" — a seq-32 projection
        // layer is weight-dominated and DDR-bound.
        let p = Platform::vck190();
        let m = filco_model();
        let perf = m.layer_perf(&p, &MmShape::new(32, 768, 768));
        assert!(perf.ddr_s > perf.compute_s, "{perf:?}");
    }

    #[test]
    fn latency_is_max_plus_reconfig() {
        let p = Platform::vck190();
        let m = filco_model();
        let perf = m.layer_perf(&p, &MmShape::new(512, 512, 512));
        let expect = perf.compute_s.max(perf.ddr_s).max(perf.stream_s) + m.reconfig_s;
        assert!((perf.latency_s - expect).abs() < 1e-15);
    }

    #[test]
    fn paged_view_transfers_more() {
        let p = Platform::vck190();
        let mut flex = filco_model();
        flex.view = MemoryView::Flexible;
        let mut paged = filco_model();
        paged.view = MemoryView::Paged { page: 256 };
        // A 100x100 MM pads to 256x256 pages -> ~6.5x traffic.
        let s = MmShape::new(100, 100, 100);
        let e_flex = flex.layer_perf(&p, &s).comm_eff;
        let e_paged = paged.layer_perf(&p, &s).comm_eff;
        assert!(e_flex > 0.9, "flex comm_eff {e_flex}");
        assert!(e_paged < 0.3, "paged comm_eff {e_paged}");
    }

    #[test]
    fn fixed_split_hurts_skewed_shapes() {
        let p = Platform::vck190();
        let shared = filco_model();
        let mut split = filco_model();
        split.func = MemoryFunc::FixedSplit { a: 1.0 / 3.0, b: 1.0 / 3.0, c: 1.0 / 3.0 };
        // A (m x k) is ~half the pool: under FMF the whole working set
        // is resident in one pass, while the rigid 1/3 split cannot hold
        // A and must tile + reload the other operands (paper Fig 5a).
        let s = MmShape::new(1024, 1024, 256);
        let l_shared = shared.layer_perf(&p, &s).latency_s;
        let l_split = split.layer_perf(&p, &s).latency_s;
        assert!(l_split > l_shared, "shared {l_shared} vs split {l_split}");
    }

    #[test]
    fn more_cus_faster_compute() {
        let p = Platform::vck190();
        let mut m1 = filco_model();
        m1.cus = 1;
        let mut m8 = filco_model();
        m8.cus = 8;
        let s = MmShape::new(4096, 4096, 4096);
        let c1 = m1.layer_perf(&p, &s).compute_s;
        let c8 = m8.layer_perf(&p, &s).compute_s;
        assert!((c1 / c8 - 8.0).abs() < 0.5, "c1/c8 = {}", c1 / c8);
    }

    #[test]
    fn batch_scales_work() {
        let p = Platform::vck190();
        let m = filco_model();
        let s1 = MmShape::new(256, 64, 256);
        let s12 = MmShape::batched(12, 256, 64, 256);
        let l1 = m.layer_perf(&p, &s1);
        let l12 = m.layer_perf(&p, &s12);
        // Batch 1 of a 256x64x256 MM cannot fill 384 AIEs (128 macro
        // tiles); batching improves utilisation, so the slowdown is
        // sub-linear but at least ~3x.
        assert!(l12.compute_s > 2.9 * l1.compute_s, "l1 {} l12 {}", l1.compute_s, l12.compute_s);
        assert!(l12.compute_s < 12.1 * l1.compute_s);
    }

    #[test]
    fn dag_gflops_positive_and_bounded() {
        let p = Platform::vck190();
        let m = filco_model();
        let dag = crate::workload::zoo::bert_layers(128, 1);
        let g = m.dag_gflops(&p, &dag);
        let peak = p.aie_peak_flops(m.aies()) / 1e9;
        assert!(g > 0.0 && g <= peak, "gflops {g} peak {peak}");
    }

    #[test]
    fn tile_fits_capacities() {
        let p = Platform::vck190();
        let m = filco_model();
        let s = MmShape::new(4096, 4096, 4096);
        let perf = m.layer_perf(&p, &s);
        let (tm, tk, tn) = perf.tile;
        assert!(m.tile_fits(tm, tk, tn), "tile {:?} overflows pool", perf.tile);
    }
}
