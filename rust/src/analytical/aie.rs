//! Single-AIE kernel cycle model (paper §2.2 + Fig 8).
//!
//! Stand-in for the Versal ACAP AI Engine SystemC simulator the paper
//! measures with. The quantity being compared is the *instruction
//! schedule*: FILCO's flexible kernel (atomic 2x8x8 VLIW op inside
//! dynamically-bounded loops) vs the static kernel (fixed 32x32x32 tile,
//! all smaller operands padded up).
//!
//! Calibration (AIE1, fp32, 8 MACs/cycle):
//! * one atomic 2x8x8 op = 128 MACs = 16 issue slots; packed as one
//!   VLIW software-pipelined body.
//! * flexible kernel: `DECODE` cycles to latch loop bounds from the
//!   stream + pipeline prologue/epilogue per invocation, and a small
//!   per-atom loop-carry bubble (`LOOP_OV`) from the dynamic bounds.
//! * static kernel: fully unrolled over the fixed tile — no per-atom
//!   bubble, tiny fixed prologue, but **everything is padded to
//!   32x32x32** (Fig 3b).
//!
//! With these constants the flexible kernel holds >95% efficiency from
//! 14x24x16 to 32x32x32 (the paper's "6x variation in operation counts
//! with only 5% efficiency loss") while the static kernel collapses on
//! small MMs — reproduced as Fig 8 by `benches/fig8_single_aie.rs`.

use crate::arch::{ATOM_K, ATOM_M, ATOM_N, MAX_TILE_K, MAX_TILE_M, MAX_TILE_N};
use crate::util::ceil_div;

/// Cycles of one atomic 2x8x8 fp32 MM on the 8-MAC datapath.
pub const ATOM_CYCLES: f64 = (ATOM_M * ATOM_K * ATOM_N) as f64 / 8.0; // 16

/// Flexible-kernel instruction decode + pipeline fill per invocation.
pub const FLEX_DECODE: f64 = 16.0;
/// Per-atom loop-carry overhead of the dynamically-bounded loops.
pub const FLEX_LOOP_OV: f64 = 0.4;
/// Static-kernel fixed prologue.
pub const STATIC_PROLOGUE: f64 = 8.0;

/// Which instruction schedule the AIE runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AieKernelModel {
    /// FILCO flexible parallelism: runtime loop bounds, atomic padding.
    Flexible,
    /// Static programming: every MM padded to the fixed max tile.
    Static,
}

impl AieKernelModel {
    /// Cycles for an `m x k x n` MM on ONE AIE (dims may be arbitrary;
    /// the kernel pads at its own granularity).
    pub fn mm_cycles(&self, m: u32, k: u32, n: u32) -> f64 {
        match self {
            AieKernelModel::Flexible => {
                let atoms = (ceil_div(m as u64, ATOM_M as u64)
                    * ceil_div(k as u64, ATOM_K as u64)
                    * ceil_div(n as u64, ATOM_N as u64)) as f64;
                FLEX_DECODE + atoms * (ATOM_CYCLES + FLEX_LOOP_OV)
            }
            AieKernelModel::Static => {
                // Pad up to a whole number of max tiles; each tile is a
                // fully unrolled 32x32x32 schedule.
                let tiles = (ceil_div(m as u64, MAX_TILE_M as u64)
                    * ceil_div(k as u64, MAX_TILE_K as u64)
                    * ceil_div(n as u64, MAX_TILE_N as u64)) as f64;
                let atoms_per_tile = ((MAX_TILE_M / ATOM_M)
                    * (MAX_TILE_K / ATOM_K)
                    * (MAX_TILE_N / ATOM_N)) as f64;
                STATIC_PROLOGUE + tiles * atoms_per_tile * ATOM_CYCLES
            }
        }
    }

    /// Efficiency = useful MACs / (cycles × 8 MACs/cycle) for the true
    /// (unpadded) workload — the y-axis of Fig 8.
    pub fn efficiency(&self, m: u32, k: u32, n: u32) -> f64 {
        let useful = m as f64 * k as f64 * n as f64;
        let cycles = self.mm_cycles(m, k, n);
        useful / (cycles * 8.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Cases;

    #[test]
    fn atom_is_16_cycles() {
        assert_eq!(ATOM_CYCLES, 16.0);
    }

    #[test]
    fn flexible_peak_efficiency_at_max_tile() {
        let e = AieKernelModel::Flexible.efficiency(32, 32, 32);
        assert!(e > 0.95, "eff = {e}");
    }

    #[test]
    fn paper_claim_5pct_loss_over_6x_range() {
        // §4.1: 14x24x16 .. 32x32x32 (≈6x ops) within 5% efficiency loss.
        let peak = AieKernelModel::Flexible.efficiency(32, 32, 32);
        let lo = AieKernelModel::Flexible.efficiency(14, 24, 16);
        assert!(lo / peak > 0.95, "lo/peak = {}", lo / peak);
    }

    #[test]
    fn static_collapses_on_small_mm() {
        let flex = AieKernelModel::Flexible.efficiency(8, 24, 16);
        let stat = AieKernelModel::Static.efficiency(8, 24, 16);
        assert!(stat < 0.15, "static eff = {stat}");
        assert!(flex > 5.0 * stat, "flex {flex} vs static {stat}");
    }

    #[test]
    fn static_fine_at_exact_tile() {
        let e = AieKernelModel::Static.efficiency(32, 32, 32);
        assert!(e > 0.99, "eff = {e}");
    }

    #[test]
    fn flexible_never_slower_than_static() {
        Cases::new(300).run(|rng| {
            let m = rng.range(1, 128) as u32;
            let k = rng.range(1, 128) as u32;
            let n = rng.range(1, 128) as u32;
            let f = AieKernelModel::Flexible.mm_cycles(m, k, n);
            let s = AieKernelModel::Static.mm_cycles(m, k, n);
            // Static pads to 32-multiples; flexible pads to atoms. The
            // flexible schedule's only penalty is the tiny loop overhead,
            // bounded by 2.5% + decode.
            assert!(
                f <= s * 1.03 + FLEX_DECODE,
                "flexible {f} vs static {s} at {m}x{k}x{n}"
            );
        });
    }

    #[test]
    fn cycles_monotone_in_each_dim() {
        Cases::new(200).run(|rng| {
            let m = rng.range(1, 64) as u32;
            let k = rng.range(1, 64) as u32;
            let n = rng.range(1, 64) as u32;
            for model in [AieKernelModel::Flexible, AieKernelModel::Static] {
                assert!(model.mm_cycles(m + 32, k, n) >= model.mm_cycles(m, k, n));
                assert!(model.mm_cycles(m, k + 32, n) >= model.mm_cycles(m, k, n));
                assert!(model.mm_cycles(m, k, n + 32) >= model.mm_cycles(m, k, n));
            }
        });
    }

    #[test]
    fn efficiency_bounded_by_one() {
        Cases::new(200).run(|rng| {
            let m = rng.range(1, 200) as u32;
            let k = rng.range(1, 200) as u32;
            let n = rng.range(1, 200) as u32;
            for model in [AieKernelModel::Flexible, AieKernelModel::Static] {
                let e = model.efficiency(m, k, n);
                assert!(e > 0.0 && e <= 1.0, "{model:?} eff {e} at {m}x{k}x{n}");
            }
        });
    }
}
