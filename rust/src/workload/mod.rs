//! DNN workloads as layer DAGs of (batched) matrix multiplies.
//!
//! The paper's entire analysis treats DNN layers as dense MM operations
//! whose *shape diversity* (intra- and inter-model, §1) is the problem
//! being solved. A workload here is a DAG: nodes are MM layers `L_i`,
//! edges are dependencies `P_{i,j}` (§3.2).
//!
//! * [`zoo`] — the models profiled in the paper: MLP (Wang et al.),
//!   DeiT, PointNet, MLP-Mixer, BERT-32..512.
//! * [`diverse`] — the synthetic diverse-MM workload generator behind
//!   Fig 9 (sweeps operation count × diversity degree).

pub mod diverse;
pub mod zoo;

/// One (optionally batched) matrix multiply: `batch × (m×k) @ (k×n)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MmShape {
    pub batch: u32,
    pub m: u32,
    pub k: u32,
    pub n: u32,
}

impl MmShape {
    pub fn new(m: u32, k: u32, n: u32) -> Self {
        Self { batch: 1, m, k, n }
    }

    pub fn batched(batch: u32, m: u32, k: u32, n: u32) -> Self {
        Self { batch, m, k, n }
    }

    /// Useful FLOPs (2 per MAC).
    pub fn flops(&self) -> u64 {
        2 * self.batch as u64 * self.m as u64 * self.k as u64 * self.n as u64
    }

    /// MACs.
    pub fn macs(&self) -> u64 {
        self.flops() / 2
    }

    /// fp32 bytes of A, B and C (per batch element summed).
    pub fn bytes(&self) -> u64 {
        4 * self.batch as u64
            * (self.m as u64 * self.k as u64
                + self.k as u64 * self.n as u64
                + self.m as u64 * self.n as u64)
    }

    /// Computation-to-communication ratio in FLOPs/byte — the "CTC
    /// ratio" the paper uses to explain why small BERT models are
    /// communication-bound (§4.3).
    pub fn ctc(&self) -> f64 {
        self.flops() as f64 / self.bytes() as f64
    }

    /// A scalar "shape skew": max dim / min dim. Square MMs ≈ 1.
    pub fn skew(&self) -> f64 {
        let dims = [self.m as f64, self.k as f64, self.n as f64];
        let mx = dims.iter().cloned().fold(f64::MIN, f64::max);
        let mn = dims.iter().cloned().fold(f64::MAX, f64::min);
        mx / mn
    }
}

/// A named DAG node.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub shape: MmShape,
}

/// Workload DAG. An edge `(i, j)` means layer `j` depends on layer `i`
/// (paper: `P_{i,j} = 1` iff `L_j` depends on `L_i`).
#[derive(Debug, Clone, Default)]
pub struct Dag {
    pub name: String,
    pub layers: Vec<Layer>,
    pub edges: Vec<(usize, usize)>,
}

impl Dag {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), layers: Vec::new(), edges: Vec::new() }
    }

    /// Append a layer, returning its index.
    pub fn add(&mut self, name: impl Into<String>, shape: MmShape) -> usize {
        self.layers.push(Layer { name: name.into(), shape });
        self.layers.len() - 1
    }

    /// Add dependency: `to` depends on `from`.
    pub fn dep(&mut self, from: usize, to: usize) {
        debug_assert!(from < self.layers.len() && to < self.layers.len());
        self.edges.push((from, to));
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Predecessor lists.
    pub fn preds(&self) -> Vec<Vec<usize>> {
        let mut p = vec![Vec::new(); self.layers.len()];
        for &(a, b) in &self.edges {
            p[b].push(a);
        }
        p
    }

    /// Successor lists.
    pub fn succs(&self) -> Vec<Vec<usize>> {
        let mut s = vec![Vec::new(); self.layers.len()];
        for &(a, b) in &self.edges {
            s[a].push(b);
        }
        s
    }

    /// Kahn topological order; `None` if the graph has a cycle.
    pub fn topo_order(&self) -> Option<Vec<usize>> {
        let mut indeg = vec![0usize; self.layers.len()];
        for &(_, b) in &self.edges {
            indeg[b] += 1;
        }
        let succs = self.succs();
        let mut queue: Vec<usize> = (0..self.layers.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.layers.len());
        while let Some(i) = queue.pop() {
            order.push(i);
            for &j in &succs[i] {
                indeg[j] -= 1;
                if indeg[j] == 0 {
                    queue.push(j);
                }
            }
        }
        (order.len() == self.layers.len()).then_some(order)
    }

    pub fn validate(&self) -> Result<(), String> {
        for &(a, b) in &self.edges {
            if a >= self.layers.len() || b >= self.layers.len() {
                return Err(format!("edge ({a},{b}) out of range"));
            }
            if a == b {
                return Err(format!("self-loop at {a}"));
            }
        }
        if self.topo_order().is_none() {
            return Err("cycle detected".into());
        }
        Ok(())
    }

    /// Total useful FLOPs.
    pub fn total_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.shape.flops()).sum()
    }

    /// Total operand/result bytes (no reuse).
    pub fn total_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.shape.bytes()).sum()
    }

    /// The paper's *diversity degree*: coefficient of variation of
    /// per-layer log-MAC counts plus mean log shape-skew. 0 for a single
    /// repeated square MM; grows with intra-model shape variance.
    pub fn diversity(&self) -> f64 {
        if self.layers.len() < 2 {
            return 0.0;
        }
        let logs: Vec<f64> = self.layers.iter().map(|l| (l.shape.macs() as f64).ln()).collect();
        let mean = logs.iter().sum::<f64>() / logs.len() as f64;
        let var = logs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / logs.len() as f64;
        let cv = var.sqrt();
        let mean_skew =
            self.layers.iter().map(|l| l.shape.skew().ln()).sum::<f64>() / self.layers.len() as f64;
        cv + mean_skew
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag {
        // 0 -> {1, 2} -> 3
        let mut d = Dag::new("diamond");
        let a = d.add("a", MmShape::new(8, 8, 8));
        let b = d.add("b", MmShape::new(8, 8, 8));
        let c = d.add("c", MmShape::new(8, 8, 8));
        let e = d.add("e", MmShape::new(8, 8, 8));
        d.dep(a, b);
        d.dep(a, c);
        d.dep(b, e);
        d.dep(c, e);
        d
    }

    #[test]
    fn shape_math() {
        let s = MmShape::new(32, 64, 16);
        assert_eq!(s.flops(), 2 * 32 * 64 * 16);
        assert_eq!(s.bytes(), 4 * (32 * 64 + 64 * 16 + 32 * 16));
        assert!((s.skew() - 4.0).abs() < 1e-12);
        let b = MmShape::batched(12, 32, 64, 16);
        assert_eq!(b.flops(), 12 * s.flops());
    }

    #[test]
    fn topo_respects_deps() {
        let d = diamond();
        let order = d.topo_order().unwrap();
        let pos = |i: usize| order.iter().position(|&x| x == i).unwrap();
        assert!(pos(0) < pos(1) && pos(0) < pos(2));
        assert!(pos(1) < pos(3) && pos(2) < pos(3));
    }

    #[test]
    fn cycle_detected() {
        let mut d = diamond();
        d.dep(3, 0);
        assert!(d.topo_order().is_none());
        assert!(d.validate().is_err());
    }

    #[test]
    fn self_loop_rejected() {
        let mut d = diamond();
        d.edges.push((1, 1));
        assert!(d.validate().is_err());
    }

    #[test]
    fn diversity_zero_for_uniform_square() {
        let mut d = Dag::new("uniform");
        for i in 0..4 {
            d.add(format!("l{i}"), MmShape::new(64, 64, 64));
        }
        assert!(d.diversity() < 1e-9);
    }

    #[test]
    fn diversity_grows_with_variance() {
        let mut small = Dag::new("low");
        small.add("a", MmShape::new(64, 64, 64));
        small.add("b", MmShape::new(64, 64, 64));
        let mut big = Dag::new("high");
        big.add("a", MmShape::new(1024, 8, 1024));
        big.add("b", MmShape::new(8, 1024, 8));
        assert!(big.diversity() > small.diversity());
    }

    #[test]
    fn preds_succs_consistent() {
        let d = diamond();
        let p = d.preds();
        let s = d.succs();
        assert_eq!(p[3], vec![1, 2]);
        assert_eq!(s[0], vec![1, 2]);
        assert!(p[0].is_empty());
        assert!(s[3].is_empty());
    }

    #[test]
    fn ctc_grows_with_size() {
        assert!(MmShape::new(512, 512, 512).ctc() > MmShape::new(32, 32, 32).ctc());
    }
}
