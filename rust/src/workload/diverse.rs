//! Synthetic diverse-MM workload generator (paper §4.2, Fig 9).
//!
//! The paper "design[s] a series of Transformer-based workloads with
//! varying sequence length, number of heads, head dimension, and MLP
//! ratio", then categorises them "according to the number of operations
//! and inter-layer diversity". This module generates that grid:
//! given a target operation count and a diversity degree, it synthesises
//! a transformer-like layer set whose measured [`Dag::diversity`] and
//! total MACs land in the requested bucket.

use super::{Dag, MmShape};
use crate::util::rng::SplitMix64;

/// Grid axis: operation-count buckets (total MACs per workload).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpBucket {
    /// ~2^24 MACs — "small" (communication-bound region).
    Small,
    /// ~2^28 MACs.
    Medium,
    /// ~2^32 MACs — "large" (compute-bound region).
    Large,
}

impl OpBucket {
    pub const ALL: [OpBucket; 3] = [OpBucket::Small, OpBucket::Medium, OpBucket::Large];

    pub fn target_macs(self) -> u64 {
        // Per-layer sides of roughly 40 / 180 / 700 elements over a
        // 12-layer workload — matching the paper's sweep from tiny
        // attention heads (seq 32, head dim 64) up to big FFN MMs.
        match self {
            OpBucket::Small => 1 << 20,
            OpBucket::Medium => 1 << 26,
            OpBucket::Large => 1 << 32,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            OpBucket::Small => "small-ops",
            OpBucket::Medium => "medium-ops",
            OpBucket::Large => "large-ops",
        }
    }
}

/// Grid axis: diversity degree (0 = uniform square shapes, higher =
/// more inter-layer variance + skew).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Diversity {
    Low,
    Medium,
    High,
}

impl Diversity {
    pub const ALL: [Diversity; 3] = [Diversity::Low, Diversity::Medium, Diversity::High];

    /// (log-size spread, skew exponent range) per degree.
    fn params(self) -> (f64, u32) {
        match self {
            Diversity::Low => (0.15, 0),
            Diversity::Medium => (0.8, 2),
            Diversity::High => (1.8, 4),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Diversity::Low => "low-div",
            Diversity::Medium => "med-div",
            Diversity::High => "high-div",
        }
    }
}

fn round_to_atom(x: f64, atom: u32) -> u32 {
    let v = (x.round() as u32).max(atom);
    v.div_ceil(atom) * atom
}

/// Generate one workload for a (bucket, diversity) grid cell.
///
/// Layers form a chain (transformer blocks are sequential); shapes are
/// log-normally perturbed around the cube root of per-layer MACs, with
/// skew applied by shifting size between M/K/N — mimicking varying
/// sequence length vs head dim vs FFN ratio.
pub fn generate(bucket: OpBucket, div: Diversity, layers: usize, seed: u64) -> Dag {
    let mut rng = SplitMix64::new(seed ^ 0xD1BE_25E5);
    let (sigma, skew_range) = div.params();
    let per_layer = bucket.target_macs() as f64 / layers as f64;

    let mut d = Dag::new(format!("{}_{}", bucket.label(), div.label()));
    let mut prev: Option<usize> = None;
    for i in 0..layers {
        // Per-layer MAC target, log-normal spread.
        let macs = per_layer * (sigma * rng.next_normal()).exp();
        let side = macs.cbrt();
        // Skew: move up to 2^skew factor from one dim to another.
        let sk = if skew_range == 0 {
            1.0
        } else {
            2f64.powi(rng.range(0, (skew_range + 1) as usize) as i32)
        };
        let (mut m, mut k, mut n) = (side, side, side);
        match rng.below(3) {
            0 => {
                m *= sk;
                k /= sk.sqrt();
                n /= sk.sqrt();
            }
            1 => {
                k *= sk;
                m /= sk.sqrt();
                n /= sk.sqrt();
            }
            _ => {
                n *= sk;
                m /= sk.sqrt();
                k /= sk.sqrt();
            }
        }
        let shape = MmShape::new(
            round_to_atom(m, crate::arch::ATOM_M),
            round_to_atom(k, crate::arch::ATOM_K),
            round_to_atom(n, crate::arch::ATOM_N),
        );
        let l = d.add(format!("mm{i}"), shape);
        if let Some(p) = prev {
            d.dep(p, l);
        }
        prev = Some(l);
    }
    d
}

/// The full 3x3 Fig 9 grid (fixed seeds → reproducible workloads).
pub fn fig9_grid(layers: usize) -> Vec<(OpBucket, Diversity, Dag)> {
    let mut out = Vec::new();
    for (bi, &b) in OpBucket::ALL.iter().enumerate() {
        for (di, &v) in Diversity::ALL.iter().enumerate() {
            out.push((b, v, generate(b, v, layers, (bi * 3 + di) as u64 + 1)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_dags_valid_and_chained() {
        for (_, _, d) in fig9_grid(12) {
            d.validate().unwrap();
            assert_eq!(d.len(), 12);
            assert_eq!(d.edges.len(), 11);
        }
    }

    #[test]
    fn op_counts_land_in_buckets() {
        for b in OpBucket::ALL {
            let d = generate(b, Diversity::Low, 12, 7);
            let total = d.layers.iter().map(|l| l.shape.macs()).sum::<u64>() as f64;
            let target = b.target_macs() as f64;
            let ratio = total / target;
            assert!(
                (0.3..3.0).contains(&ratio),
                "{}: total {total:.3e} vs target {target:.3e}",
                d.name
            );
        }
    }

    #[test]
    fn diversity_monotone_across_degrees() {
        // Averaged over seeds, measured diversity must rise Low→High.
        let avg = |v: Diversity| -> f64 {
            (0..8)
                .map(|s| generate(OpBucket::Medium, v, 16, s).diversity())
                .sum::<f64>()
                / 8.0
        };
        let lo = avg(Diversity::Low);
        let mid = avg(Diversity::Medium);
        let hi = avg(Diversity::High);
        assert!(lo < mid, "low {lo} < medium {mid}");
        assert!(mid < hi, "medium {mid} < high {hi}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = generate(OpBucket::Small, Diversity::High, 10, 42);
        let b = generate(OpBucket::Small, Diversity::High, 10, 42);
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.shape, y.shape);
        }
    }

    #[test]
    fn shapes_atomic_aligned() {
        let d = generate(OpBucket::Medium, Diversity::High, 20, 3);
        for l in &d.layers {
            assert_eq!(l.shape.m % crate::arch::ATOM_M, 0);
            assert_eq!(l.shape.k % crate::arch::ATOM_K, 0);
            assert_eq!(l.shape.n % crate::arch::ATOM_N, 0);
        }
    }
}
