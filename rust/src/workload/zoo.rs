//! The model zoo profiled in the paper (§1 Fig 1, §4.3 Fig 10):
//! MLP (Wang et al. benchmark), DeiT, PointNet, MLP-Mixer, and the
//! BERT-32..512 series.

use super::{Dag, MmShape};

/// Transformer encoder hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct EncoderCfg {
    pub seq: u32,
    pub hidden: u32,
    pub heads: u32,
    pub ffn: u32,
    pub layers: u32,
}

/// Build a transformer-encoder DAG: per layer
/// Q, K, V (parallel) -> scores -> context -> O -> FFN1 -> FFN2, with
/// sequential dependencies across layers.
pub fn encoder(name: &str, c: EncoderCfg) -> Dag {
    assert!(c.hidden % c.heads == 0, "hidden must divide heads");
    let dh = c.hidden / c.heads;
    let mut d = Dag::new(name);
    let mut prev_out: Option<usize> = None;
    for l in 0..c.layers {
        let q = d.add(format!("L{l}.q"), MmShape::new(c.seq, c.hidden, c.hidden));
        let k = d.add(format!("L{l}.k"), MmShape::new(c.seq, c.hidden, c.hidden));
        let v = d.add(format!("L{l}.v"), MmShape::new(c.seq, c.hidden, c.hidden));
        if let Some(p) = prev_out {
            d.dep(p, q);
            d.dep(p, k);
            d.dep(p, v);
        }
        let s = d.add(
            format!("L{l}.scores"),
            MmShape::batched(c.heads, c.seq, dh, c.seq),
        );
        d.dep(q, s);
        d.dep(k, s);
        let ctx = d.add(
            format!("L{l}.ctx"),
            MmShape::batched(c.heads, c.seq, c.seq, dh),
        );
        d.dep(s, ctx);
        d.dep(v, ctx);
        let o = d.add(format!("L{l}.o"), MmShape::new(c.seq, c.hidden, c.hidden));
        d.dep(ctx, o);
        let f1 = d.add(format!("L{l}.ffn1"), MmShape::new(c.seq, c.hidden, c.ffn));
        d.dep(o, f1);
        let f2 = d.add(format!("L{l}.ffn2"), MmShape::new(c.seq, c.ffn, c.hidden));
        d.dep(f1, f2);
        prev_out = Some(f2);
    }
    d
}

/// BERT-base encoder with sequence length `seq` — the §4.3 series
/// (BERT-32, -64, -128, -256, -512). Hidden 768, 12 heads, FFN 3072.
pub fn bert(seq: u32) -> Dag {
    encoder(
        &format!("BERT-{seq}"),
        EncoderCfg { seq, hidden: 768, heads: 12, ffn: 3072, layers: 12 },
    )
}

/// Short BERT (fewer layers) for simulator-heavy tests/benches.
pub fn bert_layers(seq: u32, layers: u32) -> Dag {
    encoder(
        &format!("BERT-{seq}x{layers}"),
        EncoderCfg { seq, hidden: 768, heads: 12, ffn: 3072, layers },
    )
}

/// MLP-L: large near-square MM chain (low intra-model diversity) — the
/// Fig 1 workload where monolithic designs shine. Shapes follow the
/// Wang et al. MLP benchmark scaled to data-center size.
pub fn mlp_l() -> Dag {
    chain_mlp("MLP-L", 1024, &[4096, 4096, 4096, 4096, 4096, 1024])
}

/// MLP-S: the same topology at small size (inter-model diversity vs
/// MLP-L; Fig 1's small workload).
pub fn mlp_s() -> Dag {
    chain_mlp("MLP-S", 64, &[256, 256, 256, 256, 256, 64])
}

fn chain_mlp(name: &str, batch: u32, widths: &[u32]) -> Dag {
    let mut d = Dag::new(name);
    let mut prev: Option<usize> = None;
    let mut in_dim = widths[0];
    for (i, &w) in widths.iter().enumerate().skip(1) {
        let l = d.add(format!("fc{i}"), MmShape::new(batch, in_dim, w));
        if let Some(p) = prev {
            d.dep(p, l);
        }
        prev = Some(l);
        in_dim = w;
    }
    d
}

/// DeiT-L (ViT-Large geometry: 197 tokens, hidden 1024, 16 heads) —
/// medium diversity: attention vs FFN shapes differ.
pub fn deit_l() -> Dag {
    encoder(
        "DeiT-L",
        EncoderCfg { seq: 200, hidden: 1024, heads: 16, ffn: 4096, layers: 24 },
    )
}

/// DeiT-S (hidden 384, 6 heads, 12 layers).
pub fn deit_s() -> Dag {
    encoder(
        "DeiT-S",
        EncoderCfg { seq: 200, hidden: 384, heads: 6, ffn: 1536, layers: 12 },
    )
}

/// PointNet (classification head): shared per-point MLPs
/// 3→64→64→64→128→1024 over 1024 points, T-Net 3x3 and 64x64 feature
/// transforms, then FC 1024→512→256→40. Extremely skewed shapes — the
/// highest-diversity model in Fig 1.
pub fn pointnet() -> Dag {
    let n_pts = 1024;
    let mut d = Dag::new("PointNet");
    // Input T-Net (simplified trunk): per-point MLP then FCs to 3x3.
    let t1 = d.add("tnet1.mlp1", MmShape::new(n_pts, 3, 64));
    let t2 = d.add("tnet1.mlp2", MmShape::new(n_pts, 64, 128));
    let t3 = d.add("tnet1.mlp3", MmShape::new(n_pts, 128, 1024));
    let t4 = d.add("tnet1.fc1", MmShape::new(1, 1024, 512));
    let t5 = d.add("tnet1.fc2", MmShape::new(1, 512, 256));
    let t6 = d.add("tnet1.fc3", MmShape::new(1, 256, 9));
    let tx = d.add("tnet1.apply", MmShape::new(n_pts, 3, 3));
    for w in [(t1, t2), (t2, t3), (t3, t4), (t4, t5), (t5, t6), (t6, tx)] {
        d.dep(w.0, w.1);
    }
    // Trunk MLPs.
    let m1 = d.add("mlp1", MmShape::new(n_pts, 3, 64));
    d.dep(tx, m1);
    let m2 = d.add("mlp2", MmShape::new(n_pts, 64, 64));
    d.dep(m1, m2);
    // Feature T-Net (64x64).
    let f1 = d.add("tnet2.mlp1", MmShape::new(n_pts, 64, 64));
    let f2 = d.add("tnet2.mlp2", MmShape::new(n_pts, 64, 128));
    let f3 = d.add("tnet2.mlp3", MmShape::new(n_pts, 128, 1024));
    let f4 = d.add("tnet2.fc1", MmShape::new(1, 1024, 512));
    let f5 = d.add("tnet2.fc2", MmShape::new(1, 512, 256));
    let f6 = d.add("tnet2.fc3", MmShape::new(1, 256, 64 * 64));
    let fx = d.add("tnet2.apply", MmShape::new(n_pts, 64, 64));
    d.dep(m2, f1);
    for w in [(f1, f2), (f2, f3), (f3, f4), (f4, f5), (f5, f6), (f6, fx)] {
        d.dep(w.0, w.1);
    }
    // Remaining trunk + classifier.
    let m3 = d.add("mlp3", MmShape::new(n_pts, 64, 64));
    d.dep(fx, m3);
    let m4 = d.add("mlp4", MmShape::new(n_pts, 64, 128));
    d.dep(m3, m4);
    let m5 = d.add("mlp5", MmShape::new(n_pts, 128, 1024));
    d.dep(m4, m5);
    let c1 = d.add("fc1", MmShape::new(1, 1024, 512));
    d.dep(m5, c1);
    let c2 = d.add("fc2", MmShape::new(1, 512, 256));
    d.dep(c1, c2);
    let c3 = d.add("fc3", MmShape::new(1, 256, 40));
    d.dep(c2, c3);
    d
}

/// MLP-Mixer (S/16-like): token-mixing (S×S) + channel-mixing MMs.
pub fn mlp_mixer() -> Dag {
    let (s, c, layers) = (196u32, 512u32, 8u32);
    let (ds, dc) = (256u32, 2048u32);
    let mut d = Dag::new("MLP-Mixer");
    let mut prev: Option<usize> = None;
    for l in 0..layers {
        // Token mixing operates on transposed (C, S): two MMs.
        let tm1 = d.add(format!("L{l}.tok1"), MmShape::new(c, s, ds));
        let tm2 = d.add(format!("L{l}.tok2"), MmShape::new(c, ds, s));
        // Channel mixing on (S, C).
        let cm1 = d.add(format!("L{l}.ch1"), MmShape::new(s, c, dc));
        let cm2 = d.add(format!("L{l}.ch2"), MmShape::new(s, dc, c));
        if let Some(p) = prev {
            d.dep(p, tm1);
        }
        d.dep(tm1, tm2);
        d.dep(tm2, cm1);
        d.dep(cm1, cm2);
        prev = Some(cm2);
    }
    d
}

/// The Fig 1 profiling set, in the paper's diversity order.
pub fn fig1_models() -> Vec<Dag> {
    vec![mlp_l(), mlp_s(), deit_l(), deit_s(), pointnet()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_zoo_dags_valid() {
        for d in [
            bert(32),
            bert(512),
            mlp_l(),
            mlp_s(),
            deit_l(),
            deit_s(),
            pointnet(),
            mlp_mixer(),
        ] {
            d.validate().unwrap_or_else(|e| panic!("{}: {e}", d.name));
            assert!(!d.is_empty());
        }
    }

    #[test]
    fn bert_layer_structure() {
        let d = bert_layers(128, 1);
        // 8 MMs per encoder layer: q,k,v, scores, ctx, o, ffn1, ffn2.
        assert_eq!(d.len(), 8);
        // scores layer is batched by heads with seq x dh x seq.
        let s = &d.layers[3];
        assert_eq!(s.shape.batch, 12);
        assert_eq!((s.shape.m, s.shape.k, s.shape.n), (128, 64, 128));
    }

    #[test]
    fn bert_flops_scale_superlinear_in_seq() {
        // Attention scores are quadratic in seq; BERT-512 must be much
        // more than 2x BERT-256.
        let f256 = bert(256).total_flops() as f64;
        let f512 = bert(512).total_flops() as f64;
        assert!(f512 / f256 > 2.0);
    }

    #[test]
    fn diversity_ordering_matches_fig1() {
        // Paper: MLP lowest diversity, DeiT medium, PointNet highest.
        let mlp = mlp_l().diversity();
        let deit = deit_l().diversity();
        let pnet = pointnet().diversity();
        assert!(mlp < deit, "mlp {mlp} < deit {deit}");
        assert!(deit < pnet, "deit {deit} < pnet {pnet}");
    }

    #[test]
    fn mlp_l_bigger_than_mlp_s() {
        assert!(mlp_l().total_flops() > 20 * mlp_s().total_flops());
    }

    #[test]
    fn encoder_cross_layer_dependency() {
        let d = bert_layers(64, 2);
        assert_eq!(d.len(), 16);
        // Layer 1's q (index 8) depends on layer 0's ffn2 (index 7).
        assert!(d.edges.contains(&(7, 8)));
    }

    #[test]
    fn pointnet_has_tiny_and_huge_layers() {
        let d = pointnet();
        let macs: Vec<u64> = d.layers.iter().map(|l| l.shape.macs()).collect();
        let mx = *macs.iter().max().unwrap();
        let mn = *macs.iter().min().unwrap();
        assert!(mx / mn > 1000, "PointNet should span >1000x op-count range");
    }
}
