//! Table/report formatting shared by the benches — every figure/table of
//! the paper is regenerated as an aligned text table plus a CSV file
//! under `target/bench-results/`.

use std::fmt::Write as _;
use std::path::PathBuf;

/// A simple column-aligned table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        out
    }

    /// CSV rendering.
    pub fn csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Print to stdout and persist CSV under target/bench-results/.
    pub fn emit(&self, file_stem: &str) {
        println!("{}", self.render());
        let dir = PathBuf::from("target/bench-results");
        if std::fs::create_dir_all(&dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{file_stem}.csv")), self.csv());
        }
    }
}

/// Format a float with engineering-style precision.
pub fn eng(x: f64) -> String {
    if x == 0.0 {
        return "0".into();
    }
    let a = x.abs();
    if a >= 100.0 {
        format!("{x:.0}")
    } else if a >= 1.0 {
        format!("{x:.2}")
    } else if a >= 1e-3 {
        format!("{:.3}", x)
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "val"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["v,1".into(), "q\"q".into()]);
        let c = t.csv();
        assert!(c.contains("\"v,1\""));
        assert!(c.contains("\"q\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn eng_formats() {
        assert_eq!(eng(0.0), "0");
        assert_eq!(eng(1234.5), "1234"); // round-half-even
        assert_eq!(eng(3.14159), "3.14");
        assert_eq!(eng(0.00123), "0.001");
    }
}
