//! CHARM baseline [35]: fixed-dataflow accelerator designs on the same
//! VCK190 fabric.
//!
//! * **CHARM-1** — one monolithic accelerator using all on-chip
//!   resources, buffer shapes fixed for large square MMs (on-chip tile
//!   picked for MLP-L-scale layers). Operands are padded to the on-chip
//!   buffer shape (both compute and DDR traffic).
//! * **CHARM-2** — two diverse accelerators (big + small) with a static
//!   resource split; each layer runs on whichever finishes it sooner,
//!   and independent layers can overlap across the two.
//! * **CHARM-3** — three accelerators (big + 2 small).
//!
//! The paper profiles CHARM via its public framework; this is the same
//! analytical construction (fixed dataflow = static kernel + paged
//! views + dedicated buffers).

use crate::analytical::aie::AieKernelModel;
use crate::analytical::{AccModel, MemoryFunc, MemoryView};
use crate::platform::Platform;
use crate::workload::Dag;

/// One CHARM sub-accelerator: `frac` of the AIE array + SRAM, with a
/// buffer page (the fixed on-chip matrix shape).
fn charm_sub(name: &str, p: &Platform, aie_frac: f64, sram_frac: f64, page: u32) -> AccModel {
    let aies = ((p.aie_tiles as f64 * aie_frac) as u32).max(1);
    // CHARM organises AIEs in clusters of 48 ("8x6" in the paper);
    // model as CUs of up to 48.
    let aies_per_cu = aies.min(48).max(1);
    let cus = (aies / aies_per_cu).max(1);
    AccModel {
        name: name.to_string(),
        cus,
        aies_per_cu,
        // Same staging deduction as the FILCO fabric (per-CU stream
        // buffers), then /2 for double buffering.
        onchip_elems: ((p.pl_sram_bytes as f64 * sram_frac) as u64)
            .saturating_sub(cus as u64 * 192 * 1024)
            / 4
            / 2,
        compute_gran: (32, 32, 32),
        view: MemoryView::Paged { page },
        func: MemoryFunc::FixedSplit { a: 1.0 / 3.0, b: 1.0 / 3.0, c: 1.0 / 3.0 },
        kernel: AieKernelModel::Static,
        reconfig_s: 0.0, // nothing reconfigurable at runtime
        tile_policy: Default::default(),
    }
}

/// CHARM-1: monolithic, 96% of AIEs, big 256-page buffers.
pub fn charm1(p: &Platform) -> AccModel {
    charm_sub("CHARM-1", p, 0.96, 1.0, 256)
}

/// CHARM-2: (big, small) pair — 7/8 + 1/8 of resources, pages 256 / 64.
pub fn charm2(p: &Platform) -> Vec<AccModel> {
    vec![
        charm_sub("CHARM-2.big", p, 0.96 * 7.0 / 8.0, 7.0 / 8.0, 256),
        charm_sub("CHARM-2.small", p, 0.96 / 8.0, 1.0 / 8.0, 64),
    ]
}

/// CHARM-3: big + 2 smalls — 6/8 + 1/8 + 1/8, pages 256 / 64 / 64.
pub fn charm3(p: &Platform) -> Vec<AccModel> {
    vec![
        charm_sub("CHARM-3.big", p, 0.96 * 6.0 / 8.0, 6.0 / 8.0, 256),
        charm_sub("CHARM-3.small0", p, 0.96 / 8.0, 1.0 / 8.0, 64),
        charm_sub("CHARM-3.small1", p, 0.96 / 8.0, 1.0 / 8.0, 64),
    ]
}

/// Makespan of `dag` on a set of sub-accelerators: greedy list schedule
/// in topological order; each ready layer goes to the sub-accelerator
/// that finishes it earliest (CHARM's layer-to-accelerator assignment).
pub fn multi_acc_makespan(p: &Platform, accs: &[AccModel], dag: &Dag) -> f64 {
    let order = dag.topo_order().expect("dag must be acyclic");
    let preds = dag.preds();
    let mut acc_free = vec![0.0f64; accs.len()];
    let mut done = vec![0.0f64; dag.len()];
    for &i in &order {
        let ready: f64 = preds[i].iter().map(|&j| done[j]).fold(0.0, f64::max);
        // Choose the accelerator minimising finish time.
        let mut best = (f64::INFINITY, 0usize);
        for (a, acc) in accs.iter().enumerate() {
            let lat = acc.layer_perf(p, &dag.layers[i].shape).latency_s;
            let start = ready.max(acc_free[a]);
            let fin = start + lat;
            if fin < best.0 {
                best = (fin, a);
            }
        }
        done[i] = best.0;
        acc_free[best.1] = best.0;
    }
    done.iter().cloned().fold(0.0, f64::max)
}

/// Throughput of a CHARM design (1, 2 or 3 sub-accelerators) on a DAG.
pub fn charm_gflops(p: &Platform, accs: &[AccModel], dag: &Dag) -> f64 {
    dag.total_flops() as f64 / multi_acc_makespan(p, accs, dag) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;

    #[test]
    fn charm1_wins_on_large_uniform_mlp() {
        // Fig 1: CHARM-1 achieves the highest throughput on MLP-L.
        let p = Platform::vck190();
        let dag = zoo::mlp_l();
        let g1 = charm_gflops(&p, &[charm1(&p)], &dag);
        let g2 = charm_gflops(&p, &charm2(&p), &dag);
        let g3 = charm_gflops(&p, &charm3(&p), &dag);
        assert!(g1 > 0.9 * g2, "charm1 {g1} vs charm2 {g2}");
        assert!(g1 > 0.9 * g3, "charm1 {g1} vs charm3 {g3}");
    }

    #[test]
    fn charm23_degrade_more_gracefully_on_small() {
        // Fig 1: on MLP-S the diverse designs beat the monolith.
        let p = Platform::vck190();
        let dag = zoo::mlp_s();
        let g1 = charm_gflops(&p, &[charm1(&p)], &dag);
        let g3 = charm_gflops(&p, &charm3(&p), &dag);
        assert!(g3 > g1, "charm3 {g3} should beat charm1 {g1} on MLP-S");
    }

    #[test]
    fn makespan_respects_dependencies() {
        let p = Platform::vck190();
        // A chain cannot be faster than the sum of its layer latencies
        // on the fastest accelerator.
        let dag = zoo::mlp_s();
        let accs = charm2(&p);
        let mk = multi_acc_makespan(&p, &accs, &dag);
        let fastest_sum: f64 = dag
            .layers
            .iter()
            .map(|l| {
                accs.iter()
                    .map(|a| a.layer_perf(&p, &l.shape).latency_s)
                    .fold(f64::INFINITY, f64::min)
            })
            .sum();
        assert!(mk >= fastest_sum * 0.999, "mk {mk} < chain bound {fastest_sum}");
    }

    #[test]
    fn resource_fractions_sum_sane() {
        let p = Platform::vck190();
        for accs in [charm2(&p), charm3(&p)] {
            let aies: u32 = accs.iter().map(|a| a.aies()).sum();
            assert!(aies <= p.aie_tiles, "aies {aies}");
        }
    }
}
