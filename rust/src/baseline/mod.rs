//! Baseline accelerator designs + FILCO design-point constructors.
//!
//! All designs are parameter points of [`crate::analytical::AccModel`]
//! so every Fig 1/9/10 comparison uses the same underlying equations:
//!
//! * [`charm`] — CHARM [35]: monolithic (CHARM-1) and multi-accelerator
//!   (CHARM-2/-3) fixed-dataflow designs with static buffer shapes.
//! * [`rsn`] — RSN [24]: overlay with flexible operand->memory mapping
//!   but a fixed on-chip page shape and static computation tiles.
//! * [`filco_acc`] — FILCO on the same fabric with any feature subset
//!   (the Fig 10 ablation axis).

pub mod charm;
pub mod rsn;

use crate::analytical::aie::AieKernelModel;
use crate::analytical::{AccModel, MemoryFunc, MemoryView};
use crate::arch::{Features, FilcoConfig};

/// Build the FILCO accelerator model from a fabric config + features.
///
/// Feature mapping (paper §2.2–2.4):
/// * FP on  -> atomic compute granularity + flexible kernel schedule;
///   off -> static 32x32x32 kernel with full-tile padding.
/// * FMV on -> flexible 1-D views; off -> fixed 256x256 buffer views
///   (the example geometry in Fig 4b).
/// * FMF on -> shared FMU pool; off -> fixed 1/3:1/3:1/3 A:B:C split.
pub fn filco_acc(cfg: &FilcoConfig, f: Features) -> AccModel {
    AccModel {
        name: f.label(),
        cus: cfg.m_cus,
        aies_per_cu: cfg.aies_per_cu,
        onchip_elems: cfg.fmu_elems() * cfg.n_fmus as u64,
        compute_gran: if f.fp {
            (crate::arch::ATOM_M, crate::arch::ATOM_K, crate::arch::ATOM_N)
        } else {
            (32, 32, 32)
        },
        view: if f.fmv { MemoryView::Flexible } else { MemoryView::Paged { page: 256 } },
        func: if f.fmf {
            MemoryFunc::Shared
        } else {
            MemoryFunc::FixedSplit { a: 1.0 / 3.0, b: 1.0 / 3.0, c: 1.0 / 3.0 }
        },
        kernel: if f.fp { AieKernelModel::Flexible } else { AieKernelModel::Static },
        // Runtime reconfiguration = decoding a few bytes of instructions
        // per unit at PL clock. The instruction stream runs ahead of
        // execution (double-buffered decode), so only a fraction of the
        // ~1 µs decode is exposed per layer.
        reconfig_s: 0.2e-6,
        tile_policy: Default::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::Platform;
    use crate::workload::MmShape;

    #[test]
    fn filco_full_features_beats_none() {
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let full = filco_acc(&cfg, Features::ALL);
        let none = filco_acc(&cfg, Features::NONE);
        // Small diverse MM: flexibility should win decisively.
        let s = MmShape::new(48, 100, 24);
        let lf = full.layer_perf(&p, &s).latency_s;
        let ln = none.layer_perf(&p, &s).latency_s;
        assert!(ln > 2.0 * lf, "none {ln} vs full {lf}");
    }

    #[test]
    fn features_monotone_on_small_diverse() {
        // Each added feature must not hurt (on the shapes the paper
        // motivates: small + skewed).
        let p = Platform::vck190();
        let cfg = FilcoConfig::default_for(&p);
        let s = MmShape::new(100, 48, 20);
        let l = |f: Features| filco_acc(&cfg, f).layer_perf(&p, &s).latency_s;
        let fp = l(Features::FP);
        let fp_fmf = l(Features::FP_FMF);
        let all = l(Features::ALL);
        assert!(fp >= fp_fmf * 0.999, "fp {fp} fmf {fp_fmf}");
        assert!(fp_fmf >= all * 0.999, "fmf {fp_fmf} all {all}");
    }
}
