//! RSN baseline [24] — Reconfigurable Stream Network overlay.
//!
//! RSN "can flexibly map operand matrices to on-chip buffers and
//! concatenate computation tiles", but (per the paper's §1/§5 analysis)
//! is limited by:
//! * a **static on-chip matrix shape** — operands live in fixed-shape
//!   memory-unit pages (we use 64x64, the RSN paper's tile geometry), so
//!   small/skewed operands pay page-granularity padding in storage AND
//!   DDR traffic;
//! * a **fixed computation tile size across cores** — no runtime
//!   flexibility in the kernel schedule (static 32x32x32 programming).
//!
//! Flexible mapping itself is real: the memory pool is shared between
//! operands (like FMF). The paper built an in-house RSN analytical
//! model for its experiments; this is ours, on the same equations as
//! every other design.

use crate::analytical::aie::AieKernelModel;
use crate::analytical::{AccModel, MemoryFunc, MemoryView};
use crate::platform::Platform;

/// RSN page size (fixed on-chip matrix shape).
pub const RSN_PAGE: u32 = 64;

/// The RSN overlay on the full fabric.
pub fn rsn(p: &Platform) -> AccModel {
    AccModel {
        name: "RSN".to_string(),
        cus: 8,
        aies_per_cu: (p.aie_tiles * 24 / 25) / 8,
        // Same per-CU staging deduction as the FILCO fabric, /2 for
        // double buffering.
        onchip_elems: p.pl_sram_bytes.saturating_sub(8 * 192 * 1024) / 4 / 2,
        compute_gran: (32, 32, 32),
        view: MemoryView::Paged { page: RSN_PAGE },
        func: MemoryFunc::Shared, // flexible operand->buffer mapping
        kernel: AieKernelModel::Static,
        // Token-based datapath switch: cheap, ~0.5 µs.
        // Token-based datapath switch: cheap, ~0.5 µs.
        reconfig_s: 0.5e-6,
        tile_policy: Default::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::zoo;
    use crate::workload::MmShape;

    #[test]
    fn rsn_beats_charm1_on_medium_diverse() {
        // Fig 1 (3): RSN sustains better throughput than CHARM from
        // MLP-L to DeiT-L.
        let p = Platform::vck190();
        let dag = zoo::deit_l();
        let g_rsn = rsn(&p).dag_gflops(&p, &dag);
        let charm1 = super::super::charm::charm1(&p);
        let g_charm = super::super::charm::charm_gflops(&p, &[charm1], &dag);
        assert!(g_rsn > g_charm, "rsn {g_rsn} vs charm1 {g_charm}");
    }

    #[test]
    fn rsn_pays_page_padding_on_small() {
        let p = Platform::vck190();
        let m = rsn(&p);
        // 20x20x20 pads to 64x64 pages: 10x+ wasted traffic.
        let perf = m.layer_perf(&p, &MmShape::new(20, 20, 20));
        assert!(perf.comm_eff < 0.2, "comm_eff {}", perf.comm_eff);
    }

    #[test]
    fn rsn_efficient_on_page_aligned_large() {
        let p = Platform::vck190();
        let m = rsn(&p);
        let perf = m.layer_perf(&p, &MmShape::new(1024, 1024, 1024));
        assert!(perf.comm_eff > 0.9, "comm_eff {}", perf.comm_eff);
        assert!(perf.compute_eff > 0.95);
    }
}
