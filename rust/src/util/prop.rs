//! Mini property-testing harness (`proptest` is not in the offline
//! vendored set).
//!
//! Usage:
//! ```ignore
//! use crate::util::prop::Cases;
//! Cases::new(200).run(|rng| {
//!     let m = rng.range(1, 64);
//!     assert!(some_invariant(m), "violated for m={m}");
//! });
//! ```
//! On failure the panic message is re-raised with the case seed so the
//! exact input can be replayed with `Cases::replay(seed, |rng| ...)`.

use super::rng::SplitMix64;

/// Runs `n` randomized cases, each with a deterministic per-case seed
/// derived from a master seed (env `FILCO_PROP_SEED` overrides).
pub struct Cases {
    n: usize,
    master_seed: u64,
}

impl Cases {
    pub fn new(n: usize) -> Self {
        let master_seed = std::env::var("FILCO_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xF11C0);
        Self { n, master_seed }
    }

    pub fn with_seed(n: usize, master_seed: u64) -> Self {
        Self { n, master_seed }
    }

    /// Run the property over `n` cases. Panics (with the case seed in the
    /// message) on the first failing case.
    pub fn run<F: FnMut(&mut SplitMix64)>(&self, mut prop: F) {
        let mut seeder = SplitMix64::new(self.master_seed);
        for case in 0..self.n {
            let case_seed = seeder.next_u64();
            let mut rng = SplitMix64::new(case_seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                prop(&mut rng);
            }));
            if let Err(payload) = result {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                panic!(
                    "property failed at case {case}/{} (replay seed {case_seed:#x}): {msg}",
                    self.n
                );
            }
        }
    }

    /// Replay a single failing case by seed.
    pub fn replay<F: FnMut(&mut SplitMix64)>(seed: u64, mut prop: F) {
        let mut rng = SplitMix64::new(seed);
        prop(&mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Cases::with_seed(50, 1).run(|rng| {
            count += 1;
            let x = rng.below(100);
            assert!(x < 100);
        });
        assert_eq!(count, 50);
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            Cases::with_seed(100, 2).run(|rng| {
                let x = rng.below(10);
                assert!(x != 3, "hit the bad value");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("replay seed"), "msg={msg}");
    }

    #[test]
    fn replay_is_deterministic() {
        let mut a = Vec::new();
        Cases::replay(0xDEAD, |rng| a.push(rng.next_u64()));
        let mut b = Vec::new();
        Cases::replay(0xDEAD, |rng| b.push(rng.next_u64()));
        assert_eq!(a, b);
    }
}
